"""Exact search == brute force; approximate search recall; M*/PCCP sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bregman import get_family
from repro.core.index import build_index
from repro.core import search
from repro.core.partition import fit_cost_model, correlation_matrix, pccp_order


def _dataset(family, n=600, d=24, seed=0):
    fam = get_family(family)
    data = fam.sample(jax.random.PRNGKey(seed), (n, d), scale=1.0)
    queries = fam.sample(jax.random.PRNGKey(seed + 1), (8, d), scale=1.0)
    return np.asarray(data), np.asarray(queries), fam


@pytest.mark.parametrize("family", ["squared_euclidean", "itakura_saito",
                                    "exponential"])
@pytest.mark.parametrize("pccp", [True, False])
def test_exact_knn_matches_brute_force(family, pccp):
    data, queries, fam = _dataset(family)
    index = build_index(data, family, m=4, pccp=pccp, num_clusters=16, seed=0)
    k = 7
    for qi in range(queries.shape[0]):
        y = queries[qi]
        res = search.knn(index, y, k)
        assert bool(res.exact)
        bf_idx, bf_dist = search.brute_force_knn(data, y, k, fam)
        np.testing.assert_allclose(
            np.sort(np.asarray(res.dists)), np.sort(np.asarray(bf_dist)),
            rtol=2e-3, atol=2e-3)
        # ids must reproduce the distances when evaluated directly
        direct = np.asarray(fam.distance(
            jnp.asarray(data)[np.asarray(res.ids)], jnp.asarray(y)[None]))
        np.testing.assert_allclose(np.sort(direct),
                                   np.sort(np.asarray(res.dists)),
                                   rtol=2e-3, atol=2e-3)


def test_exact_knn_budget_escape_hatch():
    data, queries, fam = _dataset("squared_euclidean", n=400)
    index = build_index(data, "squared_euclidean", m=4, num_clusters=8)
    res = search.knn(index, queries[0], 5, budget=8)  # deliberately tiny
    assert bool(res.exact)  # wrapper must have retried with larger budgets
    bf_idx, bf_dist = search.brute_force_knn(data, queries[0], 5, fam)
    np.testing.assert_allclose(np.sort(np.asarray(res.dists)),
                               np.sort(np.asarray(bf_dist)), rtol=2e-3)


def test_batch_knn():
    data, queries, fam = _dataset("exponential", n=500)
    index = build_index(data, "exponential", m=4, num_clusters=16)
    res = search.knn_batch(index, queries, 5)
    assert res.ids.shape == (queries.shape[0], 5)
    for qi in range(queries.shape[0]):
        _, bf_dist = search.brute_force_knn(data, queries[qi], 5, fam)
        np.testing.assert_allclose(np.sort(np.asarray(res.dists[qi])),
                                   np.sort(np.asarray(bf_dist)),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("p", [0.7, 0.9])
def test_approximate_knn_recall(p):
    data, queries, fam = _dataset("squared_euclidean", n=800, seed=3)
    index = build_index(data, "squared_euclidean", m=4, num_clusters=16)
    k = 10
    recalls, cand_exact, cand_approx = [], [], []
    for qi in range(queries.shape[0]):
        y = queries[qi]
        exact = search.knn(index, y, k)
        approx = search.knn(index, y, k, approx_p=p)
        got = set(np.asarray(approx.ids).tolist())
        want = set(np.asarray(exact.ids).tolist())
        recalls.append(len(got & want) / k)
        cand_exact.append(int(exact.num_candidates))
        cand_approx.append(int(approx.num_candidates))
    # probability-guarantee semantics: average recall should be >= ~p
    assert np.mean(recalls) >= p - 0.15, recalls
    # the tightened bound must not grow the candidate set
    assert np.mean(cand_approx) <= np.mean(cand_exact) + 1e-9


def test_mstar_cost_model_sane():
    data, _, fam = _dataset("squared_euclidean", n=500, d=32)
    model = fit_cost_model(data, fam, seed=0)
    assert 0 < model.alpha < 1
    assert model.a > 0 and model.beta > 0
    m = model.m_star()
    assert 1 <= m <= 32
    # cost at M* is no worse than the extremes
    assert model.online_cost(m) <= model.online_cost(1) + 1e-6 or \
           model.online_cost(m) <= model.online_cost(32) + 1e-6


def test_pccp_separates_correlated_dims():
    """Two perfectly correlated dims must land in different partitions."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=(500, 4))
    # dims 0&1 correlated, dims 2&3 correlated
    data = np.stack([base[:, 0], base[:, 0] + 0.01 * base[:, 1],
                     base[:, 2], base[:, 2] + 0.01 * base[:, 3]], axis=1)
    corr = correlation_matrix(data)
    order = pccp_order(corr, m=2, seed=0)
    part0 = set(order[:2].tolist())
    assert part0 not in ({0, 1}, {2, 3}), order
