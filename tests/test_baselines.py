"""Paper baselines (BBT, VAF) must be exact: compared against linear scan."""

import numpy as np
import jax
import pytest

from repro.core.bregman import get_family
from repro.core.baselines import BBTree, VAFile, linear_scan


def _data(family, n=400, d=12, seed=0):
    fam = get_family(family)
    return np.asarray(fam.sample(jax.random.PRNGKey(seed), (n, d))), fam


@pytest.mark.parametrize("family", ["squared_euclidean", "itakura_saito",
                                    "exponential"])
@pytest.mark.parametrize("bound", ["geodesic", "tuple"])
def test_bbtree_exact(family, bound):
    data, fam = _data(family)
    tree = BBTree(data, family, leaf_size=16, bound=bound)
    for qi in range(5):
        y = data[qi * 7]
        ids, dists, stats = tree.knn(y, 5)
        lin_ids, lin_d, _ = linear_scan(data, y, 5, family)
        np.testing.assert_allclose(np.sort(dists), np.sort(lin_d),
                                   rtol=1e-6, atol=1e-8)
        assert stats["distance_evals"] <= len(data)


@pytest.mark.parametrize("family", ["squared_euclidean", "itakura_saito"])
def test_bbtree_range_query(family):
    data, fam = _data(family, n=300)
    tree = BBTree(data, family, leaf_size=16)
    y = data[3]
    dist = np.asarray(fam.distance(data, y[None]))
    r = float(np.quantile(dist, 0.1))
    ids, stats = tree.range_query(y, r)
    want = np.sort(np.flatnonzero(dist <= r))
    np.testing.assert_array_equal(ids, want)


@pytest.mark.parametrize("family", ["squared_euclidean", "itakura_saito",
                                    "exponential"])
def test_vafile_exact(family):
    data, fam = _data(family, n=500, d=10)
    vaf = VAFile(data, family, bits=4)
    for qi in range(5):
        y = data[qi * 11]
        ids, dists, stats = vaf.knn(y, 5)
        _, lin_d, _ = linear_scan(data, y, 5, family)
        np.testing.assert_allclose(np.sort(dists), np.sort(lin_d),
                                   rtol=1e-6, atol=1e-8)
        assert stats["candidates"] <= len(data)


def test_bbtree_prunes():
    """On clustered data the tree must evaluate far fewer than n distances."""
    rng = np.random.default_rng(0)
    centers = rng.normal(scale=10.0, size=(8, 8))
    data = (centers[rng.integers(0, 8, 2000)]
            + rng.normal(scale=0.1, size=(2000, 8)))
    tree = BBTree(data, "squared_euclidean", leaf_size=32)
    _, _, stats = tree.knn(data[0], 3)
    assert stats["distance_evals"] < 800, stats
