"""Autotuner table: lookup semantics, abstract compiles, resolve plumbing.

The table is a pure perf knob (tests/test_stream_prune.py proves every
selectable value is results-invariant); what needs pinning here is the
LOOKUP contract — a tuned entry must only steer shapes it actually speaks
for (same backend, same storage tier, within MAX_N_LOG2_DISTANCE of the
tuned n) — and the consumer plumbing: ``resolve_block_rows(None, ...)``
consults the table, the serving layer pins the result per tenant/store.
"""

import json

import jax
import numpy as np
import pytest

from repro.core import search
from repro.launch import autotune


def _entry(**kw):
    e = {"backend": jax.default_backend(), "storage": "f32",
         "n_log2": 12.0, "q_log2": 3.0, "d": 32, "m": 8,
         "block_rows": 2048, "env_block_rows": 512,
         "us_per_call": 100.0, "temp_bytes": 1 << 20}
    e.update(kw)
    return e


# ---------------------------------------------------------------------------
# lookup
# ---------------------------------------------------------------------------

def test_lookup_exact_and_nearest_hit():
    table = (_entry(n_log2=12.0, q_log2=3.0, block_rows=2048),
             _entry(n_log2=14.0, q_log2=6.0, block_rows=8192))
    assert autotune.lookup_block_rows(4096, 8, table=table) == 2048
    assert autotune.lookup_block_rows(16384, 64, table=table) == 8192
    # nearest in log2(n) wins; q breaks ties
    assert autotune.lookup_block_rows(6000, 8, table=table) == 2048
    assert autotune.lookup_block_rows(12000, 64, table=table) == 8192
    # unknown q still resolves on n alone
    assert autotune.lookup_block_rows(4096, table=table) == 2048
    assert autotune.lookup_env_block_rows(4096, 8, table=table) == 512


def test_lookup_rejects_far_n():
    """An entry tuned at n=4096 must not steer n=10^8 (or an empty index)."""
    table = (_entry(n_log2=12.0),)
    far = 2 ** (12 + autotune.MAX_N_LOG2_DISTANCE + 1)
    assert autotune.lookup_block_rows(int(far), 8, table=table) is None
    assert autotune.lookup_block_rows(0, 8, table=table) is None


def test_lookup_filters_backend_and_storage():
    """A CPU-swept table can never change behavior on another backend, and
    f32 entries never steer the int8 tier (different byte ratios)."""
    table = (_entry(backend="definitely_not_this_backend"),)
    assert autotune.lookup_block_rows(4096, 8, table=table) is None
    table = (_entry(storage="f32"),)
    assert autotune.lookup_block_rows(4096, 8, storage="int8",
                                      table=table) is None
    table = (_entry(storage="int8", block_rows=4096),)
    assert autotune.lookup_block_rows(4096, 8, storage="int8",
                                      table=table) == 4096


def test_lookup_skips_malformed_entries():
    table = ({"backend": jax.default_backend()},          # no shape keys
             _entry(block_rows="not_an_int"),
             _entry(block_rows=2),                        # < floor of 8
             _entry(block_rows=1024))
    assert autotune.lookup_block_rows(4096, 8, table=table) == 1024


def test_load_table_missing_and_corrupt(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE",
                       str(tmp_path / "nope.json"))
    autotune._load_table_cached.cache_clear()
    assert autotune.load_table() == ()
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(bad))
    autotune._load_table_cached.cache_clear()
    assert autotune.load_table() == ()
    autotune._load_table_cached.cache_clear()


def test_write_then_load_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "table.json"
    autotune.write_table([_entry()], path, note="test")
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    autotune._load_table_cached.cache_clear()
    entries = autotune.load_table()
    assert len(entries) == 1 and entries[0]["block_rows"] == 2048
    payload = json.loads(path.read_text())
    assert payload["version"] == 1
    autotune._load_table_cached.cache_clear()


def test_checked_in_table_is_well_formed():
    """The repo ships a swept table; every entry must resolve via lookup."""
    entries = autotune.load_table(autotune.DEFAULT_TABLE_PATH)
    assert entries, "checked-in block_rows_table.json missing or empty"
    for e in entries:
        n = int(round(2 ** float(e["n_log2"])))
        got = autotune.lookup(n, storage=e["storage"],
                              backend=e["backend"], table=entries)
        assert got is not None
        assert int(got["block_rows"]) >= 8
        assert int(got["env_block_rows"]) % 256 == 0


# ---------------------------------------------------------------------------
# abstract compile path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("storage", ["f32", "int8"])
def test_forest_spec_and_measure_memory(storage):
    """Shape-only lowering compiles without data and reports temp bytes."""
    temp = autotune.measure_memory(2048, 8, 32, 8, storage,
                                   block_rows=1024, env_block_rows=512,
                                   k=5, budget=64)
    # None only where the backend hides memory analysis; when present it
    # must be a plausible positive working set
    assert temp is None or temp > 0


# ---------------------------------------------------------------------------
# consumer plumbing
# ---------------------------------------------------------------------------

def test_resolve_block_rows_consults_table(tmp_path, monkeypatch):
    path = tmp_path / "table.json"
    autotune.write_table(
        [_entry(n_log2=12.0, q_log2=3.0, block_rows=1536)], path)
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    autotune._load_table_cached.cache_clear()
    try:
        assert search.resolve_block_rows(None, 4096, q=8,
                                         storage="f32") == 1536
        # explicit knob always wins over the table
        assert search.resolve_block_rows(777, 4096, q=8,
                                         storage="f32") == 777
        # miss (far n / foreign storage) falls back to the default
        assert search.resolve_block_rows(
            None, 4096, q=8, storage="int8") == search.DEFAULT_BLOCK_ROWS
        assert search.resolve_block_rows(
            None, 10 ** 9, q=8, storage="f32") == search.DEFAULT_BLOCK_ROWS
        # empty index still raises BEFORE any table consultation
        with pytest.raises(ValueError, match="empty"):
            search.resolve_block_rows(None, 0, q=8, storage="f32")
    finally:
        autotune._load_table_cached.cache_clear()


def test_search_results_identical_with_and_without_table(tmp_path,
                                                         monkeypatch):
    """End to end: a table pick changes the program, never the answer."""
    import jax.numpy as jnp
    from repro.core.index import build_index
    rng = np.random.default_rng(0)
    data = rng.normal(size=(600, 16)).astype(np.float32)
    index = build_index(data, "squared_euclidean", m=4, num_clusters=8,
                        seed=0)
    ys = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    base = search.knn_search_batch(index, ys, 5, 64,
                                   block_rows=search.DEFAULT_BLOCK_ROWS)
    path = tmp_path / "table.json"
    autotune.write_table(
        [_entry(n_log2=9.23, q_log2=2.0, block_rows=128,
                env_block_rows=512)], path)
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    autotune._load_table_cached.cache_clear()
    try:
        assert search.resolve_block_rows(None, index.n, q=4,
                                         storage=index.storage) == 128
        tuned = search.knn_search_batch(index, ys, 5, 64)
        for f in ("ids", "dists", "exact", "num_candidates"):
            np.testing.assert_array_equal(np.asarray(getattr(tuned, f)),
                                          np.asarray(getattr(base, f)))
    finally:
        autotune._load_table_cached.cache_clear()
