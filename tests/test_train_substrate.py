"""Train substrate tests: optimizer, losses, sharded train step, checkpoint
elastic restart, straggler monitor, data-pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.common import ShapeSpec
from repro.data import pipeline as data_pipe
from repro.models.registry import build_model
from repro.train import checkpoint as ckpt
from repro.train import losses
from repro.train.optimizer import OptimizerConfig, init_state, apply_updates, schedule
from repro.train.straggler import StragglerConfig, StragglerMonitor
from repro.train.train_loop import (TrainConfig, init_train_state,
                                    make_train_step, state_shardings)

SMALL_SHAPE = ShapeSpec("smoke", seq_len=32, global_batch=4, kind="train")


def small_bundle():
    return build_model(configs.get_reduced("starcoder2-3b"))


def small_batch(bundle, step=0):
    cfg = data_pipe.TokenStreamConfig(
        vocab_size=bundle.cfg.vocab_size, seq_len=SMALL_SHAPE.seq_len,
        global_batch=SMALL_SHAPE.global_batch)
    return data_pipe.token_batch(cfg, step)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_schedule_warmup_cosine():
    cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-9
    assert abs(lrs[2] - 1e-3) < 1e-9
    assert lrs[2] > lrs[3] > lrs[4]
    assert abs(lrs[4] - 1e-4) < 1e-6


def test_adamw_converges_quadratic():
    cfg = OptimizerConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, grad_clip_norm=1e9)
    params = {"w": jnp.ones((4, 4)) * 3.0}
    state = init_state(params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        params, state, stats = apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05
    assert np.isfinite(float(stats["grad_norm"]))


def test_grad_clip():
    cfg = OptimizerConfig(grad_clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros((3,))}
    state = init_state(params)
    _, _, stats = apply_updates(params, {"w": jnp.full((3,), 100.0)},
                                state, cfg)
    assert float(stats["grad_norm"]) > 100.0  # pre-clip norm is reported


# ---------------------------------------------------------------------------
# chunked loss
# ---------------------------------------------------------------------------

def test_chunked_xent_matches_dense():
    rng = np.random.default_rng(0)
    b, s, d, v = 2, 64, 16, 97
    hidden = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    loss_c, m = losses.chunked_cross_entropy(hidden, labels, table, chunk=16)
    logits = hidden @ table.T
    dense = jnp.mean(jax.nn.logsumexp(logits, -1)
                     - jnp.take_along_axis(logits, labels[..., None], -1)[..., 0])
    np.testing.assert_allclose(float(m["nll"]), float(dense), rtol=1e-5)
    assert float(loss_c) >= float(m["nll"])  # z-loss is non-negative


def test_chunked_xent_grads_match_dense():
    rng = np.random.default_rng(1)
    b, s, d, v = 2, 32, 8, 31
    hidden = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)

    g1 = jax.grad(lambda t: losses.chunked_cross_entropy(
        hidden, labels, t, chunk=8, z_weight=0.0)[0])(table)

    def dense(t):
        logits = hidden @ t.T
        return jnp.mean(jax.nn.logsumexp(logits, -1) - jnp.take_along_axis(
            logits, labels[..., None], -1)[..., 0])

    g2 = jax.grad(dense)(table)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


# ---------------------------------------------------------------------------
# train step (host mesh)
# ---------------------------------------------------------------------------

def test_train_step_runs_and_improves():
    bundle = small_bundle()
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    tc = TrainConfig(microbatches=1,
                     opt=OptimizerConfig(peak_lr=3e-3, warmup_steps=5,
                                         total_steps=60))
    with mesh:
        state = init_train_state(bundle, mesh, jax.random.PRNGKey(0))
        step = make_train_step(bundle, mesh, tc, SMALL_SHAPE)
        first = None
        for i in range(30):
            state, metrics = step(state, small_batch(bundle, i % 4))
            if first is None:
                first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first - 0.5, (first, last)


def test_microbatch_accumulation_matches_full_batch():
    bundle = small_bundle()
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    batch = small_batch(bundle, 0)
    with mesh:
        s1 = init_train_state(bundle, mesh, jax.random.PRNGKey(0))
        s2 = jax.tree.map(jnp.copy, s1)
        step1 = make_train_step(bundle, mesh,
                                TrainConfig(microbatches=1), SMALL_SHAPE)
        step2 = make_train_step(bundle, mesh,
                                TrainConfig(microbatches=2), SMALL_SHAPE)
        n1, m1 = step1(s1, batch)
        n2, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-4)
    # parameters after one update agree (accumulated grads == full grads)
    a = jax.tree.leaves(n1.params)[0]
    b = jax.tree.leaves(n2.params)[0]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=5e-3)


# ---------------------------------------------------------------------------
# checkpoint: atomicity, retention, elastic restore, bit-exact restart
# ---------------------------------------------------------------------------

@pytest.fixture
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpts")


def test_checkpoint_roundtrip(ckpt_dir):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(6, 2),
            "b": {"c": jnp.ones((3,), jnp.bfloat16),
                  "step": jnp.int32(7)}}
    ckpt.save_checkpoint(ckpt_dir, 3, tree, num_shards=3)
    assert ckpt.latest_step(ckpt_dir) == 3
    out = ckpt.restore_checkpoint(ckpt_dir, 3, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_retention(ckpt_dir):
    tree = {"a": jnp.zeros((4,))}
    for s in range(6):
        ckpt.save_checkpoint(ckpt_dir, s, tree, keep=2)
    assert ckpt.list_steps(ckpt_dir) == [4, 5]


def test_checkpoint_atomic_no_partial_visible(ckpt_dir):
    tree = {"a": jnp.zeros((4,))}
    ckpt.save_checkpoint(ckpt_dir, 1, tree)
    # simulate a crashed writer: stray tmp dir must be invisible
    os.makedirs(os.path.join(ckpt_dir, "step_000000009.tmp-dead"))
    assert ckpt.latest_step(ckpt_dir) == 1
    # and a finished dir without manifest is also invisible
    os.makedirs(os.path.join(ckpt_dir, "step_000000008"))
    assert ckpt.latest_step(ckpt_dir) == 1


def test_elastic_restore_across_mesh_shapes(ckpt_dir):
    """Save on an 8-way mesh, restore onto 4-way and back onto 8-way."""
    bundle = small_bundle()
    mesh8 = jax.make_mesh((1, 1), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with mesh8:
        state = init_train_state(bundle, mesh8, jax.random.PRNGKey(0))
    ckpt.save_checkpoint(ckpt_dir, 0, state, num_shards=8)

    # "different cluster": restore with fresh shardings resolved on a new mesh
    mesh4 = jax.make_mesh((1,), ("data",),
                          axis_types=(jax.sharding.AxisType.Auto,))
    sh = state_shardings(bundle, mesh4)
    structs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    with mesh4:
        restored = ckpt.restore_checkpoint(ckpt_dir, 0, structs, shardings=sh)
    for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(restored),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_restart_is_bit_exact(ckpt_dir):
    """Train 4 steps; restart from step-2 checkpoint; trajectories match."""
    bundle = small_bundle()
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    tc = TrainConfig(opt=OptimizerConfig(peak_lr=1e-3, warmup_steps=0,
                                         total_steps=10))
    with mesh:
        step = make_train_step(bundle, mesh, tc, SMALL_SHAPE)
        state = init_train_state(bundle, mesh, jax.random.PRNGKey(0))
        losses_a = []
        for i in range(4):
            if i == 2:
                ckpt.save_checkpoint(ckpt_dir, i, state)
            state, m = step(state, small_batch(bundle, i))
            losses_a.append(float(m["loss"]))

        structs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        state_b = ckpt.restore_checkpoint(ckpt_dir, 2, structs)
        losses_b = []
        for i in range(2, 4):
            state_b, m = step(state_b, small_batch(bundle, i))
            losses_b.append(float(m["loss"]))
    np.testing.assert_allclose(losses_a[2:], losses_b, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_fires_on_slow_host():
    cfg = StragglerConfig(window=20, tolerance=1.5, patience=3,
                          warmup_steps=0)
    fired = []
    mon = StragglerMonitor(cfg, num_hosts=4,
                           mitigation=lambda ev: fired.append(ev))
    for step in range(30):
        times = [0.10, 0.11, 0.10, 0.10]
        if step >= 10:
            times[2] = 0.40            # host 2 goes bad
        mon.start_step()
        mon.end_step(times)
    assert fired and all(ev.host == 2 for ev in fired)
    assert mon.summary()["events"] >= 1


def test_straggler_quiet_on_uniform_times():
    mon = StragglerMonitor(StragglerConfig(warmup_steps=0), num_hosts=2)
    for _ in range(50):
        mon.start_step()
        mon.end_step([0.1, 0.1])
    assert mon.summary()["events"] == 0


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------

def test_token_batch_step_addressable():
    cfg = data_pipe.TokenStreamConfig(vocab_size=128, seq_len=16,
                                      global_batch=4, seed=3)
    a = data_pipe.token_batch(cfg, 7)
    b = data_pipe.token_batch(cfg, 7)
    c = data_pipe.token_batch(cfg, 8)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(a["tokens"])[:, 1:],
                                  np.asarray(a["labels"])[:, :-1])


def test_vector_datasets_match_table4():
    for _name, spec in data_pipe.PAPER_DATASETS.items():
        data = data_pipe.make_vectors(spec, scale=0.001)
        assert data.shape[1] == spec.d
        if spec.measure == "isd":
            assert data.min() > 0
        q = data_pipe.make_queries(spec, num=5, scale=0.001)
        assert q.shape == (5, spec.d)
