"""distributed_knn on a 1x1 mesh must be BIT-identical to the fused
single-host pipeline — the decomposability contract of dist/knn.py.

A 1-device mesh runs the full SPMD program (shard_map, bound exchange,
k-way merge) with every collective a no-op, so any numeric divergence
from ``knn_search_batch`` is a sharding bug, not float noise.  Multi-
device behaviour is covered by tests/dist_checks.py (subprocess, forced
8-device backend — the device-count isolation rule).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bregman import family_names, get_family
from repro.core.index import build_index, pad_points, slice_points
from repro.core import search
from repro.dist import knn as dknn
from repro.dist.sharding import make_mesh

FAMILIES = family_names()
N, D, M, K = 256, 16, 4, 6


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1,), ("data",))


def _setup(family, num_queries=5):
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(0), (N, D)))
    queries = jnp.asarray(
        np.asarray(fam.sample(jax.random.PRNGKey(1), (num_queries, D))))
    forest = build_index(data, family, m=M, num_clusters=16, seed=0)
    return forest, queries


def _assert_bitwise(res, ref):
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))
    np.testing.assert_array_equal(np.asarray(res.exact),
                                  np.asarray(ref.exact))
    np.testing.assert_array_equal(np.asarray(res.num_candidates),
                                  np.asarray(ref.num_candidates))


@pytest.mark.parametrize("family", FAMILIES)
def test_exact_mode_bit_identical(mesh, family):
    forest, queries = _setup(family)
    sharded = dknn.shard_index(forest, mesh)
    yv = dknn.query_subview(forest.partition, queries)
    for budget in (N, N // 2):
        res = dknn.distributed_knn(sharded, yv, family=family, k=K,
                                   budget=budget, mesh=mesh, max_doublings=0)
        ref = search.knn_search_batch(forest, queries, K, budget)
        _assert_bitwise(res, ref)


@pytest.mark.parametrize("family", FAMILIES)
def test_approx_mode_bit_identical(mesh, family):
    forest, queries = _setup(family)
    sharded = dknn.shard_index(forest, mesh)
    yv = dknn.query_subview(forest.partition, queries)
    res = dknn.distributed_knn(sharded, yv, family=family, k=K, budget=N,
                               mesh=mesh, approx_p=0.9, max_doublings=0)
    ref = search.knn_search_batch_approx(forest, queries, K, N,
                                         jnp.float32(0.9))
    _assert_bitwise(res, ref)


def test_budget_overflow_retry_keeps_exact_truthful(mesh):
    """Start below the union size: the per-shard retry must converge to an
    exact result (never report exact=True while capped), and a capped run
    must report exact=False."""
    family = "itakura_saito"          # unions routinely exceed tiny budgets
    forest, queries = _setup(family)
    sharded = dknn.shard_index(forest, mesh)
    yv = dknn.query_subview(forest.partition, queries)

    capped = dknn.distributed_knn(sharded, yv, family=family, k=K, budget=K,
                                  mesh=mesh, max_doublings=0)
    assert not bool(jnp.all(capped.exact)), \
        "test needs an overflowing budget; shrink it"
    # truthful under the cap: the overflowing rows are flagged, not faked
    assert int(jnp.max(capped.num_candidates)) > K

    res = dknn.distributed_knn(sharded, yv, family=family, k=K, budget=K,
                               mesh=mesh)
    assert bool(jnp.all(res.exact))
    ids_oracle, dists_oracle = search.brute_force_knn(
        forest.data, queries, K, forest.family)
    np.testing.assert_allclose(
        np.sort(np.asarray(res.dists), axis=1),
        np.sort(np.asarray(dists_oracle), axis=1), rtol=1e-5, atol=1e-5)
    # retrying host wrappers agree with each other too (same budget rule)
    ref = search.knn_batch(forest, queries, K, budget=K)
    _assert_bitwise(res, ref)


def test_query_subview_matches_partition_gather():
    forest, queries = _setup("shannon", num_queries=3)
    yv = dknn.query_subview(forest.partition, queries)
    assert yv.y.shape == queries.shape
    np.testing.assert_array_equal(np.asarray(yv.sub),
                                  np.asarray(forest.partition.gather(queries)))


def test_pad_and_slice_points_roundtrip():
    """pad_points rows are search-inert; slice_points mirrors the shard view."""
    forest, queries = _setup("squared_euclidean")
    padded = pad_points(forest, 3)        # 256 -> 258
    assert padded.n % 3 == 0
    res = search.knn_search_batch(padded, queries, K, N)
    ref = search.knn_search_batch(forest, queries, K, N)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))
    assert int(jnp.min(padded.point_ids)) == -1
    local = slice_points(padded, 0, padded.n // 3)
    assert local.n == padded.n // 3
    np.testing.assert_array_equal(np.asarray(local.data),
                                  np.asarray(padded.data)[: padded.n // 3])
