"""Engine termination semantics + hook batch hygiene (serve/engine.py).

Regression tests for two prefill-path bugs: the admission-sampled token
was not checked against the budget (``max_new_tokens=1`` emitted 2 tokens)
or against ``cfg.eos_token`` (an EOS-opening request decoded to its full
budget), and the logits hook ran over the full slot batch — including
free slots' garbage hidden rows — on admit ticks.
"""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models.registry import build_model
from repro.serve.engine import Engine, EngineConfig, Request


@pytest.fixture(scope="module")
def bundle():
    return build_model(configs.get_reduced("starcoder2-3b"))


@pytest.fixture(scope="module")
def params(bundle):
    return bundle.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prompt(bundle):
    return np.random.default_rng(0).integers(1, bundle.cfg.vocab_size, 12)


@pytest.fixture(scope="module")
def first_token(bundle, params, prompt):
    """The token the (greedy, deterministic) model samples at prefill."""
    eng = Engine(bundle, params,
                 EngineConfig(slots=2, max_seq=64, prefill_len=12))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=1))
    return eng.run(max_ticks=10)[0].output[0]


def test_max_new_tokens_one_emits_exactly_one_token(bundle, params, prompt):
    eng = Engine(bundle, params,
                 EngineConfig(slots=2, max_seq=64, prefill_len=12))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=1))
    done = eng.run(max_ticks=20)
    assert len(done) == 1 and done[0].done
    assert len(done[0].output) == 1
    # the slot was retired at admission — no decode tick was spent on it
    assert eng.ticks == 0


def test_budgets_are_respected_for_every_request(bundle, params):
    """Mixed budgets across slots all land exactly (the pre-fix engine
    overshot every budget-terminated request by the prefill token)."""
    vocab = bundle.cfg.vocab_size
    rng = np.random.default_rng(1)
    eng = Engine(bundle, params,
                 EngineConfig(slots=3, max_seq=64, prefill_len=12))
    for uid, new in enumerate((1, 2, 5)):
        eng.submit(Request(uid=uid, prompt=rng.integers(1, vocab, 10),
                           max_new_tokens=new))
    done = {r.uid: r for r in eng.run(max_ticks=50)}
    assert [len(done[uid].output) for uid in range(3)] == [1, 2, 5]


def test_eos_as_first_token_finishes_immediately(bundle, params, prompt,
                                                 first_token):
    """A prefill-sampled EOS must terminate the request, not be ignored."""
    eng = Engine(bundle, params,
                 EngineConfig(slots=2, max_seq=64, prefill_len=12,
                              eos_token=first_token))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    done = eng.run(max_ticks=20)
    assert len(done) == 1
    assert done[0].output == [first_token]


def test_eos_mid_decode_still_terminates(bundle, params, prompt, first_token):
    """The decode-path EOS check keeps working alongside the admit check."""
    # pick the SECOND sampled token as EOS so termination happens in step()
    eng = Engine(bundle, params,
                 EngineConfig(slots=2, max_seq=64, prefill_len=12))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    second = eng.run(max_ticks=20)[0].output[1]
    if second == first_token:
        pytest.skip("degenerate model repeats the first token")
    eng = Engine(bundle, params,
                 EngineConfig(slots=2, max_seq=64, prefill_len=12,
                              eos_token=second))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    done = eng.run(max_ticks=20)
    assert done[0].output == [first_token, second]


def test_admission_capacity_check_keeps_the_last_decode(bundle, params):
    """A prompt of length max_seq-1 still gets its one valid decode: the
    admission check must not reuse the decode path's one-slot margin."""
    vocab = bundle.cfg.vocab_size
    prompt = np.random.default_rng(3).integers(1, vocab, 15)
    eng = Engine(bundle, params,
                 EngineConfig(slots=2, max_seq=16, prefill_len=15))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    done = eng.run(max_ticks=20)
    # 1 prefill-sampled token + 1 decode (written at position 15), then
    # the decode-path capacity margin retires the slot.
    assert len(done) == 1 and len(done[0].output) == 2


def test_hook_never_sees_dead_slots(bundle, params):
    """Every hook invocation carries exactly the live rows, never the full
    slot batch with garbage rows from free slots."""
    vocab = bundle.cfg.vocab_size
    rng = np.random.default_rng(2)
    seen = []

    def hook(logits, hidden):
        assert hidden is not None and hidden.shape[0] == logits.shape[0]
        seen.append(int(logits.shape[0]))
        return logits

    eng = Engine(bundle, params,
                 EngineConfig(slots=4, max_seq=64, prefill_len=12),
                 logits_hook=hook)
    eng.submit(Request(uid=0, prompt=rng.integers(1, vocab, 12),
                       max_new_tokens=3))
    eng.step()                     # 1 active of 4 slots
    eng.submit(Request(uid=1, prompt=rng.integers(1, vocab, 10),
                       max_new_tokens=2))
    eng.run(max_ticks=20)
    assert seen, "hook never invoked"
    # 4 slots were never all live, so no call may carry 4 rows
    assert max(seen) <= 2
    assert seen[0] == 1            # admit tick: only the admitted slot


def test_output_unchanged_by_hook_row_masking(bundle, params, prompt):
    """Slicing sampling to live rows must not perturb greedy outputs."""
    cfg = EngineConfig(slots=4, max_seq=64, prefill_len=12)
    eng1 = Engine(bundle, params, cfg)
    eng1.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    alone = eng1.run(max_ticks=30)[0].output

    eng2 = Engine(bundle, params, cfg, logits_hook=lambda lo, hi: lo)
    eng2.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    hooked = eng2.run(max_ticks=30)[0].output
    assert alone == hooked
