"""Per-architecture smoke tests on reduced configs (brief requirement f).

For every assigned arch: instantiate the REDUCED config of the same family,
run one forward/train step and a prefill->decode step on CPU, assert output
shapes and absence of NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStructs, no allocation) — see launch/dryrun.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.registry import build_model

ARCHS = list(configs.ARCH_IDS)
B, S = 2, 32


def _batch(bundle, b=B, s=S, seed=0):
    rng = np.random.default_rng(seed)
    cfg = bundle.cfg
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if getattr(cfg, "mrope_section", None):
        pos = np.broadcast_to(np.arange(s)[None, :, None], (b, s, 3))
    else:
        pos = np.broadcast_to(np.arange(s)[None, :], (b, s))
    batch["positions"] = jnp.asarray(pos, jnp.int32)
    for name, (shape_fn, dtype, _axes) in bundle.extra_inputs.items():
        batch[name] = jnp.asarray(
            rng.normal(size=shape_fn(b, s)) * 0.02, dtype)
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    return request.param


@pytest.fixture(scope="module")
def bundle(arch):
    return build_model(configs.get_reduced(arch))


@pytest.fixture(scope="module")
def params(bundle):
    return bundle.init(jax.random.PRNGKey(0))


def test_param_count_positive(bundle):
    assert bundle.count_params > 0
    assert 0 < bundle.active_params <= bundle.count_params


def test_forward_shapes_no_nans(bundle, params):
    batch = _batch(bundle)
    hidden, aux = jax.jit(bundle.forward_train)(params, batch)
    assert hidden.shape == (B, S, bundle.cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hidden, np.float32)))
    logits = bundle.logits(params, hidden[:, -4:])
    assert logits.shape == (B, 4, bundle.cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert np.isfinite(float(aux))


def test_train_step_reduces_loss(bundle, params):
    """Two SGD steps on one batch must reduce the loss (gradients flow)."""
    batch = _batch(bundle)

    def loss_fn(p):
        hidden, aux = bundle.forward_train(p, batch)
        logits = bundle.logits(p, hidden)
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = jnp.take_along_axis(logp, batch["labels"][..., None], -1)
        return -jnp.mean(tgt) + aux

    vg = jax.jit(jax.value_and_grad(loss_fn))
    l0, g = vg(params)
    assert np.isfinite(float(l0))
    gnorm = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda x: jnp.sum(x * x), g))
    assert float(gnorm) > 0, "no gradient signal"
    # normalized descent step; shrink until decrease (guaranteed for small
    # enough steps along -g; loop bounds the search)
    gn = float(jnp.sqrt(gnorm))
    for lr in (1e-1, 1e-2, 1e-3, 1e-4):
        p1 = jax.tree.map(lambda p, gg, lr=lr: p - (lr / gn) * gg,
                          params, g)
        l1, _ = vg(p1)
        assert np.isfinite(float(l1))
        if float(l1) < float(l0):
            break
    assert float(l1) < float(l0), (float(l0), float(l1))


def test_prefill_then_decode_matches_forward(bundle, params):
    """Decode logits at position t must match teacher-forced logits at t."""
    cfg = bundle.cfg
    batch = _batch(bundle)
    hidden, _ = jax.jit(bundle.forward_train)(params, batch)
    full_logits = np.asarray(bundle.logits(params, hidden), np.float32)

    s_cut = S - 4
    caches = bundle.init_cache(B, S)
    pre_batch = {k: (v[:, :s_cut] if k in ("tokens", "positions") else v)
                 for k, v in batch.items() if k != "labels"}
    lengths = jnp.zeros((B,), jnp.int32)
    hidden_pre, caches = jax.jit(bundle.prefill)(
        params, pre_batch, caches, lengths)
    assert hidden_pre.shape == (B, s_cut, cfg.d_model)
    logits_pre = np.asarray(
        bundle.logits(params, hidden_pre[:, -1]), np.float32)
    np.testing.assert_allclose(
        logits_pre, full_logits[:, s_cut - 1], rtol=2e-2, atol=2e-2)

    lengths = jnp.full((B,), s_cut, jnp.int32)
    decode = jax.jit(bundle.decode_step)
    for t in range(s_cut, S):
        tok = batch["tokens"][:, t:t + 1]
        pos = batch["positions"][:, t:t + 1]
        logits, _hidden, caches = decode(params, tok, pos, caches, lengths)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits)))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), full_logits[:, t], rtol=2e-2,
            atol=2e-2)
        lengths = lengths + 1


def test_full_config_structs_only(arch):
    """The FULL config must build param structs without allocating."""
    bundle = build_model(configs.get_config(arch))
    structs = bundle.param_structs()
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(structs))
    assert n == bundle.count_params
    assert n > 1e7, f"{arch}: full config suspiciously small ({n})"


def test_assigned_param_counts():
    """Full-config parameter counts are in the advertised ballpark."""
    expect = {
        "qwen3-moe-30b-a3b": (29e9, 32e9),
        "llama4-scout-17b-a16e": (90e9, 115e9),   # 16 experts x 48L, untied
        "qwen2.5-32b": (31e9, 35e9),
        "qwen3-32b": (31e9, 34e9),
        "starcoder2-3b": (2.8e9, 3.3e9),
        "phi3-medium-14b": (13e9, 15e9),
        "recurrentgemma-2b": (2.3e9, 3.0e9),
        "qwen2-vl-72b": (70e9, 75e9),
        "rwkv6-1.6b": (1.4e9, 1.8e9),
        # 37M backbone + 25M learned-position table sized for decode_32k
        "whisper-tiny": (25e6, 70e6),
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(configs.get_config(arch)).count_params
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"
