"""Batched pipeline parity: knn_search_batch vs per-query vs brute force.

Covers all five Bregman families, exact and approximate modes, the
streaming k-selection (multi-block) path, the capped budget-doubling
retry, the batched refine kernel, and the ub_filter dispatch regression
(no silent ref fallback).
"""

import logging

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bregman import get_family, family_names
from repro.core.index import build_index
from repro.core import search
from repro.kernels import ops, ref
from repro.kernels import bregman_ub as _ub
from repro.kernels.bregman_dist import bregman_refine_batch


def _dataset(family, n=500, d=24, q=6, seed=0):
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(seed), (n, d), scale=1.0))
    queries = np.asarray(
        fam.sample(jax.random.PRNGKey(seed + 1), (q, d), scale=1.0))
    return data, queries, fam


@pytest.mark.parametrize("family", family_names())
def test_batch_matches_per_query_and_brute_force(family):
    """Exact batch results == per-query results == linear scan, all families."""
    data, queries, fam = _dataset(family)
    index = build_index(data, family, m=4, num_clusters=16, seed=0)
    k = 7
    res = search.knn_batch(index, queries, k)
    assert bool(jnp.all(res.exact))
    bf_ids, bf_dists = search.brute_force_knn(data, queries, k, fam)
    for qi in range(queries.shape[0]):
        single = search.knn(index, queries[qi], k)
        # identical neighbor sets, per-query vs batched vs oracle
        assert (set(np.asarray(res.ids[qi]).tolist())
                == set(np.asarray(single.ids).tolist()))
        np.testing.assert_allclose(
            np.sort(np.asarray(res.dists[qi])),
            np.sort(np.asarray(single.dists)), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.sort(np.asarray(res.dists[qi])),
            np.sort(np.asarray(bf_dists[qi])), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("family", ["squared_euclidean", "itakura_saito"])
def test_batch_approx_matches_per_query(family):
    """Approximate mode: batched CDF shrink == the per-query shrink."""
    data, queries, fam = _dataset(family, n=700, seed=3)
    index = build_index(data, family, m=4, num_clusters=16, seed=0)
    k, p = 8, 0.8
    res = search.knn_batch(index, queries, k, approx_p=p)
    for qi in range(queries.shape[0]):
        single = search.knn(index, queries[qi], k, approx_p=p)
        if bool(res.exact[qi]) and bool(single.exact):
            assert (set(np.asarray(res.ids[qi]).tolist())
                    == set(np.asarray(single.ids).tolist()))
            np.testing.assert_allclose(
                np.sort(np.asarray(res.dists[qi])),
                np.sort(np.asarray(single.dists)), rtol=1e-5, atol=1e-5)
        assert (int(res.num_candidates[qi]) == int(single.num_candidates))


def test_batch_streaming_blocks_match_single_shot():
    """block_rows < n exercises the scan merge; results must be identical."""
    data, queries, fam = _dataset("exponential", n=600)
    index = build_index(data, "exponential", m=4, num_clusters=16, seed=0)
    full = search.knn_batch(index, queries, 5)
    stream = search.knn_batch(index, queries, 5, block_rows=64)
    np.testing.assert_array_equal(np.asarray(full.ids),
                                  np.asarray(stream.ids))
    np.testing.assert_allclose(np.asarray(full.dists),
                               np.asarray(stream.dists), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(full.num_candidates),
                                  np.asarray(stream.num_candidates))


def test_batch_budget_retry_path():
    """A deliberately tiny budget must be doubled until the batch is exact."""
    data, queries, fam = _dataset("squared_euclidean", n=400)
    index = build_index(data, "squared_euclidean", m=4, num_clusters=8, seed=0)
    res = search.knn_batch(index, queries, 5, budget=8)
    assert bool(jnp.all(res.exact))
    _, bf_dists = search.brute_force_knn(data, queries, 5, fam)
    np.testing.assert_allclose(np.sort(np.asarray(res.dists), axis=1),
                               np.sort(np.asarray(bf_dists), axis=1),
                               rtol=2e-3, atol=2e-3)


def test_batch_retry_cap_escalates_to_full_refine(caplog):
    """Exhausting the doubling cap logs a warning and escalates to budget=n,
    so exact-mode results stay exact (the pre-batch invariant)."""
    data, queries, fam = _dataset("squared_euclidean", n=400)
    index = build_index(data, "squared_euclidean", m=4, num_clusters=8, seed=0)
    with caplog.at_level(logging.WARNING, logger="repro.core.search"):
        res = search.knn_batch(index, queries, 5, budget=8, max_doublings=0)
    assert any("budget cap exhausted" in r.message for r in caplog.records)
    assert bool(jnp.all(res.exact))
    _, bf_dists = search.brute_force_knn(data, queries, 5, fam)
    np.testing.assert_allclose(np.sort(np.asarray(res.dists), axis=1),
                               np.sort(np.asarray(bf_dists), axis=1),
                               rtol=2e-3, atol=2e-3)


def test_knn_batch_rejects_k_larger_than_index():
    data, queries, _ = _dataset("squared_euclidean", n=128)
    index = build_index(data[:16], "squared_euclidean", m=4, num_clusters=4,
                        seed=0)
    with pytest.raises(ValueError, match="exceeds index size"):
        search.knn_batch(index, queries, 17)


def test_knn_batch_rejects_single_vector():
    data, queries, _ = _dataset("squared_euclidean", n=128)
    index = build_index(data, "squared_euclidean", m=4, num_clusters=8, seed=0)
    with pytest.raises(ValueError, match=r"\(q, d\)"):
        search.knn_batch(index, queries[0], 5)
    with pytest.raises(ValueError, match=r"\(q, d\)"):
        search.knn_search_batch(index, jnp.asarray(queries[0]), 5, 16)


def test_knn_batch_rejects_budget_smaller_than_k():
    data, queries, _ = _dataset("squared_euclidean", n=128)
    index = build_index(data, "squared_euclidean", m=4, num_clusters=8, seed=0)
    with pytest.raises(ValueError, match="must be >= k"):
        search.knn_batch(index, queries, 10, budget=4)


def test_knnlm_hook_mixes_and_gates_on_exact(monkeypatch):
    """KNNLMHook (serve layer): exact rows get the kNN mixture, rows flagged
    inexact fall back to the pure LM distribution.  Lives here because
    test_serve.py needs the missing repro.dist tree to collect."""
    from repro.serve.knnlm import Datastore, KNNLMHook
    from repro.serve import knnlm as knnlm_mod

    data, queries, fam = _dataset("squared_euclidean", n=200, d=16)
    index = build_index(data, "squared_euclidean", m=4, num_clusters=8,
                        seed=0)
    store = Datastore(index=index,
                      next_tokens=np.arange(200, dtype=np.int32) % 32,
                      hidden_dim=16)
    hook = KNNLMHook(store=store, k=4, lam=0.5)
    logits = jnp.zeros((3, 32))
    hidden = jnp.asarray(data[:3])
    out = hook(logits, hidden)
    uniform = jax.nn.log_softmax(jnp.zeros((32,)))
    assert out.shape == (3, 32) and hook.queries_served == 3
    # exact retrieval must actually perturb the LM distribution
    assert not np.allclose(np.asarray(out[0]), np.asarray(uniform),
                           atol=1e-5)
    # value table uploaded once, reused across ticks
    dev = hook._next_dev
    hook(logits, hidden)
    assert hook._next_dev is dev

    # rows flagged inexact must serve the pure LM distribution
    real = knnlm_mod.bp_search.knn_batch

    def inexact_knn(*args, **kwargs):
        res = real(*args, **kwargs)
        if kwargs.get("return_stats"):
            res, stats = res
            return res._replace(exact=jnp.zeros_like(res.exact)), stats
        return res._replace(exact=jnp.zeros_like(res.exact))

    monkeypatch.setattr(knnlm_mod.bp_search, "knn_batch", inexact_knn)
    gated = KNNLMHook(store=store, k=4, lam=0.5)(logits, hidden)
    np.testing.assert_allclose(np.asarray(gated),
                               np.broadcast_to(np.asarray(uniform), (3, 32)),
                               atol=1e-5)


def test_brute_force_batched_matches_per_query():
    data, queries, fam = _dataset("shannon", n=300)
    ids_b, dists_b = search.brute_force_knn(data, queries, 6, fam)
    assert ids_b.shape == dists_b.shape == (queries.shape[0], 6)
    for qi in range(queries.shape[0]):
        ids_1, dists_1 = search.brute_force_knn(data, queries[qi], 6, fam)
        np.testing.assert_array_equal(np.asarray(ids_b[qi]),
                                      np.asarray(ids_1))
        np.testing.assert_allclose(np.asarray(dists_b[qi]),
                                   np.asarray(dists_1), rtol=1e-6)


# ---------------------------------------------------------------------------
# kernel dispatch regressions
# ---------------------------------------------------------------------------

def test_ub_filter_single_query_uses_pallas_path(monkeypatch):
    """Regression: single-query shape must hit the kernel, not silently fall
    back to the jnp reference (the old ``qconst.ndim != 1`` guard)."""
    rng = np.random.default_rng(0)
    alpha = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    sg = jnp.asarray(np.abs(rng.normal(size=(64, 8))), jnp.float32)
    qc = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    sd = jnp.asarray(np.abs(rng.normal(size=(8,))), jnp.float32)

    calls = []
    real = _ub.bregman_ub_matrix
    monkeypatch.setattr(
        ops._ub, "bregman_ub_matrix",
        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    totals, comp_of = ops.bregman_ub_filter(alpha, sg, qc, sd,
                                            impl="interpret")
    assert calls, "interpret impl bypassed the Pallas kernel"
    want = ref.bregman_ub_totals(alpha, sg, qc, sd)
    np.testing.assert_allclose(np.asarray(totals), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(comp_of(3)),
                               np.asarray(alpha[3] + qc + sg[3] * sd),
                               rtol=1e-5)


def test_ub_filter_rejects_query_batch():
    rng = np.random.default_rng(0)
    alpha = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)
    sg = jnp.abs(alpha)
    qc = jnp.zeros((2, 4), jnp.float32)
    sd = jnp.ones((2, 4), jnp.float32)
    with pytest.raises(ValueError, match="bregman_ub_matrix"):
        ops.bregman_ub_filter(alpha, sg, qc, sd)


@pytest.mark.parametrize("family", family_names())
def test_batched_refine_kernel_matches_ref(family):
    fam = get_family(family)
    rows = fam.sample(jax.random.PRNGKey(2), (5, 33, 70))
    ys = fam.sample(jax.random.PRNGKey(3), (5, 70))
    grad = fam.phi_prime(ys)
    c_y = jnp.sum(ys * grad, -1) - fam.f(ys)
    got = bregman_refine_batch(rows, grad, c_y, family,
                               block_b=16, block_d=32, interpret=True)
    want = ref.bregman_refine_batch(rows, grad, c_y, family)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    direct = fam.distance(rows, ys[:, None, :])
    np.testing.assert_allclose(np.asarray(got), np.asarray(direct),
                               rtol=1e-3, atol=1e-3)


def test_refine_batch_dispatch_rejects_bad_rank():
    with pytest.raises(ValueError, match="bregman_refine_batch"):
        ops.bregman_refine_batch(jnp.zeros((4, 8)), jnp.zeros((4, 8)),
                                 jnp.zeros((4,)), "squared_euclidean")
