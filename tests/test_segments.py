"""Mutation invariants of the segmented BallForest (core/segments.py).

The contract under test: a forest with live append segments and tombstones
returns BIT-IDENTICAL kNN results to a freshly rebuilt forest over the
same live points — in ``knn_search``, ``knn_search_batch``, and
``distributed_knn`` (1x1 mesh) — with ``exact=True`` staying truthful;
deleted ids never surface in any path; ``pad_points``/``slice_points``
round-trip a mutated view; and compaction (merge or rebuild) preserves
results and original ids.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bregman import family_names, get_family
from repro.core.index import (POINT_FIELDS, build_index, concat_points,
                              pad_points, slice_points)
from repro.core.partition import CostModel, decide_compaction
from repro.core.segments import SegmentedForest, build_segmented_index
from repro.core import search
from repro.dist import knn as dknn
from repro.dist.sharding import make_mesh

FAMILIES = family_names()
N0, N_ADD, D, M, K = 256, 44, 16, 4, 6
DELETED = (3, 7, 270)            # two sealed-segment ids, one appended id


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1,), ("data",))


def _mutated_setup(family, seed=0):
    """A segmented forest after insert+delete, plus the fresh-rebuild ref.

    Returns (segmented, fresh_forest, orig_ids, queries) where ``orig_ids``
    maps the fresh forest's input positions back to original ids.
    """
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(seed), (N0 + N_ADD, D)))
    queries = jnp.asarray(
        np.asarray(fam.sample(jax.random.PRNGKey(seed + 1), (5, D))))
    sf = build_segmented_index(data[:N0], family, m=M, num_clusters=16,
                               seed=seed)
    ids = sf.insert(data[N0:], auto_compact=False)
    assert ids.tolist() == list(range(N0, N0 + N_ADD))
    assert sf.delete(DELETED, auto_compact=False) == len(DELETED)

    live_mask = np.ones(N0 + N_ADD, bool)
    live_mask[list(DELETED)] = False
    fresh = build_index(data[live_mask], family, m=M, num_clusters=16,
                        seed=seed)
    return sf, fresh, np.arange(N0 + N_ADD)[live_mask], queries


def _fresh_result_in_orig_ids(res, orig_ids):
    return res._replace(ids=jnp.asarray(orig_ids)[res.ids])


@pytest.mark.parametrize("family", FAMILIES)
def test_exact_bit_identical_to_fresh_rebuild(family):
    """Acceptance: batched + single-query results == fresh rebuild, bitwise."""
    sf, fresh, orig_ids, queries = _mutated_setup(family)
    assert sf.live_n == N0 + N_ADD - len(DELETED)

    # One budget (= live count) for both sides: only live rows are ever
    # admitted, so the union always fits, and the refine runs the same
    # static shape on both indexes (bitwise-identical reduction order).
    budget = sf.live_n
    res = search.knn_search_batch(sf, queries, K, budget)
    ref = _fresh_result_in_orig_ids(
        search.knn_search_batch(fresh, queries, K, budget), orig_ids)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))
    assert bool(jnp.all(res.exact)) and bool(jnp.all(ref.exact))

    single = search.knn_search(sf, queries[0], K, budget)
    single_ref = search.knn_search(fresh, queries[0], K, budget)
    np.testing.assert_array_equal(
        np.asarray(single.ids),
        np.asarray(orig_ids)[np.asarray(single_ref.ids)])
    np.testing.assert_array_equal(np.asarray(single.dists),
                                  np.asarray(single_ref.dists))
    assert bool(single.exact) and bool(single_ref.exact)


@pytest.mark.parametrize("family", FAMILIES)
def test_approx_mode_on_mutated_forest(family):
    """§8 approx on a mutated forest: batch==single parity on the same
    index, only live points, true distances, sane recall."""
    sf, fresh, orig_ids, queries = _mutated_setup(family)
    fam = sf.family
    p = 0.9
    res = search.knn_batch(sf, queries, K, approx_p=p)
    for qi in range(queries.shape[0]):
        single = search.knn(sf, queries[qi], K, approx_p=p)
        assert int(res.num_candidates[qi]) == int(single.num_candidates)
        if bool(res.exact[qi]) and bool(single.exact):
            assert (set(np.asarray(res.ids[qi]).tolist())
                    == set(np.asarray(single.ids).tolist()))
    ids = np.asarray(res.ids)
    assert not np.isin(ids, list(DELETED)).any()
    # returned distances are the EXACT distances of the returned live points
    view = sf.view()
    id_to_row = {int(i): r for r, i in
                 enumerate(np.asarray(view.point_ids)) if int(i) >= 0}
    for qi in range(queries.shape[0]):
        rows = np.stack([np.asarray(view.data)[id_to_row[int(i)]]
                         for i in ids[qi]])
        true_d = np.asarray(fam.distance(jnp.asarray(rows), queries[qi][None]))
        np.testing.assert_allclose(np.asarray(res.dists[qi]), true_d,
                                   rtol=1e-4, atol=1e-4)
    # recall floor vs brute force over live points (p=0.9 guarantee)
    live = np.asarray(view.data)[np.asarray(view.point_ids) >= 0]
    _, bf_d = search.brute_force_knn(live, queries, K, fam)
    hits = sum(
        len(set(np.round(np.asarray(res.dists[qi]), 4).tolist())
            & set(np.round(np.asarray(bf_d[qi]), 4).tolist()))
        for qi in range(queries.shape[0]))
    assert hits >= int(0.5 * K * queries.shape[0])


def test_distributed_1x1_bit_identical(mesh):
    family = "itakura_saito"
    sf, fresh, orig_ids, queries = _mutated_setup(family)
    sharded = dknn.shard_index(sf, mesh)
    assert sharded.global_live_n == sf.live_n
    budget = sf.live_n                 # same refine shape on both sides
    res = dknn.distributed_knn(sharded, queries, family=family, k=K,
                               budget=budget, mesh=mesh)
    ref = _fresh_result_in_orig_ids(
        search.knn_search_batch(fresh, queries, K, budget), orig_ids)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))
    assert bool(jnp.all(res.exact))
    assert not np.isin(np.asarray(res.ids), list(DELETED)).any()


def test_deleted_true_neighbors_never_surface_any_path(mesh):
    """Delete a query's entire true top-k; every path must return the next
    tier, never a tombstoned id, and stay exact."""
    family = "squared_euclidean"
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(2), (N0 + N_ADD, D)))
    queries = jnp.asarray(
        np.asarray(fam.sample(jax.random.PRNGKey(3), (3, D))))
    sf = build_segmented_index(data[:N0], family, m=M, num_clusters=16,
                               seed=0)
    sf.insert(data[N0:], auto_compact=False)
    top_ids, _ = search.brute_force_knn(data, queries[0], K, fam)
    doomed = np.asarray(top_ids).tolist()
    sf.delete(doomed, auto_compact=False)

    live_mask = np.ones(N0 + N_ADD, bool)
    live_mask[doomed] = False
    bf_ids, bf_d = search.brute_force_knn(data[live_mask], queries, K, fam)
    bf_ids = np.arange(N0 + N_ADD)[live_mask][np.asarray(bf_ids)]

    batch = search.knn_batch(sf, queries, K)
    single = search.knn(sf, queries[0], K)
    sharded = dknn.shard_index(sf, mesh)
    dist = dknn.distributed_knn(sharded, queries, family=family, k=K,
                                budget=search.default_budget(sf.view(), K),
                                mesh=mesh)
    for res_ids in (np.asarray(batch.ids), np.asarray(single.ids)[None],
                    np.asarray(dist.ids)):
        assert not np.isin(res_ids, doomed).any()
    assert bool(jnp.all(batch.exact)) and bool(jnp.all(dist.exact))
    np.testing.assert_array_equal(np.asarray(batch.ids), bf_ids)
    np.testing.assert_allclose(np.sort(np.asarray(batch.dists), axis=1),
                               np.sort(np.asarray(bf_d), axis=1),
                               rtol=1e-5, atol=1e-5)


def test_exact_flag_truthful_under_tiny_budget():
    """Tombstones must not be counted as candidates: the retry ladder
    converges and the final exact flag is truthful."""
    sf, fresh, orig_ids, queries = _mutated_setup("itakura_saito", seed=4)
    res = search.knn_batch(sf, queries, K, budget=K)
    assert bool(jnp.all(res.exact))
    ref = _fresh_result_in_orig_ids(
        search.knn_search_batch(fresh, queries, K, fresh.n), orig_ids)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))


def test_budget_cap_escalation_skips_tombstones():
    """The brute-force escape hatch must mask dead rows: with a starved
    budget cap on a mutated forest, no deleted id (or -1) may surface."""
    sf, fresh, orig_ids, queries = _mutated_setup("squared_euclidean",
                                                  seed=6)
    res = search.knn_batch(sf, queries, K, budget=K, max_doublings=0)
    assert bool(jnp.all(res.exact))
    ids = np.asarray(res.ids)
    assert not np.isin(ids, list(DELETED)).any() and (ids >= 0).all()
    view = sf.view()
    live = np.asarray(view.data)[np.asarray(view.point_ids) >= 0]
    _, bf_d = search.brute_force_knn(live, queries, K, sf.family)
    np.testing.assert_allclose(np.sort(np.asarray(res.dists), axis=1),
                               np.sort(np.asarray(bf_d), axis=1),
                               rtol=1e-5, atol=1e-5)


def test_budget_exceeding_n_is_clamped():
    """A pinned budget can outlive a compaction that shrank the index
    (serve-side contract); the host wrappers must clamp, not crash."""
    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(9), (64, D)))
    sf = build_segmented_index(data, "squared_euclidean", m=M,
                               num_clusters=4, seed=0)
    sf.delete(range(40), auto_compact=False)
    sf.compact("merge")                       # physical n shrinks to 24
    res = search.knn_batch(sf, jnp.asarray(data[40:43]), 3, budget=512)
    np.testing.assert_array_equal(np.asarray(res.ids)[:, 0],
                                  np.arange(40, 43))
    single = search.knn(sf, data[41], 3, budget=512)
    assert int(single.ids[0]) == 41


def test_pad_slice_roundtrip_with_segments_and_tombstones():
    sf, fresh, orig_ids, queries = _mutated_setup("exponential")
    view = sf.view()
    padded = pad_points(view, 7)
    assert padded.n % 7 == 0
    res = search.knn_search_batch(padded, queries, K, view.n)
    ref = search.knn_search_batch(view, queries, K, view.n)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))
    h = padded.n // 2
    halves = [slice_points(padded, 0, h), slice_points(padded, h,
                                                       padded.n - h)]
    rt = concat_points(halves)
    for f in POINT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(rt, f)),
                                      np.asarray(getattr(padded, f)))


def test_compact_merge_and_rebuild_preserve_results_and_ids():
    family = "shannon"
    sf, fresh, orig_ids, queries = _mutated_setup(family)
    budget = sf.live_n                 # compaction shrinks n to live_n, so
    before = search.knn_search_batch(sf, queries, K, budget)  # shapes match

    merged = _mutated_setup(family)[0]
    assert merged.compact("merge") == "merge"
    assert not merged.segments and merged.n == merged.live_n
    after_m = search.knn_search_batch(merged, queries, K, budget)
    np.testing.assert_array_equal(np.asarray(after_m.ids),
                                  np.asarray(before.ids))
    np.testing.assert_array_equal(np.asarray(after_m.dists),
                                  np.asarray(before.dists))

    rebuilt = _mutated_setup(family)[0]
    assert rebuilt.compact("rebuild") == "rebuild"
    assert not rebuilt.segments and rebuilt.n == rebuilt.live_n
    after_r = search.knn_search_batch(rebuilt, queries, K, budget)
    np.testing.assert_array_equal(np.asarray(after_r.ids),
                                  np.asarray(before.ids))
    np.testing.assert_array_equal(np.asarray(after_r.dists),
                                  np.asarray(before.dists))
    with pytest.raises(ValueError, match="unknown compaction mode"):
        _mutated_setup(family)[0].compact("defrag")


def test_auto_compact_on_threshold():
    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(5), (200, D)))
    sf = build_segmented_index(data[:100], "squared_euclidean", m=M,
                               num_clusters=8, seed=0,
                               compact_threshold=0.25)
    sf.insert(data[100:110], auto_compact=True)      # 10% — below threshold
    assert len(sf.segments) == 1
    sf.insert(data[110:160], auto_compact=True)      # ~60% appended — crosses
    assert not sf.segments and sf.n == sf.live_n == 160
    res = search.knn_batch(sf, jnp.asarray(data[:4]), 1)
    np.testing.assert_array_equal(np.asarray(res.ids).ravel(),
                                  np.arange(4))


def test_decide_compaction_cost_rule():
    model = CostModel(a=1.0, alpha=0.5, beta=1e-4, n=4096, d=64)
    # fresh index, nothing stale -> merge is free, rebuild never wins
    assert decide_compaction(model, 4, stale_fraction=0.0) == "merge"
    # hugely stale + generous amortization window -> rebuild pays off
    assert decide_compaction(model, 4, stale_fraction=50.0,
                             amortize_queries=10**9) == "rebuild"
    # the rule is monotone in stale_fraction
    flips = [decide_compaction(model, 4, stale_fraction=s,
                               amortize_queries=10**9)
             for s in (0.0, 0.5, 5.0, 50.0)]
    assert flips == sorted(flips, key=lambda x: x == "rebuild")


def test_k_validated_against_live_count():
    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(6), (32, D)))
    sf = build_segmented_index(data, "squared_euclidean", m=M,
                               num_clusters=4, seed=0)
    sf.delete(range(16), auto_compact=False)
    with pytest.raises(ValueError, match="live point count"):
        search.knn_batch(sf, jnp.asarray(data[:2]), 17)
    with pytest.raises(ValueError, match="live"):
        dknn.distributed_knn(
            dknn.shard_index(sf, make_mesh((1,), ("data",))),
            jnp.asarray(data[:2]), family="squared_euclidean", k=17,
            budget=32)


def test_delete_everything_then_reinsert():
    """Full eviction (the rolled-over-corpus flow) must not crash the
    auto-compaction; a later insert revives the index."""
    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(10), (48, D)))
    sf = build_segmented_index(data[:32], "squared_euclidean", m=M,
                               num_clusters=4, seed=0)
    assert sf.delete(range(32)) == 32     # auto-compact fires on empty
    assert sf.live_n == 0 and sf.n == 0 and not sf.segments
    with pytest.raises(ValueError, match="live point count"):
        search.knn_batch(sf, jnp.asarray(data[:1]), 1)
    ids = sf.insert(data[32:], auto_compact=False)
    assert ids.tolist() == list(range(32, 48))
    res = search.knn_batch(sf, jnp.asarray(data[32:35]), 1)
    np.testing.assert_array_equal(np.asarray(res.ids).ravel(), ids[:3])


def test_insert_rejects_bad_shape():
    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(7), (64, D)))
    sf = build_segmented_index(data, "squared_euclidean", m=M,
                               num_clusters=4, seed=0)
    with pytest.raises(ValueError, match="expected"):
        sf.insert(np.ones((3, D + 1), np.float32))
    with pytest.raises(ValueError, match="expected"):
        sf.insert(np.ones((D,), np.float32))


def test_datastore_grow_evict_contract():
    from repro.serve.knnlm import Datastore, KNNLMHook

    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(8), (220, D)))
    store = Datastore(
        index=build_index(data[:200], "squared_euclidean", m=M,
                          num_clusters=8, seed=0),
        next_tokens=np.arange(200, dtype=np.int32) % 32, hidden_dim=D)
    hook = KNNLMHook(store=store, k=4, lam=0.5)
    logits = jnp.zeros((3, 32))
    hook(logits, jnp.asarray(data[:3]))

    new_ids = store.grow(data[200:220], np.full(20, 7, np.int32))
    assert isinstance(store.index, SegmentedForest)
    assert store.next_tokens.shape == (220,) and store.version == 1
    # the new keys are immediately retrievable and resolve to their token
    res = search.knn_batch(store.index, jnp.asarray(data[200:203]), 1)
    np.testing.assert_array_equal(np.asarray(res.ids).ravel(),
                                  new_ids[:3])
    out = hook(logits, jnp.asarray(data[200:203]))
    assert out.shape == (3, 32)
    # mixture must now lean on token 7 for an exact self-hit
    assert int(jnp.argmax(out[0])) == 7

    assert store.evict(new_ids) == 20 and store.version == 2
    res2 = search.knn_batch(store.index, jnp.asarray(data[200:203]), 1)
    assert not np.isin(np.asarray(res2.ids), new_ids).any()
    with pytest.raises(ValueError, match="one next-token per key"):
        store.grow(data[:2], np.zeros(3, np.int32))
    with pytest.raises(ValueError, match="expected"):
        store.grow(np.ones((2, D + 2), np.float32), np.zeros(2, np.int32))

    # evicting below k must degrade the hook to the pure LM distribution,
    # not raise mid-decode; auto_compact=False keeps eviction tombstone-only
    store.auto_compact = False
    store.evict(np.arange(200 - hook.k + 1))
    assert store.index.live_n < hook.k
    assert isinstance(store.index, SegmentedForest) and store.index.n == 220
    out_low = hook(logits, jnp.asarray(data[:3]))
    np.testing.assert_allclose(np.asarray(out_low), np.asarray(logits),
                               atol=0)
