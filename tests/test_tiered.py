"""Out-of-core tiered store parity + residency invariants (core/tiered.py).

The contract under test: a TieredPointStore — cold point blocks in host
RAM, fetched to device only on envelope admission — returns results
BIT-IDENTICAL to the fully-resident ``knn_search_batch`` /
``knn_search_batch_approx`` on the same point set, across all five
Bregman families x {fp32, int8} x {exact, approx}, and after every
point-table mutation the index layer supports (pad / tombstone / slice /
concat, SegmentedForest insert / delete / compact).  Residency mechanics
— the LRU block-cache budget, pinned append blocks, the resident fast
path, prefetch stats, fetch timeouts, and the knob resolvers — are
pinned alongside.
"""

import dataclasses
import functools
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bregman import family_names, get_family
from repro.core.index import (build_index, cold_point_fields, concat_points,
                              pad_points, slice_points, tombstone_rows)
from repro.core.segments import build_segmented_index
from repro.core import search
from repro.core.tiered import (DEFAULT_PREFETCH_DEPTH, FetchTimeout,
                               TieredPointStore, resolve_prefetch_depth,
                               resolve_resident_bytes)

N, D, M, Q, K = 420, 16, 4, 4, 5
BLOCK_ROWS = 96          # 5 cold blocks at N=420 — real multi-block tiering
BUDGET = 64
P_APPROX = 0.8
# fp32 cold-tier footprint at these shapes; int8 tiers are ~8x smaller,
# so budgets are sized per index (see _small_budget) to force real
# multi-block fetch/evict traffic in both storage modes.
SMALL_BUDGET_BYTES = 40_000


def _small_budget(index):
    """~60% of the index's cold footprint: tiered, holds a few bundles."""
    view = getattr(index, "view", None)
    forest = view() if callable(view) else index
    cold = sum(np.asarray(getattr(forest, f)).nbytes
               for f in cold_point_fields(forest))
    return max(1, (6 * cold) // 10)


def _assert_bitwise_equal(a, b):
    for f in ("ids", "dists", "exact", "num_candidates"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


@functools.lru_cache(maxsize=None)
def _built(family, quantize):
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(0), (N, D), scale=1.0))
    queries = jnp.asarray(np.asarray(
        fam.sample(jax.random.PRNGKey(1), (Q, D), scale=1.0)))
    index = build_index(data, family, m=M, num_clusters=8, seed=0,
                        quantize=quantize)
    return index, queries


@functools.lru_cache(maxsize=None)
def _mutated(family, quantize):
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(2), (N, D), scale=1.0))
    sf = build_segmented_index(data[:N - 64], family, m=M, num_clusters=8,
                               seed=0, quantize=quantize)
    sf.insert(data[N - 64:], auto_compact=False)
    sf.delete([1, 5, N - 30], auto_compact=False)
    return sf


# ---------------------------------------------------------------------------
# Bit parity: all families x storage tiers x {exact, approx}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("family", family_names())
def test_tiered_matches_resident(family, quantize):
    """Exact + approx, fp32 + int8: tiered == resident, bit for bit."""
    index, queries = _built(family, quantize)
    store = TieredPointStore(index, resident_bytes=_small_budget(index),
                             block_rows=BLOCK_ROWS)
    assert not store.is_resident and store.num_blocks == 5

    res = store.search(queries, K, BUDGET)
    ref = search.knn_search_batch(index, queries, K, BUDGET,
                                  block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res, ref)
    assert store.stats["host_bytes_fetched"] > 0

    res_a = store.search(queries, K, BUDGET, p_guarantee=P_APPROX)
    ref_a = search.knn_search_batch_approx(index, queries, K, BUDGET,
                                           jnp.float32(P_APPROX),
                                           block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res_a, ref_a)


@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("family", family_names())
def test_tiered_matches_resident_mutated_segmented(family, quantize):
    """Parity over a segmented index with appends + tombstones; the
    append-segment rows are pinned device-resident."""
    sf = _mutated(family, quantize)
    fam = get_family(family)
    queries = jnp.asarray(np.asarray(
        fam.sample(jax.random.PRNGKey(3), (Q, D), scale=1.0)))
    store = TieredPointStore.from_index(sf,
                                        resident_bytes=_small_budget(sf),
                                        block_rows=BLOCK_ROWS)
    lo, hi = sf.append_row_range()
    assert lo == sf.main.n and hi == sf.n
    want_pinned = set(range(lo // store._bn, -(-hi // store._bn)))
    assert set(store._pinned) == want_pinned and want_pinned

    budget = sf.live_n
    res = store.search(queries, K, budget)
    ref = search.knn_search_batch(sf, queries, K, budget,
                                  block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res, ref)
    # tombstoned ids can never surface through the tiered compaction
    gone = {1, 5, N - 30}
    assert not gone & set(np.asarray(res.ids).ravel().tolist())
    # pinned blocks survive every eviction the search cycle caused
    assert want_pinned <= set(store._cache)

    res_a = store.search(queries, K, budget, p_guarantee=P_APPROX)
    ref_a = search.knn_search_batch_approx(sf, queries, K, budget,
                                           jnp.float32(P_APPROX),
                                           block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res_a, ref_a)


@pytest.mark.parametrize("quantize", [False, True])
def test_tiered_matches_after_pad_tombstone_slice_concat(quantize):
    """Every point-table mutation path feeds the same tier contract."""
    index, queries = _built("squared_euclidean", quantize)

    mutants = {
        "pad": pad_points(index, 7),
        "concat": concat_points([slice_points(index, 0, 224),
                                 slice_points(index, 224, N - 224)]),
    }
    dead = np.zeros(index.n, bool)
    dead[::3] = True
    mutants["tombstone"] = tombstone_rows(index, jnp.asarray(dead))
    mutants["slice"] = slice_points(index, 96, 224)

    for name, forest in mutants.items():
        k = min(K, int((np.asarray(forest.point_ids) >= 0).sum()))
        budget = min(BUDGET, forest.n)
        store = TieredPointStore(forest,
                                 resident_bytes=_small_budget(forest),
                                 block_rows=BLOCK_ROWS)
        res = store.search(queries, k, budget)
        ref = search.knn_search_batch(forest, queries, k, budget,
                                      block_rows=BLOCK_ROWS, validate=False)
        _assert_bitwise_equal(res, ref)
        del name


def test_tiered_matches_after_compact():
    fam = get_family("shannon")
    data = np.asarray(fam.sample(jax.random.PRNGKey(2), (N, D), scale=1.0))
    sf = build_segmented_index(data[:N - 64], "shannon", m=M, num_clusters=8,
                               seed=0)
    sf.insert(data[N - 64:], auto_compact=False)
    sf.delete([1, 5, N - 30], auto_compact=False)
    sf.compact("merge")
    queries = jnp.asarray(np.asarray(get_family("shannon").sample(
        jax.random.PRNGKey(4), (Q, D), scale=1.0)))
    store = TieredPointStore.from_index(sf,
                                        resident_bytes=_small_budget(sf),
                                        block_rows=BLOCK_ROWS)
    # post-compaction there are no append segments left to pin
    assert sf.append_row_range()[0] == sf.append_row_range()[1]
    assert not store._pinned
    res = store.search(queries, K, BUDGET)
    ref = search.knn_search_batch(sf, queries, K, BUDGET,
                                  block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res, ref)


# ---------------------------------------------------------------------------
# Routing: one public API for both residency modes
# ---------------------------------------------------------------------------

def test_public_entry_points_route_tiered_stores():
    index, queries = _built("squared_euclidean", False)
    store = TieredPointStore(index, resident_bytes=_small_budget(index),
                             block_rows=BLOCK_ROWS)
    ref = search.knn_search_batch(index, queries, K, BUDGET,
                                  block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(
        search.knn_search_batch(store, queries, K, BUDGET,
                                block_rows=BLOCK_ROWS), ref)
    ref_a = search.knn_search_batch_approx(index, queries, K, BUDGET,
                                           jnp.float32(P_APPROX),
                                           block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(
        search.knn_search_batch_approx(store, queries, K, BUDGET,
                                       jnp.float32(P_APPROX),
                                       block_rows=BLOCK_ROWS), ref_a)

    # single-query wrappers slice the batched result to scalar shapes
    one = search.knn_search(store, queries[0], K, BUDGET)
    assert one.ids.shape == (K,)
    np.testing.assert_array_equal(np.asarray(one.ids),
                                  np.asarray(ref.ids)[0])
    one_a = search.knn_search_approx(store, queries[0], K, BUDGET,
                                     jnp.float32(P_APPROX))
    np.testing.assert_array_equal(np.asarray(one_a.ids),
                                  np.asarray(ref_a.ids)[0])

    # knn_batch retries/escalation accept a store
    res = search.knn_batch(store, queries, K, budget=BUDGET,
                           block_rows=BLOCK_ROWS)
    assert res.ids.shape == (Q, K)

    # O(n*q) diagnostics refuse a store with actionable guidance
    with pytest.raises(TypeError, match="as_resident_forest"):
        search.knn_search_batch_stats(store, queries, K, BUDGET)
    with pytest.raises(TypeError, match="as_resident_forest"):
        search.knn_search_batch_reference(store, queries, K, BUDGET)

    # ... and the escape hatch is the full resident forest, bit for bit
    forest = store.as_resident_forest()
    _assert_bitwise_equal(
        search.knn_search_batch(forest, queries, K, BUDGET,
                                block_rows=BLOCK_ROWS), ref)
    for f in cold_point_fields(forest):
        assert isinstance(getattr(forest, f), jax.Array)


def test_search_rejects_conflicting_block_rows_and_knob_misuse():
    index, queries = _built("squared_euclidean", False)
    store = TieredPointStore(index, resident_bytes=_small_budget(index),
                             block_rows=BLOCK_ROWS)
    with pytest.raises(ValueError, match="pinned"):
        store.search(queries, K, BUDGET, block_rows=2 * BLOCK_ROWS)
    with pytest.raises(ValueError, match="at most one"):
        store.search(queries, K, BUDGET, p_guarantee=0.9, target_recall=0.9)
    with pytest.raises(ValueError, match="p_guarantee"):
        store.search(queries, K, BUDGET, p_guarantee=1.5)
    with pytest.raises(ValueError, match=r"\(q, d\)"):
        store.search(queries[0], K, BUDGET)


# ---------------------------------------------------------------------------
# Residency mechanics
# ---------------------------------------------------------------------------

def test_resident_fast_path_when_budget_fits():
    """cold_bytes <= resident_bytes (or None) => no tiering at all."""
    index, queries = _built("squared_euclidean", False)
    ref = search.knn_search_batch(index, queries, K, BUDGET,
                                  block_rows=BLOCK_ROWS)
    for budget_bytes in (None, 10**9):
        store = TieredPointStore(index, resident_bytes=budget_bytes,
                                 block_rows=BLOCK_ROWS)
        assert store.is_resident
        res = store.search(queries, K, BUDGET)
        _assert_bitwise_equal(res, ref)
        assert store.stats["host_bytes_fetched"] == 0
        assert store.warm_cache()["resident_fast_path"]


def test_block_cache_hits_and_lru_budget():
    index, queries = _built("squared_euclidean", False)
    # Largest budget still below the cold footprint (fast-path threshold):
    # the cache retains most bundles, so a repeat search is mostly hits.
    # Pin blocks 0-1 (2 * bn rows): they can never be evicted, so repeat
    # traffic is guaranteed hits even though full admission over a
    # partial budget makes the unpinned tail a cyclic-LRU worst case.
    store = TieredPointStore(index, resident_bytes=_small_budget(index),
                             block_rows=BLOCK_ROWS,
                             pinned_row_range=(0, 2 * BLOCK_ROWS))
    assert not store.is_resident
    store.search(queries, K, BUDGET)
    fetched = store.stats["host_bytes_fetched"]
    assert fetched > 0
    store.search(queries, K, BUDGET)
    assert store.stats["cache_hits"] > 0
    info = store.cache_info()
    assert 0 < info["blocks_cached"] <= store.num_blocks
    per_block = max(b["nbytes"] for b in store._cache.values())
    # pinned blocks may legitimately hold the cache over budget; the
    # overshoot is bounded by the pinned set plus one in-use bundle
    assert info["bytes_cached"] <= store.resident_bytes + 3 * per_block

    # A budget below ~one bundle forces refetching on every pass but the
    # cache never durably exceeds the budget by more than the single
    # in-use bundle the eviction loop must keep.
    tiny = TieredPointStore(index, resident_bytes=per_block // 2,
                            block_rows=BLOCK_ROWS)
    tiny.search(queries, K, BUDGET)
    assert tiny._cache_bytes <= tiny.resident_bytes + per_block
    tiny.search(queries, K, BUDGET)
    assert tiny.stats["fetches"] > tiny.num_blocks  # real refetch traffic


def test_warm_cache_populates_up_to_budget():
    index, _ = _built("squared_euclidean", False)
    store = TieredPointStore(index, resident_bytes=_small_budget(index),
                             block_rows=BLOCK_ROWS)
    out = store.warm_cache()
    assert 0 < out["blocks_cached"] <= store.num_blocks
    assert out["bytes_cached"] <= store.resident_bytes
    # warming is accounting-free: per-query stats stay zero
    assert store.stats["fetches"] == 0 and store.stats["queries"] == 0


def test_fetch_timeout_surfaces_as_fetch_timeout():
    """A wedged host->device copy raises FetchTimeout (containable by the
    service ladder) instead of blocking the search forever."""
    index, queries = _built("squared_euclidean", False)

    calls = {"n": 0}

    def stuck_transfer(tiles):
        calls["n"] += 1
        if calls["n"] == 1:          # one wedged copy, then healthy
            time.sleep(0.5)
        return jax.device_put(tiles)

    store = TieredPointStore(index, resident_bytes=_small_budget(index),
                             block_rows=BLOCK_ROWS,
                             transfer=stuck_transfer, fetch_timeout_s=0.05)
    with pytest.raises(FetchTimeout, match="exceeded"):
        store.search(queries, K, BUDGET)
    # the abandoned fetch completes in the background; a retry after the
    # stall clears is served from cache/in-flight futures and succeeds
    time.sleep(0.8)
    res = store.search(queries, K, BUDGET)
    ref = search.knn_search_batch(index, queries, K, BUDGET,
                                  block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res, ref)


def test_stage_a_keeps_cold_leaves_on_host():
    """The hot forest's cold leaves stay numpy — nothing in the store
    transfers them wholesale (only as_resident_forest may)."""
    index, queries = _built("squared_euclidean", False)
    store = TieredPointStore(index, resident_bytes=_small_budget(index),
                             block_rows=BLOCK_ROWS)
    store.search(queries, K, BUDGET)
    for f in cold_point_fields(store._hot):
        assert isinstance(getattr(store._hot, f), np.ndarray), f


# ---------------------------------------------------------------------------
# Knob resolvers (brelint knob-contract surface)
# ---------------------------------------------------------------------------

def test_resolve_resident_bytes_validation():
    assert resolve_resident_bytes(None) is None
    assert resolve_resident_bytes(1) == 1
    assert resolve_resident_bytes(np.int64(1 << 30)) == 1 << 30
    for bad in (0, -1, 1.5, True, "1GB"):
        with pytest.raises(ValueError, match="resident_bytes"):
            resolve_resident_bytes(bad)


def test_resolve_prefetch_depth_validation():
    assert resolve_prefetch_depth(None) == DEFAULT_PREFETCH_DEPTH
    assert resolve_prefetch_depth(1) == 1
    assert resolve_prefetch_depth(64) == 64
    for bad in (0, -2, 65, 2.5, True):
        with pytest.raises(ValueError, match="prefetch_depth"):
            resolve_prefetch_depth(bad)


def test_hot_forest_preserves_calibration_and_statics():
    index, _ = _built("shannon", False)
    index = dataclasses.replace(index, calibration={"marker": 1})
    store = TieredPointStore(index, resident_bytes=_small_budget(index),
                             block_rows=BLOCK_ROWS)
    assert store.calibration == {"marker": 1}
    assert store.family_name == "shannon"
    assert store.storage == index.storage
    assert (store.n, store.d, store.m) == (index.n, index.d, index.m)
