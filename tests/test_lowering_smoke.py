"""Reduced-config lowering smoke: the dry-run machinery end-to-end on a
16-device host mesh (subprocess — device-count isolation)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    sys.path.insert(0, %r)
    import dataclasses
    import jax
    from repro import configs
    from repro.configs.common import ShapeSpec
    from repro.launch import hlo_analysis as ha
    from repro.launch import lowering

    mesh = jax.make_mesh((4, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    out = {}
    cells = [
        ("starcoder2-3b", ShapeSpec("t", 64, 8, "train")),
        ("rwkv6-1.6b", ShapeSpec("t", 64, 8, "train")),
        ("qwen3-moe-30b-a3b", ShapeSpec("t", 64, 8, "train")),
        ("recurrentgemma-2b", ShapeSpec("d", 64, 8, "decode")),
        ("whisper-tiny", ShapeSpec("p", 64, 8, "prefill")),
    ]
    for arch, shape in cells:
        cfg = configs.get_reduced(arch)
        cfg = dataclasses.replace(cfg, scan_layers=False) \\
            if hasattr(cfg, "scan_layers") else cfg
        low = lowering.lower_cell(arch, shape.name, mesh, config=cfg,
                                  shape=shape)
        compiled = low.compile()
        costs = ha.analyze_text(compiled.as_text())
        out[f"{arch}/{shape.kind}"] = {
            "flops": costs.flops, "bytes": costs.bytes,
            "coll": costs.collective_bytes,
            "unknown_loops": costs.unknown_loops,
        }
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.timeout(580)
def test_reduced_cells_lower_compile_and_analyze():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT % src], env=env,
                         capture_output=True, text=True, timeout=570)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    res = json.loads(line[len("RESULT "):])
    assert len(res) == 5
    for cell, costs in res.items():
        assert costs["flops"] > 0, cell
        assert costs["bytes"] > 0, cell
        # every cell on a >1-device mesh must communicate something
        assert costs["coll"] > 0, cell
