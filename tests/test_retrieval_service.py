"""Robustness contract of serve/retrieval.py under seeded fault injection.

Every test runs on a VirtualClock: real launches take zero virtual time,
so latency exists exactly where a fault injects it and each scenario is
deterministic and replayable from its FaultPlan seed.
"""

import numpy as np
import pytest

from repro.core import search as bp
from repro.core.bregman import get_family, validate_rows
from repro.core.search import validate_queries
from repro.core.segments import build_segmented_index
from repro.serve.faults import (
    CompactDuringSearch,
    FaultPlan,
    FetchStall,
    LatencySpike,
    LaunchError,
    PoisonQuery,
    VirtualClock,
)
from repro.serve.retrieval import (
    CircuitBreaker,
    RetrievalService,
    ServiceConfig,
)

N, D, K = 400, 16, 5
SPIKE = 0.3     # injected seconds per launch in the latency tests


def make_index(seed=0, n=N):
    rng = np.random.default_rng(seed)
    data = rng.random((n, D)).astype(np.float32) + 0.1
    return build_segmented_index(data, "shannon", m=4)


@pytest.fixture(scope="module")
def index():
    return make_index()


@pytest.fixture(scope="module")
def queries():
    rng = np.random.default_rng(7)
    return rng.random((4, D)).astype(np.float32) + 0.1


def oracle(index, queries, k=K):
    """Fault-free exact reference over the CURRENT live rows."""
    snap = bp._as_forest(index)
    return bp.knn_search_batch(snap, queries, k, snap.n)


def make_service(index, *, faults=None, **cfg):
    clock = VirtualClock()
    svc = RetrievalService(ServiceConfig(**cfg), clock=clock, faults=faults)
    svc.register_tenant("t", index)
    return svc, clock


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------

def test_deadline_honored_under_latency(index, queries):
    """No response exceeds its deadline by more than ONE launch — the
    documented guarantee (a running XLA launch cannot be preempted)."""
    plan = FaultPlan([LatencySpike(SPIKE)], seed=1)
    svc, clock = make_service(index, faults=plan, default_deadline_s=0.5)
    for _ in range(6):
        r = svc.search_sync("t", queries, K)
        assert r.latency_s <= 0.5 + SPIKE + 1e-6
        if r.quality != "shed":
            assert r.deadline_met or r.latency_s - 0.5 <= SPIKE + 1e-6
    assert svc.counters["launches"] == len(plan.fired("latency"))


def test_ladder_degrades_as_cost_rises(index, queries):
    """Once the cost model knows a launch costs SPIKE, tighter deadlines
    walk down the ladder: exact -> approx -> partial -> shed."""
    plan = FaultPlan([LatencySpike(SPIKE)], seed=1)
    svc, clock = make_service(index, faults=plan)
    svc.search_sync("t", queries, K)          # teach the cost model
    assert svc.tenants["t"].cost.estimate() >= SPIKE

    # exact needs exact_margin(2.0) * est headroom
    r = svc.search_sync("t", queries, K, deadline_s=2.5 * SPIKE)
    assert r.meta["tier_path"][0] == "exact"
    # approx fits in [1.0, 2.0) * est
    r = svc.search_sync("t", queries, K, deadline_s=1.5 * SPIKE)
    assert r.meta["tier_path"][0] == "approx"
    # partial fits in [0.5, 1.0) * est
    r = svc.search_sync("t", queries, K, deadline_s=0.8 * SPIKE)
    assert r.meta["tier_path"][0] == "partial"
    # below partial_margin * est: shed WITHOUT launching
    before = svc.counters["launches"]
    r = svc.search_sync("t", queries, K, deadline_s=0.3 * SPIKE)
    assert r.quality == "shed" and r.shed_reason == "deadline"
    assert svc.counters["launches"] == before


def test_expired_requests_shed_without_launch(index, queries):
    svc, clock = make_service(index)
    ticket = svc.submit("t", queries, K, deadline_s=0.1)
    clock.advance(0.2)                        # deadline passes while queued
    svc.step()
    assert ticket.done and ticket.response.quality == "shed"
    assert ticket.response.shed_reason == "deadline"
    # Truthful labels: this deadline was MISSED, and the response says so.
    assert ticket.response.deadline_met is False
    assert svc.counters["launches"] == 0


def test_stale_batchmate_never_coupled_to_fresh_traffic(index, queries):
    """REGRESSION: a microbatch runs on min(deadline), so a nearly-expired
    request used to drag fresh batchmates into its shed.  The formation
    spread guard keeps them in separate batches: the stale one sheds
    alone, the fresh one completes at full quality."""
    svc, _ = make_service(index)
    svc.tenants["t"].cost.observe(SPIKE)      # price the tiers
    stale = svc.submit("t", queries, K, deadline_s=0.3 * SPIKE)
    fresh = svc.submit("t", queries, K, deadline_s=10 * SPIKE)
    svc.run_until_drained()
    assert stale.response.shed_reason == "deadline"
    assert fresh.response.quality == "exact"
    np.testing.assert_array_equal(fresh.response.ids,
                                  np.asarray(oracle(index, queries).ids))


def test_deadline_shed_requeues_batchmates_with_slack(index, queries):
    """Within the spread guard two requests DO batch; when the batch sheds
    on its tightest member's deadline, the member with remaining slack is
    requeued and served on its own deadline, not resolved as shed."""
    svc, _ = make_service(index)
    svc.tenants["t"].cost.observe(SPIKE)
    # Remaining-deadline ratio 1.83 <= deadline_spread(2.0): one batch.
    # Its min (0.3*SPIKE) is below the partial floor (0.5*SPIKE) -> shed,
    # but the 0.55*SPIKE member affords the partial tier by itself.
    tight = svc.submit("t", queries, K, deadline_s=0.3 * SPIKE)
    slack = svc.submit("t", queries, K, deadline_s=0.55 * SPIKE)
    svc.step()
    assert tight.done and tight.response.shed_reason == "deadline"
    assert not slack.done                     # requeued, not shed
    svc.run_until_drained()
    assert slack.response.quality in ("exact", "partial")
    assert svc.counters["launches"] >= 1


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

def test_queue_full_returns_retry_after(index, queries):
    svc, _ = make_service(index, queue_depth=2)
    t1 = svc.submit("t", queries, K)
    t2 = svc.submit("t", queries, K)
    t3 = svc.submit("t", queries, K)          # bounced: queue is full
    assert not t1.done and not t2.done
    assert t3.done and t3.response.quality == "shed"
    assert t3.response.shed_reason == "queue_full"
    assert t3.response.retry_after is not None and t3.response.retry_after > 0
    assert svc.counters["rejected_queue_full"] == 1
    svc.run_until_drained()
    assert t1.done and t2.done


def test_bad_k_rejected_up_front(index, queries):
    svc, _ = make_service(index)
    t = svc.submit("t", queries, index.live_n + 1)
    assert t.done and t.response.quality == "shed"
    assert t.response.shed_reason == "bad_k"
    assert "live_n" in t.response.error
    assert svc.counters["launches"] == 0
    # The rejection's sentinel arrays are clamped to live_n columns: a
    # huge k must not allocate gigabytes while building its own bounce.
    t = svc.submit("t", queries, 10**9)
    assert t.response.shed_reason == "bad_k"
    assert t.response.ids.shape == (queries.shape[0], index.live_n)
    t = svc.submit("t", queries, 0)
    assert t.response.shed_reason == "bad_k"
    assert t.response.ids.shape == (queries.shape[0], 1)


def test_microbatching_coalesces_requests(index, queries):
    svc, _ = make_service(index)
    tickets = [svc.submit("t", queries[i:i + 1], K) for i in range(3)]
    svc.step()
    assert all(t.done for t in tickets)
    # 3 single-row requests -> ONE bucketed microbatch (plus possible
    # budget retries, which relaunch the same block).
    assert svc.counters["launches"] <= 2
    ref = oracle(index, queries[:3])
    for i, t in enumerate(tickets):
        assert t.response.quality == "exact"
        np.testing.assert_array_equal(t.response.ids[0],
                                      np.asarray(ref.ids)[i])


# ---------------------------------------------------------------------------
# Circuit breaker + retry
# ---------------------------------------------------------------------------

def test_breaker_opens_half_opens_closes(index, queries):
    plan = FaultPlan([LaunchError(at_launches=(0, 1))], seed=3)
    svc, clock = make_service(index, faults=plan, breaker_threshold=2,
                              breaker_cooldown_s=1.0)
    brk = svc.tenants["t"].breaker

    # Two injected failures: retry with backoff, then the breaker opens.
    r1 = svc.search_sync("t", queries, K)
    assert r1.quality == "shed" and r1.shed_reason == "launch_failed"
    assert "InjectedLaunchError" in r1.error
    assert brk.state == "open" and brk.opens == 1

    # While open: shed with a retry_after hint, no launches.
    before = svc.counters["launches"]
    r2 = svc.search_sync("t", queries, K)
    assert r2.shed_reason == "breaker_open"
    assert 0 < r2.retry_after <= 1.0
    assert svc.counters["launches"] == before

    # After the cooldown: one half-open probe, which succeeds and closes.
    clock.advance(1.1)
    r3 = svc.search_sync("t", queries, K)
    assert r3.quality == "exact"
    assert brk.state == "closed"
    np.testing.assert_array_equal(r3.ids, np.asarray(oracle(index,
                                                            queries).ids))


def test_breaker_allow_is_side_effect_free():
    """allow() must not transition open -> half_open: the probe is marked
    only when a launch actually goes out (begin_probe), so a caller that
    checks and then sheds anyway cannot wedge the breaker."""
    brk = CircuitBreaker(threshold=1, cooldown_s=2.0)
    brk.record_failure(0.0)
    assert brk.state == "open"
    assert not brk.allow(1.0)
    assert brk.allow(2.5) and brk.allow(2.5)  # idempotent, no transition
    assert brk.state == "open"
    brk.begin_probe()
    assert brk.state == "half_open"
    assert not brk.allow(2.5)                 # probe in flight
    assert brk.retry_after(2.5) > 0           # nonzero hint, never 0-forever
    brk.record_failure(3.0)
    assert brk.state == "open" and brk.retry_after(3.5) > 0


def test_breaker_probe_survives_deadline_shed(index, queries):
    """REGRESSION: a post-cooldown batch that sheds on deadline WITHOUT
    launching used to leave the breaker wedged in half_open (allow()
    False, retry_after 0.0 forever).  It must stay open and still admit
    the probe for the next request that can afford a launch."""
    plan = FaultPlan([LaunchError(at_launches=(0, 1))], seed=9)
    svc, clock = make_service(index, faults=plan, breaker_threshold=2,
                              breaker_cooldown_s=1.0)
    brk = svc.tenants["t"].breaker
    r = svc.search_sync("t", queries, K)
    assert r.shed_reason == "launch_failed" and brk.state == "open"

    clock.advance(1.1)                        # cooldown passed: probe due
    svc.tenants["t"].cost.observe(1.0)        # price every tier off-deadline
    r = svc.search_sync("t", queries, K, deadline_s=0.01)
    assert r.shed_reason == "deadline"        # shed BEFORE any launch
    assert brk.state == "open"                # NOT wedged in half_open
    assert brk.retry_after(clock.now()) == 0  # probe still on offer

    r = svc.search_sync("t", queries, K, deadline_s=10.0)
    assert r.quality == "exact"               # the probe ran and closed it
    assert brk.state == "closed"


def test_transient_failure_retried_within_deadline(index, queries):
    plan = FaultPlan([LaunchError(at_launches=0)], seed=4)
    svc, _ = make_service(index, faults=plan, breaker_threshold=3)
    r = svc.search_sync("t", queries, K)
    assert r.quality == "exact"               # retry after backoff succeeded
    assert svc.counters["launch_failures"] == 1
    assert r.latency_s > 0                    # the jittered backoff slept


# ---------------------------------------------------------------------------
# Poison containment
# ---------------------------------------------------------------------------

def test_poisoned_query_degrades_only_its_row(index, queries):
    plan = FaultPlan([PoisonQuery(at_submits=0, row=1)], seed=5)
    svc, _ = make_service(index, faults=plan)
    r = svc.search_sync("t", queries, K)
    assert plan.fired("poison")
    assert r.flagged_rows == [1]
    assert r.row_quality[1] == "shed"
    assert (r.ids[1] == -1).all() and np.isinf(r.dists[1]).all()
    # The batchmates are untouched AND still exact vs the oracle.
    ref = np.asarray(oracle(index, queries).ids)
    for i in (0, 2, 3):
        assert r.row_quality[i] == "exact"
        np.testing.assert_array_equal(r.ids[i], ref[i])
    assert r.quality == "exact"               # headline = worst VALID row


def test_poisoned_index_rows_quarantined_at_register():
    idx = make_index(seed=11, n=200)
    bad = np.full((2, D), 0.5, np.float32)
    bad[0, 3] = np.nan
    bad[1, 5] = -1.0                          # shannon domain is x > 0
    bad_ids = idx.insert(bad, auto_compact=False)
    svc, _ = make_service(idx)
    tenant = svc.tenants["t"]
    assert tenant.degraded
    assert sorted(tenant.quarantined) == sorted(bad_ids)
    q = np.random.default_rng(2).random((2, D)).astype(np.float32) + 0.1
    r = svc.search_sync("t", q, K)
    assert r.quality == "exact" and r.tenant_degraded
    assert not np.isin(r.ids, bad_ids).any()  # quarantined ids never surface


# ---------------------------------------------------------------------------
# Snapshot consistency under mutation
# ---------------------------------------------------------------------------

def test_compaction_during_search_is_snapshot_consistent(queries):
    idx = make_index(seed=13, n=200)
    n0 = idx.live_n
    plan = FaultPlan([CompactDuringSearch(at_launches=0, insert_rows=8)],
                     seed=6)
    svc, _ = make_service(idx, faults=plan, record_snapshots=True)
    r = svc.search_sync("t", queries, K)
    assert plan.fired("compact")
    assert idx.live_n == n0 + 8               # the race really happened
    # Results are bit-identical to searching the pre-mutation snapshot
    # with the same final budget (queries.shape[0] == bucket, no padding).
    snap = r.meta["snapshot"]
    assert snap.n == n0
    ref = bp.knn_search_batch(snap, queries, K, r.meta["budget"])
    np.testing.assert_array_equal(r.ids, np.asarray(ref.ids))
    np.testing.assert_array_equal(r.dists, np.asarray(ref.dists))


# ---------------------------------------------------------------------------
# Quality labels are truthful in all four tiers
# ---------------------------------------------------------------------------

def test_quality_exact_matches_oracle(index, queries):
    svc, _ = make_service(index)
    r = svc.search_sync("t", queries, K)
    assert r.quality == "exact"
    np.testing.assert_array_equal(r.ids, np.asarray(oracle(index,
                                                           queries).ids))


def test_quality_approx_labels_approx_pipeline(index, queries):
    svc, _ = make_service(index)
    r = svc.search_sync("t", queries, K, target_recall=0.9)
    assert r.meta["tier_path"][0] == "approx"
    # §8 results must NEVER claim "exact", however complete they look.
    assert r.quality == "approx"
    assert all(q in ("approx", "partial") for q in r.row_quality)


def test_quality_partial_when_deadline_caps_retries(index, queries):
    # The seed data overflows the default budget (the exact tier needs a
    # budget retry); a deadline that affords exactly one launch caps the
    # ladder there, and the overflowed rows must come back "partial".
    stats_probe = bp.knn_batch(bp._as_forest(index), queries, K,
                               return_stats=True)[1]
    assert stats_probe.escalations >= 1       # scenario precondition
    plan = FaultPlan([LatencySpike(SPIKE)], seed=8)
    # exact_margin=1.0: the exact tier is entered as soon as ONE launch
    # fits, so a 1.2-launch deadline admits the first launch and the
    # stop_retry gate then caps the budget ladder after it.
    svc, _ = make_service(index, faults=plan, exact_margin=1.0)
    svc.tenants["t"].cost.observe(SPIKE)      # pre-trained cost model
    r = svc.search_sync("t", queries, K, deadline_s=1.2 * SPIKE)
    assert r.meta["tier_path"] == ["exact"]
    assert r.quality == "partial"             # capped, and says so
    assert any(q == "partial" for q in r.row_quality)
    # Rows still labeled exact really are exact.
    ref = np.asarray(oracle(index, queries).ids)
    for i, q in enumerate(r.row_quality):
        if q == "exact":
            np.testing.assert_array_equal(r.ids[i], ref[i])


def test_quality_shed_is_explicit(index, queries):
    svc, _ = make_service(index)
    svc.tenants["t"].cost.observe(1.0)
    r = svc.search_sync("t", queries, K, deadline_s=0.01)
    assert r.quality == "shed" and r.shed_reason == "deadline"
    assert (r.ids == -1).all() and np.isinf(r.dists).all()
    assert svc.counters["shed"] >= 1


# ---------------------------------------------------------------------------
# Satellite: structured escalation stats + query validation
# ---------------------------------------------------------------------------

def test_knn_batch_returns_structured_stats(index, queries):
    snap = bp._as_forest(index)
    res, stats = bp.knn_batch(snap, queries, K, return_stats=True)
    assert bool(np.asarray(res.exact).all())
    assert stats.escalations >= 1             # this data overflows (above)
    assert stats.budget_final >= bp.default_budget(snap, K)
    assert not stats.escalated_to_scan and not stats.stopped_early

    # stop_retry=True before the first RETRY -> budget-capped partial.
    res2, stats2 = bp.knn_batch(snap, queries, K, stop_retry=lambda: True,
                                return_stats=True)
    assert stats2.stopped_early and stats2.escalations == 0
    assert not bool(np.asarray(res2.exact).all())


def test_validate_queries_names_offending_row(index):
    fam = get_family("shannon")
    q = np.full((3, D), 0.5, np.float32)
    q[2, 4] = np.nan
    with pytest.raises(ValueError, match="row 2"):
        validate_queries(fam, q)
    q[2, 4] = -0.5                            # finite but out of domain
    with pytest.raises(ValueError, match="row 2"):
        validate_queries(fam, q)
    mask = validate_queries(fam, q, mode="mask")
    assert mask.tolist() == [True, True, False]
    with pytest.raises(ValueError, match="row 2"):
        bp.knn_search_batch(index, q, K, 64)


def test_segments_insert_validation_and_quarantine():
    idx = make_index(seed=17, n=200)
    bad = np.full((1, D), 0.5, np.float32)
    bad[0, 0] = np.nan
    with pytest.raises(ValueError, match="insert row 0"):
        idx.insert(bad, validate=True)
    assert idx.find_invalid().size == 0       # the raise kept it out
    (bid,) = idx.insert(bad, validate=False)  # simulated corruption
    assert idx.find_invalid().tolist() == [bid]
    assert idx.quarantine().tolist() == [bid]
    assert idx.find_invalid().size == 0
    assert bid not in idx.live_ids()


def test_validate_rows_mask_matches_family_domain():
    fam = get_family("squared_euclidean")
    rows = np.array([[1.0, -2.0], [np.inf, 0.0]], np.float32)
    mask = validate_rows(fam, rows, mode="mask")
    assert mask.tolist() == [True, False]     # all-reals family: finite only


# ---------------------------------------------------------------------------
# Tiered tenants: warm() + FetchStall containment
# ---------------------------------------------------------------------------

def make_tiered_service(index, *, faults=None, **cfg):
    """A service whose tenant's cold point blocks live in host RAM
    (resident_bytes below the ~38 KB cold footprint at n=400, d=16)."""
    clock = VirtualClock()
    svc = RetrievalService(ServiceConfig(**cfg), clock=clock, faults=faults)
    svc.register_tenant("t", index, resident_bytes=20_000)
    return svc, clock


def test_tiered_tenant_matches_oracle_and_warm_prefills(index, queries):
    svc, _ = make_tiered_service(index)
    store = svc.tenants["t"].tiered
    assert store is not None and not store.is_resident

    out = svc.warm("t", shapes=[(len(queries), K)])
    assert len(out["programs"]) >= 1
    assert out["tiered"]["blocks_cached"] > 0
    assert svc.counters["submitted"] == 0      # warming is accounting-free

    r = svc.search_sync("t", queries, K)
    ref = oracle(index, queries)
    assert r.quality == "exact"
    np.testing.assert_array_equal(r.ids, np.asarray(ref.ids))


def test_fetch_stall_within_timeout_rides_like_latency(index, queries):
    """A slow (but not wedged) cold-block fetch delays the launch without
    breaking results or labels."""
    plan = FaultPlan([FetchStall(0.2, at_launches=0, tenant="t")], seed=11)
    svc, _ = make_tiered_service(index, faults=plan)
    r = svc.search_sync("t", queries, K)
    assert len(plan.fired("fetch_stall")) == 1
    assert r.quality == "exact" and r.latency_s >= 0.2
    np.testing.assert_array_equal(r.ids, np.asarray(oracle(index, queries).ids))


def test_fetch_stall_beyond_timeout_contained_by_retry(index, queries):
    """A wedged fetch surfaces as FetchTimeout; the service charges the
    full wait window, retries, and the retry (no longer stalled) serves
    exact results — no hang, no wedged microbatch."""
    plan = FaultPlan([FetchStall(10.0, at_launches=0, tenant="t")], seed=12)
    svc, clock = make_tiered_service(index, faults=plan)
    r = svc.search_sync("t", queries, K, deadline_s=20.0)
    events = plan.fired("fetch_stall")
    assert len(events) == 1 and "FetchTimeout" in events[0].detail
    assert r.quality == "exact"                # retry succeeded, truthfully
    assert svc.counters["launches"] >= 2       # failed launch + clean retry
    assert r.latency_s >= 5.0                  # the timeout window was paid
    np.testing.assert_array_equal(r.ids, np.asarray(oracle(index, queries).ids))


def test_fetch_stall_noop_on_resident_tenant(index, queries):
    """Fully-resident tenants have no fetch to stall: the fault never
    fires and nothing slows down."""
    plan = FaultPlan([FetchStall(10.0, tenant="t")], seed=13)
    svc, _ = make_service(index, faults=plan)
    assert svc.tenants["t"].tiered is None
    r = svc.search_sync("t", queries, K)
    assert not plan.fired("fetch_stall")
    assert r.quality == "exact"


def test_mesh_and_resident_bytes_are_mutually_exclusive(index):
    svc, _ = make_service(index)
    with pytest.raises(ValueError, match="resident_bytes"):
        svc.register_tenant("x", make_index(seed=3), mesh=(1, 1),
                            resident_bytes=20_000)
