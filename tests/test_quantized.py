"""Int8 storage tier: quantized-vs-fp32 parity across every search path.

The tier's contract (docs/quantization.md): an int8 index's point set IS
its decoded rows ``rows_view()``, and exact-mode search over the int8 tier
returns the EXACT kNN of that point set — identical ids (recall@k = 1.0)
to an fp32 BallForest built over the same decoded rows, in ``knn_search``,
``knn_search_batch``, ``distributed_knn``, and a mutated
``SegmentedForest``, for all five Bregman families.  The lossy part is the
storage round-off (bounded, applied once at ingest); the search pipeline
itself loses nothing because the Alg.-4 bounds are inflated by the stat
rounding slack and the corner stats are directed-rounded.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core import quantize as qz
from repro.core import search
from repro.core.bregman import family_names, get_family
from repro.core.index import (build_index, pad_points, point_fields,
                              slice_points, tombstone_rows)
from repro.core.segments import build_segmented_index

K = 7


def _dataset(family, n=500, d=24, q=6, seed=0):
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(seed), (n, d), scale=1.0))
    queries = np.asarray(
        fam.sample(jax.random.PRNGKey(seed + 1), (q, d), scale=1.0))
    return data, queries, fam


def _decoded_oracle(view, queries, k, fam):
    """Brute-force kNN over the LIVE decoded rows -> original ids per query."""
    xhat = np.asarray(view.rows_view())
    pid = np.asarray(view.point_ids)
    live = pid >= 0
    bf_ids, bf_dists = search.brute_force_knn(xhat[live], queries, k, fam)
    return pid[live][np.asarray(bf_ids)], np.asarray(bf_dists)


def _assert_same_neighbors(ids, oracle_ids, dists=None, oracle_dists=None):
    for qi in range(oracle_ids.shape[0]):
        assert (set(np.asarray(ids[qi]).tolist())
                == set(oracle_ids[qi].tolist())), f"query {qi}"
        if dists is not None:
            np.testing.assert_allclose(
                np.sort(np.asarray(dists[qi])), np.sort(oracle_dists[qi]),
                rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Quantizer properties (the admissibility preconditions)
# ---------------------------------------------------------------------------

def test_stat_quantizer_error_bounds():
    rng = np.random.default_rng(0)
    v = jnp.asarray((rng.normal(size=(512, 8))
                     * rng.lognormal(size=(512, 1))).astype(np.float32))
    c, s, z = qz.quantize_stats(v, "nearest")
    err = np.abs(np.asarray(qz.dequantize_stats(c, s, z)) - np.asarray(v))
    # the |err| <= scale/2 bound _qb_slack relies on (+ float fuzz headroom)
    assert (err <= qz.UB_SLACK * np.asarray(s)[:, None] + 1e-6).all()

    c, s, z = qz.quantize_stats(v, "floor")
    assert (np.asarray(qz.dequantize_stats(c, s, z))
            <= np.asarray(v) + 1e-5).all()
    c, s, z = qz.quantize_stats(v, "ceil")
    assert (np.asarray(qz.dequantize_stats(c, s, z))
            >= np.asarray(v) - 1e-5).all()


def test_constant_rows_quantize_exactly():
    v = jnp.full((4, 6), 3.25, jnp.float32)
    c, s, z = qz.quantize_stats(v)
    assert np.all(np.asarray(s) == 0.0)
    np.testing.assert_array_equal(np.asarray(qz.dequantize_stats(c, s, z)),
                                  np.asarray(v))


@pytest.mark.parametrize("family", ["itakura_saito", "shannon"])
def test_dequantized_rows_stay_in_domain(family):
    fam = get_family(family)
    # rows hugging the domain boundary, where rounding could cross zero
    x = jnp.asarray(np.random.default_rng(0).uniform(
        1e-7, 2.0, size=(64, 16)).astype(np.float32))
    codes, s, z = qz.quantize_rows(x)
    xhat = np.asarray(qz.dequantize_rows(codes, s, z, fam))
    assert (xhat > 0).all()
    assert np.isfinite(np.asarray(fam.phi(jnp.asarray(xhat)))).all()


# ---------------------------------------------------------------------------
# Kernel parity (deterministic — no hypothesis gate; test_kernels.py holds
# the property sweep).  Pallas interpret vs jnp ref vs the direct math.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,q", [(64, 8, 1), (100, 28, 3), (7, 5, 2)])
def test_ub_quant_kernel_matches_ref_and_tracks_fp32(n, m, q):
    from repro.kernels import ref
    from repro.kernels.bregman_ub import bregman_ub_matrix_quant
    rng = np.random.default_rng(0)
    alpha = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    sg = jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32)
    a_q, a_s, a_z = qz.quantize_stats(alpha)
    g_q, g_s, g_z = qz.quantize_stats(sg)
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.asarray(np.abs(rng.normal(size=(q, m))), jnp.float32)
    got = bregman_ub_matrix_quant(a_q, a_s, a_z, g_q, g_s, g_z,
                                  jnp.sum(qc, -1), sd,
                                  block_n=32, block_q=4, interpret=True)
    want = ref.bregman_ub_matrix_quant(a_q, a_s, a_z, g_q, g_s, g_z, qc, sd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # The decoded-codes matrix tracks the fp32 matrix within the stat
    # rounding: alpha contributes scale/2 per subspace (M terms), the
    # Cauchy term scale/2 * sd_i per subspace — the row total of the
    # per-subspace slack _qb_slack spreads over the Alg.-4 components.
    full = ref.bregman_ub_matrix(alpha, sg, qc, sd)
    slack = (m * np.asarray(a_s)[:, None]
             + np.asarray(g_s)[:, None] * np.asarray(jnp.sum(sd, -1))[None, :])
    assert (np.abs(np.asarray(want) - np.asarray(full))
            <= 0.5 * slack + 1e-4).all()


@pytest.mark.parametrize("family", family_names())
@pytest.mark.parametrize("qn,b,d", [(1, 16, 24), (3, 33, 130)])
def test_refine_quant_kernel_parity(family, qn, b, d):
    """Fused dequantize+refine == ref == exact D_f over the decoded rows."""
    from repro.kernels import ref
    from repro.kernels.bregman_dist import bregman_refine_batch_quant
    fam = get_family(family)
    rows = fam.sample(jax.random.PRNGKey(1), (qn * b, d)).reshape(qn, b, d)
    codes, scale, zp = qz.quantize_rows(rows.reshape(-1, d))
    codes = codes.reshape(qn, b, d)
    scale, zp = scale.reshape(qn, b), zp.reshape(qn, b)
    ys = fam.sample(jax.random.PRNGKey(2), (qn, d))
    grad = fam.phi_prime(ys)
    c_y = jnp.sum(ys * grad, -1) - fam.f(ys)
    got = bregman_refine_batch_quant(codes, scale, zp, grad, c_y, family,
                                     block_b=16, block_d=64, interpret=True)
    want = ref.bregman_refine_batch_quant(codes, scale, zp, grad, c_y, family)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # exact distances over the decoded point set (the tier's contract)
    xhat = qz.dequantize_rows(
        codes.reshape(-1, d), scale.reshape(-1), zp.reshape(-1),
        fam).reshape(qn, b, d)
    direct = jax.vmap(lambda x, y: fam.distance(x, y[None]))(xhat, ys)
    np.testing.assert_allclose(np.asarray(got), np.asarray(direct),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,m,q", [(64, 8, 1), (100, 28, 3), (7, 5, 2)])
def test_prune_quant_kernel_matches_ref_and_is_conservative(n, m, q):
    """Fused int8 admit mask == ref; decoded-corner admits ⊇ fp32 admits."""
    from repro.kernels import ref
    from repro.kernels.bregman_prune import bregman_prune_mask_quant
    rng = np.random.default_rng(0)
    amin = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    gmax = jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32)
    a_q, a_s, a_z = qz.quantize_stats(amin, "floor")
    g_q, g_s, g_z = qz.quantize_stats(gmax, "ceil")
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.asarray(np.abs(rng.normal(size=(q, m))), jnp.float32)
    qb = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    got = bregman_prune_mask_quant(a_q, a_s, a_z, g_q, g_s, g_z, qc, sd, qb,
                                   block_n=32, block_q=4, interpret=True)
    want = ref.bregman_prune_mask_quant(a_q, a_s, a_z, g_q, g_s, g_z,
                                        qc, sd, qb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Directed rounding makes the decoded test CONSERVATIVE: every pair
    # the true-corner test admits, the decoded-corner test admits too.
    full = ref.bregman_prune_mask(amin, gmax, qc, sd, qb)
    assert (np.asarray(got) >= np.asarray(full)).all()


# ---------------------------------------------------------------------------
# Parity: single-query, batched, approximate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", family_names())
def test_quantized_matches_fp32_index_over_decoded_points(family):
    """recall@k == 1.0 vs the fp32 index on the same stored point set."""
    data, queries, fam = _dataset(family)
    qidx = build_index(data, family, m=4, num_clusters=16, seed=0,
                       quantize=True)
    assert qidx.storage == "int8" and qidx.data.dtype == jnp.int8

    # fp32 index over the decoded rows, restored to ORIGINAL id order so
    # both builds cluster the same input with the same seed.
    xhat = np.asarray(qidx.rows_view())
    restore = np.argsort(np.asarray(qidx.point_ids))
    fidx = build_index(xhat[restore], family, m=4, num_clusters=16, seed=0)

    res_q = search.knn_batch(qidx, queries, K)
    res_f = search.knn_batch(fidx, queries, K)
    assert bool(jnp.all(res_q.exact)) and bool(jnp.all(res_f.exact))
    _assert_same_neighbors(res_q.ids, np.asarray(res_f.ids),
                           res_q.dists, np.asarray(res_f.dists))

    oracle_ids, oracle_dists = _decoded_oracle(qidx, queries, K, fam)
    _assert_same_neighbors(res_q.ids, oracle_ids, res_q.dists, oracle_dists)

    # single-query path agrees with the batched path
    for qi in range(queries.shape[0]):
        single = search.knn(qidx, queries[qi], K)
        assert bool(single.exact)
        assert (set(np.asarray(single.ids).tolist())
                == set(oracle_ids[qi].tolist()))


@pytest.mark.parametrize("family", ["squared_euclidean", "burg"])
def test_quantized_approx_mode_runs_and_single_matches_batch(family):
    data, queries, fam = _dataset(family, n=700, seed=3)
    qidx = build_index(data, family, m=4, num_clusters=16, seed=0,
                       quantize=True)
    res = search.knn_batch(qidx, queries, K, approx_p=0.8)
    for qi in range(queries.shape[0]):
        single = search.knn(qidx, queries[qi], K, approx_p=0.8)
        assert (int(res.num_candidates[qi]) == int(single.num_candidates))
        if bool(res.exact[qi]) and bool(single.exact):
            assert (set(np.asarray(res.ids[qi]).tolist())
                    == set(np.asarray(single.ids).tolist()))


def test_quantized_streaming_blocks_match_single_shot():
    data, queries, fam = _dataset("exponential", n=600)
    qidx = build_index(data, "exponential", m=4, num_clusters=16, seed=0,
                       quantize=True)
    full = search.knn_batch(qidx, queries, 5)
    stream = search.knn_batch(qidx, queries, 5, block_rows=64)
    np.testing.assert_array_equal(np.asarray(full.ids),
                                  np.asarray(stream.ids))
    np.testing.assert_array_equal(np.asarray(full.num_candidates),
                                  np.asarray(stream.num_candidates))


def test_quantized_budget_escalation_stays_exact():
    data, queries, fam = _dataset("squared_euclidean", n=400)
    qidx = build_index(data, "squared_euclidean", m=4, num_clusters=8,
                       seed=0, quantize=True)
    res = search.knn_batch(qidx, queries, 5, budget=8, max_doublings=0)
    assert bool(jnp.all(res.exact))
    oracle_ids, _ = _decoded_oracle(qidx, queries, 5, fam)
    _assert_same_neighbors(res.ids, oracle_ids)


# ---------------------------------------------------------------------------
# Parity: distributed + segmented
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", family_names())
def test_quantized_distributed_matches_batched(family):
    from repro.dist.knn import distributed_knn, shard_index
    data, queries, fam = _dataset(family, n=300, d=16, q=4)
    qidx = build_index(data, family, m=4, num_clusters=8, seed=0,
                       quantize=True)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    sharded = shard_index(qidx, mesh, axis="data")
    res = distributed_knn(sharded, queries, family=family, k=5, budget=64)
    local = search.knn_batch(qidx, queries, 5)
    assert bool(jnp.all(res.exact))
    np.testing.assert_array_equal(
        np.sort(np.asarray(res.ids), axis=1),
        np.sort(np.asarray(local.ids), axis=1))
    np.testing.assert_allclose(
        np.sort(np.asarray(res.dists), axis=1),
        np.sort(np.asarray(local.dists), axis=1), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("family", family_names())
def test_quantized_segmented_mutations_stay_exact(family):
    data, queries, fam = _dataset(family, n=400, seed=2)
    sf = build_segmented_index(data, family, m=4, num_clusters=16,
                               quantize=True)
    assert sf.storage == "int8"
    extra = np.asarray(
        fam.sample(jax.random.PRNGKey(9), (50, data.shape[1]), scale=1.0))
    sf.insert(extra, auto_compact=False)
    sf.delete(np.arange(0, 30), auto_compact=False)

    res = search.knn_batch(sf, queries, K)
    assert bool(jnp.all(res.exact))
    oracle_ids, oracle_dists = _decoded_oracle(sf.view(), queries, K, fam)
    _assert_same_neighbors(res.ids, oracle_ids, res.dists, oracle_dists)
    # deleted ids can never surface
    assert not (np.asarray(res.ids) < 30).any()


def test_quantized_merge_compaction_preserves_points_bit_exactly():
    data, queries, fam = _dataset("squared_euclidean", n=400)
    sf = build_segmented_index(data, "squared_euclidean", m=4,
                               num_clusters=16, quantize=True)
    extra = np.asarray(fam.sample(jax.random.PRNGKey(9), (40, 24), scale=1.0))
    sf.insert(extra, auto_compact=False)
    sf.delete(np.arange(10), auto_compact=False)
    view = sf.view()
    before = {int(i): row for i, row in
              zip(np.asarray(view.point_ids), np.asarray(view.rows_view()),
                  strict=True)
              if i >= 0}
    oracle_ids, _ = _decoded_oracle(view, queries, K, fam)

    assert sf.compact(mode="merge") == "merge"
    view2 = sf.view()
    for i, row in zip(np.asarray(view2.point_ids),
                      np.asarray(view2.rows_view()), strict=True):
        assert np.array_equal(before[int(i)], row)
    res = search.knn_batch(sf, queries, K)
    _assert_same_neighbors(res.ids, oracle_ids)


def test_quantized_rebuild_compaction_stays_exact_over_new_codes():
    data, queries, fam = _dataset("itakura_saito", n=300)
    sf = build_segmented_index(data, "itakura_saito", m=4, num_clusters=16,
                               quantize=True)
    sf.delete(np.arange(20), auto_compact=False)
    assert sf.compact(mode="rebuild") == "rebuild"
    assert sf.storage == "int8"
    res = search.knn_batch(sf, queries, K)
    assert bool(jnp.all(res.exact))
    oracle_ids, oracle_dists = _decoded_oracle(sf.view(), queries, K, fam)
    _assert_same_neighbors(res.ids, oracle_ids, res.dists, oracle_dists)


# ---------------------------------------------------------------------------
# Point-major plumbing: pad / slice / tombstone with the quant fields
# ---------------------------------------------------------------------------

def test_quantized_pad_slice_tombstone_roundtrip():
    data, queries, fam = _dataset("squared_euclidean", n=100)
    qidx = build_index(data, "squared_euclidean", m=4, num_clusters=8,
                       seed=0, quantize=True)
    assert len(point_fields(qidx)) == len(point_fields("f32")) + 10

    padded = pad_points(qidx, 64)
    assert padded.n == 128
    assert padded.data.dtype == jnp.int8
    # padded rows are search-inert and decode to the domain-safe ones-row
    np.testing.assert_array_equal(
        np.asarray(padded.point_ids[100:]), -1)
    np.testing.assert_array_equal(
        np.asarray(padded.rows_view())[100:], 1.0)
    back = slice_points(padded, 0, 100)
    for f in point_fields(qidx):
        np.testing.assert_array_equal(np.asarray(getattr(back, f)),
                                      np.asarray(getattr(qidx, f)))

    dead = np.zeros(100, bool)
    dead[:7] = True
    stoned = tombstone_rows(qidx, jnp.asarray(dead))
    res = search.knn_batch(stoned, queries, 5)
    gone = set(np.asarray(qidx.point_ids)[:7].tolist())
    assert not (np.isin(np.asarray(res.ids), list(gone))).any()
