"""Measured-recall calibration contract (core/calibrate.py).

The curve's promise — ``target_recall`` resolves to the smallest fitted
shrink whose MEASURED recall meets the target — is pinned here at three
layers: the fitted curve itself (monotone, honest about p=1), the search
entry points (single-host, int8, 1x1-mesh distributed), and the serving
path (approx responses carry ``expected_recall``; the microbatch key
keeps per-tenant resolution separate).

Inversion semantics are tested on HAND-CRAFTED curves: at this repo's
test scale the Theorem-3 prune admits essentially every row, so fitted
curves truthfully measure recall 1.0 at every p — correct, but useless
for exercising the non-trivial resolve() branches.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import search
from repro.core.bregman import family_names, get_family
from repro.core.calibrate import (
    RecallCalibration,
    ensure_calibration,
    resolve_p_guarantee,
)
from repro.core.index import build_index
from repro.core.segments import build_segmented_index
from repro.dist import knn as dknn
from repro.dist.sharding import make_mesh
from repro.serve.faults import VirtualClock
from repro.serve.retrieval import RetrievalService, ServiceConfig

FAMILIES = family_names()
N, D, M, K = 300, 16, 4, 5
GRID = (0.0, 0.5, 0.8, 1.0)     # small fit grid — p is traced, one compile


def _data(family, n=N, seed=0, d=D):
    fam = get_family(family)
    return np.asarray(fam.sample(jax.random.PRNGKey(seed), (n, d)))


def _queries(family, num=6, seed=1):
    return _data(family, n=num, seed=seed)


def _calibrated(family, quantize=False):
    idx = build_index(_data(family), family, m=M, num_clusters=16,
                      quantize=quantize, seed=0)
    return ensure_calibration(idx, k=K, num_queries=24, p_grid=GRID)


# ---------------------------------------------------------------------------
# The fitted curve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
def test_fitted_curve_monotone(family):
    cal = _calibrated(family).calibration
    assert cal is not None and cal.k == K
    p = np.asarray(cal.p_grid)
    r = np.asarray(cal.recall_grid)
    assert p.shape == r.shape and p[-1] == 1.0
    assert np.all(np.diff(p) > 0)
    assert np.all(np.diff(r) >= 0)          # isotonic by construction
    assert np.all((r >= 0) & (r <= 1))
    assert r[-1] == 1.0                     # p=1 disables the shrink: exact


def test_build_index_calibrate_flag_attaches_curve():
    idx = build_index(_data("shannon"), "shannon", m=M, calibrate=True,
                      calibrate_k=K, calibration_queries=24, seed=0)
    assert idx.calibration is not None and idx.calibration.k == K


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("quantize", [False, True],
                         ids=["fp32", "int8"])
def test_measured_recall_meets_target(family, quantize):
    """target_recall=0.9 must deliver measured recall@k within tolerance
    of the curve's promise, on both storage tiers, for every family."""
    idx = _calibrated(family, quantize=quantize)
    qs = _queries(family)
    p, expected = resolve_p_guarantee(idx, 0.9)
    assert expected is not None and (expected >= 0.9 or p == 1.0)
    exact = search.knn_batch(idx, qs, K)
    res = search.knn_batch(idx, qs, K, target_recall=0.9)
    recs = [len(set(np.asarray(res.ids[i]).tolist())
                & set(np.asarray(exact.ids[i]).tolist())) / K
            for i in range(qs.shape[0])]
    assert float(np.mean(recs)) >= expected - 0.15


# ---------------------------------------------------------------------------
# resolve() semantics (hand-crafted curves)
# ---------------------------------------------------------------------------

def _curve(recall_grid, p_grid=(0.0, 0.5, 1.0)):
    return RecallCalibration(p_grid=tuple(p_grid),
                             recall_grid=tuple(recall_grid),
                             k=K, num_queries=8, seed=0)


def test_resolve_is_conservative():
    """Smallest fitted p whose MEASURED recall >= target — never an
    optimistic interpolation between grid points."""
    cal = _curve((0.4, 0.8, 1.0))
    assert cal.resolve(0.3) == (0.0, 0.4)
    assert cal.resolve(0.4) == (0.0, 0.4)
    assert cal.resolve(0.7) == (0.5, 0.8)   # 0.41..0.8 all round UP to p=0.5
    assert cal.resolve(0.9) == (1.0, 1.0)
    assert cal.resolve(1.0) == (1.0, 1.0)


def test_resolve_unreachable_target_is_honest():
    """A target above everything measured: run exact-mode p=1 and report
    the measured ceiling, not the requested number."""
    cal = _curve((0.2, 0.5, 0.9))
    p, expected = cal.resolve(0.95)
    assert p == 1.0 and expected == 0.9


def test_resolve_rejects_out_of_range_targets():
    cal = _curve((0.4, 0.8, 1.0))
    for bad in (-0.1, 1.1):
        with pytest.raises(ValueError):
            cal.resolve(bad)


def test_expected_recall_interpolates():
    cal = _curve((0.4, 0.8, 1.0))
    assert cal.expected_recall(0.25) == pytest.approx(0.6)
    assert cal.expected_recall(1.0) == pytest.approx(1.0)


def test_uncalibrated_fallback_is_historical_behavior():
    """No curve: target_recall degrades to p=target (pre-calibration
    semantics) with no expected-recall claim, bit-identical to passing
    approx_p directly."""
    idx = build_index(_data("burg"), "burg", m=M, seed=0)
    assert idx.calibration is None
    assert resolve_p_guarantee(idx, 0.9) == (0.9, None)
    qs = _queries("burg")
    a = search.knn_batch(idx, qs, K, target_recall=0.9)
    b = search.knn_batch(idx, qs, K, approx_p=0.9)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


def test_exclusive_knob_validation():
    idx = _calibrated("shannon")
    qs = _queries("shannon")
    with pytest.raises(ValueError):
        search.knn_batch(idx, qs, K, approx_p=0.9, target_recall=0.9)
    with pytest.raises(ValueError):
        search.knn_search_batch_approx(idx, jnp.asarray(qs), K, N)
    with pytest.raises(ValueError):
        search.knn_search_batch_approx(idx, jnp.asarray(qs), K, N,
                                       p_guarantee=0.9, target_recall=0.9)


# ---------------------------------------------------------------------------
# Lifecycle: insert / tombstone leave the curve, compact refits it
# ---------------------------------------------------------------------------

def test_curve_survives_mutations_and_compact_refits():
    sf = build_segmented_index(_data("shannon", n=200), "shannon", m=M)
    sf = ensure_calibration(sf, k=K, num_queries=16, p_grid=GRID)
    fitted = sf.calibration
    assert fitted is not None

    ids = sf.insert(_data("shannon", n=40, seed=3), auto_compact=False)
    assert sf.calibration is fitted         # stale-but-measured: no refit
    sf.delete(ids[:10], auto_compact=False)
    assert sf.calibration is fitted
    assert sf.view().calibration is fitted  # snapshot carries it too

    sf.compact("merge")
    assert sf.calibration is not None and sf.calibration is not fitted
    assert sf.calibration.k == K            # refit with the stored params
    assert tuple(sf.calibration.p_grid) == GRID

    sf2 = build_segmented_index(_data("burg", n=60), "burg", m=M)
    sf2 = ensure_calibration(sf2, k=K, num_queries=8, p_grid=GRID)
    sf2.delete(np.arange(60 - K + 1), auto_compact=False)
    sf2.compact("merge")                    # live_n < k: nothing measurable
    assert sf2.calibration is None


def test_uncalibrated_compact_stays_uncalibrated():
    sf = build_segmented_index(_data("exponential", n=80), "exponential",
                               m=M)
    sf.insert(_data("exponential", n=10, seed=2), auto_compact=False)
    sf.compact("merge")
    assert sf.calibration is None           # no surprise background fits


# ---------------------------------------------------------------------------
# 1x1-mesh distributed parity
# ---------------------------------------------------------------------------

def test_dist_1x1_parity_with_target_recall():
    """distributed_knn(target_recall=...) on a 1-device mesh must match
    the single-host calibrated path bit-for-bit: same curve, same
    resolved p, same SPMD-vs-fused numerics (dist/knn.py contract)."""
    mesh = make_mesh((1,), ("data",))
    forest = _calibrated("itakura_saito")
    qs = _queries("itakura_saito")
    sharded = dknn.shard_index(forest, mesh)
    assert sharded.forest.calibration is not None   # survives sharding
    yv = dknn.query_subview(forest.partition, jnp.asarray(qs))
    res = dknn.distributed_knn(sharded, yv, family="itakura_saito", k=K,
                               budget=N, mesh=mesh, max_doublings=0,
                               target_recall=0.9)
    ref = search.knn_search_batch_approx(forest, jnp.asarray(qs), K, N,
                                         target_recall=0.9)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))
    with pytest.raises(ValueError):
        dknn.distributed_knn(sharded, yv, family="itakura_saito", k=K,
                             budget=N, mesh=mesh, approx_p=0.9,
                             target_recall=0.9)


# ---------------------------------------------------------------------------
# Serving path
# ---------------------------------------------------------------------------

def _service(**cfg):
    return RetrievalService(ServiceConfig(**cfg), clock=VirtualClock())


def test_service_approx_reports_expected_recall():
    svc = _service()
    sf = build_segmented_index(_data("shannon", n=200), "shannon", m=M)
    svc.register_tenant("t", sf, calibrate=True, calibrate_k=K)
    assert sf.calibration is not None       # register fit it in place

    r = svc.search_sync("t", _queries("shannon"), K, target_recall=0.9)
    assert r.quality == "approx"
    assert r.expected_recall is not None and 0.0 <= r.expected_recall <= 1.0
    assert r.meta["expected_recall"] == r.expected_recall
    p, expected = resolve_p_guarantee(sf.view(), 0.9)
    assert r.meta["p_guarantee"] == p and r.expected_recall == expected

    # Exact-tier responses claim nothing: recall is 1.0 by construction.
    r = svc.search_sync("t", _queries("shannon"), K)
    assert r.quality == "exact" and r.expected_recall is None


def test_service_uncalibrated_approx_reports_nothing():
    svc = _service()
    svc.register_tenant("t", build_segmented_index(
        _data("shannon", n=200), "shannon", m=M))
    r = svc.search_sync("t", _queries("shannon"), K, target_recall=0.9)
    assert r.quality == "approx" and r.expected_recall is None
    assert r.meta["p_guarantee"] == 0.9     # fallback: p = target


def test_microbatch_key_separates_divergent_tenants():
    """Two tenants sharing target_recall=0.9 resolve to DIFFERENT shrink
    levels through their own curves — the tenant component of the
    microbatch key in step() is load-bearing for this, not just for
    isolation (see the comment there)."""
    weak = _curve((0.2, 0.9, 1.0))          # needs p=0.5 to hit 0.9
    strong = _curve((0.95, 0.99, 1.0))      # already at 0.95 with p=0.0
    svc = _service()
    for name, cal in (("weak", weak), ("strong", strong)):
        idx = build_index(_data("shannon", seed=hash(name) % 7), "shannon",
                          m=M, seed=0)
        svc.register_tenant(name, dataclasses.replace(idx, calibration=cal))

    qs = _queries("shannon")
    t1 = svc.submit("weak", qs, K, target_recall=0.9)
    t2 = svc.submit("strong", qs, K, target_recall=0.9)
    while not (t1.done and t2.done):
        svc.step()
    assert t1.response.meta["p_guarantee"] == 0.5
    assert t1.response.expected_recall == 0.9
    assert t2.response.meta["p_guarantee"] == 0.0
    assert t2.response.expected_recall == 0.95
