"""Theorems 1-3 + transforms: correctness and property tests."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bounds
from repro.core.bregman import get_family, family_names
from repro.core.transform import make_partition, p_transform, q_transform

FAMILIES = family_names()


def _sample(fam, key, shape, scale=1.0):
    return np.asarray(fam.sample(jax.random.PRNGKey(key), shape, scale))


@pytest.mark.parametrize("family", FAMILIES)
def test_distance_nonnegative_and_zero_at_identity(family):
    fam = get_family(family)
    x = _sample(fam, 0, (64, 16))
    y = _sample(fam, 1, (16,))
    d = np.asarray(fam.distance(jnp.asarray(x), jnp.asarray(y)[None]))
    assert np.all(d >= -1e-4)
    d_self = np.asarray(fam.distance(jnp.asarray(x), jnp.asarray(x)))
    np.testing.assert_allclose(d_self, 0.0, atol=1e-4)


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("m", [1, 3, 4, 16])
def test_theorem_1_2_upper_bound(family, m):
    """UB from partitioned tuples dominates the true Bregman distance."""
    fam = get_family(family)
    d = 16
    x = _sample(fam, 2, (128, d))
    y = _sample(fam, 3, (d,))
    part = make_partition(d, m)
    p = p_transform(jnp.asarray(x), part, fam)
    q = q_transform(jnp.asarray(y), part, fam)
    q1 = {k: v[None] for k, v in q.items() if v.ndim == 1}
    ub = np.asarray(jnp.sum(bounds.ub_components(p, q1), -1))
    lb = np.asarray(jnp.sum(bounds.lb_components(p, q1), -1))
    dist = np.asarray(fam.distance(jnp.asarray(x), jnp.asarray(y)[None]))
    assert np.all(ub >= dist - 1e-3 * np.maximum(1, np.abs(dist)))
    assert np.all(lb <= dist + 1e-3 * np.maximum(1, np.abs(dist)))


@pytest.mark.parametrize("family", FAMILIES)
def test_partition_sums_match_full_distance(family):
    """Separability: sum of subspace distances == full distance."""
    fam = get_family(family)
    d, m = 20, 6  # non-divisible -> exercises padding masks
    x = _sample(fam, 4, (8, d))
    y = _sample(fam, 5, (d,))
    part = make_partition(d, m)
    xs = part.gather(jnp.asarray(x))
    ys = part.gather(jnp.asarray(y))
    mask = part.subspace_mask()
    per_sub = fam.distance_masked(xs, ys[None], mask[None])  # (8, M)
    total = np.asarray(jnp.sum(per_sub, -1))
    full = np.asarray(fam.distance(jnp.asarray(x), jnp.asarray(y)[None]))
    np.testing.assert_allclose(total, full, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("family", FAMILIES)
def test_refine_distance_matches_direct(family):
    fam = get_family(family)
    d = 24
    x = jnp.asarray(_sample(fam, 6, (32, d)))
    y = jnp.asarray(_sample(fam, 7, (d,)))
    q = bounds.query_refine_constants(y, fam)
    got = np.asarray(bounds.refine_distance(x, q, fam))
    want = np.asarray(fam.distance(x, y[None]))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_qb_determine_structure():
    fam = get_family("squared_euclidean")
    d, m, k = 12, 4, 5
    x = jnp.asarray(_sample(fam, 8, (200, d)))
    y = jnp.asarray(_sample(fam, 9, (d,)))
    part = make_partition(d, m)
    p = p_transform(x, part, fam)
    q = q_transform(y, part, fam)
    out = bounds.qb_determine(p, q, k)
    # tau equals the sum of its per-subspace components
    np.testing.assert_allclose(float(jnp.sum(out["qb"])), float(out["tau"]),
                               rtol=1e-5)
    # tau is the kth smallest total
    totals = np.sort(np.asarray(bounds.ub_total(
        p, {kk: vv[None] for kk, vv in q.items() if vv.ndim == 1})))
    np.testing.assert_allclose(float(out["tau"]), totals[k - 1], rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    family=st.sampled_from(FAMILIES),
    d=st.integers(2, 32),
    m=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_cauchy_bound_holds(family, d, m, seed):
    """Hypothesis: for random valid data, UB >= D_f >= LB >= 0-side holds."""
    m = min(m, d)
    fam = get_family(family)
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = fam.sample(k1, (16, d), scale=1.5)
    y = fam.sample(k2, (d,), scale=1.5)
    part = make_partition(d, m)
    p = p_transform(x, part, fam)
    q = q_transform(y, part, fam)
    q1 = {k: v[None] for k, v in q.items() if v.ndim == 1}
    ub = np.asarray(jnp.sum(bounds.ub_components(p, q1), -1))
    dist = np.asarray(fam.distance(x, y[None]))
    tol = 1e-3 * np.maximum(1.0, np.abs(dist)) + 1e-3
    assert np.all(ub >= dist - tol), (family, d, m)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(2, 40),
    m=st.integers(1, 12),
)
def test_property_partition_covers_all_dims(d, m):
    m = min(m, d)
    part = make_partition(d, m)
    covered = part.idx.reshape(-1)[part.mask.reshape(-1) > 0]
    assert sorted(covered.tolist()) == list(range(d))
    assert part.mask.sum() == d
