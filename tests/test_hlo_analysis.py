"""HLO analyzer validation: trip-count recovery, FLOP parity with XLA's
cost model on unrolled modules, and collective extraction (subprocess with
a multi-device host platform)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as ha


def _matmul_flops(n=256, k=512, m=512):
    return 2.0 * n * k * m


def test_unrolled_matches_cost_analysis():
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)

    def f(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    compiled = jax.jit(f).lower(x, w).compile()
    ours = ha.analyze_text(compiled.as_text())
    xla = compiled.cost_analysis()
    assert ours.flops == pytest.approx(xla["flops"], rel=0.02)
    # 4 matmuls dominate
    assert ours.flops == pytest.approx(4 * _matmul_flops(256, 512, 512),
                                       rel=0.05)


def test_scan_trip_count_multiplied():
    """The whole point: scan bodies must be counted trip_count times."""
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    compiled = jax.jit(f).lower(x, w).compile()
    ours = ha.analyze_text(compiled.as_text())
    xla = compiled.cost_analysis()
    # XLA counts once; we count 8x
    assert xla["flops"] == pytest.approx(_matmul_flops(256, 512, 512), rel=0.05)
    assert ours.flops == pytest.approx(8 * _matmul_flops(256, 512, 512),
                                       rel=0.05)
    assert ours.unknown_loops == 0


def test_nested_scan():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    compiled = jax.jit(f).lower(x, w).compile()
    ours = ha.analyze_text(compiled.as_text())
    assert ours.flops == pytest.approx(15 * 2 * 64 * 128 * 128, rel=0.05)


def test_bytes_nonzero_and_dominated_by_weights():
    w = jax.ShapeDtypeStruct((2048, 2048), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 2048), jnp.float32)
    compiled = jax.jit(lambda x, w: x @ w).lower(x, w).compile()
    ours = ha.analyze_text(compiled.as_text())
    assert ours.bytes >= 4 * 2048 * 2048  # at least the weight bytes


_COLLECTIVE_SCRIPT = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hlo_analysis as ha

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    x = jax.ShapeDtypeStruct((64, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)

    def f(x, w):
        y = x @ w                      # w col-sharded -> y col-sharded
        return jnp.sum(y, axis=-1)     # reduce over sharded dim -> psum

    fn = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                  NamedSharding(mesh, P(None, "model"))),
                 out_shardings=NamedSharding(mesh, P("data")))
    compiled = fn.lower(x, w).compile()
    costs = ha.analyze_text(compiled.as_text())
    print(json.dumps({
        "kinds": sorted(ha.collective_summary(costs)),
        "coll_bytes": costs.collective_bytes,
        "flops": costs.flops,
    }))
""")


def test_collectives_extracted_under_spmd(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c",
                          _COLLECTIVE_SCRIPT % os.path.abspath(src)],
                         capture_output=True, text=True, timeout=240)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["coll_bytes"] > 0, res
    assert any(k in ("all-reduce", "reduce-scatter", "all-gather")
               for k in res["kinds"]), res
    # per-device flops: the 64x512x512 matmul split over 8 devices
    assert res["flops"] == pytest.approx(2 * 64 * 512 * 512 / 8, rel=0.3)
