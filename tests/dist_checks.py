"""Distributed-layer assertions, run under 8 forced host devices.

Invoked by tests/test_distributed.py in a subprocess so the main pytest
session keeps its single-device view (per the dry-run isolation rule).
Exits non-zero on any failure.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.bregman import get_family  # noqa: E402
from repro.core.index import build_index  # noqa: E402
from repro.core import search  # noqa: E402
from repro.dist import knn as dknn  # noqa: E402
from repro.dist.sharding import make_mesh  # noqa: E402
from repro.dist.collective_matmul import (  # noqa: E402
    ag_matmul, ag_matmul_reference, matmul_rs)
from repro.dist.compression import (  # noqa: E402
    compressed_psum_mean, init_ef_state, compressed_grad_allreduce)
from repro.dist.pipeline import pipeline_apply  # noqa: E402


def check_distributed_knn():
    for mesh_shape, axes in [
        ((2, 4), ("data", "model")),
        ((2, 2, 2), ("pod", "data", "model")),
    ]:
        mesh = make_mesh(mesh_shape, axes)
        family = "itakura_saito"
        fam = get_family(family)
        n, d, m, k = 512, 16, 4, 6
        data = np.asarray(fam.sample(jax.random.PRNGKey(0), (n, d)))
        queries = np.asarray(fam.sample(jax.random.PRNGKey(1), (4, d)))
        forest = build_index(data, family, m=m, num_clusters=16, seed=0)
        sharded = dknn.shard_index(forest, mesh)
        y_sub = dknn.query_subview(forest.partition, jnp.asarray(queries))
        ids, dists, exact, ncand = dknn.distributed_knn(
            sharded, y_sub, family=family, k=k, budget=n // 2, mesh=mesh)
        assert bool(jnp.all(exact)), "distributed knn overflowed budget"
        for qi in range(queries.shape[0]):
            ref = search.knn(forest, queries[qi], k)
            np.testing.assert_allclose(
                np.sort(np.asarray(dists[qi])),
                np.sort(np.asarray(ref.dists)), rtol=2e-3, atol=2e-3)
            got_ids = set(np.asarray(ids[qi]).tolist())
            want_ids = set(np.asarray(ref.ids).tolist())
            # allow distance ties to swap ids; distances already matched
            assert len(got_ids & want_ids) >= k - 1, (got_ids, want_ids)
        print(f"  knn ok on mesh {dict(zip(axes, mesh_shape, strict=True))} "
              f"(candidates={np.asarray(ncand).tolist()})")


def check_collective_matmul():
    mesh = make_mesh((8,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16), jnp.float32)

    # the ring loop's output is value-replicated (every chunk visits every
    # device) but that cannot be statically inferred -> check_vma=False
    fused = jax.jit(jax.shard_map(
        lambda xl, w_: ag_matmul(xl, w_, "model"),
        mesh=mesh, in_specs=(P("model"), P()), out_specs=P(),
        check_vma=False))
    ref = jax.jit(jax.shard_map(
        lambda xl, w_: ag_matmul_reference(xl, w_, "model"),
        mesh=mesh, in_specs=(P("model"), P()), out_specs=P(),
        check_vma=False))
    np.testing.assert_allclose(np.asarray(fused(x, w)), np.asarray(ref(x, w)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fused(x, w)), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)

    # reduce-scatter dual: x k-sharded, w k-sharded -> rows scattered
    xk = jax.random.normal(jax.random.PRNGKey(2), (16, 64), jnp.float32)
    wk = jax.random.normal(jax.random.PRNGKey(3), (64, 8), jnp.float32)
    rs = jax.jit(jax.shard_map(
        lambda a, b: matmul_rs(a, b, "model"),
        mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
        out_specs=P("model", None)))
    np.testing.assert_allclose(np.asarray(rs(xk, wk)), np.asarray(xk @ wk),
                               rtol=1e-4, atol=1e-4)
    print("  collective matmul ok")


def check_compression():
    mesh = make_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 128), jnp.float32)

    def body(gl, res):
        return compressed_psum_mean(gl, "data", res)

    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P("data")))
    res = jnp.zeros_like(g)
    mean_est, res = fn(g, res)
    true_mean = jnp.mean(g, axis=0, keepdims=True)
    err0 = float(jnp.max(jnp.abs(mean_est - true_mean)))
    assert err0 < 0.05, err0  # int8 quantization error bound

    # error feedback: the *accumulated* applied update converges to the true
    # mean direction — residual stays bounded, applied sum tracks t * mean.
    applied = jnp.zeros_like(true_mean)
    for _t in range(1, 6):
        mean_est, res = fn(g, res)
        applied = applied + mean_est[:1]
    drift = float(jnp.max(jnp.abs(applied / 5 - true_mean)))
    assert drift < err0 + 1e-6, (drift, err0)
    assert float(jnp.max(jnp.abs(res))) < 0.1
    print(f"  compression ok (one-shot err {err0:.4f}, EF drift {drift:.4f})")

    # tree API smoke
    ef = init_ef_state({"a": g[0], "b": g[0] * 2})
    def tree_body(gl, ef_res):
        means, new_ef = compressed_grad_allreduce(
            {"a": gl, "b": gl * 2}, "data",
            type(ef)(residual={"a": ef_res["a"], "b": ef_res["b"]}))
        return means["a"], new_ef.residual["a"]
    fn2 = jax.jit(jax.shard_map(
        tree_body, mesh=mesh,
        in_specs=(P("data"), {"a": P("data"), "b": P("data")}),
        out_specs=(P("data"), P("data"))))
    m, _ = fn2(g, {"a": jnp.zeros_like(g), "b": jnp.zeros_like(g)})
    np.testing.assert_allclose(np.asarray(m[:1]), np.asarray(true_mean),
                               atol=0.05)


def check_pipeline():
    mesh = make_mesh((4,), ("stage",))
    p, n_micro, dim = 4, 6, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (p, dim, dim), jnp.float32) * 0.3

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, 8, dim))
    got = pipeline_apply(stage_fn, mesh, "stage", ws, xs)
    want = xs
    for s in range(p):
        want = jax.vmap(lambda x, s=s: stage_fn(ws[s], x))(want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("  pipeline ok")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    print("distributed checks on", jax.device_count(), "devices")
    check_collective_matmul()
    check_compression()
    check_pipeline()
    check_distributed_knn()
    print("ALL DISTRIBUTED CHECKS PASSED")
