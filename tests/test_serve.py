"""Serving engine + kNN-LM integration tests (reduced configs, CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.registry import build_model
from repro.serve.engine import Engine, EngineConfig, Request
from repro.serve.knnlm import KNNLMHook, build_datastore


@pytest.fixture(scope="module")
def bundle():
    return build_model(configs.get_reduced("starcoder2-3b"))


@pytest.fixture(scope="module")
def params(bundle):
    return bundle.init(jax.random.PRNGKey(0))


def _req(uid, length, vocab, new=4, seed=0):
    rng = np.random.default_rng(seed + uid)
    return Request(uid=uid, prompt=rng.integers(1, vocab, length),
                   max_new_tokens=new)


def test_engine_serves_batch(bundle, params):
    cfg = EngineConfig(slots=4, max_seq=64, prefill_len=16)
    eng = Engine(bundle, params, cfg)
    for uid in range(6):                      # more requests than slots
        eng.submit(_req(uid, 12, bundle.cfg.vocab_size))
    done = eng.run(max_ticks=100)
    assert len(done) == 6
    for r in done:
        assert len(r.output) == r.max_new_tokens
        assert all(0 <= t < bundle.cfg.vocab_size for t in r.output)


def test_engine_matches_offline_decode(bundle, params):
    """Engine greedy output == straight teacher-forced greedy decode."""
    vocab = bundle.cfg.vocab_size
    prompt = np.arange(1, 13) % vocab
    cfg = EngineConfig(slots=2, max_seq=64, prefill_len=12)
    eng = Engine(bundle, params, cfg)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    done = eng.run(max_ticks=50)
    assert len(done) == 1

    # offline: repeated full forward + argmax (the slow oracle)
    toks = list(prompt)
    out = []
    for _ in range(5):
        batch = {
            "tokens": jnp.asarray([toks], jnp.int32),
            "positions": jnp.arange(len(toks), dtype=jnp.int32)[None],
        }
        hidden, _ = bundle.forward_train(params, batch)
        logits = bundle.logits(params, hidden[:, -1])
        nxt = int(jnp.argmax(logits, -1)[0])
        out.append(nxt)
        toks.append(nxt)
    assert done[0].output == out


def test_engine_slot_isolation(bundle, params):
    """Admitting new requests must not change a running request's output."""
    vocab = bundle.cfg.vocab_size
    prompt = (np.arange(1, 13) * 7) % vocab

    # run A alone
    cfg = EngineConfig(slots=2, max_seq=64, prefill_len=12)
    eng1 = Engine(bundle, params, cfg)
    eng1.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    alone = eng1.run(max_ticks=50)[0].output

    # run A while B and C arrive mid-flight
    eng2 = Engine(bundle, params, cfg)
    eng2.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    eng2.step()
    eng2.submit(_req(1, 12, vocab, new=6, seed=5))
    eng2.step()
    eng2.submit(_req(2, 12, vocab, new=6, seed=9))
    eng2.run(max_ticks=50)
    crowded = next(r for r in eng2.finished if r.uid == 0).output
    assert alone == crowded


def test_knnlm_hook_changes_distribution(bundle, params):
    corpus = np.random.default_rng(0).integers(
        1, bundle.cfg.vocab_size, (4, 24))
    store = build_datastore(bundle, params, corpus, m=4)
    assert store.index.n == 4 * 23
    hook = KNNLMHook(store=store, k=4, lam=0.5)
    logits = jnp.zeros((2, bundle.cfg.vocab_size))
    hidden = jnp.asarray(np.random.default_rng(1).normal(
        size=(2, bundle.cfg.d_model)), jnp.float32)
    out = hook(logits, hidden)
    assert out.shape == logits.shape
    assert not np.allclose(np.asarray(out), np.asarray(logits))
    assert hook.queries_served == 2
    # still a (log-)distribution: logsumexp finite, probs sum to 1
    p = np.asarray(jnp.exp(jax.nn.log_softmax(out, -1)).sum(-1))
    np.testing.assert_allclose(p, 1.0, rtol=1e-4)


def test_knnlm_engine_end_to_end(bundle, params):
    vocab = bundle.cfg.vocab_size
    corpus = np.random.default_rng(0).integers(1, vocab, (4, 24))
    store = build_datastore(bundle, params, corpus, m=4)
    hook = KNNLMHook(store=store, k=4, lam=0.3)
    cfg = EngineConfig(slots=2, max_seq=48, prefill_len=12)
    eng = Engine(bundle, params, cfg, logits_hook=hook)
    eng.submit(_req(0, 12, vocab, new=4))
    done = eng.run(max_ticks=30)
    assert len(done) == 1 and len(done[0].output) == 4
    assert hook.queries_served >= 4


def test_knnlm_hook_routes_through_service(bundle, params):
    """service-routed lookups match the direct path when exact, and a
    shedding service degrades to the pure LM distribution, not an error."""
    from repro.serve.retrieval import RetrievalService, ServiceConfig

    vocab = bundle.cfg.vocab_size
    corpus = np.random.default_rng(0).integers(1, vocab, (4, 24))
    store = build_datastore(bundle, params, corpus, m=4)
    logits = jnp.zeros((2, vocab))
    hidden = jnp.asarray(np.random.default_rng(1).normal(
        size=(2, bundle.cfg.d_model)), jnp.float32)

    direct = KNNLMHook(store=store, k=4, lam=0.5)
    svc = RetrievalService(ServiceConfig())
    routed = KNNLMHook(store=store, k=4, lam=0.5, service=svc,
                       deadline_s=60.0)
    np.testing.assert_allclose(np.asarray(routed(logits, hidden)),
                               np.asarray(direct(logits, hidden)),
                               rtol=1e-5, atol=1e-6)
    assert svc.counters["exact"] >= 1
    assert routed.service_tenant in svc.tenants

    # Hopeless deadline: the service sheds, the hook serves pure LM.
    svc.tenants[routed.service_tenant].cost.observe(10.0)
    routed.deadline_s = 0.001
    out = routed(logits, hidden)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(logits))
    assert svc.counters["shed"] >= 1


def test_knnlm_hook_exposes_escalation_stats(bundle, params):
    corpus = np.random.default_rng(0).integers(
        1, bundle.cfg.vocab_size, (4, 24))
    store = build_datastore(bundle, params, corpus, m=4)
    hook = KNNLMHook(store=store, k=4, lam=0.5)
    hidden = jnp.asarray(np.random.default_rng(1).normal(
        size=(2, bundle.cfg.d_model)), jnp.float32)
    hook(jnp.zeros((2, bundle.cfg.vocab_size)), hidden)
    assert hook.escalations >= 0
    assert hook.budget_final >= 4          # >= k: the launch's real budget
    assert hook.scan_fallbacks == 0
