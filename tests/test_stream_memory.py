"""Memory regression guard for the streamed batched pipeline.

Compiles ``knn_search_batch``'s jit core at a serving-sized shape
(n = 65536, q = 128) against abstract (ShapeDtypeStruct) index arrays —
no data, no k-means — and walks the optimized HLO with
``launch/hlo_analysis`` to assert the historical O(n * q) intermediates
(the (n, q) Theorem-3 bool mask, the (q, n) int32 compaction cumsum)
never come back: no instruction in the compiled module may produce an
(n, q)-sized tensor.  Where the backend exposes a compiled memory
analysis, peak temp-buffer bytes are additionally bounded by a
constant * block_rows * q budget (plus the O(n * M) index-table
reshapes, which scale with the INDEX, not with n * q).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index import ENV_BLOCK_ROWS, BallForest
from repro.core.transform import make_partition
from repro.core import search
from repro.launch import hlo_analysis as ha

N, Q, D, M, C, K = 65536, 128, 32, 8, 64, 8
BUDGET = 256
BLOCK_ROWS = 4096
S = 1024                      # beta sample size (unused by the exact path)


def _forest_spec(n=N, d=D, m=M, c=C):
    """A shape-only fp32 BallForest for aval lowering."""
    part = make_partition(d, m)
    w = part.width
    ne = -(-n // ENV_BLOCK_ROWS)
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    return BallForest(
        family_name="squared_euclidean",
        partition=part,
        num_clusters=c,
        data=sds((n, d), f32),
        point_ids=sds((n,), jnp.int32),
        alpha=sds((n, m), f32),
        sqrt_gamma=sds((n, m), f32),
        assign=sds((n, m), jnp.int32),
        alpha_min=sds((m, c), f32),
        sqrt_gamma_max=sds((m, c), f32),
        counts=sds((m, c), jnp.int32),
        centers=sds((m, c, w), f32),
        beta_samples=sds((S,), f32),
        alpha_min_pt=sds((n, m), f32),
        sqrt_gamma_max_pt=sds((n, m), f32),
        gamma_edges=sds((m, 3), f32),
        env_alpha_min=sds((ne, m), f32),
        env_sqrt_gamma_max=sds((ne, m), f32),
    )


def _forbidden_shapes(n, q):
    return {(n, q), (q, n)}


def _instr_shapes(txt):
    comps, _ = ha.parse_computations(txt)
    for instrs in comps.values():
        for instr in instrs:
            for _, shape in instr.out:
                yield instr, tuple(shape)


def _compile(core_jit, n, q, budget, block_rows):
    forest = _forest_spec(n=n)
    ys = jax.ShapeDtypeStruct((q, D), jnp.float32)
    return core_jit.lower(forest, ys, K, budget, block_rows).compile()


@pytest.fixture(scope="module")
def compiled_stream():
    return _compile(search._knn_search_batch_jit, N, Q, BUDGET, BLOCK_ROWS)


def test_no_point_query_sized_intermediates(compiled_stream):
    """THE guard: nothing in the module is (n, q)-shaped, or n*q-sized."""
    bad = []
    nq = N * Q
    for instr, shape in _instr_shapes(compiled_stream.as_text()):
        numel = int(np.prod(shape)) if shape else 1
        if shape in _forbidden_shapes(N, Q) or numel >= nq:
            bad.append((instr.opcode, shape))
    assert not bad, f"(n, q)-sized intermediates re-materialized: {bad[:5]}"


def test_detector_catches_reference_pipeline():
    """Sanity: the same detector DOES flag the mask/cumsum reference."""
    n, q = 4096, 32
    compiled = _compile(search._knn_search_batch_ref_jit, n, q, 64, n)
    hits = [shape for _, shape in _instr_shapes(compiled.as_text())
            if shape in _forbidden_shapes(n, q)]
    assert hits, "reference path no longer materializes (n, q) — update test"


def test_peak_temp_bytes_bounded(compiled_stream):
    """Peak temps ~ C1 * block_rows * q + C2 * n * M, never ~ n * q.

    The n * M term covers XLA's padded copies of the (n, M) index tables
    the two scans stream (layout copies of the INPUT, scaling with the
    index like the index itself) — the point of the streamed pipeline is
    that nothing scales with n * q.
    """
    try:
        mem = compiled_stream.memory_analysis()
        temp = int(mem.temp_size_in_bytes)
    except (AttributeError, NotImplementedError, TypeError) as e:
        pytest.skip(f"backend exposes no memory_analysis ({e})")
    # Measured 8.9 MB on this container (vs 69.9 MB for the reference
    # pipeline at the same shape); the bound leaves headroom for layout
    # copies across jax/XLA versions while still rejecting any
    # per-pair-scaling intermediate.
    bound = 16 * BLOCK_ROWS * Q * 4 + 6 * N * M * 4
    assert temp <= bound, (
        f"temp bytes {temp} exceed the streaming bound {bound} "
        f"(5-byte-per-pair mask/cumsum would be {5 * N * Q})")
    # and strictly under even a 2-byte-per-pair footprint (the old
    # mask/cumsum pipeline held ~5 bytes per point-query pair)
    assert temp < 2 * N * Q


def test_tiered_stage_a_never_materializes_cold_tiers():
    """Tiered Stage A (filter + bounds + envelope gate over HOT tables)
    compiles with NO n-sized allocation for the cold tiers.

    The TieredPointStore keeps the (n, d) point table and the (n, m)
    per-point corner tables cold (host numpy); Stage A's jit sees them
    only as unused leaves of the hot forest and ``keep_unused=False``
    prunes them, so at compile time the module must contain no (n, d)
    instruction and nothing >= n * d elements.  The hot (n, M) filter
    tables (n * M = 524288 elements here) remain, by design.
    """
    from repro.core import tiered

    forest = _forest_spec()
    ys = jax.ShapeDtypeStruct((Q, D), jnp.float32)
    pg = jax.ShapeDtypeStruct((), jnp.float32)
    compiled = tiered._stage_a_jit.lower(
        forest, ys, K, BLOCK_ROWS, None, pg, False).compile()

    nd = N * D
    bad = [(instr.opcode, shape)
           for instr, shape in _instr_shapes(compiled.as_text())
           if shape == (N, D) or (int(np.prod(shape)) if shape else 1) >= nd]
    assert not bad, f"cold-tier-sized allocations in Stage A: {bad[:5]}"

    # the hot tables themselves do appear — the guard is not vacuous
    hot_sized = [shape for _, shape in _instr_shapes(compiled.as_text())
                 if shape and int(np.prod(shape)) >= N * M]
    assert hot_sized, "no (n, M)-sized hot tables found — shapes changed?"


def test_streamed_results_match_reference_at_compile_shape_small():
    """The compile-shape guard plus a small real-data parity anchor."""
    rng = np.random.default_rng(0)
    data = rng.normal(size=(2048, D)).astype(np.float32)
    from repro.core.index import build_index
    index = build_index(data, "squared_euclidean", m=M, num_clusters=16,
                        seed=0)
    ys = jnp.asarray(rng.normal(size=(16, D)).astype(np.float32))
    res = search.knn_search_batch(index, ys, K, 256, block_rows=512)
    ref = search.knn_search_batch_reference(index, ys, K, 256,
                                            block_rows=512)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))
