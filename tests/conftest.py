import os

# Keep the default device count at 1 for smoke tests/benches (the dry-run
# sets its own XLA_FLAGS in a fresh process — see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

import numpy as np
import pytest


def pytest_configure(config):
    # Register the `timeout` mark so the suite runs warning-free without
    # pytest-timeout installed (the mark degrades to a no-op; with the
    # plugin installed its own registration takes over enforcement).
    config.addinivalue_line(
        "markers",
        "timeout(seconds): per-test time limit (no-op unless pytest-timeout "
        "is installed)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
