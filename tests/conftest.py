import os

# Keep the default device count at 1 for smoke tests/benches (the dry-run
# sets its own XLA_FLAGS in a fresh process — see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
