"""Streaming prune+compact parity + block-envelope invariants.

The contract under test: the streamed, envelope-gated prune+compact scan
(``core/search._stream_prune_compact``) is BIT-IDENTICAL to the
materialized mask/cumsum reference (``knn_search_batch_reference``) on
every output field, across all five Bregman families x {exact, approx} x
{fp32, int8} x {BallForest, mutated SegmentedForest, 1x1-mesh
distributed}; block envelopes always dominate their rows' per-point
corners (including after tombstone and merge); and the envelope gate
actually skips (block, query) tiles on clustered data.
"""

import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bregman import family_names, get_family
from repro.core.index import (ENV_BLOCK_ROWS, build_index, corner_envelopes,
                              pad_points, tombstone_rows)
from repro.core.quantize import decoded_corner_tables
from repro.core.segments import build_segmented_index
from repro.core import search
from repro.dist import knn as dknn
from repro.dist.sharding import make_mesh

N, D, M, Q, K = 420, 16, 4, 4, 5
BLOCK_ROWS = 96          # multi-block AND misaligned with ENV_BLOCK_ROWS
P_APPROX = 0.8


def _assert_bitwise_equal(a, b):
    for f in ("ids", "dists", "exact", "num_candidates"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


@functools.lru_cache(maxsize=None)
def _built(family, quantize):
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(0), (N, D), scale=1.0))
    queries = jnp.asarray(np.asarray(
        fam.sample(jax.random.PRNGKey(1), (Q, D), scale=1.0)))
    index = build_index(data, family, m=M, num_clusters=8, seed=0,
                        quantize=quantize)
    return index, queries


@functools.lru_cache(maxsize=None)
def _mutated(family, quantize):
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(2), (N, D), scale=1.0))
    sf = build_segmented_index(data[:N - 64], family, m=M, num_clusters=8,
                               seed=0, quantize=quantize)
    sf.insert(data[N - 64:], auto_compact=False)
    sf.delete([1, 5, N - 30], auto_compact=False)
    return sf


@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("family", family_names())
def test_stream_matches_reference_ballforest(family, quantize):
    """Exact + approx, fp32 + int8: streamed == mask/cumsum, bit for bit."""
    index, queries = _built(family, quantize)
    budget = 64
    res = search.knn_search_batch(index, queries, K, budget,
                                  block_rows=BLOCK_ROWS)
    ref = search.knn_search_batch_reference(index, queries, K, budget,
                                            block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res, ref)

    res_a = search.knn_search_batch_approx(index, queries, K, budget,
                                           jnp.float32(P_APPROX),
                                           block_rows=BLOCK_ROWS)
    ref_a = search.knn_search_batch_reference(index, queries, K, budget,
                                              p_guarantee=P_APPROX,
                                              block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res_a, ref_a)


@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("family", family_names())
def test_stream_matches_reference_mutated_segmented(family, quantize):
    """Same parity over a segmented index with appends + tombstones."""
    sf = _mutated(family, quantize)
    fam = get_family(family)
    queries = jnp.asarray(np.asarray(
        fam.sample(jax.random.PRNGKey(3), (Q, D), scale=1.0)))
    budget = sf.live_n
    res = search.knn_search_batch(sf, queries, K, budget,
                                  block_rows=BLOCK_ROWS)
    ref = search.knn_search_batch_reference(sf, queries, K, budget,
                                            block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res, ref)
    assert bool(jnp.all(res.exact))
    # tombstoned ids can never surface through the streamed compaction
    gone = {1, 5, N - 30}
    assert not gone & set(np.asarray(res.ids).ravel().tolist())

    res_a = search.knn_search_batch_approx(sf, queries, K, budget,
                                           jnp.float32(P_APPROX),
                                           block_rows=BLOCK_ROWS)
    ref_a = search.knn_search_batch_reference(sf, queries, K, budget,
                                              p_guarantee=P_APPROX,
                                              block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res_a, ref_a)


@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("family", family_names())
def test_stream_matches_reference_distributed_1x1(family, quantize):
    """1x1-mesh distributed == single-host streamed == reference."""
    index, queries = _built(family, quantize)
    budget = index.n          # union always fits -> no retry, one program
    mesh = make_mesh((1,), ("data",))
    sharded = dknn.shard_index(index, mesh)
    res_d = dknn.distributed_knn(sharded, queries, family=family, k=K,
                                 budget=budget, block_rows=BLOCK_ROWS)
    ref = search.knn_search_batch_reference(index, queries, K, budget,
                                            block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res_d, ref)

    res_da = dknn.distributed_knn(sharded, queries, family=family, k=K,
                                  budget=budget, approx_p=P_APPROX,
                                  block_rows=BLOCK_ROWS)
    ref_a = search.knn_search_batch_reference(index, queries, K, budget,
                                              p_guarantee=P_APPROX,
                                              block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(res_da, ref_a)


# ---------------------------------------------------------------------------
# Envelope invariants
# ---------------------------------------------------------------------------

def _assert_envelopes_dominate(forest):
    """Every row's decoded corner is dominated by its block's envelope."""
    amin, gmax = (np.asarray(t) for t in decoded_corner_tables(forest))
    ea = np.asarray(forest.env_alpha_min)
    eg = np.asarray(forest.env_sqrt_gamma_max)
    n = amin.shape[0]
    assert ea.shape[0] == max(-(-n // ENV_BLOCK_ROWS), 1)
    grp = np.arange(n) // ENV_BLOCK_ROWS
    assert (ea[grp] <= amin).all()
    assert (eg[grp] >= gmax).all()


@pytest.mark.parametrize("quantize", [False, True])
def test_envelopes_dominate_after_mutations(quantize):
    sf = _mutated("squared_euclidean", quantize)
    for seg in [sf.main] + sf.segments:
        _assert_envelopes_dominate(seg)
    view = sf.view()
    _assert_envelopes_dominate(view)
    # padding appends inert envelope rows; domination must survive
    _assert_envelopes_dominate(pad_points(view, 7))
    # tombstoning leaves the tables conservatively loose, never invalid
    dead = np.zeros(view.n, bool)
    dead[::3] = True
    _assert_envelopes_dominate(tombstone_rows(view, jnp.asarray(dead)))
    # merge compaction refits them exactly
    sf.compact("merge")
    _assert_envelopes_dominate(sf.view())


def test_envelope_property_random_blocks():
    """Hypothesis sweep: corner_envelopes dominates at any n/M alignment."""
    hyp = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(n=st.integers(1, 700), m=st.integers(1, 6),
               seed=st.integers(0, 1000))
    def prop(n, m, seed):
        rng = np.random.default_rng(seed)
        amin = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
        gmax = jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32)
        ea, eg = corner_envelopes(amin, gmax)
        grp = np.arange(n) // ENV_BLOCK_ROWS
        assert (np.asarray(ea)[grp] <= np.asarray(amin)).all()
        assert (np.asarray(eg)[grp] >= np.asarray(gmax)).all()

    prop()


def test_missing_envelopes_disable_skipping_for_every_block():
    """env=None fallback must cover ALL blocks, not just block 0.

    Regression: a hand-assembled forest without envelope tables once got a
    1-row always-admit fallback, so blocks past the first sliced into the
    inert padding and were wrongly skipped (wrong ids with exact=True).
    """
    import dataclasses
    rng = np.random.default_rng(0)
    data = rng.normal(size=(2000, 24)).astype(np.float32)
    index = build_index(data, "squared_euclidean", m=4, num_clusters=16,
                        seed=0)
    bare = dataclasses.replace(index, env_alpha_min=None,
                               env_sqrt_gamma_max=None)
    queries = jnp.asarray(data[1800:1806] + 0.01)   # rows far past block 0
    res = search.knn_search_batch(bare, queries, 5, 2000, block_rows=512)
    ref = search.knn_search_batch_reference(index, queries, 5, 2000,
                                            block_rows=512)
    _assert_bitwise_equal(res, ref)


def test_block_skip_rate_positive_on_clustered_data():
    """Well-separated blobs: whole blocks must be pruned at envelope level."""
    rng = np.random.default_rng(0)
    a = rng.normal(size=(1024, 32)).astype(np.float32)
    b = rng.normal(size=(1024, 32)).astype(np.float32) + 50.0
    index = build_index(np.concatenate([a, b]), "squared_euclidean", m=4,
                        num_clusters=16, seed=0)
    queries = jnp.asarray(a[:8] + 0.01)
    res, stats = search.knn_search_batch_stats(index, queries, 5, 1024,
                                               block_rows=ENV_BLOCK_ROWS)
    assert bool(jnp.all(res.exact))
    assert stats["num_blocks"] == index.n // ENV_BLOCK_ROWS
    assert stats["block_skip_rate"] > 0.0
    # the skipped tiles must not change results
    ref = search.knn_search_batch_reference(index, queries, 5, 1024,
                                            block_rows=ENV_BLOCK_ROWS)
    _assert_bitwise_equal(res, ref)


# ---------------------------------------------------------------------------
# block_rows knob plumbing
# ---------------------------------------------------------------------------

def test_resolve_block_rows_validation():
    assert search.resolve_block_rows(None, 100) == search.DEFAULT_BLOCK_ROWS
    assert search.resolve_block_rows(64, 100) == 64
    assert search.resolve_block_rows(10_000, 100) == 10_000   # clamped later
    with pytest.raises(ValueError, match="block_rows"):
        search.resolve_block_rows(0, 100)
    with pytest.raises(ValueError, match="block_rows"):
        search.resolve_block_rows(-64, 100)
    with pytest.raises(ValueError, match="block_rows"):
        search.resolve_block_rows(4.5, 100)
    with pytest.raises(ValueError, match="empty"):
        search.resolve_block_rows(64, 0)


def test_resolve_block_rows_empty_index_fires_on_default_path():
    """Regression: the n < 1 guard must fire when block_rows is None too.

    It used to sit below the ``block_rows is None`` early-return, so the
    default-knob path (the common one) sailed past an empty index and died
    later inside the scan with an opaque shape error.
    """
    with pytest.raises(ValueError, match="empty"):
        search.resolve_block_rows(None, 0)
    with pytest.raises(ValueError, match="empty"):
        search.resolve_block_rows(None, -3, q=4, storage="f32")


def test_resolve_env_block_rows_validation():
    eb = ENV_BLOCK_ROWS
    assert search.resolve_env_block_rows(None) == eb
    assert search.resolve_env_block_rows(eb) == eb
    assert search.resolve_env_block_rows(4 * eb) == 4 * eb
    for bad in (0, eb // 2, eb + 1, 3 * eb // 2, True):
        with pytest.raises(ValueError, match="env_block_rows"):
            search.resolve_env_block_rows(bad)


def test_knn_batch_and_hook_forward_block_rows(monkeypatch):
    """The knob reaches the jit core from knn_batch and from KNNLMHook."""
    from repro.serve.knnlm import Datastore, KNNLMHook
    index, queries = _built("squared_euclidean", False)

    seen = []
    real = search._knn_search_batch_jit

    def spy(index, ys, k, budget, block_rows, env_block_rows=None):
        seen.append(block_rows)
        return real(index, ys, k, budget, block_rows, env_block_rows)

    monkeypatch.setattr(search, "_knn_search_batch_jit", spy)
    search.knn_batch(index, queries, K, budget=64, block_rows=128)
    assert seen[-1] == 128

    store = Datastore(index=index,
                      next_tokens=np.arange(N, dtype=np.int32) % 32,
                      hidden_dim=D, block_rows=96)
    hook = KNNLMHook(store=store, k=K, lam=0.5)
    hook(jnp.zeros((2, 32)), jnp.asarray(np.asarray(queries)[:2]))
    assert seen[-1] == 96          # store default
    hook = KNNLMHook(store=store, k=K, lam=0.5, block_rows=192)
    hook(jnp.zeros((2, 32)), jnp.asarray(np.asarray(queries)[:2]))
    assert seen[-1] == 192         # per-hook override wins


# ---------------------------------------------------------------------------
# Fused filter+prune scan vs the two-kernel scan vs the reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("family", family_names())
def test_fused_scan_matches_unfused_and_reference(family, quantize):
    """The fused-kernel scan (default) == two-kernel scan == reference.

    The fused path also swaps the per-step windowed envelope gate for the
    hoisted whole-table gate, so this pins BOTH changes to bit-parity.
    """
    index, queries = _built(family, quantize)
    budget = 64
    br = search.resolve_block_rows(BLOCK_ROWS, index.n)
    eb = search.resolve_env_block_rows(None)
    fused = search._knn_search_batch_jit(index, queries, K, budget, br, eb)
    unfused = search._knn_search_batch_unfused_jit(index, queries, K,
                                                   budget, br, eb)
    ref = search.knn_search_batch_reference(index, queries, K, budget,
                                            block_rows=BLOCK_ROWS)
    _assert_bitwise_equal(fused, unfused)
    _assert_bitwise_equal(fused, ref)


# ---------------------------------------------------------------------------
# Knob sweep: every autotuner-selectable choice is results-invariant
# ---------------------------------------------------------------------------

# Autotuner candidates rescaled to the N=420 test fixture (the real
# candidate set starts at 1024 and the sweep skips br > 2n, so at test
# size every multi-block/misaligned/single-block regime is covered by):
SWEEP_BLOCK_ROWS = (32, 96, 256, N)


@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("family", family_names())
def test_block_rows_choice_never_changes_results(family, quantize):
    """Bit-identical SearchResult for every block_rows the tuner may pick.

    This is the safety property that makes the autotuner table a pure
    perf knob: exact and approx searches must return the same ids/dists/
    exact/num_candidates regardless of the scan's block size.
    """
    index, queries = _built(family, quantize)
    budget = 64
    base = search.knn_search_batch(index, queries, K, budget,
                                   block_rows=search.DEFAULT_BLOCK_ROWS)
    base_a = search.knn_search_batch_approx(index, queries, K, budget,
                                            jnp.float32(P_APPROX),
                                            block_rows=search.DEFAULT_BLOCK_ROWS)
    for br in SWEEP_BLOCK_ROWS:
        got = search.knn_search_batch(index, queries, K, budget,
                                      block_rows=br)
        _assert_bitwise_equal(got, base)
        got_a = search.knn_search_batch_approx(index, queries, K, budget,
                                               jnp.float32(P_APPROX),
                                               block_rows=br)
        _assert_bitwise_equal(got_a, base_a)


@pytest.mark.parametrize("quantize", [False, True])
def test_env_block_rows_choice_never_changes_results(quantize):
    """Envelope-gate granularity is results-invariant (superset admits).

    Coarsening the gate to f*ENV_BLOCK_ROWS min/maxes envelope rows
    together — looser bounds admit a superset of blocks whose extra admit
    tiles are provably all-zero, so compaction output is unchanged.
    """
    for family in ("squared_euclidean", "itakura_saito"):
        index, queries = _built(family, quantize)
        budget = 64
        base = search.knn_search_batch(index, queries, K, budget,
                                       block_rows=BLOCK_ROWS)
        for eb in (ENV_BLOCK_ROWS, 2 * ENV_BLOCK_ROWS, 4 * ENV_BLOCK_ROWS):
            got = search.knn_search_batch(index, queries, K, budget,
                                          block_rows=BLOCK_ROWS,
                                          env_block_rows=eb)
            _assert_bitwise_equal(got, base)
