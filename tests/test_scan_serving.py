"""Scanned prefill/decode (stacked caches) must match the unscanned path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.registry import build_model

B, S = 2, 24


@pytest.mark.parametrize("arch", ["starcoder2-3b", "rwkv6-1.6b",
                                  "qwen3-moe-30b-a3b"])
def test_scan_serving_matches_loop(arch):
    # f32 compute: scan vs unrolled differ only by bf16 reassociation noise,
    # so the equivalence check runs in f32 where they match tightly
    cfg_loop = dataclasses.replace(configs.get_reduced(arch),
                                   compute_dtype=jnp.float32)
    cfg_scan = dataclasses.replace(cfg_loop, scan_layers=True)
    b_loop = build_model(cfg_loop)
    b_scan = build_model(cfg_scan)
    params_loop = b_loop.init(jax.random.PRNGKey(0))
    # scan params = stacked loop params (same init key ordering differs, so
    # stack the loop params manually for an apples-to-apples comparison)
    stacked_layers = jax.tree.map(
        lambda *xs: jnp.stack(xs), *params_loop["layers"])
    params_scan = dict(params_loop, layers=stacked_layers)

    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg_loop.vocab_size, (B, S)),
                         jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)

    batch = {"tokens": tokens[:, :-1], "positions": pos[:, :-1]}
    caches_l = b_loop.init_cache(B, S)
    caches_s = b_scan.init_cache(B, S)
    lengths = jnp.zeros((B,), jnp.int32)
    h_l, caches_l = jax.jit(b_loop.prefill)(params_loop, batch, caches_l,
                                            lengths)
    h_s, caches_s = jax.jit(b_scan.prefill)(params_scan, batch, caches_s,
                                            lengths)
    np.testing.assert_allclose(np.asarray(h_l, np.float32),
                               np.asarray(h_s, np.float32), atol=1e-4,
                               rtol=1e-4)

    lengths = jnp.full((B,), S - 1, jnp.int32)
    lg_l, _, _ = jax.jit(b_loop.decode_step)(
        params_loop, tokens[:, -1:], pos[:, -1:], caches_l, lengths)
    lg_s, _, _ = jax.jit(b_scan.decode_step)(
        params_scan, tokens[:, -1:], pos[:, -1:], caches_s, lengths)
    np.testing.assert_allclose(np.asarray(lg_l, np.float32),
                               np.asarray(lg_s, np.float32), atol=1e-4,
                               rtol=1e-4)
