"""Distributed-layer integration tests (8 forced host devices, subprocess).

The multi-device checks live in tests/dist_checks.py and run in a fresh
process because jax locks the device count at first backend init — the
main pytest session must keep its 1-device view (same isolation rule as
the dry-run).
"""

import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(600)
def test_distributed_checks_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    script = os.path.join(os.path.dirname(__file__), "dist_checks.py")
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=580)
    assert out.returncode == 0, (out.stdout[-3000:], out.stderr[-3000:])
    assert "ALL DISTRIBUTED CHECKS PASSED" in out.stdout
