"""Pallas kernels (interpret=True) vs pure-jnp ref oracles: shape/dtype sweeps."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ref
from repro.kernels.bregman_ub import bregman_ub_matrix, bregman_ub_matrix_quant
from repro.kernels.bregman_fused import (bregman_filter_prune,
                                         bregman_filter_prune_quant)
from repro.kernels.bregman_prune import (bregman_prune_mask,
                                         bregman_prune_mask_quant)
from repro.kernels.bregman_dist import bregman_refine
from repro.kernels.pccp_corr import pccp_correlation
from repro.kernels.flash_attention import flash_attention
from repro.core import quantize as qz
from repro.core.bregman import get_family

# NOTE: the DETERMINISTIC parity tests for the quantized kernels live in
# tests/test_quantized.py, outside this module's hypothesis gate, so they
# run wherever jax runs; only the property sweep below needs hypothesis.


# ---------------------------------------------------------------------------
# bregman_ub
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,q", [(64, 8, 1), (100, 28, 3), (513, 50, 5),
                                   (32, 1, 1), (7, 5, 2)])
def test_ub_kernel_shapes(n, m, q):
    rng = np.random.default_rng(0)
    alpha = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    sg = jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32)
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.asarray(np.abs(rng.normal(size=(q, m))), jnp.float32)
    got = bregman_ub_matrix(alpha, sg, jnp.sum(qc, -1), sd,
                            block_n=32, block_q=4, interpret=True)
    want = ref.bregman_ub_matrix(alpha, sg, qc, sd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 200), m=st.integers(1, 40), q=st.integers(1, 6),
       seed=st.integers(0, 1000))
def test_ub_kernel_property(n, m, q, seed):
    rng = np.random.default_rng(seed)
    alpha = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    sg = jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32)
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.asarray(np.abs(rng.normal(size=(q, m))), jnp.float32)
    got = bregman_ub_matrix(alpha, sg, jnp.sum(qc, -1), sd, interpret=True)
    want = ref.bregman_ub_matrix(alpha, sg, qc, sd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 200), m=st.integers(1, 40), q=st.integers(1, 6),
       seed=st.integers(0, 1000))
def test_ub_quant_kernel_property(n, m, q, seed):
    rng = np.random.default_rng(seed)
    a_q, a_s, a_z = qz.quantize_stats(
        jnp.asarray(rng.normal(size=(n, m)), jnp.float32))
    g_q, g_s, g_z = qz.quantize_stats(
        jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32))
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.asarray(np.abs(rng.normal(size=(q, m))), jnp.float32)
    got = bregman_ub_matrix_quant(a_q, a_s, a_z, g_q, g_s, g_z,
                                  jnp.sum(qc, -1), sd, interpret=True)
    want = ref.bregman_ub_matrix_quant(a_q, a_s, a_z, g_q, g_s, g_z, qc, sd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# bregman_prune (Theorem-3 admit mask)
# ---------------------------------------------------------------------------

def _prune_inputs(rng, n, m, q):
    amin = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    gmax = jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32)
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.asarray(np.abs(rng.normal(size=(q, m))), jnp.float32)
    # bounds near the lb distribution so both mask values actually occur
    qb = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    return amin, gmax, qc, sd, qb


@pytest.mark.parametrize("n,m,q", [(64, 8, 1), (100, 28, 3), (257, 50, 5),
                                   (32, 1, 1), (7, 5, 2)])
def test_prune_kernel_shapes(n, m, q):
    rng = np.random.default_rng(0)
    amin, gmax, qc, sd, qb = _prune_inputs(rng, n, m, q)
    got = bregman_prune_mask(amin, gmax, qc, sd, qb,
                             block_n=32, block_q=4, interpret=True)
    want = ref.bregman_prune_mask(amin, gmax, qc, sd, qb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32
    # non-degenerate case: both admitted and pruned pairs exist
    if n * q >= 500:
        assert 0 < int(np.asarray(got).sum()) < n * q


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 200), m=st.integers(1, 40), q=st.integers(1, 6),
       seed=st.integers(0, 1000))
def test_prune_kernel_property(n, m, q, seed):
    rng = np.random.default_rng(seed)
    amin, gmax, qc, sd, qb = _prune_inputs(rng, n, m, q)
    got = bregman_prune_mask(amin, gmax, qc, sd, qb, interpret=True)
    want = ref.bregman_prune_mask(amin, gmax, qc, sd, qb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 200), m=st.integers(1, 40), q=st.integers(1, 6),
       seed=st.integers(0, 1000))
def test_prune_quant_kernel_property(n, m, q, seed):
    rng = np.random.default_rng(seed)
    a_q, a_s, a_z = qz.quantize_stats(
        jnp.asarray(rng.normal(size=(n, m)), jnp.float32), "floor")
    g_q, g_s, g_z = qz.quantize_stats(
        jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32), "ceil")
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.asarray(np.abs(rng.normal(size=(q, m))), jnp.float32)
    qb = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    got = bregman_prune_mask_quant(a_q, a_s, a_z, g_q, g_s, g_z,
                                   qc, sd, qb, interpret=True)
    want = ref.bregman_prune_mask_quant(a_q, a_s, a_z, g_q, g_s, g_z,
                                        qc, sd, qb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# bregman_fused (one-pass filter UB + Theorem-3 admit)
# ---------------------------------------------------------------------------

def _fused_inputs(rng, n, m, q):
    alpha = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    sg = jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32)
    amin, gmax, qc, sd, qb = _prune_inputs(rng, n, m, q)
    return alpha, sg, amin, gmax, qc, sd, qb


@pytest.mark.parametrize("n,m,q", [(64, 8, 1), (100, 28, 3), (257, 50, 5),
                                   (32, 1, 1), (7, 5, 2)])
def test_fused_kernel_shapes(n, m, q):
    """Fused (ub, admit) == (ub kernel, prune kernel) at odd shapes.

    ``ub`` is allclose to the standalone UB kernel; ``admit`` must be
    BIT-IDENTICAL to the standalone prune kernel (the streaming scan's
    compaction consumes it, so any drift changes SearchResult).
    """
    rng = np.random.default_rng(0)
    alpha, sg, amin, gmax, qc, sd, qb = _fused_inputs(rng, n, m, q)
    qsum = jnp.sum(qc, -1)
    ub, admit = bregman_filter_prune(alpha, sg, amin, gmax, qsum, qc, sd, qb,
                                     block_n=32, block_q=4, interpret=True)
    ub_ref, admit_ref = ref.bregman_filter_prune(alpha, sg, amin, gmax,
                                                 qc, sd, qb)
    np.testing.assert_allclose(np.asarray(ub), np.asarray(ub_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(admit), np.asarray(admit_ref))
    assert admit.dtype == jnp.int32
    # the admit half must match the standalone prune kernel bit for bit
    solo = bregman_prune_mask(amin, gmax, qc, sd, qb,
                              block_n=32, block_q=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(admit), np.asarray(solo))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 200), m=st.integers(1, 40), q=st.integers(1, 6),
       seed=st.integers(0, 1000))
def test_fused_kernel_property(n, m, q, seed):
    rng = np.random.default_rng(seed)
    alpha, sg, amin, gmax, qc, sd, qb = _fused_inputs(rng, n, m, q)
    ub, admit = bregman_filter_prune(alpha, sg, amin, gmax,
                                     jnp.sum(qc, -1), qc, sd, qb,
                                     interpret=True)
    ub_ref, admit_ref = ref.bregman_filter_prune(alpha, sg, amin, gmax,
                                                 qc, sd, qb)
    np.testing.assert_allclose(np.asarray(ub), np.asarray(ub_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(admit), np.asarray(admit_ref))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 200), m=st.integers(1, 40), q=st.integers(1, 6),
       seed=st.integers(0, 1000))
def test_fused_quant_kernel_property(n, m, q, seed):
    rng = np.random.default_rng(seed)
    a_q, a_s, a_z = qz.quantize_stats(
        jnp.asarray(rng.normal(size=(n, m)), jnp.float32))
    g_q, g_s, g_z = qz.quantize_stats(
        jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32))
    am_q, am_s, am_z = qz.quantize_stats(
        jnp.asarray(rng.normal(size=(n, m)), jnp.float32), "floor")
    gm_q, gm_s, gm_z = qz.quantize_stats(
        jnp.asarray(np.abs(rng.normal(size=(n, m))), jnp.float32), "ceil")
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.asarray(np.abs(rng.normal(size=(q, m))), jnp.float32)
    qb = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    ub, admit = bregman_filter_prune_quant(
        a_q, a_s, a_z, g_q, g_s, g_z, am_q, am_s, am_z, gm_q, gm_s, gm_z,
        jnp.sum(qc, -1), qc, sd, qb, interpret=True)
    ub_ref, admit_ref = ref.bregman_filter_prune_quant(
        a_q, a_s, a_z, g_q, g_s, g_z, am_q, am_s, am_z, gm_q, gm_s, gm_z,
        qc, sd, qb)
    np.testing.assert_allclose(np.asarray(ub), np.asarray(ub_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(admit), np.asarray(admit_ref))
    # bit-parity with the standalone quantized prune kernel
    solo = bregman_prune_mask_quant(am_q, am_s, am_z, gm_q, gm_s, gm_z,
                                    qc, sd, qb, interpret=True)
    np.testing.assert_array_equal(np.asarray(admit), np.asarray(solo))


# ---------------------------------------------------------------------------
# bregman_dist (refinement)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["squared_euclidean", "itakura_saito",
                                    "exponential", "burg", "shannon"])
@pytest.mark.parametrize("b,d", [(16, 24), (100, 128), (33, 300)])
def test_refine_kernel(family, b, d):
    fam = get_family(family)
    key = jax.random.PRNGKey(1)
    rows = fam.sample(key, (b, d))
    y = fam.sample(jax.random.PRNGKey(2), (d,))
    grad = fam.phi_prime(y)
    c_y = jnp.sum(y * grad) - fam.f(y)
    got = bregman_refine(rows, grad, c_y, family,
                         block_b=16, block_d=64, interpret=True)
    want = ref.bregman_refine(rows, grad, c_y, family)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # also against the direct definition
    direct = fam.distance(rows, y[None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(direct),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# pccp_corr
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(100, 8), (257, 40), (64, 129)])
def test_corr_kernel(n, d):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    got = pccp_correlation(x, block_d=16, block_n=64, interpret=True)
    want = ref.pccp_correlation(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kh,sq,skv,d,causal,window",
    [
        (2, 4, 4, 64, 64, 32, True, None),      # MHA causal
        (1, 8, 2, 64, 64, 32, True, None),      # GQA 4:1
        (2, 4, 1, 32, 32, 16, True, None),      # MQA
        (1, 4, 4, 64, 64, 32, False, None),     # bidirectional (encoder)
        (1, 4, 2, 64, 64, 32, True, 16),        # sliding window
        (2, 4, 2, 1, 96, 32, True, None),       # decode: 1 new token vs cache
        (1, 2, 2, 48, 48, 32, True, None),      # non-pow2 seq (padding path)
    ],
)
def test_flash_attention(b, h, kh, sq, skv, d, causal, window, dtype):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, sq, d), dtype)
    k = jax.random.normal(kk, (b, kh, skv, d), dtype)
    v = jax.random.normal(kv, (b, kh, skv, d), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_kv=32, interpret=True)
    want = ref.attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)
