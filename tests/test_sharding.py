"""Property tests for the sharding layer (hypothesis) + constrain no-op."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.dist import sharding as shd  # noqa: E402


def _mesh(shape, axes):
    # abstract mesh over a device grid — never touches the backend count
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


MESH = _mesh((16, 16), ("data", "model"))
MESH3 = _mesh((2, 16, 16), ("pod", "data", "model"))


@given(
    batch=st.sampled_from([1, 2, 16, 32, 128, 256]),
    seq=st.sampled_from([1, 8, 4096, 32768, 100]),
    heads=st.sampled_from([6, 8, 16, 24, 32, 64]),
    hd=st.sampled_from([64, 128]),
)
@settings(max_examples=60, deadline=None)
def test_spec_properties(batch, seq, heads, hd):
    for mesh in (MESH, MESH3):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))
        spec = shd.spec_for_shape(("batch", "seq", "heads", "head_dim"),
                                  (batch, seq, heads, hd), mesh)
        dims = (batch, seq, heads, hd)
        used = []
        for dim, entry in zip(dims, tuple(spec) + (None,) * (4 - len(spec)),
                              strict=True):
            axes = (entry,) if isinstance(entry, str) else (entry or ())
            prod = 1
            for a in axes:
                assert a in mesh.axis_names
                assert a not in used, "mesh axis used twice"
                used.append(a)
                prod *= sizes[a]
            assert dim % prod == 0, (dim, axes, "indivisible sharding")
        # priority: if heads could take `model`, it must have (not seq)
        if heads % sizes["model"] == 0:
            flat = [e for e in tuple(spec)]
            assert flat[2] == "model" or (isinstance(flat[2], tuple)
                                          and "model" in flat[2])


def test_priority_context_parallel_fallback():
    # starcoder2-like: 24 heads cannot take model=16 -> seq gets it
    spec = shd.spec_for_shape(("batch", "seq", "heads", "head_dim"),
                              (256, 4096, 24, 128), MESH)
    assert tuple(spec)[1] == "model"
    assert tuple(spec)[2] is None
    # qwen3-like: 64 heads take model; seq stays unsharded
    spec = shd.spec_for_shape(("batch", "seq", "heads", "head_dim"),
                              (256, 4096, 64, 128), MESH)
    assert tuple(spec)[1] is None
    assert tuple(spec)[2] == "model"


def test_residual_stream_gets_sequence_parallel():
    spec = shd.spec_for_shape(("batch", "seq", "embed"),
                              (256, 4096, 5120), MESH)
    assert tuple(spec) == ("data", "model", None)


def test_multipod_batch_spans_pod_and_data():
    spec = shd.spec_for_shape(("batch", "seq", "embed"),
                              (256, 4096, 5120), MESH3)
    assert tuple(spec)[0] == ("pod", "data")
    # batch=1 (long_500k): everything about batch replicated
    spec1 = shd.spec_for_shape(("batch", "seq", "embed"),
                               (1, 4096, 5120), MESH3)
    assert tuple(spec1)[0] is None


def test_kv_heads_indivisible_replicated():
    spec = shd.spec_for_shape(("fsdp", "kv_heads", "head_dim"),
                              (5120, 8, 128), MESH)
    assert tuple(spec) == ("data", None, None)


def test_constrain_is_identity_without_context():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, ("batch", "embed"))
    assert y is x


def test_serve_rules_drop_fsdp():
    spec = shd.spec_for_shape(("fsdp", "mlp"), (5120, 27648), MESH,
                              shd.SERVE_RULES)
    assert tuple(spec) == (None, "model")
