"""The docs front door stays healthy: links resolve, every page mapped.

Runs tools/docs_health.py both in-process (against this repo — the
actual gate) and against synthetic trees that pin the two failure modes
it exists to catch (broken link, unreached page).
"""

import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import docs_health  # noqa: E402


def test_this_repo_is_healthy():
    assert docs_health.check(REPO) == []


def test_cli_exit_status():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "docs_health.py"), str(REPO)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "docs health OK" in proc.stdout


def _tree(tmp_path, front_door: str, pages: dict):
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text("[docs](docs/README.md)\n")
    (tmp_path / "docs" / "README.md").write_text(front_door)
    for name, text in pages.items():
        (tmp_path / "docs" / name).write_text(text)
    return tmp_path


def test_broken_link_detected(tmp_path):
    root = _tree(tmp_path, "[gone](missing.md)\n", {})
    errors = docs_health.check(root)
    assert any("broken link" in e and "missing.md" in e for e in errors)


def test_unreachable_page_detected(tmp_path):
    root = _tree(tmp_path, "no links here\n", {"orphan.md": "# lonely\n"})
    errors = docs_health.check(root)
    assert any("orphan.md" in e and "not reachable" in e for e in errors)


def test_transitive_reachability_and_fragments_ok(tmp_path):
    root = _tree(
        tmp_path,
        "[a](a.md)\n",
        {"a.md": "[b](b.md#some-section)\n```\n[not a link](nope.md)\n```\n",
         "b.md": "[up](../README.md)\n"})
    assert docs_health.check(root) == []
