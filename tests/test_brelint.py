"""brelint stays green on this repo, and each pass catches its defect.

Runs tools/analyze both in-process (against this repo — the actual gate)
and against synthetic src/ trees that seed one violation per pass,
including a regression fixture reproducing the PR 6 outage class: a
host-side validator (np.asarray + raise) reachable from a jit+vmap
region without the ``validate=False`` opt-out.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from tools import analyze  # noqa: E402


# ---------------------------------------------------------------------------
# The actual gate: this repo is healthy
# ---------------------------------------------------------------------------

def test_this_repo_is_healthy():
    assert analyze.check(REPO) == []


def test_cli_exit_status():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analyze", str(REPO)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "brelint OK" in proc.stdout


# ---------------------------------------------------------------------------
# Synthetic trees
# ---------------------------------------------------------------------------

def _tree(tmp_path, files: dict) -> Path:
    """Materialize a fixture repo: {relpath: source} under tmp_path."""
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text), encoding="utf-8")
    return tmp_path


def _findings(root, invariant=None):
    found = analyze.analyze(Path(root))
    if invariant is None:
        return found
    return [f for f in found if f.invariant == invariant]


# -- trace-safety -----------------------------------------------------------

# The PR 6 defect, minimized: a host validator (np.asarray + raise on the
# query payload) sits behind `validate=True` defaults, and a jitted+vmapped
# lambda calls the search wrapper WITHOUT discharging the guard.
_PR6_SEARCH = """\
    import jax
    import jax.numpy as jnp
    import numpy as np

    def validate_queries(family, q):
        arr = np.asarray(q)
        if not np.all(np.isfinite(arr)):
            raise ValueError("query outside family domain")

    def knn_search(index, y, k, validate=True):
        if validate:
            validate_queries(None, y)
        return jnp.sum(y) + k
"""

_PR6_BAD_BENCH = _PR6_SEARCH + """\

    run = jax.jit(jax.vmap(lambda y: knn_search(None, y, 5)))
"""

_PR6_GOOD_BENCH = _PR6_SEARCH + """\

    run = jax.jit(jax.vmap(lambda y: knn_search(None, y, 5, validate=False)))
"""


def test_trace_safety_catches_pr6_host_validate_under_jit(tmp_path):
    root = _tree(tmp_path, {"src/repro/search.py": _PR6_BAD_BENCH})
    hits = _findings(root, "trace-host-op")
    assert any("validate_queries" in f.symbol or "asarray" in f.message
               for f in hits), [f.render(root) for f in _findings(root)]


def test_trace_safety_validate_false_discharges_the_guard(tmp_path):
    root = _tree(tmp_path, {"src/repro/search.py": _PR6_GOOD_BENCH})
    assert _findings(root, "trace-host-op") == []


def test_trace_safety_flags_item_and_branch_on_traced(tmp_path):
    root = _tree(tmp_path, {"src/repro/mod.py": """\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            if jnp.sum(x) > 0:
                return float(x.sum())
            return x.mean().item()
    """})
    assert _findings(root, "trace-host-op")
    assert _findings(root, "trace-branch-on-array")


# -- pytree-contract --------------------------------------------------------

_PYTREE_MOD = """\
    import dataclasses
    import jax


    @dataclasses.dataclass
    class Box:
        data: object
        name: str
        cache: object = None

        def tree_flatten(self):
            dyn = (self.data,)
            static = (self.name,)
            return dyn, static

        @classmethod
        def tree_unflatten(cls, static, dyn):
            return cls(dyn[0], static[0])


    jax.tree_util.register_pytree_node(
        Box, Box.tree_flatten, Box.tree_unflatten)
"""


def test_pytree_catches_unaccounted_field(tmp_path):
    root = _tree(tmp_path, {"src/repro/box.py": _PYTREE_MOD})
    hits = _findings(root, "pytree-field-unaccounted")
    assert any(f.symbol.endswith("Box.cache") for f in hits), \
        [f.render(root) for f in _findings(root)]


def test_pytree_host_only_declaration_accounts_the_field(tmp_path):
    fixed = _PYTREE_MOD.replace(
        "cache: object = None",
        'cache: object = None\n\n    HOST_ONLY_FIELDS = ("cache",)')
    root = _tree(tmp_path, {"src/repro/box.py": fixed})
    assert _findings(root, "pytree-field-unaccounted") == []


def test_pytree_catches_double_accounted_field(tmp_path):
    doubled = _PYTREE_MOD.replace("static = (self.name,)",
                                  "static = (self.name, self.data)")
    root = _tree(tmp_path, {"src/repro/box.py": doubled})
    hits = _findings(root, "pytree-field-double-accounted")
    assert any(f.symbol.endswith("Box.data") for f in hits)


# -- kernel-triplet ---------------------------------------------------------

_KERNEL_TREE = {
    "src/repro/kernels/__init__.py": "",
    "src/repro/kernels/ref.py": """\
        import jax.numpy as jnp

        def scale(x):
            return x * 2.0
    """,
    "src/repro/kernels/doubler.py": """\
        import jax.experimental.pallas as pl

        def _kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0

        def double_rows(x, *, interpret=False):
            return pl.pallas_call(_kernel, out_shape=x,
                                  interpret=interpret)(x)
    """,
    "src/repro/kernels/ops.py": """\
        from . import doubler as _doubler
        from . import ref

        def double_rows(x, *, interpret=False, use_ref=False):
            if use_ref:
                return ref.scale(x)
            return _doubler.double_rows(x, interpret=interpret)
    """,
    "tests/test_doubler.py":
        "def test_parity():\n    assert callable('double_rows'.strip)\n",
}


def test_kernel_triplet_healthy_fixture_passes(tmp_path):
    root = _tree(tmp_path, dict(_KERNEL_TREE))
    kernel_findings = [f for f in _findings(root)
                       if f.invariant.startswith("kernel-")]
    assert kernel_findings == [], [f.render(root) for f in kernel_findings]


def test_kernel_triplet_catches_orphan_kernel(tmp_path):
    files = dict(_KERNEL_TREE)
    files["src/repro/kernels/ops.py"] = "from . import ref\n"
    root = _tree(tmp_path, files)
    hits = _findings(root, "kernel-missing-dispatch")
    assert any(f.symbol.endswith("doubler.double_rows") for f in hits)


def test_kernel_triplet_catches_missing_interpret_and_ref(tmp_path):
    files = dict(_KERNEL_TREE)
    files["src/repro/kernels/ops.py"] = """\
        from . import doubler as _doubler

        def double_rows(x):
            return _doubler.double_rows(x)
    """
    root = _tree(tmp_path, files)
    assert _findings(root, "kernel-missing-interpret")
    assert _findings(root, "kernel-missing-ref")


def test_kernel_triplet_catches_missing_parity_test(tmp_path):
    files = dict(_KERNEL_TREE)
    del files["tests/test_doubler.py"]
    root = _tree(tmp_path, files)
    hits = _findings(root, "kernel-missing-parity-test")
    assert any(f.symbol.endswith("doubler.double_rows") for f in hits)


# -- knob-contract ----------------------------------------------------------

_KNOB_MOD = """\
    def resolve_budget(budget, n, k):
        return min(budget or 4 * k, n)

    def search(xs, k, budget=None):
        return xs[:budget]
"""


def test_knob_catches_unvalidated_budget(tmp_path):
    root = _tree(tmp_path, {"src/repro/api.py": _KNOB_MOD})
    hits = _findings(root, "knob-unresolved")
    assert any(f.symbol.endswith("search:budget") for f in hits)


def test_knob_resolver_call_satisfies_the_contract(tmp_path):
    fixed = _KNOB_MOD.replace(
        "return xs[:budget]",
        "budget = resolve_budget(budget, len(xs), k)\n    return xs[:budget]")
    root = _tree(tmp_path, {"src/repro/api.py": fixed})
    assert _findings(root, "knob-unresolved") == []


def test_knob_forwarding_satisfies_the_contract(tmp_path):
    forwarded = _KNOB_MOD.replace(
        "return xs[:budget]",
        "return _inner(xs, k, budget=budget)") + """\

    def _inner(xs, k, budget=None):
        budget = resolve_budget(budget, len(xs), k)
        return xs[:budget]
"""
    root = _tree(tmp_path, {"src/repro/api.py": forwarded})
    assert _findings(root, "knob-unresolved") == []


# -- baseline mechanics -----------------------------------------------------

def test_baseline_suppresses_with_reason_and_flags_stale(tmp_path):
    root = _tree(tmp_path, {"src/repro/api.py": _KNOB_MOD})
    rel = "src/repro/api.py"
    sym = "repro.api.search:budget"

    good = tmp_path / "baseline_good.txt"
    good.write_text(f"knob-unresolved {rel}:{sym}  # reviewed: fixture\n")
    assert analyze.check(root, good) == []

    uncommented = tmp_path / "baseline_bare.txt"
    uncommented.write_text(f"knob-unresolved {rel}:{sym}\n")
    errs = analyze.check(root, uncommented)
    assert any("no reason" in e for e in errs)

    stale = tmp_path / "baseline_stale.txt"
    stale.write_text(
        f"knob-unresolved {rel}:{sym}  # reviewed: fixture\n"
        f"knob-unresolved {rel}:repro.api.gone:budget  # obsolete\n")
    errs = analyze.check(root, stale)
    assert any("stale baseline entry" in e for e in errs)
