"""brelint pass: pytree-contract (`pytree-*`).

For every class registered as a pytree node (``register_pytree_node`` /
``register_pytree_node_class`` / ``register_dataclass``) whose definition
lives in the tree, each dataclass field must be accounted for **exactly
once** across:

* the dynamic children tuple returned first from ``tree_flatten``,
* the static aux tuple returned second, and
* an explicit class-level ``HOST_ONLY_FIELDS = (...)`` declaration for
  fields deliberately dropped from the pytree (the ``calibration`` cache
  that PR 8 had to hand-audit out of the flatten).

Modules that define the point-table walk constants additionally get the
walk-consistency checks: ``INERT_FILL`` keys must equal ``POINT_FIELDS``,
``INERT_FILL_INT8`` keys must equal ``POINT_FIELDS + QUANT_FIELDS``, and
every name in the walk constants must be a field of the registered class
defined in the same module.
"""

from __future__ import annotations

import ast

from .common import Finding, ModuleInfo, Project, dotted_name

UNACCOUNTED = "pytree-field-unaccounted"
DOUBLE = "pytree-field-double-accounted"
UNKNOWN = "pytree-unknown-field"
POINT_WALK = "pytree-point-walk"

_REGISTER_FNS = {"register_pytree_node", "register_pytree_node_class",
                 "register_dataclass"}


def _registered_classes(project: Project,
                        mod: ModuleInfo) -> list[ast.ClassDef]:
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            dotted = dotted_name(node.func) or ""
            if dotted.rsplit(".", 1)[-1] in _REGISTER_FNS and node.args:
                target = node.args[0]
                if isinstance(target, ast.Name) \
                        and target.id in mod.classes:
                    out.append(mod.classes[target.id])
    for cls in mod.classes.values():
        for deco in cls.decorator_list:
            base = deco.func if isinstance(deco, ast.Call) else deco
            dotted = dotted_name(base) or ""
            if dotted.rsplit(".", 1)[-1] in _REGISTER_FNS \
                    and cls not in out:
                out.append(cls)
    return out


def _dataclass_fields(cls: ast.ClassDef) -> list[str]:
    fields = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name):
            ann = dotted_name(node.annotation) or ""
            if "ClassVar" in ann:
                continue
            fields.append(node.target.id)
    return fields


def _host_only(cls: ast.ClassDef) -> list[str]:
    for node in cls.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "HOST_ONLY_FIELDS"
                and isinstance(node.value, (ast.Tuple, ast.List))):
            return [e.value for e in node.value.elts
                    if isinstance(e, ast.Constant)]
    return []


def _self_names(expr: ast.expr) -> list[str]:
    names = []
    for sub in ast.walk(expr):
        if (isinstance(sub, ast.Attribute)
                and isinstance(sub.value, ast.Name)
                and sub.value.id == "self"):
            names.append(sub.attr)
    return names


def _flatten_sides(cls: ast.ClassDef) -> tuple[list[str], list[str],
                                               int] | None:
    """(children names, static names, lineno) from ``tree_flatten``."""
    fn = next((n for n in cls.body
               if isinstance(n, ast.FunctionDef)
               and n.name == "tree_flatten"), None)
    if fn is None:
        return None
    assigns = {}
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            assigns[node.targets[0].id] = node.value
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and isinstance(
                node.value, ast.Tuple) and len(node.value.elts) == 2:
            sides = []
            for side in node.value.elts:
                if isinstance(side, ast.Name) and side.id in assigns:
                    side = assigns[side.id]
                sides.append(_self_names(side))
            return sides[0], sides[1], node.lineno
    return None


def run(ctx) -> list[Finding]:
    project: Project = ctx.project
    findings: list[Finding] = []
    for mod in project.modules.values():
        classes = _registered_classes(project, mod)
        for cls in classes:
            findings += _check_class(mod, cls)
        if classes:
            findings += _check_point_walk(project, mod, classes)
    return findings


def _check_class(mod: ModuleInfo, cls: ast.ClassDef) -> list[Finding]:
    fields = _dataclass_fields(cls)
    if not fields:       # NamedTuple/plain classes flatten themselves
        return []
    sides = _flatten_sides(cls)
    if sides is None:
        return []        # register_dataclass-style: fields are the leaves
    children, static, line = sides
    host_only = _host_only(cls)
    findings = []
    symbol = f"{mod.name}.{cls.name}"
    counts = {f: 0 for f in fields}
    for group in (children, static, host_only):
        for name in group:
            if name in counts:
                counts[name] += 1
            else:
                findings.append(Finding(
                    UNKNOWN, mod.path, line, f"{symbol}.{name}",
                    f"`{name}` appears in {cls.name}.tree_flatten / "
                    "HOST_ONLY_FIELDS but is not a dataclass field"))
    for name, n in counts.items():
        if n == 0:
            findings.append(Finding(
                UNACCOUNTED, mod.path, line, f"{symbol}.{name}",
                f"field `{name}` of registered pytree {cls.name} is in "
                "neither the flatten children, the static aux, nor "
                "HOST_ONLY_FIELDS — it will silently vanish across "
                "jit/device boundaries"))
        elif n > 1:
            findings.append(Finding(
                DOUBLE, mod.path, line, f"{symbol}.{name}",
                f"field `{name}` of registered pytree {cls.name} is "
                f"accounted for {n} times across children/static/"
                "HOST_ONLY_FIELDS"))
    return findings


def _check_point_walk(project: Project, mod: ModuleInfo,
                      classes: list[ast.ClassDef]) -> list[Finding]:
    consts = project.constants(mod)
    point = consts.get("POINT_FIELDS")
    if not isinstance(point, tuple):
        return []
    findings: list[Finding] = []
    fields = {f for cls in classes for f in _dataclass_fields(cls)}
    quant = consts.get("QUANT_FIELDS") or ()
    for cname in ("POINT_FIELDS", "QUANT_FIELDS", "ENV_FIELDS",
                  "REPLICATED_FIELDS"):
        val = consts.get(cname)
        if not isinstance(val, tuple):
            continue
        for name in val:
            if name not in fields:
                findings.append(Finding(
                    POINT_WALK, mod.path, 1, f"{mod.name}.{cname}.{name}",
                    f"`{cname}` names `{name}`, which is not a field of "
                    "any registered pytree class in this module"))
    for fill_name, expect in (("INERT_FILL", tuple(point)),
                              ("INERT_FILL_INT8", tuple(point)
                               + tuple(quant))):
        fill = consts.get(fill_name)
        if not isinstance(fill, dict):
            continue
        missing = sorted(set(expect) - set(fill))
        extra = sorted(set(fill) - set(expect))
        if missing or extra:
            detail = []
            if missing:
                detail.append(f"missing {missing}")
            if extra:
                detail.append(f"extra {extra}")
            findings.append(Finding(
                POINT_WALK, mod.path, 1, f"{mod.name}.{fill_name}",
                f"`{fill_name}` keys must match the point-table walk "
                f"({'; '.join(detail)}) — pad/tombstone would corrupt "
                "unlisted fields"))
    return findings
