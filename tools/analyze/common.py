"""Shared AST project model for the brelint passes.

Builds a whole-tree view of ``src/`` once (parsed modules, import alias
maps, every function/method with a stable qualified name) so the passes
can resolve call targets without importing any repo code.  Everything is
stdlib ``ast`` — brelint must run in the dependency-free CI jobs.

Resolution is deliberately best-effort: a call we cannot resolve simply
contributes no edge, so the passes stay quiet rather than noisy when the
tree grows new idioms.  The contract each pass enforces is documented in
docs/static_analysis.md.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path


@dataclasses.dataclass
class Finding:
    """One violation: stable id + location + suppression key."""

    invariant: str      # e.g. "trace-host-op"
    path: Path          # absolute path of the offending file
    line: int
    symbol: str         # qualname used as the baseline suppression key
    message: str

    def key(self, root: Path) -> tuple[str, str, str]:
        return (self.invariant, self.relpath(root), self.symbol)

    def relpath(self, root: Path) -> str:
        try:
            return self.path.relative_to(root).as_posix()
        except ValueError:
            return self.path.as_posix()

    def render(self, root: Path) -> str:
        return (f"{self.relpath(root)}:{self.line}: [{self.invariant}] "
                f"{self.message}  (key: {self.symbol})")


@dataclasses.dataclass
class FunctionInfo:
    """A def/lambda anywhere in the tree, with a stable qualname."""

    qualname: str                    # repro.core.search.knn / ...Cls.meth
    name: str                        # last component
    module: "ModuleInfo"
    node: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda
    cls: str | None = None           # enclosing class, if a method

    @property
    def line(self) -> int:
        return self.node.lineno

    @property
    def args(self) -> ast.arguments:
        return self.node.args

    @property
    def params(self) -> list[str]:
        a = self.args
        return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]

    def positional_params(self) -> list[str]:
        a = self.args
        return [p.arg for p in a.posonlyargs + a.args]

    def default_of(self, param: str) -> ast.expr | None:
        """The default expression for ``param``, or None if required."""
        a = self.args
        pos = a.posonlyargs + a.args
        for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
            if p.arg == param:
                return d
        for p, d in zip(a.kwonlyargs, a.kw_defaults):
            if p.arg == param and d is not None:
                return d
        return None

    def has_kwargs(self) -> bool:
        return self.args.kwarg is not None


@dataclasses.dataclass
class ModuleInfo:
    """One parsed source file plus its import alias maps."""

    name: str                        # dotted, e.g. repro.core.search
    path: Path
    tree: ast.Module
    # local alias -> dotted module name ("np" -> "numpy")
    imports: dict[str, str] = dataclasses.field(default_factory=dict)
    # local name -> (source module, original name) for from-imports
    from_imports: dict[str, tuple[str, str]] = dataclasses.field(
        default_factory=dict)
    functions: dict[str, FunctionInfo] = dataclasses.field(
        default_factory=dict)   # qualname -> info
    classes: dict[str, ast.ClassDef] = dataclasses.field(
        default_factory=dict)

    @property
    def package(self) -> str:
        return self.name.rsplit(".", 1)[0] if "." in self.name else ""


def dotted_name(node: ast.expr) -> str | None:
    """'a.b.c' for a pure Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_const(node: ast.expr | None, *values) -> bool:
    return isinstance(node, ast.Constant) and any(
        node.value is v for v in values)


class Project:
    """All parsed modules under ``src_root`` with cross-module resolution."""

    def __init__(self, src_root: Path):
        self.src_root = src_root
        self.modules: dict[str, ModuleInfo] = {}
        self.functions: dict[str, FunctionInfo] = {}
        for path in sorted(src_root.rglob("*.py")):
            rel = path.relative_to(src_root).with_suffix("")
            parts = list(rel.parts)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            name = ".".join(parts) if parts else "__root__"
            try:
                tree = ast.parse(path.read_text(encoding="utf-8"))
            except SyntaxError:
                continue
            mod = ModuleInfo(name=name, path=path, tree=tree)
            self._index_module(mod)
            self.modules[name] = mod
        self.packages = {m.rsplit(".", 1)[0] for m in self.modules
                         if "." in m} | set(self.modules)

    # -- indexing ----------------------------------------------------------

    def _index_module(self, mod: ModuleInfo) -> None:
        for node in mod.tree.body:
            if isinstance(node, ast.Import):
                for alias in node.names:
                    mod.imports[alias.asname or alias.name.split(".")[0]] = (
                        alias.name if alias.asname else
                        alias.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                src = self._resolve_from(mod, node)
                if src is None:
                    continue
                for alias in node.names:
                    mod.from_imports[alias.asname or alias.name] = (
                        src, alias.name)
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_function(mod, node, prefix=mod.name, cls=None)
            elif isinstance(node, ast.ClassDef):
                mod.classes[node.name] = node
                for item in node.body:
                    if isinstance(item,
                                  (ast.FunctionDef, ast.AsyncFunctionDef)):
                        self._add_function(
                            mod, item, prefix=f"{mod.name}.{node.name}",
                            cls=node.name)

    def _resolve_from(self, mod: ModuleInfo,
                      node: ast.ImportFrom) -> str | None:
        if node.level == 0:
            return node.module
        base = mod.name.split(".")
        # level 1 == current package; the module's own last component is
        # not part of the package unless this file is an __init__.
        if not mod.path.name == "__init__.py":
            base = base[:-1]
        drop = node.level - 1
        if drop:
            base = base[:-drop] if drop <= len(base) else []
        return ".".join(base + ([node.module] if node.module else [])) or None

    def _add_function(self, mod: ModuleInfo, node, prefix: str,
                      cls: str | None) -> None:
        qual = f"{prefix}.{node.name}"
        info = FunctionInfo(qualname=qual, name=node.name, module=mod,
                            node=node, cls=cls)
        mod.functions[qual] = info
        self.functions[qual] = info
        # nested defs get qualnames too (trace roots are often closures)
        for child in ast.walk(node):
            if child is node:
                continue
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                nested_qual = f"{qual}.{child.name}"
                if nested_qual not in self.functions:
                    ninfo = FunctionInfo(qualname=nested_qual,
                                         name=child.name, module=mod,
                                         node=child, cls=cls)
                    mod.functions[nested_qual] = ninfo
                    self.functions[nested_qual] = ninfo

    # -- resolution --------------------------------------------------------

    def canonical(self, mod: ModuleInfo, node: ast.expr) -> str | None:
        """Alias-expanded dotted name of an expression, if nameable.

        ``np.asarray`` -> ``numpy.asarray``; ``shd.shard_map`` ->
        ``repro.dist.compat.shard_map``; plain names resolve through
        from-imports (``partial`` -> ``functools.partial``).
        """
        dotted = dotted_name(node)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        if head in mod.imports:
            base = mod.imports[head]
            return f"{base}.{rest}" if rest else base
        if head in mod.from_imports:
            src, orig = mod.from_imports[head]
            base = f"{src}.{orig}"
            return f"{base}.{rest}" if rest else base
        return dotted

    def resolve_call(self, mod: ModuleInfo, call: ast.Call,
                     scope: FunctionInfo | None = None) -> str | None:
        """Project qualname for a call target, if it lives in the tree."""
        func = call.func
        if isinstance(func, ast.Name):
            name = func.id
            # innermost enclosing scope wins: try the scope itself, then
            # each enclosing function, then the module top level.
            prefix = scope.qualname if scope is not None else mod.name
            while True:
                cand = f"{prefix}.{name}"
                if cand in self.functions:
                    return cand
                if prefix == mod.name or "." not in prefix:
                    break
                prefix = prefix.rsplit(".", 1)[0]
            local = f"{mod.name}.{name}"
            if local in self.functions:
                return local
            if name in mod.from_imports:
                src, orig = mod.from_imports[name]
                target = f"{src}.{orig}"
                if target in self.functions:
                    return target
            return None
        if isinstance(func, ast.Attribute):
            base = func.value
            if (isinstance(base, ast.Name) and base.id == "self"
                    and scope is not None and scope.cls is not None):
                cand = f"{mod.name}.{scope.cls}.{func.attr}"
                return cand if cand in self.functions else None
            canon = self.canonical(mod, func)
            if canon is not None and canon in self.functions:
                return canon
            # ``module_alias.fn`` where the alias names a project module
            if canon is not None:
                head, _, fn = canon.rpartition(".")
                if head in self.modules:
                    cand = f"{head}.{fn}"
                    return cand if cand in self.functions else None
        return None

    def constants(self, mod: ModuleInfo) -> dict[str, object]:
        """Module-level constant tuples/dicts, shallowly evaluated."""
        out: dict[str, object] = {}
        for node in mod.tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                val = _const_eval(node.value, out)
                if val is not None:
                    out[node.targets[0].id] = val
        return out


def _const_eval(node: ast.expr, env: dict[str, object]):
    """Tuples, string/number constants, + concatenation, dict literals."""
    if isinstance(node, ast.Constant):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.Tuple):
        items = [_const_eval(e, env) for e in node.elts]
        return None if any(i is None for i in items) else tuple(items)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        left = _const_eval(node.left, env)
        right = _const_eval(node.right, env)
        if isinstance(left, tuple) and isinstance(right, tuple):
            return left + right
        return None
    if isinstance(node, ast.Dict):
        out = {}
        for k, v in zip(node.keys, node.values):
            if k is None:          # {**other, ...} expansion
                expanded = _const_eval(v, env)
                if not isinstance(expanded, dict):
                    return None
                out.update(expanded)
                continue
            key = _const_eval(k, env)
            if key is None:
                return None
            out[key] = _const_eval(v, env)
        return out
    return None
