"""brelint pass: kernel-triplet (`kernel-*`).

Every public Pallas kernel entry point in ``src/repro/kernels/`` (a
top-level function that reaches a ``pl.pallas_call`` directly or through
a same-module helper) must ship the full triplet:

* a dispatcher in ``ops.py`` that references it (the jit-facing wrapper
  that picks pallas/interpret/ref per backend),
* an interpret-mode dispatch — the dispatcher passes ``interpret=`` so
  the kernel body is executable off-TPU,
* a ref-mode branch calling an oracle that exists in ``ref.py`` (the
  pure-jnp implementation parity tests compare against), and
* at least one test under ``tests/`` that references the kernel or its
  dispatcher by name.

The dispatcher's own ``ref.<name>`` call is the source of truth for the
oracle name (``flash_attention`` dispatches to ``ref.attention``), so
renamed oracles do not need name symmetry with the kernel.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from .common import Finding, ModuleInfo, Project, dotted_name

MISSING_DISPATCH = "kernel-missing-dispatch"
MISSING_INTERPRET = "kernel-missing-interpret"
MISSING_REF = "kernel-missing-ref"
MISSING_TEST = "kernel-missing-parity-test"

_SKIP = {"ops", "ref", "__init__"}


def _has_pallas_call(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            dotted = dotted_name(node.func) or ""
            if dotted.rsplit(".", 1)[-1] == "pallas_call":
                return True
    return False


def _kernel_entries(mod: ModuleInfo) -> list[ast.FunctionDef]:
    """Public top-level fns reaching pallas_call (direct or one module hop)."""
    top = {n.name: n for n in mod.tree.body
           if isinstance(n, ast.FunctionDef)}
    direct = {name for name, fn in top.items() if _has_pallas_call(fn)}
    reaches = set(direct)
    changed = True
    while changed:
        changed = False
        for name, fn in top.items():
            if name in reaches:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and isinstance(
                        node.func, ast.Name) and node.func.id in reaches:
                    reaches.add(name)
                    changed = True
                    break
    return [top[n] for n in sorted(reaches) if not n.startswith("_")]


def _alias_for(ops_mod: ModuleInfo, kernel_mod: str) -> str | None:
    for alias, (src, orig) in ops_mod.from_imports.items():
        if f"{src}.{orig}" == kernel_mod:
            return alias
    for alias, target in ops_mod.imports.items():
        if target == kernel_mod:
            return alias
    return None


def run(ctx) -> list[Finding]:
    project: Project = ctx.project
    kernels_pkg = next((name for name in project.modules
                        if name.endswith(".kernels.ops")), None)
    if kernels_pkg is None:
        return []
    pkg = kernels_pkg.rsplit(".", 1)[0]
    ops_mod = project.modules[kernels_pkg]
    ref_mod = project.modules.get(f"{pkg}.ref")
    ref_fns = {fn.name for fn in ref_mod.functions.values()} \
        if ref_mod else set()
    test_text = _tests_text(ctx.root)

    findings: list[Finding] = []
    for name, mod in sorted(project.modules.items()):
        if not name.startswith(f"{pkg}."):
            continue
        if name.rsplit(".", 1)[-1] in _SKIP:
            continue
        alias = _alias_for(ops_mod, name)
        for kernel in _kernel_entries(mod):
            findings += _check_kernel(mod, kernel, alias, ops_mod,
                                      ref_fns, test_text)
    return findings


def _check_kernel(mod: ModuleInfo, kernel: ast.FunctionDef,
                  alias: str | None, ops_mod: ModuleInfo,
                  ref_fns: set, test_text: str) -> list[Finding]:
    symbol = f"{mod.name}.{kernel.name}"
    findings = []
    dispatchers = []
    if alias is not None:
        for fn in ops_mod.functions.values():
            if not isinstance(fn.node, ast.FunctionDef):
                continue
            for node in ast.walk(fn.node):
                if (isinstance(node, ast.Attribute)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == alias
                        and node.attr == kernel.name):
                    dispatchers.append(fn)
                    break
    if not dispatchers:
        findings.append(Finding(
            MISSING_DISPATCH, mod.path, kernel.lineno, symbol,
            f"Pallas kernel `{kernel.name}` has no dispatcher in "
            "kernels/ops.py — jitted programs cannot reach it through "
            "the backend-policy layer"))
        return findings

    has_interpret = any(
        isinstance(node, ast.keyword) and node.arg == "interpret"
        for d in dispatchers for node in ast.walk(d.node))
    if not has_interpret:
        findings.append(Finding(
            MISSING_INTERPRET, ops_mod.path, dispatchers[0].line, symbol,
            f"dispatcher for `{kernel.name}` never passes `interpret=` — "
            "the kernel body cannot be exercised off-TPU"))

    ref_calls = set()
    for d in dispatchers:
        for node in ast.walk(d.node):
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "ref"):
                ref_calls.add(node.attr)
    if not (ref_calls & ref_fns):
        findings.append(Finding(
            MISSING_REF, ops_mod.path, dispatchers[0].line, symbol,
            f"dispatcher for `{kernel.name}` has no ref.<fn> branch that "
            "resolves in kernels/ref.py — no pure-jnp oracle to test "
            "parity against"))

    names = [kernel.name] + [d.name for d in dispatchers]
    if not any(re.search(rf"\b{re.escape(n)}\b", test_text)
               for n in names):
        findings.append(Finding(
            MISSING_TEST, mod.path, kernel.lineno, symbol,
            f"no test under tests/ references `{kernel.name}` or its "
            f"dispatcher(s) {sorted(set(d.name for d in dispatchers))} "
            "by name — the triplet has no parity coverage"))
    return findings


def _tests_text(root: Path) -> str:
    tests = root / "tests"
    if not tests.is_dir():
        return ""
    return "\n".join(p.read_text(encoding="utf-8")
                     for p in sorted(tests.glob("**/*.py")))
