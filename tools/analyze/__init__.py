"""brelint — repo-specific static analysis for the BrePartition tree.

    python -m tools.analyze [repo_root] [--baseline PATH | --no-baseline]

Four stdlib-``ast`` passes over ``src/`` enforce the invariants generic
linters cannot see (docs/static_analysis.md has the full catalog):

* ``trace-safety``   — no host-only op reachable from a traced region
  without a ``validate=False``-style opt-out (the PR 6 outage class);
* ``pytree-contract`` — every registered pytree field accounted for
  exactly once across children / static aux / HOST_ONLY_FIELDS, and the
  point-table walks stay consistent;
* ``kernel-triplet`` — every Pallas kernel ships ref oracle + interpret
  dispatch + a parity test that names it;
* ``knob-contract``  — public entry-point knobs flow through their named
  resolver/validator before first use.

Findings carry ``file:line``, an invariant id, and a suppression key.
False positives are suppressed in the checked-in baseline file
(``tools/analyze/baseline.txt``) — every entry requires a trailing
``#``-comment saying why, and stale entries fail the run, so the
baseline cannot rot.  Adding a pass = one module with ``run(ctx) ->
list[Finding]`` plus a registration line in ``PASSES`` below.
"""

from __future__ import annotations

import argparse
import dataclasses
from pathlib import Path

from .common import Finding, Project
from . import kernels, knobs, pytree, trace_safety

BASELINE_NAME = "baseline.txt"

PASSES = (
    ("trace-safety", trace_safety.run),
    ("pytree-contract", pytree.run),
    ("kernel-triplet", kernels.run),
    ("knob-contract", knobs.run),
)


@dataclasses.dataclass
class Context:
    """Everything a pass may need: repo root + the parsed project."""

    root: Path
    project: Project


@dataclasses.dataclass
class BaselineEntry:
    invariant: str
    relpath: str
    symbol: str
    reason: str
    line: int          # line in the baseline file itself


def load_baseline(path: Path) -> tuple[list[BaselineEntry], list[str]]:
    """Parse suppressions; malformed/uncommented entries are errors."""
    entries: list[BaselineEntry] = []
    errors: list[str] = []
    if not path.is_file():
        return entries, errors
    for lineno, raw in enumerate(
            path.read_text(encoding="utf-8").splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        body, sep, reason = line.partition("#")
        parts = body.split()
        if len(parts) != 2 or ":" not in parts[1]:
            errors.append(
                f"{path.name}:{lineno}: malformed baseline entry "
                f"(want `<invariant> <path>:<symbol>  # reason`): {raw}")
            continue
        if not sep or not reason.strip():
            errors.append(
                f"{path.name}:{lineno}: baseline entry has no reason "
                "comment — every suppression must say why: " + raw)
            continue
        # Split on the FIRST colon: paths never contain one, but symbols
        # may (the knob pass uses `qualname:knob` keys).
        relpath, _, symbol = parts[1].partition(":")
        entries.append(BaselineEntry(parts[0], relpath, symbol,
                                     reason.strip(), lineno))
    return entries, errors


def analyze(root: Path) -> list[Finding]:
    """Raw findings from every pass (no baseline applied)."""
    src = root / "src"
    ctx = Context(root=root, project=Project(src))
    findings: list[Finding] = []
    for _name, run in PASSES:
        findings.extend(run(ctx))
    findings.sort(key=lambda f: (f.relpath(root), f.line, f.invariant))
    return findings


def check(root: Path, baseline_path: Path | None = None) -> list[str]:
    """All violations as printable strings (empty list == healthy)."""
    root = Path(root).resolve()
    if baseline_path is None:
        baseline_path = Path(__file__).with_name(BASELINE_NAME)
    entries, errors = load_baseline(baseline_path)
    findings = analyze(root)
    used = set()
    out = list(errors)
    for f in findings:
        key = f.key(root)
        hit = next((e for e in entries
                    if (e.invariant, e.relpath, e.symbol) == key), None)
        if hit is not None:
            used.add(hit.line)
            continue
        out.append(f.render(root))
    for e in entries:
        if e.line not in used:
            out.append(
                f"{baseline_path.name}:{e.line}: stale baseline entry "
                f"(no matching finding) — delete it: {e.invariant} "
                f"{e.relpath}:{e.symbol}")
    return out


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="brelint: repo-specific static analysis")
    parser.add_argument("root", nargs="?", default=".",
                        help="repo root (default: cwd)")
    parser.add_argument("--baseline", type=Path, default=None,
                        help="suppression file (default: "
                             "tools/analyze/baseline.txt)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="report raw findings, ignoring suppressions")
    args = parser.parse_args(argv)
    root = Path(args.root).resolve()
    if args.no_baseline:
        findings = analyze(root)
        for f in findings:
            print(f.render(root))
        print(f"brelint (no baseline): {len(findings)} finding(s)")
        return 1 if findings else 0
    violations = check(root, args.baseline)
    for v in violations:
        print(v)
    if not violations:
        n_files = len(list((root / "src").rglob("*.py")))
        print(f"brelint OK: {n_files} files, {len(PASSES)} passes, "
              "0 findings")
    return 1 if violations else 0
