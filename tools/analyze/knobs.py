"""brelint pass: knob-contract (`knob-unresolved`).

Every *public* entry-point parameter that names an exactness/performance
knob must flow through its named resolver/validator before first use:

================  =====================================================
knob              approved resolvers / validators
================  =====================================================
block_rows        resolve_block_rows, lookup_block_rows
env_block_rows    resolve_env_block_rows, lookup_env_block_rows
target_recall     resolve_p_guarantee, validate_target_recall, resolve
p_guarantee       resolve_p_guarantee, validate_p_guarantee
approx_p          resolve_p_guarantee, validate_p_guarantee
budget            resolve_budget, default_budget, fitted_budget,
                  fitted_budget_for_n
deadline_s        resolve_deadline_s
resident_bytes    resolve_resident_bytes
prefetch_depth    resolve_prefetch_depth
================  =====================================================

A function satisfies the contract for a knob parameter when it

* calls an approved resolver with that parameter in the arguments, or
* forwards the parameter (same-named keyword, or positionally into a
  parameter of the same name) to a function that itself satisfies the
  contract — computed to a fixpoint, so thin public wrappers stay thin.

The point is the `(None, 0)` class of defect: a knob that skips its
validator on some path reaches the kernels with an unchecked value.
Private helpers (leading underscore) are exempt — the contract binds
the public surface where unvalidated values enter.
"""

from __future__ import annotations

import ast

from .common import Finding, FunctionInfo, Project, dotted_name

UNRESOLVED = "knob-unresolved"

KNOBS: dict[str, frozenset] = {
    "block_rows": frozenset({"resolve_block_rows", "lookup_block_rows"}),
    "env_block_rows": frozenset({"resolve_env_block_rows",
                                 "lookup_env_block_rows"}),
    "target_recall": frozenset({"resolve_p_guarantee",
                                "validate_target_recall", "resolve"}),
    "p_guarantee": frozenset({"resolve_p_guarantee",
                              "validate_p_guarantee"}),
    "approx_p": frozenset({"resolve_p_guarantee", "validate_p_guarantee"}),
    "budget": frozenset({"resolve_budget", "default_budget",
                         "fitted_budget", "fitted_budget_for_n"}),
    "deadline_s": frozenset({"resolve_deadline_s"}),
    "resident_bytes": frozenset({"resolve_resident_bytes"}),
    "prefetch_depth": frozenset({"resolve_prefetch_depth"}),
}

_ALL_RESOLVERS = frozenset().union(*KNOBS.values())


def _call_name(call: ast.Call) -> str:
    dotted = dotted_name(call.func) or ""
    return dotted.rsplit(".", 1)[-1]


def _mentions(expr_list, name: str) -> bool:
    for expr in expr_list:
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Name) and sub.id == name:
                return True
    return False


def _resolves_directly(fn: FunctionInfo, knob: str) -> bool:
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Call) \
                and _call_name(node) in KNOBS[knob]:
            exprs = list(node.args) + [kw.value for kw in node.keywords]
            if _mentions(exprs, knob):
                return True
        # the `knob = resolver(...)` idiom (lookup-style resolvers choose
        # the value instead of validating a passed one)
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == knob
                and isinstance(node.value, ast.Call)
                and _call_name(node.value) in KNOBS[knob]):
            return True
    return False


def _forward_edges(project: Project, fn: FunctionInfo,
                   knob: str) -> list[str]:
    """Callee qualnames this fn forwards the knob parameter into."""
    out = []
    for node in ast.walk(fn.node):
        if not isinstance(node, ast.Call):
            continue
        target = project.resolve_call(fn.module, node, fn)
        if target is None:
            continue
        callee = project.functions[target]
        if knob not in callee.params:
            continue
        forwarded = any(
            kw.arg == knob and isinstance(kw.value, ast.Name)
            and kw.value.id == knob for kw in node.keywords)
        if not forwarded:
            pos = callee.positional_params()
            offset = 1 if (pos and pos[0] in ("self", "cls")
                           and isinstance(node.func, ast.Attribute)) else 0
            if knob in pos:
                idx = pos.index(knob) - offset
                if 0 <= idx < len(node.args) and isinstance(
                        node.args[idx], ast.Name) \
                        and node.args[idx].id == knob:
                    forwarded = True
        if forwarded:
            out.append(target)
    return out


def run(ctx) -> list[Finding]:
    project: Project = ctx.project
    # ok[(qualname, knob)] -> satisfies contract
    holders: list[tuple[FunctionInfo, str]] = []
    for fn in project.functions.values():
        if not isinstance(fn.node,
                          (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for knob in KNOBS:
            if knob in fn.params:
                holders.append((fn, knob))

    ok: dict[tuple[str, str], bool] = {}
    edges: dict[tuple[str, str], list[str]] = {}
    for fn, knob in holders:
        key = (fn.qualname, knob)
        if fn.name in _ALL_RESOLVERS:
            ok[key] = True       # the resolver itself
            continue
        ok[key] = _resolves_directly(fn, knob)
        if not ok[key]:
            edges[key] = _forward_edges(project, fn, knob)

    changed = True
    while changed:
        changed = False
        for key, targets in edges.items():
            if ok[key]:
                continue
            if any(ok.get((t, key[1]), False) for t in targets):
                ok[key] = True
                changed = True

    findings = []
    for fn, knob in holders:
        if ok[(fn.qualname, knob)]:
            continue
        if fn.name.startswith("_") or _in_private_scope(fn):
            continue
        findings.append(Finding(
            UNRESOLVED, fn.module.path, fn.line, f"{fn.qualname}:{knob}",
            f"public `{fn.name}` takes knob `{knob}` but neither calls "
            f"an approved resolver ({', '.join(sorted(KNOBS[knob]))}) "
            "nor forwards it to a function that does — the knob reaches "
            "first use unvalidated"))
    return findings


def _in_private_scope(fn: FunctionInfo) -> bool:
    """Nested inside a private function, or a method of a private class."""
    parts = fn.qualname.split(".")
    return any(p.startswith("_") for p in parts[:-1])
