"""brelint pass: trace-safety (`trace-host-op`, `trace-branch-on-array`).

The PR 6 bench outage class: a host-only operation (``np.*`` coercion,
``float()``/``bool()``/``int()`` on runtime values, ``.item()``,
``jax.device_get``) reachable through the call graph from a traced region
(``jax.jit`` / ``vmap`` / ``shard_map`` / ``lax.scan`` / ``lax.cond`` /
``pallas_call``) without a ``validate=False``-style opt-out.

Mechanics:

* every project function is scanned for host markers and project-internal
  call edges, each tagged with the parameter guards (``if validate:``)
  enclosing it;
* taint propagates caller-ward to a fixpoint, translating guard
  conditions through call sites — passing the constant ``False``/``None``
  for a guard parameter *discharges* the taint (the opt-out), forwarding
  a caller parameter re-conditions it on that parameter;
* at each trace root, conditioned taint survives unless every condition
  parameter defaults to ``False``/``None`` (i.e. host work is opt-in).

Functions jitted with ``static_argnames`` may coerce those (static)
parameters with ``int()``/``float()``/``bool()`` — that is trace-time
Python on static values, not a leak, and is not flagged.

A second check flags Python ``if``/``while`` tests built directly from
``jnp.*`` calls inside the traced region (implicit bool() on a tracer).
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import NamedTuple

from .common import Finding, FunctionInfo, ModuleInfo, Project, \
    dotted_name, is_const

HOST_OP = "trace-host-op"
BRANCH_ON_ARRAY = "trace-branch-on-array"

# wrapper canonical name -> positions of the traced callee argument(s)
_WRAPPERS = {
    "jax.jit": (0,), "jax.pmap": (0,), "jax.vmap": (0,),
    "jax.grad": (0,), "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,), "jax.remat": (0,),
    "jax.lax.map": (0,), "jax.lax.scan": (0,),
    "jax.lax.while_loop": (0, 1), "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2), "jax.lax.switch": (1,),
}
# wrappers matched on the final attribute regardless of module prefix
# (compat shims re-export shard_map; pallas is imported as ``pl``).
_WRAPPER_ATTRS = {"shard_map": (0,), "pallas_call": (0,)}

_COERCIONS = {"float", "int", "bool"}
# annotation words that mark a parameter as host-static (config values,
# shapes, section tuples): trace-time Python on these is fine.  Anything
# array-ish — or unannotated — is presumed traced.
_STATIC_ANN = {"int", "float", "bool", "str", "bytes", "tuple", "list",
               "dict", "type", "None", "Literal"}
_ARRAY_ANN = {"Array", "ndarray", "ArrayLike", "Any", "object"}
# builtins/modules whose results stay static when their inputs are static
_STATIC_CALLS = {"int", "float", "bool", "len", "min", "max", "range",
                 "tuple", "str", "sorted", "abs", "sum", "round", "divmod"}
# attribute reads that are trace-time metadata even on traced arrays
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize"}
_HOST_ATTR_CALLS = {"item", "tolist"}
_HOST_CANONICAL = {"jax.device_get", "jax.block_until_ready"}
# numpy attrs that are static/metadata at trace time, not array coercions
_NP_SAFE = {"dtype", "iinfo", "finfo", "result_type", "issubdtype",
            "ndim", "shape", "size", "errstate", "seterr", "isdtype"}
_JNP_STATIC = {"issubdtype", "result_type", "iinfo", "finfo", "dtype",
               "ndim", "shape", "size", "isdtype"}


def _ann_static(annotation: ast.expr) -> bool:
    """Non-array annotation => host-static parameter."""
    words = re.findall(r"[A-Za-z_][A-Za-z0-9_]*", ast.unparse(annotation))
    if any(w in _ARRAY_ANN for w in words):
        return False
    return any(w in _STATIC_ANN or w.endswith("Config") for w in words)


class TaintItem(NamedTuple):
    origin: str      # qualname of the function containing the marker
    line: int
    desc: str
    conds: frozenset  # caller-param names that must all be truthy


@dataclasses.dataclass
class _FnFacts:
    markers: list  # [(line, desc, frozenset(guard params))]
    edges: list    # [(callee qualname, ast.Call, frozenset(guard params))]
    branchy: list  # [(line, desc)] python-branch-on-jnp sites


class _BodyScan(ast.NodeVisitor):
    """Markers + edges + guard tracking for one function body."""

    def __init__(self, project: Project, mod: ModuleInfo,
                 fn: FunctionInfo, statics: frozenset):
        self.project = project
        self.mod = mod
        self.fn = fn
        self.statics = statics
        self.params = set(fn.params)
        self.guards: list[str] = []
        self.facts = _FnFacts([], [], [])
        # params that are host-static: declared via static_argnames, or
        # carrying a non-array annotation (config/shape/tuple values)
        self.static_names = set(statics)
        if not isinstance(fn.node, ast.Lambda):
            a = fn.node.args
            for p in a.posonlyargs + a.args + a.kwonlyargs:
                if p.annotation is not None and _ann_static(p.annotation):
                    self.static_names.add(p.arg)
        self.runtime_locals: set[str] = set()

    # -- guard bookkeeping -------------------------------------------------

    def _guard_params(self, test: ast.expr) -> set[str]:
        if isinstance(test, ast.Name) and test.id in self.params:
            return {test.id}
        if (isinstance(test, ast.Compare) and len(test.ops) == 1
                and isinstance(test.ops[0], (ast.IsNot, ast.NotEq))
                and isinstance(test.left, ast.Name)
                and test.left.id in self.params
                and is_const(test.comparators[0], None)):
            return {test.left.id}
        if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
            out: set[str] = set()
            for v in test.values:
                out |= self._guard_params(v)
            return out
        return set()

    def visit_If(self, node: ast.If) -> None:
        self._note_branch(node)
        self.visit(node.test)
        extra = sorted(self._guard_params(node.test))
        self.guards.extend(extra)
        for stmt in node.body:
            self.visit(stmt)
        del self.guards[len(self.guards) - len(extra):len(self.guards)]
        for stmt in node.orelse:
            self.visit(stmt)

    # nested defs and lambdas are separate functions (or trace roots,
    # handled by the root extractor) — their bodies are not part of this
    # function's host-op surface.
    def visit_FunctionDef(self, node):  # noqa: ARG002
        return

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    # -- markers and edges -------------------------------------------------

    def _mark(self, node: ast.expr, desc: str) -> None:
        guards = frozenset(g for g in self.guards if g in self.params)
        self.facts.markers.append((node.lineno, desc, guards))

    def _expr_static(self, exprs: list[ast.expr]) -> bool:
        """True when the expressions only touch host-static values:
        static/config params, locals derived from them, constants,
        shape/dtype metadata (static at trace time even on tracers), and
        static-preserving calls (numpy/math/builtins on static inputs)."""
        return all(self._static(e) for e in exprs)

    def _static(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return True              # x.shape is trace-time metadata
        if isinstance(node, ast.Name):
            if node.id in self.runtime_locals:
                return False
            return not (node.id in self.params
                        and node.id not in self.static_names)
        if isinstance(node, ast.Call):
            canon = self.project.canonical(self.mod, node.func) or ""
            named_static = (
                canon.startswith(("numpy.", "math."))
                or (isinstance(node.func, ast.Name)
                    and node.func.id in _STATIC_CALLS))
            if not named_static:
                return False         # jnp/lax/project calls: runtime
            return all(self._static(a) for a in node.args) and all(
                self._static(kw.value) for kw in node.keywords)
        if isinstance(node, ast.Constant):
            return True
        return all(self._static(c) for c in ast.iter_child_nodes(node)
                   if isinstance(c, (ast.expr, ast.keyword,
                                     ast.comprehension)))

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)     # marker checks inside the value first
        static = self._expr_static([node.value])
        for target in node.targets:
            for sub in ast.walk(target):
                if isinstance(sub, ast.Name):
                    if static:
                        self.runtime_locals.discard(sub.id)
                    else:
                        self.runtime_locals.add(sub.id)

    def visit_For(self, node: ast.For) -> None:
        for sub in ast.walk(node.target):
            if isinstance(sub, ast.Name):
                self.runtime_locals.add(sub.id)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        canon = self.project.canonical(self.mod, func)
        if isinstance(func, ast.Name) and func.id in _COERCIONS:
            if node.args and not self._expr_static(node.args):
                self._mark(node, f"host coercion `{func.id}()` on a "
                                 "runtime value")
        elif isinstance(func, ast.Attribute) \
                and func.attr in _HOST_ATTR_CALLS and not node.args:
            self._mark(node, f"host sync `.{func.attr}()`")
        elif canon in _HOST_CANONICAL:
            self._mark(node, f"host sync `{canon}`")
        elif canon is not None and canon.startswith("numpy."):
            name = canon.split(".", 1)[1]
            if name not in _NP_SAFE and not self._expr_static(node.args):
                self._mark(node, f"numpy call `{canon}` (host-only)")
        target = self.project.resolve_call(self.mod, node, self.fn)
        if target is not None:
            guards = frozenset(g for g in self.guards if g in self.params)
            self.facts.edges.append((target, node, guards))
        self.generic_visit(node)

    # -- implicit bool() on a tracer ---------------------------------------

    def _test_touches_jnp(self, test: ast.expr) -> int | None:
        for sub in ast.walk(test):
            if isinstance(sub, ast.Call):
                canon = self.project.canonical(self.mod, sub.func) or ""
                if canon.startswith(("jax.numpy.", "jax.lax.")):
                    attr = canon.rsplit(".", 1)[1]
                    if attr not in _JNP_STATIC:
                        return sub.lineno
        return None

    def visit_While(self, node: ast.While) -> None:
        line = self._test_touches_jnp(node.test)
        if line is not None:
            self.facts.branchy.append(
                (line, "python `while` on a jax array expression"))
        self.generic_visit(node)

    def _note_branch(self, node: ast.If) -> None:
        line = self._test_touches_jnp(node.test)
        if line is not None:
            self.facts.branchy.append(
                (line, "python `if` on a jax array expression"))

    def run(self) -> _FnFacts:
        body = self.fn.node.body
        if isinstance(self.fn.node, ast.Lambda):
            self.visit(self.fn.node.body)
            return self.facts
        for stmt in body:
            self.visit(stmt)
        return self.facts


@dataclasses.dataclass
class _Root:
    fn: FunctionInfo
    site: str            # human description of the traced site
    statics: frozenset   # declared static param names


def _decorator_root(project: Project, mod: ModuleInfo,
                    fn: FunctionInfo) -> _Root | None:
    node = fn.node
    if isinstance(node, ast.Lambda):
        return None
    for deco in node.decorator_list:
        canon = project.canonical(mod, deco) if not isinstance(
            deco, ast.Call) else project.canonical(mod, deco.func)
        if not isinstance(deco, ast.Call):
            if canon in ("jax.jit", "jax.pmap"):
                return _Root(fn, f"@{canon}", frozenset())
            continue
        if canon == "functools.partial" and deco.args:
            inner = project.canonical(mod, deco.args[0])
            if inner in ("jax.jit", "jax.pmap"):
                return _Root(fn, f"@partial({inner})",
                             _static_names(project, mod, deco.keywords, fn))
        elif canon in ("jax.jit", "jax.pmap"):
            return _Root(fn, f"@{canon}(...)",
                         _static_names(project, mod, deco.keywords, fn))
    return None


def _static_names(project: Project, mod: ModuleInfo, keywords,
                  fn: FunctionInfo) -> frozenset:
    for kw in keywords:
        if kw.arg == "static_argnames":
            val = kw.value
            names = []
            if isinstance(val, ast.Constant) and isinstance(val.value, str):
                names = [val.value]
            elif isinstance(val, (ast.Tuple, ast.List)):
                names = [e.value for e in val.elts
                         if isinstance(e, ast.Constant)]
            return frozenset(names)
        if kw.arg == "static_argnums":
            val = kw.value
            nums = []
            if isinstance(val, ast.Constant) and isinstance(val.value, int):
                nums = [val.value]
            elif isinstance(val, (ast.Tuple, ast.List)):
                nums = [e.value for e in val.elts
                        if isinstance(e, ast.Constant)]
            pos = fn.positional_params()
            return frozenset(pos[i] for i in nums if i < len(pos))
    return frozenset()


def _resolve_func_expr(project: Project, mod: ModuleInfo, expr: ast.expr,
                       scope: FunctionInfo | None) -> FunctionInfo | None:
    fake = ast.Call(func=expr, args=[], keywords=[])
    qual = project.resolve_call(mod, fake, scope)
    return project.functions.get(qual) if qual else None


def _wrapper_positions(project: Project, mod: ModuleInfo,
                       call: ast.Call) -> tuple | None:
    canon = project.canonical(mod, call.func)
    if canon in _WRAPPERS:
        return _WRAPPERS[canon]
    dotted = dotted_name(call.func) or ""
    attr = dotted.rsplit(".", 1)[-1]
    if attr in _WRAPPER_ATTRS and "." in dotted:
        return _WRAPPER_ATTRS[attr]
    return None


def run(ctx) -> list[Finding]:
    project: Project = ctx.project
    facts: dict[str, _FnFacts] = {}
    all_fns: dict[str, FunctionInfo] = dict(project.functions)
    roots: list[_Root] = []

    # decorated roots + per-function statics
    statics: dict[str, frozenset] = {}
    for mod in project.modules.values():
        for fn in list(mod.functions.values()):
            root = _decorator_root(project, mod, fn)
            if root is not None:
                statics[fn.qualname] = root.statics
                roots.append(root)

    def scan(fn: FunctionInfo) -> _FnFacts:
        if fn.qualname not in facts:
            facts[fn.qualname] = _BodyScan(
                project, fn.module, fn,
                statics.get(fn.qualname, frozenset())).run()
        return facts[fn.qualname]

    # wrapper-call roots (jax.vmap(f), lax.scan(step, ...), shard_map, ...)
    lambda_n = 0
    for mod in project.modules.values():
        scopes: list[FunctionInfo | None] = [None]
        scopes += list(mod.functions.values())
        for scope in scopes:
            body = mod.tree if scope is None else scope.node
            if isinstance(body, ast.Lambda):
                continue
            for node in ast.walk(body):
                if not isinstance(node, ast.Call):
                    continue
                positions = _wrapper_positions(project, mod, node)
                if positions is None:
                    continue
                canon = project.canonical(mod, node.func) or \
                    dotted_name(node.func) or "?"
                for pos in positions:
                    if pos >= len(node.args):
                        continue
                    cands = [node.args[pos]]
                    if isinstance(node.args[pos], (ast.Tuple, ast.List)):
                        cands = list(node.args[pos].elts)   # lax.switch
                    for cand in cands:
                        if isinstance(cand, ast.Lambda):
                            lambda_n += 1
                            owner = scope.qualname if scope else mod.name
                            lf = FunctionInfo(
                                qualname=(f"{owner}.<lambda@"
                                          f"{cand.lineno}>"),
                                name=f"<lambda@{cand.lineno}>",
                                module=mod, node=cand,
                                cls=scope.cls if scope else None)
                            all_fns[lf.qualname] = lf
                            facts[lf.qualname] = _BodyScan(
                                project, mod, lf, frozenset()).run()
                            roots.append(_Root(
                                lf, f"{canon}(<lambda>)", frozenset()))
                        else:
                            target = _resolve_func_expr(
                                project, mod, cand, scope)
                            if target is not None:
                                st = _static_names(project, mod,
                                                   node.keywords, target)
                                roots.append(_Root(
                                    target, f"{canon}({target.name})", st))

    for fn in project.functions.values():
        scan(fn)

    # -- taint fixpoint ----------------------------------------------------
    taint: dict[str, set[TaintItem]] = {q: set() for q in all_fns}
    for qual, f in facts.items():
        for line, desc, guards in f.markers:
            taint[qual].add(TaintItem(qual, line, desc, guards))

    changed = True
    while changed:
        changed = False
        for qual, f in facts.items():
            fn = all_fns[qual]
            for callee_qual, call, guards in f.edges:
                for item in taint.get(callee_qual, ()):
                    moved = _translate(item, call, all_fns.get(callee_qual),
                                       fn, guards)
                    if moved is not None and moved not in taint[qual]:
                        taint[qual].add(moved)
                        changed = True

    # -- report at roots ---------------------------------------------------
    findings: dict[tuple, Finding] = {}
    reachable: set[str] = set()
    frontier = []
    for root in roots:
        if root.fn.qualname not in reachable:
            reachable.add(root.fn.qualname)
            frontier.append(root.fn.qualname)
        for item in taint.get(root.fn.qualname, ()):
            if item.conds and all(
                    is_const(root.fn.default_of(c), False, None)
                    for c in item.conds):
                continue   # opt-in host path: off by default at this root
            origin = all_fns.get(item.origin)
            path = origin.module.path if origin else root.fn.module.path
            cond_txt = (" [enabled unless "
                        + "/".join(f"{c}=False" for c in sorted(item.conds))
                        + "]") if item.conds else ""
            key = (HOST_OP, str(path), item.line, root.fn.qualname)
            findings[key] = Finding(
                HOST_OP, path, item.line, item.origin,
                f"{item.desc} reachable from traced "
                f"`{root.fn.qualname}` ({root.site}){cond_txt}")

    while frontier:
        here = frontier.pop()
        for callee, _call, _g in facts.get(here, _FnFacts([], [], [])).edges:
            if callee not in reachable:
                reachable.add(callee)
                frontier.append(callee)

    for qual in sorted(reachable):
        fn = all_fns.get(qual)
        if fn is None:
            continue
        for line, desc in facts.get(qual, _FnFacts([], [], [])).branchy:
            key = (BRANCH_ON_ARRAY, str(fn.module.path), line, qual)
            findings[key] = Finding(
                BRANCH_ON_ARRAY, fn.module.path, line, qual,
                f"{desc} inside the traced region")

    return list(findings.values())


def _translate(item: TaintItem, call: ast.Call,
               callee: FunctionInfo | None, caller: FunctionInfo,
               guards: frozenset) -> TaintItem | None:
    """Re-express a callee taint item in the caller's parameter space."""
    conds = set(guards)
    if callee is None:
        return TaintItem(item.origin, item.line, item.desc,
                         frozenset(conds | item.conds))
    pos = callee.positional_params()
    offset = 1 if (pos and pos[0] in ("self", "cls")
                   and isinstance(call.func, ast.Attribute)) else 0
    caller_params = set(caller.params)
    for p in item.conds:
        expr = None
        for kw in call.keywords:
            if kw.arg == p:
                expr = kw.value
                break
        else:
            if p in pos:
                idx = pos.index(p) - offset
                if 0 <= idx < len(call.args) and not isinstance(
                        call.args[idx], ast.Starred):
                    expr = call.args[idx]
        if expr is None:
            default = callee.default_of(p)
            if is_const(default, False, None):
                return None         # discharged by default
            continue                # enabled (required/truthy default)
        if is_const(expr, False, None):
            return None             # explicit opt-out at this call site
        if isinstance(expr, ast.Name) and expr.id in caller_params:
            conds.add(expr.id)      # condition forwarded upward
        # any other expression: enabled unconditionally
    return TaintItem(item.origin, item.line, item.desc, frozenset(conds))
