"""Docs health check: links resolve, and the map reaches every page.

    python tools/docs_health.py [repo_root]

Two invariants, enforced in CI (the ``docs`` job) and by
``tests/test_docs_health.py``:

1. Every intra-repo markdown link in ``README.md`` and ``docs/*.md``
   resolves to an existing file (fragments are stripped; external
   ``http(s)``/``mailto`` targets and pure-anchor links are skipped).
2. Every ``docs/*.md`` page is reachable from ``docs/README.md`` by
   following markdown links — the front door must actually front every
   door, so a new page that nobody linked fails the build instead of
   silently rotting.

Exit status 0 iff both hold; violations are printed one per line.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# Inline links only ([text](target)); reference-style links are not used
# in this tree.  Images ride the same syntax with a leading ! and are
# checked identically.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
_EXTERNAL = ("http://", "https://", "mailto:")


def _links(path: Path) -> list[str]:
    text = _FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    out = []
    for target in _LINK_RE.findall(text):
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        out.append(target.split("#", 1)[0])
    return [t for t in out if t]


def check(root: Path) -> list[str]:
    """All violations under ``root`` (empty list == healthy)."""
    docs_dir = root / "docs"
    front_door = docs_dir / "README.md"
    scanned = sorted(docs_dir.glob("*.md")) + [root / "README.md"]
    errors = []
    for page in (front_door, root / "README.md"):
        if not page.is_file():
            errors.append(f"missing front door: {page.relative_to(root)}")
    if errors:
        return errors

    # 1. Every link on every scanned page resolves.
    resolved: dict[Path, list[Path]] = {}
    for page in scanned:
        resolved[page] = []
        for target in _links(page):
            dest = (page.parent / target).resolve()
            if not dest.exists():
                errors.append(
                    f"{page.relative_to(root)}: broken link -> {target}")
            elif dest.is_file():
                resolved[page].append(dest)

    # 2. BFS from docs/README.md: every docs page must be reachable.
    seen = {front_door.resolve()}
    frontier = [front_door.resolve()]
    while frontier:
        here = frontier.pop()
        for dest in resolved.get(here, []):
            if dest.suffix == ".md" and dest not in seen:
                seen.add(dest)
                if dest in resolved:      # only scanned pages have links
                    frontier.append(dest)
    for page in docs_dir.glob("*.md"):
        if page.resolve() not in seen:
            errors.append(f"docs/{page.name}: not reachable from "
                          "docs/README.md — add it to the map")
    return errors


def main(argv: list[str]) -> int:
    root = Path(argv[1]).resolve() if len(argv) > 1 else Path.cwd()
    errors = check(root)
    for e in errors:
        print(e)
    pages = len(list((root / "docs").glob("*.md")))
    if not errors:
        print(f"docs health OK: {pages} docs pages, all linked, "
              "all reachable")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
