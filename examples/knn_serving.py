"""Distributed kNN serving: the paper's workload as a multi-device SPMD
program (dist/knn.py) with batched queries.

On this CPU container the mesh is whatever jax.devices() offers (run under
XLA_FLAGS=--xla_force_host_platform_device_count=8 to see real sharding);
on a pod the same code runs on the (pod, data, model) production mesh.

    PYTHONPATH=src python examples/knn_serving.py
"""

import time

import jax
import numpy as np

from repro.core.index import build_index
from repro.core import search
from repro.data.pipeline import PAPER_DATASETS, make_queries, make_vectors
from repro.dist.knn import distributed_knn, query_subview, shard_index
from repro.launch.mesh import make_host_mesh


def main():
    spec = PAPER_DATASETS["deep"]
    data = make_vectors(spec, scale=0.01)
    queries = make_queries(spec, num=16, scale=0.01)
    index = build_index(data, spec.measure, m=8)

    mesh = make_host_mesh()
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    sharded = shard_index(index, mesh)
    ysub = query_subview(index.partition, jax.numpy.asarray(queries))

    k, budget = 10, max(64, data.shape[0] // 8)
    ids, dists, exact, ncand = distributed_knn(
        sharded, ysub, family=index.family_name, k=k, budget=budget,
        mesh=mesh)
    jax.block_until_ready(ids)

    t0 = time.time()
    ids, dists, exact, ncand = distributed_knn(
        sharded, ysub, family=index.family_name, k=k, budget=budget,
        mesh=mesh)
    jax.block_until_ready(ids)
    dt = time.time() - t0
    print(f"{len(queries)} queries in {dt*1e3:.1f} ms "
          f"({dt/len(queries)*1e6:.0f} us/query), all exact: "
          f"{bool(np.all(np.asarray(exact)))}")

    # verify against the single-device reference pipeline
    ref = search.knn_batch(index, queries, k)
    match = np.array_equal(np.sort(np.asarray(ids), -1),
                           np.sort(np.asarray(ref.ids), -1))
    print(f"matches single-device BrePartition: {match}")


if __name__ == "__main__":
    main()
