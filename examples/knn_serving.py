"""kNN serving through the fault-tolerant retrieval service
(serve/retrieval.py): deadlines, admission control, and the degradation
ladder over BrePartition search — plus the distributed launch path when
more than one device is available.

On this CPU container the mesh is whatever jax.devices() offers (run under
XLA_FLAGS=--xla_force_host_platform_device_count=8 to see a sharded
tenant); on a pod the same code runs on the production mesh.

    PYTHONPATH=src python examples/knn_serving.py
"""

import jax
import numpy as np

from repro.core.index import build_index
from repro.data.pipeline import PAPER_DATASETS, make_queries, make_vectors
from repro.launch.mesh import make_host_mesh
from repro.serve import RetrievalService, ServiceConfig


def main():
    spec = PAPER_DATASETS["deep"]
    data = make_vectors(spec, scale=0.01)
    queries = make_queries(spec, num=16, scale=0.01)
    index = build_index(data, spec.measure, m=8)

    svc = RetrievalService(ServiceConfig(default_deadline_s=2.0))
    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    tenant = svc.register_tenant("demo", index, mesh=mesh)
    print(f"tenant live_n={tenant.live_n} "
          f"sharded={'yes' if tenant.sharded else 'no'}")

    # k is validated against the LIVE point count up front: an oversized k
    # resolves to an explicit shed, never a deep pipeline error.
    bad = svc.search_sync("demo", queries[:1], k=tenant.live_n + 1)
    print(f"k > live_n: quality={bad.quality} reason={bad.shed_reason}")

    k = 10
    # Warm the compiled-program cache with an unhurried deadline (the
    # budget-retry ladder compiles one program per budget size); under a
    # tight deadline a cold cache degrades instead of blocking — exactly
    # the ladder the chaos drill exercises (docs/serving_robustness.md).
    for _ in range(3):
        r = svc.search_sync("demo", queries, k, deadline_s=60.0)
        svc.search_sync("demo", queries, k, deadline_s=60.0,
                        target_recall=0.9)
    r = svc.search_sync("demo", queries, k)
    print(f"{len(queries)} queries: quality={r.quality} "
          f"latency={r.latency_s * 1e3:.1f} ms "
          f"deadline_met={r.deadline_met}")

    # Exact-tier responses match the single-device reference pipeline.
    from repro.core import search
    ref = search.knn_batch(index, queries, k)
    match = np.array_equal(np.sort(r.ids, -1),
                           np.sort(np.asarray(ref.ids), -1))
    print(f"matches single-device BrePartition: {match}")

    # Degraded tiers on demand: a deadline below the known launch cost
    # walks the ladder (exact -> approx -> partial -> shed) instead of
    # blowing the budget.  The quality label reports what actually ran.
    for frac, note in ((1.5, "approx window"), (0.7, "partial window"),
                       (0.1, "must shed")):
        est = tenant.cost.estimate()     # the ladder prices with LIVE est
        resp = svc.search_sync("demo", queries, k, deadline_s=est * frac)
        print(f"deadline={est * frac * 1e3:6.1f} ms ({note}): "
              f"quality={resp.quality} tiers={resp.meta.get('tier_path')}")

    # §8 approximate mode is a first-class request parameter.
    resp = svc.search_sync("demo", queries, k, target_recall=0.9)
    print(f"target_recall=0.9: quality={resp.quality}")
    print(f"stats: launches={svc.counters['launches']} "
          f"tier mix=exact:{svc.counters['exact']} "
          f"approx:{svc.counters['approx']} "
          f"partial:{svc.counters['partial']} shed:{svc.counters['shed']}")


if __name__ == "__main__":
    main()
