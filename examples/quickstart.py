"""Quickstart: build a BrePartition index and run exact + approximate kNN.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import search
from repro.core.index import build_index
from repro.data.pipeline import PAPER_DATASETS, make_queries, make_vectors


def main():
    spec = PAPER_DATASETS["audio"]          # 192-dim, exponential distance
    data = make_vectors(spec, scale=0.05)   # CPU-sized slice of "Audio"
    queries = make_queries(spec, num=5, scale=0.05)
    print(f"dataset {spec.name}: n={data.shape[0]} d={spec.d} "
          f"measure={spec.measure}")

    # Offline (paper Alg. 5): M* from Theorem 4, PCCP partitioning,
    # P-transform tuples, ball forest.
    index = build_index(data, spec.measure)
    print(f"index: M={index.m} subspaces, {index.num_clusters} balls each")

    # Online (paper Alg. 6): filter with Cauchy bounds, prune balls, refine.
    for k in (5, 20):
        res = search.knn_batch(index, queries, k)
        brute_ids, brute_d = search.brute_force_knn(
            data, queries[0], k, spec.measure)
        ok = np.array_equal(np.sort(np.asarray(res.ids[0])),
                            np.sort(np.asarray(brute_ids)))
        print(f"k={k}: exact={bool(res.exact.all())} "
              f"mean_candidates={float(np.mean(res.num_candidates)):.0f} "
              f"(of {data.shape[0]}), matches brute force: {ok}")

    # Approximate mode (paper §8): probability-guaranteed, tighter bounds.
    res_a = search.knn_batch(index, queries, 20, approx_p=0.8)
    print("approx p=0.8: mean_candidates="
          f"{float(np.mean(res_a.num_candidates)):.0f}")


if __name__ == "__main__":
    main()
