"""kNN-LM serving: a small LM decodes with BrePartition retrieval over a
datastore of its own hidden states (the paper's technique as a first-class
serving feature).

    PYTHONPATH=src python examples/knnlm_decode.py
"""

import jax
import numpy as np

from repro import configs
from repro.models.registry import build_model
from repro.serve.engine import Engine, EngineConfig, Request
from repro.serve.knnlm import KNNLMHook, build_datastore


def main():
    bundle = build_model(configs.get_reduced("qwen2.5-32b"))
    params = bundle.init(jax.random.PRNGKey(0))
    vocab = bundle.cfg.vocab_size

    rng = np.random.default_rng(0)
    corpus = rng.integers(1, vocab, (8, 48))
    store = build_datastore(bundle, params, corpus)
    print(f"datastore: {store.index.n} keys, dim {store.hidden_dim}, "
          f"M={store.index.m} subspaces")

    hook = KNNLMHook(store=store, k=8, lam=0.3)
    cfg = EngineConfig(slots=4, max_seq=96, prefill_len=16)
    eng = Engine(bundle, params, cfg, logits_hook=hook)
    for uid in range(6):
        eng.submit(Request(uid=uid, prompt=rng.integers(1, vocab, 16),
                           max_new_tokens=12))
    done = eng.run()
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: {r.output}")
    print(f"kNN queries served: {hook.queries_served} "
          f"(engine ticks: {eng.ticks})")

    # approximate mode (paper §8)
    hook_a = KNNLMHook(store=store, k=8, lam=0.3, approx_p=0.8)
    eng2 = Engine(bundle, params, cfg, logits_hook=hook_a)
    eng2.submit(Request(uid=0, prompt=rng.integers(1, vocab, 16),
                        max_new_tokens=8))
    eng2.run()
    print(f"approximate mode (p=0.8) served {hook_a.queries_served} queries")


if __name__ == "__main__":
    main()
