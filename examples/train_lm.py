"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production substrate — sharded train step, checkpoint/restart,
straggler monitor, deterministic data pipeline.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

import jax

from repro import configs
from repro.configs.common import ShapeSpec
from repro.data.pipeline import TokenStreamConfig, token_batch
from repro.models.registry import build_model
from repro.models.transformer import LMConfig
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptimizerConfig
from repro.train.straggler import StragglerMonitor
from repro.train.train_loop import (TrainConfig, init_train_state,
                                    make_train_step)
from repro.launch.mesh import make_host_mesh


def small_100m() -> LMConfig:
    # ~100M params: 12L x 512 with a 32k vocab
    return configs.get_config(
        "starcoder2-3b", num_layers=12, d_model=512, num_heads=8,
        num_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=32768,
        scan_layers=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpts")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = small_100m()
    bundle = build_model(cfg)
    print(f"model: {bundle.count_params/1e6:.1f}M params")

    mesh = make_host_mesh()
    shape = ShapeSpec("train", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    tc = TrainConfig(microbatches=1, loss_chunk=128,
                     opt=OptimizerConfig(peak_lr=3e-4, warmup_steps=20,
                                         total_steps=args.steps))
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch)

    with mesh:
        step_fn = make_train_step(bundle, mesh, tc, shape)
        start = ckpt.latest_step(args.ckpt_dir)
        if start is not None:
            print(f"resuming from checkpoint step {start}")
            state = init_train_state(bundle, mesh, jax.random.PRNGKey(0))
            structs = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state = ckpt.restore_checkpoint(args.ckpt_dir, start, structs)
        else:
            start = 0
            state = init_train_state(bundle, mesh, jax.random.PRNGKey(0))

        mon = StragglerMonitor()
        for i in range(start, args.steps):
            mon.start_step()
            batch = token_batch(stream, i, mesh)
            state, metrics = step_fn(state, batch)
            mon.end_step()
            if (i + 1) % args.ckpt_every == 0:
                ckpt.save_checkpoint(args.ckpt_dir, i + 1, state)
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                      f"acc={float(metrics['accuracy']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f}")
        print("straggler summary:", mon.summary())


if __name__ == "__main__":
    main()
