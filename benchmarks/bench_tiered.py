"""Out-of-core tiered store vs fully-resident pipeline (core/tiered.py).

The acceptance row for the tiered datastore: a point table whose cold
tier is LARGER than ``resident_bytes`` is served with

* ``host_bytes_fetched_per_query`` strictly below the full cold-table
  bytes (the envelope gate skips blocks; the LRU cache amortizes the
  rest), and
* double-buffered wall clock within 1.15x of the fully-resident fused
  pipeline at the default bench scale (prefetch hides transfer behind
  the prune kernels).

Also reported: steady-state cache hit rate (warm cache, repeat traffic)
and the resident fast path's zero-overhead delegation when the budget
fits the whole cold tier.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import search
from repro.core.index import build_index, cold_point_fields
from repro.core.tiered import TieredPointStore

from .common import Row


def _cold_bytes(index) -> int:
    return sum(np.asarray(getattr(index, f)).nbytes
               for f in cold_point_fields(index))


def run(scale: float = 1.0):
    n = max(2048, int(16384 * scale))
    d, m, k, q = 32, 4, 10, 32
    block_rows = 512
    # Blob corpus with row-block locality: the regime the envelope gate
    # exists for.  Well-separated blobs stored contiguously + traffic
    # concentrated on one blob (lookup-style near-duplicate queries, the
    # kNN-LM datastore pattern) means most cold blocks are rejected at
    # envelope level and never fetched.  Shuffled rows would make every
    # envelope block an average of the whole corpus and admit everything.
    rng = np.random.default_rng(0)
    n_blobs = 16
    per = n // n_blobs
    data = np.concatenate([
        rng.normal(size=(per, d)) + 100.0 * j
        for j in range(n_blobs)]).astype(np.float32)
    ys = data[rng.integers(0, per, size=q)] + 0.01   # blob-0 traffic

    index = build_index(data, "squared_euclidean", m=m, num_clusters=64,
                        seed=0)
    budget = search.default_budget(index, k)
    cold = _cold_bytes(index)
    # the point table does NOT fit: budget is ~40% of the cold tier
    resident_bytes = max(1, (4 * cold) // 10)

    store = TieredPointStore(index, resident_bytes=resident_bytes,
                             block_rows=block_rows)
    assert not store.is_resident
    # Cold pass: every admitted block is fetched here, so this is where
    # the fetched-bytes acceptance column comes from (steady state fetches
    # nothing by design — the LRU cache holds the admitted working set).
    res_t = store.search(ys, k, budget)
    cold_stats = dict(store.stats)
    fetched_pq = cold_stats["host_bytes_fetched"] / max(
        1, cold_stats["queries"])
    res_r = search.knn_search_batch(index, ys, k, budget,
                                    block_rows=block_rows)
    np.testing.assert_array_equal(np.asarray(res_t.ids),
                                  np.asarray(res_r.ids))

    # resident fast path: budget >= cold tier delegates outright
    fast = TieredPointStore(index, resident_bytes=2 * cold,
                            block_rows=block_rows)
    assert fast.is_resident

    store.warm_cache()
    resident_fn = lambda: search.knn_search_batch(   # noqa: E731
        index, ys, k, budget, block_rows=block_rows)
    tiered_fn = lambda: store.search(ys, k, budget)  # noqa: E731
    fast_fn = lambda: fast.search(ys, k, budget)     # noqa: E731
    for _ in range(4):   # settle every jit before timing
        resident_fn(), tiered_fn(), fast_fn()
    store.reset_stats()
    # INTERLEAVED timing, min-of-samples estimator: the wall ratio is a
    # ratio of two timings, so both sides must sample the same noise
    # environment (separate back-to-back windows let a scheduler hiccup
    # land on one side only), and on a shared box the minimum is the
    # least-noise estimate of the true cost — the same estimator
    # ``python -m timeit`` reports.
    for fn in (resident_fn, tiered_fn, fast_fn):
        fn.samples = []
    for _ in range(30):
        for fn in (resident_fn, tiered_fn, fast_fn):
            t0 = time.perf_counter()
            jax.block_until_ready(fn().ids)
            fn.samples.append(time.perf_counter() - t0)
    us_res, us_tier, us_fast = (
        float(np.min(fn.samples) * 1e6)
        for fn in (resident_fn, tiered_fn, fast_fn))

    s = store.stats
    lookups = s["cache_hits"] + s["cache_misses"]
    hit_rate = s["cache_hits"] / max(1, lookups)
    wall_ratio = us_tier / us_res

    rows = [
        Row("tiered", f"resident_n{n}_q{q}", us_res, {
            "n": n, "d": d, "qps": round(q / (us_res / 1e6), 1),
            "cold_bytes": cold,
        }),
        Row("tiered", f"tiered_n{n}_q{q}", us_tier, {
            "n": n, "d": d, "qps": round(q / (us_tier / 1e6), 1),
            "resident_bytes": resident_bytes,
            "cold_bytes": cold,
            # acceptance: strictly below the full cold-table bytes
            "host_bytes_fetched_per_query": round(fetched_pq, 1),
            "cache_hit_rate": round(hit_rate, 3),
            "blocks_admitted": s["blocks_admitted"],
            "blocks_total": s["blocks_total"],
            # acceptance: <= 1.15 at default scale (double-buffering)
            "wall_ratio_vs_resident": round(wall_ratio, 3),
        }),
    ]

    rows.append(Row("tiered", f"fastpath_n{n}_q{q}", us_fast, {
        "qps": round(q / (us_fast / 1e6), 1),
        "wall_ratio_vs_resident": round(us_fast / us_res, 3),
    }))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
