"""Shard-count scaling of the distributed fused pipeline (dist/knn.py).

Runs the measurement in a SUBPROCESS with 8 forced host devices — the same
device-count isolation rule as tests/dist_checks.py: jax locks the device
count at first backend init, so the benchmarking session must keep its
1-device view.  Meshes of 1/2/4/8 shards are carved from the 8-device
backend; queries-per-second per shard count shows how the per-shard
filter/prune/refine cost amortizes (on host CPU the collectives are
memcpys, so this tracks the partitioning overhead floor, not ICI).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import Row

_SCRIPT = textwrap.dedent("""
    import os, json, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys
    sys.path.insert(0, %(src)r)
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.bregman import get_family
    from repro.core.index import build_index
    from repro.dist import knn as dknn
    from repro.dist.sharding import make_mesh

    n, d, m, k, q = %(n)d, 64, 8, 10, 64
    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(0), (n, d), scale=1.0))
    ys = jnp.asarray(np.asarray(
        fam.sample(jax.random.PRNGKey(1), (q, d), scale=1.0)))
    forest = build_index(data, "squared_euclidean", m=m, num_clusters=64,
                         seed=0)
    budget = max(2 * k, n // 16)
    out = []
    for shards in (1, 2, 4, 8):
        mesh = make_mesh((shards,), ("data",),
                         devices=jax.devices()[:shards])
        sharded = dknn.shard_index(forest, mesh)
        yv = dknn.query_subview(forest.partition, ys)
        run = lambda: jax.block_until_ready(dknn.distributed_knn(
            sharded, yv, family="squared_euclidean", k=k, budget=budget,
            mesh=mesh).ids)
        run()                                    # compile + warm
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            run()
            times.append(time.perf_counter() - t0)
        us = float(np.median(times) * 1e6)
        out.append({"shards": shards, "us": us,
                    "qps": round(q / (us / 1e6), 1)})
    print("RESULT " + json.dumps(out))
""")


def run(scale: float = 1.0):
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    n = max(512, int(8192 * scale))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT % {"src": src, "n": n}],
        env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError("dist bench subprocess failed:\n"
                           f"{proc.stderr[-2000:]}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    records = json.loads(line[len("RESULT "):])
    base_us = records[0]["us"]
    return [Row("dist_knn", f"shards{r['shards']}", r["us"],
                {"n": n, "qps": r["qps"],
                 "vs_1shard": round(base_us / r["us"], 2)})
            for r in records]


if __name__ == "__main__":
    for row in run(0.25):
        print(row.csv())
