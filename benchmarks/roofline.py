"""Roofline terms per (arch x shape x mesh) from dry-run records.

Reads the JSON written by ``repro.launch.dryrun --out`` and derives, per
cell (TPU v5e constants from repro.launch.mesh):

    compute term    = per-device HLO FLOPs / 197e12
    memory term     = per-device HLO bytes / 819e9
    collective term = per-device link bytes / 50e9

Two collective accountings are reported:

* ``simple``: sum of collective operand bytes (the brief's formula);
* ``ring``:   ring-algorithm link traffic per device —
      all-reduce      2 (p-1)/p x bytes
      all-gather      (p-1)      x bytes   (operand = the local shard)
      reduce-scatter  (p-1)/p    x bytes
      all-to-all      (p-1)/p    x bytes
      collective-permute      1  x bytes

Also derived: MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve), the useful-
compute fraction MODEL_FLOPS / (chips x HLO_FLOPs/device), the dominant
term, and the roofline fraction = ideal-compute-time / bounding-term-time.
"""

from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")  # allow `python -m benchmarks.roofline` from repo root

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16  # noqa: E402

RING_FACTORS = {
    "all-reduce": lambda p: 2.0 * (p - 1) / p if p > 1 else 0.0,
    "all-gather": lambda p: float(p - 1),
    "reduce-scatter": lambda p: (p - 1) / p if p > 1 else 0.0,
    "all-to-all": lambda p: (p - 1) / p if p > 1 else 0.0,
    "ragged-all-to-all": lambda p: (p - 1) / p if p > 1 else 0.0,
    "collective-permute": lambda p: 1.0,
}


def derive(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return None
    hlo = rec["hlo"]
    chips = rec["chips"]
    compute_s = hlo["flops_per_device"] / PEAK_FLOPS_BF16
    memory_s = hlo["bytes_per_device"] / HBM_BW
    simple_s = hlo["collective_bytes_per_device"] / ICI_BW

    ring_bytes = 0.0
    for kind, agg in hlo["collectives"].items():
        # group size: fall back to the mesh minor axis when unknown
        p = 16
        factor = RING_FACTORS.get(kind, lambda p: 1.0)(p)
        ring_bytes += agg["bytes_in"] * factor
    ring_s = ring_bytes / ICI_BW

    model_flops = rec["model_flops"]
    ideal_s = model_flops / (chips * PEAK_FLOPS_BF16)
    terms = {"compute": compute_s, "memory": memory_s, "collective": ring_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_simple_s": simple_s, "collective_ring_s": ring_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_fraction": model_flops / max(chips * hlo["flops_per_device"],
                                             1e-30),
        "roofline_fraction": ideal_s / max(bound_s, 1e-30),
        # serve cells carry the documented CPU-bf16-upcast adjustment
        "peak_gib": rec["memory"].get(
            "peak_bytes_tpu_adjusted",
            rec["memory"]["peak_bytes_est"]) / 2**30,
        "fits_hbm": rec["memory"].get(
            "peak_bytes_tpu_adjusted",
            rec["memory"]["peak_bytes_est"]) < 16 * 2**30,
    }


MOVE_DOWN = {
    "compute": "cut remat recompute (remat_policy=dots) / rebalance "
               "under-sharded matmuls",
    "memory": "fuse or shrink HBM traffic: bigger flash tiles, fewer "
              "materialized intermediates, bf16 carriers",
    "collective": "reshard to cut gather volume (weight-stationary layout) "
                  "or overlap collectives with compute",
}


def move_down(r: dict) -> str:
    if r["dominant"] == "compute" and r["useful_fraction"] < 0.7 \
            and r["shape"].startswith("train"):
        return "compute is 1/3 remat recompute: remat_policy=dots"
    return MOVE_DOWN[r["dominant"]]


def table(records: list[dict]) -> str:
    rows = [derive(r) for r in records]
    rows = [r for r in rows if r]
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s "
           "| dominant | useful | roofline | peak GiB (adj) | fits "
           "| to move the dominant term down |")
    sep = "|" + "---|" * 12
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_ring_s']:.3e} | {r['dominant']} "
            f"| {r['useful_fraction']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['peak_gib']:.1f} | {'Y' if r['fits_hbm'] else 'N'} "
            f"| {move_down(r)} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("dryrun_json")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    records = json.load(open(args.dryrun_json))
    if args.csv:
        for r in records:
            d = derive(r)
            if d:
                print(",".join(str(v) for v in d.values()))
    else:
        print(table(records))


if __name__ == "__main__":
    main()
