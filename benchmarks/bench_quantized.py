"""Int8 storage tier vs fp32: filter-phase bytes moved + end-to-end QPS.

The quantized BallForest's headline win is HBM traffic: the batched filter
and prune phases stream the four (n, M) stat tables for EVERY query block,
and the int8 tier streams them as 1-byte codes plus eight fp32 decode
scalars per row.  The ``*_filter_bytes`` derived fields are the exact
per-query-block byte counts implied by the stored dtypes (the analytic
traffic model the TPU roofline uses); the QPS rows are measured wall-clock
on whatever backend runs the bench (on CPU the int8 path pays a decode
convert it would not pay on the TPU MXU path, so read the traffic ratio as
the hardware-independent signal and the QPS pair as the end-to-end sanity
check).

Capacity is reported alongside: bytes per stored point across the
point-major tables (the "millions of users" number).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import search
from repro.core.bregman import get_family
from repro.core.index import build_index

from .common import Row, timeit

F32 = 4


def _filter_bytes(index) -> int:
    """Bytes the filter+prune phases stream per query block (whole index)."""
    n, m = index.alpha.shape
    stat_tables = 4                       # alpha, sqrt_gamma, amin_pt, gmax_pt
    if index.storage == "int8":
        return n * (stat_tables * m * 1 + 8 * F32)
    return n * stat_tables * m * F32


def _point_bytes(index) -> float:
    """Stored bytes per point across the point-major tables (capacity)."""
    n = index.n
    total = 0
    from repro.core.index import point_fields
    for f in point_fields(index):
        a = getattr(index, f)
        total += a.size * a.dtype.itemsize
    return total / n


def run(scale: float = 1.0):
    n = max(1024, int(16384 * scale))
    d, m, k, q = 128, 32, 10, 64
    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(0), (n, d), scale=1.0))
    ys = np.asarray(fam.sample(jax.random.PRNGKey(1), (q, d), scale=1.0))

    rows = []
    indexes = {}
    for name, quant in (("f32", False), ("int8", True)):
        index = build_index(data, "squared_euclidean", m=m, num_clusters=64,
                            quantize=quant, seed=0)
        indexes[name] = index
        budget = search.default_budget(index, k)
        us = timeit(lambda: search.knn_search_batch(index, ys, k, budget),
                    repeats=5)
        rows.append(Row("quantized", f"search_{name}_q{q}", us, {
            "n": n, "d": d, "m": m,
            "qps": round(q / (us / 1e6), 1),
            "filter_bytes": _filter_bytes(index),
            "point_bytes": round(_point_bytes(index), 1),
        }))

    ratio = _filter_bytes(indexes["f32"]) / _filter_bytes(indexes["int8"])
    cap_ratio = _point_bytes(indexes["f32"]) / _point_bytes(indexes["int8"])
    rows.append(Row("quantized", "traffic_ratio", 0.0, {
        "filter_traffic_x": round(ratio, 2),        # acceptance: >= 3x
        "capacity_x": round(cap_ratio, 2),
    }))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
