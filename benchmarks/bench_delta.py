"""Markdown delta table between two BENCH_*.json artifacts.

    python benchmarks/bench_delta.py PREV.json CURRENT.json

Reads the ``benchmarks.run --json`` payloads, joins rows on
``(bench, name)``, and prints a GitHub-flavored markdown table of
us/call and qps deltas — CI appends it to the job summary so perf
regressions are visible at review time without downloading artifacts.
The script never fails the job: any malformed input degrades to a note
(the delta is advisory; the artifacts remain the source of truth).
"""

from __future__ import annotations

import json
import sys

# us/call swings below this are timer noise on shared CI runners; the
# table marks larger ones so reviewers scan only the meaningful lines.
NOISE_PCT = 10.0


def _rows(path):
    with open(path) as f:
        payload = json.load(f)
    rows = {}
    for r in payload.get("rows", []):
        rows[(r["bench"], r["name"])] = r
    return payload, rows


def _fmt_pct(pct):
    mark = " ⚠" if abs(pct) >= NOISE_PCT else ""
    return f"{pct:+.1f}%{mark}"


def main(argv) -> int:
    if len(argv) != 3:
        print("usage: bench_delta.py PREV.json CURRENT.json",
              file=sys.stderr)
        return 0                       # advisory: never fail the job
    try:
        prev_payload, prev = _rows(argv[1])
        cur_payload, cur = _rows(argv[2])
    except (OSError, ValueError, KeyError) as e:
        print(f"bench delta unavailable: {e}")
        return 0

    print("## Benchmark delta vs previous push")
    print()
    print(f"prev: scale={prev_payload.get('scale')} "
          f"wall={prev_payload.get('wall_seconds')}s "
          f"failures={len(prev_payload.get('failures', []))} · "
          f"current: scale={cur_payload.get('scale')} "
          f"wall={cur_payload.get('wall_seconds')}s "
          f"failures={len(cur_payload.get('failures', []))}")
    print()
    print("| bench | name | prev us | cur us | Δus | prev qps | cur qps |")
    print("|---|---|---:|---:|---:|---:|---:|")
    for key in sorted(set(prev) | set(cur)):
        b, n = key
        p, c = prev.get(key), cur.get(key)
        if p is None or c is None:
            status = "added" if p is None else "removed"
            print(f"| {b} | {n} | — | — | {status} | — | — |")
            continue
        try:
            pu, cu = float(p["us_per_call"]), float(c["us_per_call"])
            pct = 100.0 * (cu - pu) / pu if pu else 0.0
            pq = (p.get("derived") or {}).get("qps", "—")
            cq = (c.get("derived") or {}).get("qps", "—")
            print(f"| {b} | {n} | {pu:.0f} | {cu:.0f} | {_fmt_pct(pct)} "
                  f"| {pq} | {cq} |")
        except (KeyError, TypeError, ValueError):
            # Schema drift in one artifact must not break the summary.
            print(f"| {b} | {n} | — | — | malformed row | — | — |")
    print()
    print(f"(Δus ⚠ marks swings ≥ {NOISE_PCT:.0f}%; positive = slower. "
          "Non-blocking — artifacts are the source of truth.)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
