"""Markdown delta table between two BENCH_*.json artifacts.

    python benchmarks/bench_delta.py PREV.json CURRENT.json
    python benchmarks/bench_delta.py --ratchet PREV.json CURRENT.json

Reads the ``benchmarks.run --json`` payloads, joins rows on
``(bench, name)``, and prints a GitHub-flavored markdown table of
us/call and qps deltas — CI appends it to the job summary so perf
regressions are visible at review time without downloading artifacts.
In the default (table) mode the script never fails the job: any
malformed input degrades to a note (the delta is advisory; the
artifacts remain the source of truth).

``--ratchet`` is the BLOCKING mode: it compares only the
``kernel_roofline`` rows' ``derived.roofline_fraction`` and exits 1 when
a kernel's achieved fraction of the roofline dropped by more than
``ROOFLINE_DROP_TOL`` relative — the regression gate the roofline
summary was promoted into (ROADMAP "Roofline follow-ups").  Missing
artifacts still exit 0 (first push of a branch has no baseline); a
fetched baseline that parses but lost a kernel row fails, so rows
cannot silently disappear from the gate.
"""

from __future__ import annotations

import json
import sys

# us/call swings below this are timer noise on shared CI runners; the
# table marks larger ones so reviewers scan only the meaningful lines.
NOISE_PCT = 10.0

# Relative drop in derived.roofline_fraction that fails the ratchet.
# Wide on purpose: shared CI runners jitter the achieved bandwidth run
# to run, and the gate exists to catch structural regressions (a kernel
# falling off its fused path), not single-digit noise.
ROOFLINE_DROP_TOL = 0.30

ROOFLINE_BENCH = "kernel_roofline"


def _rows(path):
    with open(path) as f:
        payload = json.load(f)
    rows = {}
    for r in payload.get("rows", []):
        rows[(r["bench"], r["name"])] = r
    return payload, rows


def _fmt_pct(pct):
    mark = " ⚠" if abs(pct) >= NOISE_PCT else ""
    return f"{pct:+.1f}%{mark}"


def _roofline_fractions(rows):
    out = {}
    for (bench, name), r in rows.items():
        if bench != ROOFLINE_BENCH:
            continue
        frac = (r.get("derived") or {}).get("roofline_fraction")
        if isinstance(frac, (int, float)):
            out[name] = float(frac)
    return out


def ratchet(prev_path, cur_path) -> int:
    """Blocking roofline gate; returns the process exit code."""
    try:
        _, prev = _rows(prev_path)
    except (OSError, ValueError, KeyError) as e:
        # No baseline (first push of a branch / expired artifact) is not
        # a regression — the CURRENT artifact becomes the next baseline.
        print(f"roofline ratchet: no usable baseline ({e}); passing")
        return 0
    try:
        _, cur = _rows(cur_path)
    except (OSError, ValueError, KeyError) as e:
        print(f"roofline ratchet: current artifact unreadable: {e}")
        return 1
    base = _roofline_fractions(prev)
    now = _roofline_fractions(cur)
    if not base:
        print("roofline ratchet: baseline has no kernel_roofline rows; "
              "passing")
        return 0

    failures = []
    print("## Roofline ratchet")
    print()
    print("| kernel | baseline | current | rel Δ | status |")
    print("|---|---:|---:|---:|---|")
    for name in sorted(base):
        if name not in now:
            failures.append(f"{name}: roofline row vanished from the "
                            "current run")
            print(f"| {name} | {base[name]:.3f} | — | — | MISSING |")
            continue
        rel = (now[name] - base[name]) / base[name] if base[name] else 0.0
        ok = rel >= -ROOFLINE_DROP_TOL
        status = "ok" if ok else "REGRESSED"
        if not ok:
            failures.append(
                f"{name}: roofline_fraction {base[name]:.3f} -> "
                f"{now[name]:.3f} ({rel:+.1%}, tolerance "
                f"-{ROOFLINE_DROP_TOL:.0%})")
        print(f"| {name} | {base[name]:.3f} | {now[name]:.3f} "
              f"| {rel:+.1%} | {status} |")
    for name in sorted(set(now) - set(base)):
        print(f"| {name} | — | {now[name]:.3f} | — | new |")
    print()
    if failures:
        print("roofline ratchet FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"roofline ratchet OK ({len(base)} kernels, tolerance "
          f"-{ROOFLINE_DROP_TOL:.0%} relative)")
    return 0


def main(argv) -> int:
    if len(argv) == 4 and argv[1] == "--ratchet":
        return ratchet(argv[2], argv[3])
    if len(argv) != 3:
        print("usage: bench_delta.py [--ratchet] PREV.json CURRENT.json",
              file=sys.stderr)
        return 0                       # advisory: never fail the job
    try:
        prev_payload, prev = _rows(argv[1])
        cur_payload, cur = _rows(argv[2])
    except (OSError, ValueError, KeyError) as e:
        print(f"bench delta unavailable: {e}")
        return 0

    print("## Benchmark delta vs previous push")
    print()
    print(f"prev: scale={prev_payload.get('scale')} "
          f"wall={prev_payload.get('wall_seconds')}s "
          f"failures={len(prev_payload.get('failures', []))} · "
          f"current: scale={cur_payload.get('scale')} "
          f"wall={cur_payload.get('wall_seconds')}s "
          f"failures={len(cur_payload.get('failures', []))}")
    print()
    print("| bench | name | prev us | cur us | Δus | prev qps | cur qps |")
    print("|---|---|---:|---:|---:|---:|---:|")
    for key in sorted(set(prev) | set(cur)):
        b, n = key
        p, c = prev.get(key), cur.get(key)
        if p is None or c is None:
            status = "added" if p is None else "removed"
            print(f"| {b} | {n} | — | — | {status} | — | — |")
            continue
        try:
            pu, cu = float(p["us_per_call"]), float(c["us_per_call"])
            pct = 100.0 * (cu - pu) / pu if pu else 0.0
            pq = (p.get("derived") or {}).get("qps", "—")
            cq = (c.get("derived") or {}).get("qps", "—")
            print(f"| {b} | {n} | {pu:.0f} | {cu:.0f} | {_fmt_pct(pct)} "
                  f"| {pq} | {cq} |")
        except (KeyError, TypeError, ValueError):
            # Schema drift in one artifact must not break the summary.
            print(f"| {b} | {n} | — | — | malformed row | — | — |")
    print()
    print(f"(Δus ⚠ marks swings ≥ {NOISE_PCT:.0f}%; positive = slower. "
          "Non-blocking — artifacts are the source of truth.)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
