"""§Perf hillclimb harness: re-lower a cell under a named variant and diff
its roofline terms against the baseline record.

    PYTHONPATH=src python -m benchmarks.perf_iterate \
        --arch qwen3-moe-30b-a3b --shape train_4k \
        --variant remat_dots --baseline dryrun_all.json

Variants are (config overrides, sharding-rule overrides, train-config)
bundles — each one is a hypothesis from EXPERIMENTS.md §Perf.  The harness
prints before/after terms so the iteration log writes itself.
"""

from __future__ import annotations

import argparse
import importlib
import json
import sys

sys.path.insert(0, "src")


def variant_space():
    """name -> (config_overrides, rules_name, microbatches, note)."""
    return {
        "baseline": ({}, None, 1, "as swept"),
        # compute-term levers
        "remat_dots": ({"remat_policy": "dots"}, None, 1,
                       "save matmul outputs: kill bwd recompute FLOPs "
                       "(useful 0.67 -> ~0.75) at activation-memory cost"),
        "remat_dots_micro4": ({"remat_policy": "dots"}, None, 4,
                              "dots policy + 4 microbatches: recompute "
                              "savings with 1/4 the live activations"),
        "micro2": ({}, None, 2, "2 microbatches"),
        "no_remat": ({"remat": False}, None, 1,
                     "no rematerialization at all (memory ceiling probe)"),
        # memory-term levers
        "micro4": ({}, None, 4, "4 microbatches: 4x smaller live batch"),
        "loss_chunk_2k": ({}, None, 1, "fewer, larger loss chunks"),
        # collective-term levers
        "replicated_seq": ({}, "noseq", 1,
                           "disable sequence parallelism (ablation: the "
                           "paper-naive activation layout)"),
        "replicated_seq_micro8": ({}, "noseq", 8,
                                  "no SP + 8 microbatches: trade the SP "
                                  "activation all-gathers for live-batch "
                                  "slices (collective-bound trains)"),
        "moe_group_2k": ({"moe_group": 2048}, None, 1,
                         "bigger MoE dispatch groups: fewer, larger a2a"),
        "moe_cf1": ({"moe_cf": 1.0}, None, 1,
                    "capacity factor 1.0: 20% less expert compute+a2a, "
                    "more drops"),
        "attn_chunk_2k": ({"q_chunk": 2048, "kv_chunk": 2048}, None, 1,
                          "bigger flash tiles: fewer chunk boundaries"),
        "rwkv_chunk_256": ({"rwkv_chunk": 256}, None, 1,
                           "bigger WKV chunks: fewer state hops, bigger "
                           "pairwise tensor"),
    }


def apply_variant(arch, overrides):
    """Translate variant overrides into a config object."""
    import dataclasses
    from repro import configs
    kw = dict(overrides)
    moe_group = kw.pop("moe_group", None)
    moe_cf = kw.pop("moe_cf", None)
    cfg = configs.get_config(arch, **kw)
    if moe_group or moe_cf:
        moe = dataclasses.replace(
            cfg.moe,
            **({"group_tokens": moe_group} if moe_group else {}),
            **({"capacity_factor": moe_cf} if moe_cf else {}))
        cfg = dataclasses.replace(cfg, moe=moe)
    return cfg


def run(arch: str, shape: str, variant: str, multi_pod: bool = False):
    # import inside: XLA_FLAGS must be set by dryrun import order
    from repro.launch import dryrun
    overrides, rules_name, micro, note = variant_space()[variant]

    # rules override: register a no-seq rules table on the fly
    if rules_name == "noseq":
        from repro.dist import sharding as shd
        shd.NOSEQ_RULES = dict(shd.DEFAULT_RULES, seq=())
        # patch the lookup dict used by run_cell
        _orig = dryrun.run_cell

        def run_cell(*a, **kw):
            kw["rules_name"] = None
            import repro.dist.sharding as s
            saved = s.DEFAULT_RULES
            s.DEFAULT_RULES = shd.NOSEQ_RULES
            try:
                return _orig(*a, **kw)
            finally:
                s.DEFAULT_RULES = saved
        cell_fn = run_cell
    else:
        cell_fn = dryrun.run_cell

    cfg = apply_variant(arch, overrides) if overrides else None
    if cfg is not None:
        # route through run_cell's overrides path by monkeypatching configs
        from repro import configs as _configs
        _orig_get = _configs.get_config
        _configs.get_config = lambda a, **kw: (
            cfg if a == arch and not kw else _orig_get(a, **kw))
        try:
            rec = cell_fn(arch, shape, multi_pod, microbatches=micro)
        finally:
            _configs.get_config = _orig_get
    else:
        rec = cell_fn(arch, shape, multi_pod, microbatches=micro)
    rec["variant"] = variant
    rec["note"] = note
    return rec


def main():
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--baseline", default=None,
                    help="dryrun JSON with the baseline record")
    ap.add_argument("--out", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    rec = run(args.arch, args.shape, args.variant, args.multi_pod)
    roofline = importlib.import_module("benchmarks.roofline")
    after = roofline.derive(rec)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=1,
                     default=str)[:2000])
    if after:
        print("\nAFTER :", {k: (f"{v:.4g}" if isinstance(v, float) else v)
                            for k, v in after.items()})
    if args.baseline:
        base = [r for r in json.load(open(args.baseline))
                if r["arch"] == args.arch and r["shape"] == args.shape
                and r["mesh"] == rec["mesh"]]
        if base:
            before = roofline.derive(base[0])
            print("BEFORE:", {k: (f"{v:.4g}" if isinstance(v, float) else v)
                              for k, v in (before or {}).items()})
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec, default=str) + "\n")


if __name__ == "__main__":
    main()
