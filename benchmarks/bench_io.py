"""Fig. 11 — I/O cost (bytes-moved proxy) vs k: BP / BBT / VAF."""

from __future__ import annotations

import numpy as np

from repro.core.baselines import BBTree, VAFile
from repro.core.index import build_index
from repro.core import search

from .common import Row, dataset


def run(scale: float = 0.02) -> list[Row]:
    rows = []
    for name in ("audio", "deep"):
        spec, data, queries = dataset(name, scale)
        idx = build_index(data, spec.measure, m=8, kmeans_iters=4)
        bbt = BBTree(data, spec.measure)
        vaf = VAFile(data, spec.measure)
        for k in (20, 60, 100):
            res = search.knn_batch(idx, queries, k)
            bp_bytes = float(np.mean(np.asarray(res.num_candidates))
                             ) * data.shape[1] * 4
            bbt_bytes = np.mean([bbt.knn(q, k)[2]["bytes_moved"]
                                 for q in queries])
            vaf_bytes = np.mean([vaf.knn(q, k)[2]["bytes_moved"]
                                 for q in queries])
            rows += [
                Row("fig11_io", f"BP/{name}/k={k}", 0.0,
                    {"bytes_moved": int(bp_bytes)}),
                Row("fig11_io", f"BBT/{name}/k={k}", 0.0,
                    {"bytes_moved": int(bbt_bytes)}),
                Row("fig11_io", f"VAF/{name}/k={k}", 0.0,
                    {"bytes_moved": int(vaf_bytes)}),
            ]
    return rows
