"""Merge dry-run JSON shards and emit the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src:. python -m benchmarks.make_roofline_tables \
        dryrun_all.json dryrun_rest1.json dryrun_multi.json ... \
        --out-prefix roofline
"""

import argparse
import json
import sys

sys.path.insert(0, "src")

from benchmarks import roofline  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("jsons", nargs="+")
    ap.add_argument("--out-prefix", default="roofline")
    args = ap.parse_args()

    merged: dict = {}
    for path in args.jsons:
        try:
            recs = json.load(open(path))
        except (OSError, ValueError) as e:
            print(f"# skipping {path}: {e}", file=sys.stderr)
            continue
        for r in recs:
            key = (r["arch"], r["shape"], r["mesh"])
            # later files win (re-runs supersede)
            merged[key] = r

    records = list(merged.values())
    with open(f"{args.out_prefix}_merged.json", "w") as f:
        json.dump(records, f, indent=1)

    singles = [r for r in records if r["mesh"] == "16x16"]
    multis = [r for r in records if r["mesh"] == "2x16x16"]
    for name, recs in (("single", singles), ("multi", multis)):
        ok = [r for r in recs if r.get("ok")]
        fail = [r for r in recs if not r.get("ok")]
        with open(f"{args.out_prefix}_{name}.md", "w") as f:
            f.write(f"# Roofline — {name}-pod mesh "
                    f"({len(ok)} ok / {len(recs)} swept)\n\n")
            f.write(roofline.table(recs))
            f.write("\n")
            if fail:
                f.write("\nFailed cells:\n")
                for r in fail:
                    f.write(f"- {r['arch']} x {r['shape']}: "
                            f"{r.get('error', '?')[:200]}\n")
        print(f"{args.out_prefix}_{name}.md: {len(ok)}/{len(recs)} ok")


if __name__ == "__main__":
    main()
