"""Fig. 13 — impact of dimensionality (Fonts 10..400 dims), M* recomputed."""

from __future__ import annotations

import numpy as np

from repro.core.bregman import get_family
from repro.core.index import build_index
from repro.core.partition import fit_cost_model
from repro.core import search

from .common import Row, dataset, timeit


def run(scale: float = 0.01) -> list[Row]:
    spec, data, queries = dataset("fonts", scale)
    rows = []
    fam = get_family(spec.measure)
    for d in (10, 50, 100, 200, 400):
        sub = np.ascontiguousarray(data[:, :d])
        qs = np.ascontiguousarray(queries[:, :d])
        mstar = fit_cost_model(sub, fam).m_star()
        idx = build_index(sub, spec.measure, m=mstar, kmeans_iters=4)
        us = timeit(lambda: search.knn_batch(idx, qs, 20), repeats=3)
        res = search.knn_batch(idx, qs, 20)
        cand = float(np.mean(np.asarray(res.num_candidates)))
        rows.append(Row("fig13_dimensionality", f"fonts/d={d}",
                        us / len(qs),
                        {"mstar": mstar, "candidates": round(cand, 1),
                         "bytes_moved": int(cand * d * 4)}))
    return rows
