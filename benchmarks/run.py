"""Benchmark aggregator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.02] [--only fig12]
                                            [--json BENCH_ci.json]

Prints ``bench,name,us_per_call,derived`` CSV rows; ``--json`` also writes
the rows (plus failures and wall time) to a machine-readable file — CI
uploads it as the ``BENCH_*.json`` artifact on every push.  The roofline
table (deliverable g) reads the dry-run JSON instead:
``benchmarks/roofline.py``.
"""

from __future__ import annotations

import argparse
import dataclasses
import importlib
import json
import sys
import time

MODULES = [
    "bench_construction",    # Fig. 7
    "bench_partitions",      # Figs. 8-9
    "bench_pccp",            # Fig. 10
    "bench_io",              # Fig. 11
    "bench_running_time",    # Fig. 12
    "bench_dimensionality",  # Fig. 13
    "bench_datasize",        # Fig. 14
    "bench_approx",          # Fig. 15
    "bench_batch_search",    # fused batch pipeline vs vmapped per-query
    "bench_quantized",       # int8 tier: filter bytes moved + QPS vs fp32
    "bench_incremental",     # segmented insert/delete/compact vs rebuild
    "bench_dist_knn",        # shard-count scaling (8 forced host devices)
    "bench_retrieval",       # retrieval-service overhead (chaos: --chaos)
    "bench_kernels",         # kernel micro-benches
    "bench_kernel_roofline",  # fused vs unfused kernel HLO roofline terms
    "bench_recall_frontier",  # calibrated approx tier: recall-vs-QPS + ppl
    "bench_tiered",          # out-of-core tier: fetched bytes + wall ratio
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=None,
                    help="dataset scale factor (default: per-module)")
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (the CI bench artifact)")
    args = ap.parse_args(argv)

    print("bench,name,us_per_call,derived")
    failures, all_rows, t_start = [], [], time.time()
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        t0 = time.time()
        try:
            rows = (mod.run(args.scale) if args.scale is not None
                    else mod.run())
        except Exception as e:  # noqa: BLE001 — keep the sweep going
            print(f"# {mod_name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            failures.append(f"{mod_name}: {type(e).__name__}: {e}")
            continue
        for row in rows:
            print(row.csv())
        all_rows.extend(rows)
        print(f"# {mod_name}: {time.time() - t0:.1f}s", file=sys.stderr)

    if args.json:
        payload = {
            "scale": args.scale,
            "only": args.only,
            "wall_seconds": round(time.time() - t_start, 1),
            "failures": failures,
            "rows": [dataclasses.asdict(r) for r in all_rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json} ({len(all_rows)} rows)", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
