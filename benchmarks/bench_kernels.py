"""Kernel micro-benches: Pallas (interpret) vs pure-jnp reference.

CPU-interpret timings are NOT TPU performance — they validate dispatch and
give a structural sanity check; real kernel perf lives in the §Roofline
analysis of the compiled HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import Row, timeit


def run(scale: float = 1.0) -> list[Row]:
    rng = np.random.default_rng(0)
    n, m, q, d = 4096, 32, 8, 256
    alpha = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    sg = jnp.abs(jnp.asarray(rng.normal(size=(n, m)), jnp.float32))
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.abs(jnp.asarray(rng.normal(size=(q, m)), jnp.float32))

    rows = [Row("kernels", "bregman_ub/ref",
                timeit(jax.jit(lambda *a: ops.bregman_ub_matrix(*a, impl="ref")),
                       alpha, sg, qc, sd), {"n": n, "q": q})]

    rows_b = jnp.asarray(rng.normal(size=(512, d)), jnp.float32)
    grad = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    rows.append(Row("kernels", "bregman_refine/ref",
                    timeit(jax.jit(lambda r, g: ops.bregman_refine(
                        r, g, jnp.float32(0.0), "squared_euclidean",
                        impl="ref")), rows_b, grad), {"b": 512, "d": d}))

    x = jnp.asarray(rng.normal(size=(2048, 64)), jnp.float32)
    rows.append(Row("kernels", "pccp_corr/ref",
                    timeit(jax.jit(lambda x: ops.pccp_correlation(
                        x, impl="ref")), x), {"n": 2048, "d": 64}))

    q4 = jnp.asarray(rng.normal(size=(1, 4, 128, 32)), jnp.bfloat16)
    kv = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.bfloat16)
    rows.append(Row("kernels", "flash_attention/ref",
                    timeit(jax.jit(lambda q, k, v: ops.flash_attention(
                        q, k, v, impl="ref")), q4, kv, kv),
                    {"s": 128}))
    return rows
