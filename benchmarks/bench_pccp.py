"""Fig. 10 — impact of PCCP: candidates, bytes and time with/without."""

from __future__ import annotations

import numpy as np

from repro.core.index import build_index
from repro.core import search

from .common import Row, dataset, timeit


def run(scale: float = 0.02) -> list[Row]:
    rows = []
    k = 20
    for name in ("audio", "deep"):
        spec, data, queries = dataset(name, scale)
        for pccp in (True, False):
            idx = build_index(data, spec.measure, m=8, pccp=pccp,
                              kmeans_iters=4)

            def q():
                return search.knn_batch(idx, queries, k)

            us = timeit(q, repeats=3)
            res = q()
            cand = float(np.mean(np.asarray(res.num_candidates)))
            rows.append(Row(
                "fig10_pccp", f"{name}/{'pccp' if pccp else 'contiguous'}",
                us / len(queries),
                {"candidates": round(cand, 1),
                 "bytes_moved": int(cand * data.shape[1] * 4)}))
    return rows
