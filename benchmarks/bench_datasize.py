"""Fig. 14 — impact of data size (Sift subsets); M fixed at the paper's 22
(Theorem 4: n has little effect on M*)."""

from __future__ import annotations

import numpy as np

from repro.core.index import build_index
from repro.core import search

from .common import Row, dataset, timeit


def run(scale: float = 0.01) -> list[Row]:
    spec, data, queries = dataset("sift", scale)
    rows = []
    n = data.shape[0]
    for frac in (0.2, 0.4, 0.6, 0.8, 1.0):
        sub = data[: max(int(n * frac), 64)]
        idx = build_index(sub, spec.measure, m=8, kmeans_iters=4)
        us = timeit(lambda: search.knn_batch(idx, queries, 20), repeats=3)
        res = search.knn_batch(idx, queries, 20)
        cand = float(np.mean(np.asarray(res.num_candidates)))
        rows.append(Row("fig14_datasize", f"sift/n={len(sub)}",
                        us / len(queries),
                        {"candidates": round(cand, 1),
                         "bytes_moved": int(cand * spec.d * 4)}))
    return rows
