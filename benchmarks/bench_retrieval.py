"""Retrieval-service overhead + the CI chaos drill (serve/retrieval.py).

Two modes:

* ``run(scale)`` (benchmarks.run aggregator): prices the service layer —
  ``search_sync`` vs calling ``knn_search_batch`` directly, and the
  microbatching win for many single-query requests.

* ``--chaos`` (the non-blocking CI job): an open-loop load generator
  drives the service under a SEEDED FaultPlan (latency spikes, a poisoned
  query, an injected launch error, compaction mid-stream) on an
  OffsetClock — injected latency moves the clock, not the wall.  The run
  then VERIFIES the robustness contract it observed:

    - zero hangs (the queue drains within a bounded step count),
    - zero crashes (every submitted request resolves),
    - every response within deadline + one observed launch, or shed,
    - quality labels truthful against a fault-free oracle (exact-labeled
      rows match brute force over the microbatch's own snapshot;
      §8/partial/shed rows never claim exactness).

  Violations exit nonzero (the job is continue-on-error: chaos findings
  are review signal, not merge gates).  ``--json-append`` folds the
  latency/shed/tier-mix rows into an existing ``benchmarks.run --json``
  payload so they ride the BENCH_<sha> artifact and delta table.

    PYTHONPATH=src python -m benchmarks.bench_retrieval --chaos \
        [--requests 48] [--seed 0] [--json-append BENCH_x.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import numpy as np

from .common import Row, timeit


def _build(n: int, d: int = 16, seed: int = 0):
    from repro.core.segments import build_segmented_index
    rng = np.random.default_rng(seed)
    data = rng.random((n, d)).astype(np.float32) + 0.1
    return build_segmented_index(data, "shannon", m=4, num_clusters=16)


def run(scale: float | None = None) -> list[Row]:
    from repro.core import search as bp
    from repro.serve.retrieval import RetrievalService, ServiceConfig

    n = int(2000 * (scale or 1.0))
    k, q = 8, 8
    idx = _build(max(n, 256))
    rng = np.random.default_rng(1)
    ys = rng.random((q, idx.d)).astype(np.float32) + 0.1

    svc = RetrievalService(ServiceConfig(max_batch=32))
    svc.register_tenant("bench", idx)
    snap = bp._as_forest(idx)
    budget = bp.default_budget(snap, k)

    rows = []
    t_direct = timeit(lambda: bp.knn_search_batch(snap, ys, k, budget))
    rows.append(Row("retrieval", f"direct_batch_q{q}", t_direct,
                    {"n": snap.n, "k": k}))
    # A generous deadline keeps the ladder pinned to the exact tier: this
    # row prices the SERVICE machinery (queue, bucketing, labeling), not a
    # degradation decision made off the cold-compile launch cost.
    t_svc = timeit(lambda: svc.search_sync("bench", ys, k, deadline_s=60.0))
    rows.append(Row("retrieval", f"search_sync_q{q}", t_svc,
                    {"n": snap.n, "k": k,
                     "overhead_pct": round(100 * (t_svc - t_direct)
                                           / max(t_direct, 1e-9), 1)}))

    def microbatched():
        tickets = [svc.submit("bench", ys[i:i + 1], k, deadline_s=60.0)
                   for i in range(q)]
        svc.run_until_drained()
        return tickets

    t_micro = timeit(microbatched)
    rows.append(Row("retrieval", f"microbatch_{q}x1", t_micro / q,
                    {"n": snap.n, "k": k,
                     "note": "per-request; one bucketed launch"}))
    return rows


# ---------------------------------------------------------------------------
# Chaos mode
# ---------------------------------------------------------------------------

class _TrackingCost:
    """LaunchCostModel wrapper recording the largest observed launch."""

    def __init__(self, inner):
        self.inner = inner
        self.max_s = 0.0

    def observe(self, dt: float) -> None:
        self.max_s = max(self.max_s, float(dt))
        self.inner.observe(dt)

    def estimate(self) -> float:
        return self.inner.estimate()


def chaos(requests: int, seed: int, deadline_s: float = 0.75) -> dict:
    from repro.core import search as bp
    from repro.serve.faults import (
        CompactDuringSearch,
        FaultPlan,
        FetchStall,
        LatencySpike,
        LaunchError,
        OffsetClock,
        PoisonQuery,
    )
    from repro.serve.retrieval import RetrievalService, ServiceConfig

    import jax

    idx = _build(1200, seed=seed)
    k = 8
    rng = np.random.default_rng(seed + 1)

    plan = FaultPlan([
        LatencySpike(0.2, jitter_s=0.05, every=3, tenant="prod"),
        # submit index 2 always routes to "prod" (index 3 is the sharded
        # tenant's slot when devices >= 2), so the poison fires in both
        # single- and multi-device topologies.
        PoisonQuery(at_submits=2, row=0, tenant="prod"),
        LaunchError(at_launches=5, tenant="prod"),
        CompactDuringSearch(at_launches=12, tenant="prod", insert_rows=16),
        # "prod" is tiered (resident_bytes below): one merely-slow cold
        # fetch riding the clock, and one wedged past launch_timeout_s so
        # the FetchTimeout -> retry/ladder containment is exercised.
        FetchStall(0.15, at_launches=8, tenant="prod"),
        FetchStall(3.0, at_launches=10, tenant="prod"),
    ], seed=seed)
    svc = RetrievalService(
        ServiceConfig(queue_depth=16, max_batch=8, record_snapshots=True,
                      default_deadline_s=deadline_s, launch_timeout_s=2.0),
        clock=OffsetClock(), seed=seed)
    # The primary tenant runs OUT-OF-CORE: a residency budget well below
    # its ~113 KiB of cold tables forces real host->device block fetches
    # under chaos, and the recorded snapshots (the oracle's search target)
    # are the TieredPointStore itself — so the exact-label contract is
    # verified THROUGH the tiered path.
    svc.register_tenant("prod", idx, resident_bytes=48_000)
    tenants = ["prod"]
    if len(jax.devices()) >= 2:
        # A second, sharded tenant exercises the distributed_knn launch
        # path (frozen shard snapshot) under the same chaos plan.
        from repro.dist.sharding import make_mesh
        shards = min(4, len(jax.devices()))
        mesh = make_mesh((shards,), ("data",),
                         devices=jax.devices()[:shards])
        svc.register_tenant("dist", _build(1200, seed=seed + 7).view(),
                            mesh=mesh)
        tenants.append("dist")

    # Warm the compiled-program caches BEFORE chaos starts: a cold first
    # launch is dominated by jit compilation (~1s), which would teach the
    # cost model that every launch costs 1s and shed the entire run.  A
    # production deployment warms its buckets at startup for the same
    # reason (docs/serving_robustness.md).  The fault plan attaches after,
    # so warmup neither consumes fault triggers nor skews counters.
    for name in tenants:
        # First-class warm API: compiles the bucketed exact/approx
        # programs and pre-populates the tiered block cache; the
        # search_sync replay below additionally compiles the escalated
        # budgets real traffic reaches.
        svc.warm(name, shapes=[(qsize, k) for qsize in (1, 2, 4, 8)])
        for qsize in (1, 2, 4, 8):
            wq = rng.random((qsize, idx.d)).astype(np.float32) + 0.1
            svc.search_sync(name, wq, k, deadline_s=60.0)
            svc.search_sync(name, wq, k, deadline_s=60.0, target_recall=0.9)
    svc.faults = plan
    for key in svc.counters:
        svc.counters[key] = 0
    for tenant in svc.tenants.values():
        tenant.cost = _TrackingCost(type(tenant.cost)())

    # Open-loop load: arrivals come in fixed-size waves regardless of
    # completions; a 16-deep queue against 8-row batches forces real
    # queue-full backpressure under the injected latency.
    submitted = {}
    per_wave = 6
    for wave in range(0, requests, per_wave):
        for i in range(wave, min(wave + per_wave, requests)):
            tenant = tenants[i % len(tenants)] if len(tenants) > 1 and \
                i % 4 == 3 else "prod"
            q = rng.random((rng.integers(1, 4), idx.d)).astype(
                np.float32) + 0.1
            ticket = svc.submit(tenant, q, k)
            submitted[ticket.uid] = (q, tenant, ticket)
        svc.step()
    svc.run_until_drained(max_steps=500)       # zero-hang check (raises)
    return _verify_and_summarize(svc, plan, submitted, deadline_s, k)


def _verify_and_summarize(svc, plan, submitted, deadline_s, k):
    from repro.core import search as bp

    violations = []
    mix = {"exact": 0, "approx": 0, "partial": 0, "shed": 0}
    latencies = []
    max_launch = max((t.cost.max_s if isinstance(t.cost, _TrackingCost)
                      else 0.0) for t in svc.tenants.values())

    for uid, (q, _tenant, ticket) in submitted.items():
        if not ticket.done:                    # zero crashes / lost tickets
            violations.append(f"uid {uid}: never resolved")
            continue
        r = ticket.response
        mix[r.quality] += 1
        latencies.append(r.latency_s)
        if r.quality != "shed" and \
                r.latency_s > deadline_s + max_launch + 1e-6:
            violations.append(
                f"uid {uid}: latency {r.latency_s:.3f}s exceeds deadline "
                f"{deadline_s}s + one launch {max_launch:.3f}s")
        snap = r.meta.get("snapshot")
        for i, quality in enumerate(r.row_quality):
            if quality == "shed":
                if not (r.ids[i] == -1).all():
                    violations.append(f"uid {uid} row {i}: shed row "
                                      "carries ids")
            elif quality == "exact" and snap is not None:
                ref = bp.knn_search_batch(snap, q[i:i + 1], k, snap.n)
                if not (np.asarray(ref.ids)[0] == r.ids[i]).all():
                    violations.append(
                        f"uid {uid} row {i}: labeled exact but differs "
                        "from the snapshot oracle")

    lat = np.array(latencies) if latencies else np.zeros(1)
    total = max(sum(mix.values()), 1)
    return {
        "requests": len(submitted),
        "faults_fired": {kind: len(plan.fired(kind))
                         for kind in ("latency", "poison", "error",
                                      "compact", "fetch_stall")},
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "shed_rate": mix["shed"] / total,
        "tier_mix": mix,
        "max_launch_s": max_launch,
        "counters": {key: val for key, val in svc.counters.items()
                     if isinstance(val, int)},
        "violations": violations,
    }


def _chaos_rows(summary: dict) -> list[Row]:
    mix = summary["tier_mix"]
    return [
        Row("retrieval_chaos", "p50_latency",
            summary["p50_latency_s"] * 1e6, {"requests":
                                             summary["requests"]}),
        Row("retrieval_chaos", "p99_latency",
            summary["p99_latency_s"] * 1e6,
            {"shed_rate": round(summary["shed_rate"], 3)}),
        Row("retrieval_chaos", "tier_mix", 0.0,
            {**mix, "violations": len(summary["violations"])}),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos", action="store_true")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=0.75)
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--json-append", default=None, metavar="PATH",
                    help="fold chaos rows into an existing bench JSON")
    args = ap.parse_args(argv)

    if not args.chaos:
        for row in run(args.scale):
            print(row.csv())
        return 0

    summary = chaos(args.requests, args.seed, args.deadline)
    rows = _chaos_rows(summary)
    print("bench,name,us_per_call,derived")
    for row in rows:
        print(row.csv())
    print(json.dumps({key: val for key, val in summary.items()
                      if key != "counters"}, indent=2, sort_keys=True))

    if args.json_append:
        payload = {"rows": []}
        if os.path.exists(args.json_append):
            with open(args.json_append) as f:
                payload = json.load(f)
        payload.setdefault("rows", []).extend(
            dataclasses.asdict(r) for r in rows)
        payload["chaos"] = {key: val for key, val in summary.items()
                            if key != "violations"}
        with open(args.json_append, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# appended chaos rows to {args.json_append}",
              file=sys.stderr)

    if summary["violations"]:
        print("CHAOS CONTRACT VIOLATIONS:", file=sys.stderr)
        for v in summary["violations"]:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print("# chaos contract held: zero hangs, every response within "
          "deadline + one launch or shed, labels truthful",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
