"""Recall-vs-QPS frontier of the calibrated approximate tier.

For every Bregman family: build a calibrated index (core/calibrate.py),
then sweep ``target_recall`` operating points and report, per point, the
MEASURED recall@10 against exact search, the curve's promised
``expected_recall``, the resolved §8 shrink ``p``, and throughput.  This
is the end-to-end check that the measured-recall contract holds: at
``target=0.9`` every family must land measured recall@10 >= 0.85.

A second section measures the decode-time impact on the kNN-LM path:
held-out perplexity of the mixed distribution with exact retrieval vs
``target_recall=0.9`` on a synthetic datastore whose neighbor structure
is predictive of the next token (so retrieval quality actually moves the
mixture).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search
from repro.core.bregman import family_names, get_family
from repro.core.calibrate import resolve_p_guarantee
from repro.core.index import build_index

from .common import Row, recall, timeit

TARGETS = (0.8, 0.9, 0.95)
K = 10


def _family_rows(scale: float) -> list[Row]:
    n = max(600, int(16000 * scale))
    d = 32
    num_queries = 16
    rows = []
    for fi, name in enumerate(family_names()):
        fam = get_family(name)
        data = np.asarray(fam.sample(jax.random.PRNGKey(fi), (n, d)))
        queries = np.asarray(
            fam.sample(jax.random.PRNGKey(100 + fi), (num_queries, d)))
        idx = build_index(data, name, m=8, kmeans_iters=4,
                          calibrate=True, calibrate_k=K,
                          calibration_queries=48, seed=fi)
        exact = search.knn_batch(idx, queries, K)
        for target in TARGETS:
            p, expected = resolve_p_guarantee(idx, target)
            res = search.knn_batch(idx, queries, K, target_recall=target)
            us = timeit(lambda t=target: search.knn_batch(
                idx, queries, K, target_recall=t), repeats=3)
            recs = [recall(res.ids[i], exact.ids[i])
                    for i in range(num_queries)]
            us_per_q = us / num_queries
            rows.append(Row(
                "recall_frontier", f"{name}/target={target}", us_per_q,
                {"recall": round(float(np.mean(recs)), 4),
                 "expected_recall": round(float(expected), 4),
                 "p": round(float(p), 4),
                 "qps": round(1e6 / us_per_q, 1)}))
    return rows


def _knnlm_rows(scale: float) -> list[Row]:
    """Perplexity impact of calibrated approximate decode-time retrieval.

    Synthetic regime where the datastore is informative: next tokens are
    a (noisy) function of the key through a fixed random projection, so
    a query's nearest keys vote for its true token and the kNN mixture
    beats the (uniform) base LM.  Lost recall shows up directly as lost
    perplexity, which is what this row tracks across quality tiers.
    """
    from repro.serve.knnlm import Datastore, KNNLMHook

    n = max(500, int(12000 * scale))
    d, vocab, num_eval = 24, 64, 32
    rng = np.random.default_rng(0)
    keys = rng.standard_normal((n, d)).astype(np.float32)
    proj = rng.standard_normal((d, vocab)).astype(np.float32)
    next_tokens = np.argmax(keys @ proj, axis=1).astype(np.int32)

    index = build_index(keys, "squared_euclidean", m=8, kmeans_iters=4,
                        calibrate=True, calibrate_k=8,
                        calibration_queries=48, seed=0)
    store = Datastore(index=index, next_tokens=next_tokens, hidden_dim=d)

    # Held-out queries: jittered live keys; the true token is the jitter
    # source's token (its projection argmax is stable under small noise).
    pick = rng.choice(n, size=num_eval, replace=False)
    hidden = keys[pick] + 0.05 * rng.standard_normal(
        (num_eval, d)).astype(np.float32)
    true_tok = next_tokens[pick]
    base_logits = jnp.zeros((num_eval, vocab), jnp.float32)

    def ppl(hook) -> float:
        out = np.asarray(jax.nn.log_softmax(hook(base_logits,
                                                 jnp.asarray(hidden))))
        return float(np.exp(-np.mean(out[np.arange(num_eval), true_tok])))

    rows = [Row("recall_frontier", "knnlm/ppl_base", 0.0,
                {"ppl": round(float(vocab), 2)})]  # uniform LM: ppl == V
    for label, kwargs in (("exact", {}),
                          ("target=0.9", {"target_recall": 0.9})):
        hook = KNNLMHook(store=store, k=8, lam=0.5, **kwargs)
        value = ppl(hook)
        us = timeit(lambda h=hook: h(base_logits, jnp.asarray(hidden)),
                    repeats=3)
        derived = {"ppl": round(value, 3)}
        if "target_recall" in kwargs:
            _, expected = resolve_p_guarantee(index, kwargs["target_recall"])
            derived["expected_recall"] = round(float(expected), 4)
        rows.append(Row("recall_frontier", f"knnlm/ppl_{label}",
                        us / num_eval, derived))
    return rows


def run(scale: float = 0.05) -> list[Row]:
    return _family_rows(scale) + _knnlm_rows(scale)
