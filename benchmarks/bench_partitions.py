"""Figs. 8 & 9 — impact of the number of partitions M on I/O (bytes moved)
and running time; marks the Theorem-4 optimum M*."""

from __future__ import annotations

import numpy as np

from repro.core.index import build_index
from repro.core.partition import fit_cost_model
from repro.core import search
from repro.core.bregman import get_family

from .common import Row, dataset, timeit


def run(scale: float = 0.02) -> list[Row]:
    rows = []
    for name in ("audio", "deep"):
        spec, data, queries = dataset(name, scale)
        fam = get_family(spec.measure)
        mstar = fit_cost_model(data, fam).m_star()
        for m in sorted({2, 4, 8, 16, 32, mstar}):
            if m > data.shape[1]:
                continue
            idx = build_index(data, spec.measure, m=m, kmeans_iters=4)
            k = 20

            def q():
                return search.knn_batch(idx, queries, k)

            us = timeit(q, repeats=3)
            res = q()
            # bytes-moved proxy: refined candidates x d x 4B (paper's I/O)
            cand = float(np.mean(np.asarray(res.num_candidates)))
            rows.append(Row(
                "fig8_9_partitions", f"{name}/M={m}", us / len(queries),
                {"bytes_moved": int(cand * data.shape[1] * 4),
                 "candidates": round(cand, 1),
                 "is_mstar": int(m == mstar)}))
    return rows
