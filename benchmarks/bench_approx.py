"""Fig. 15 — approximate solution: overall ratio (OR), recall, time vs p,
on Normal and Uniform (the paper's approximate-eval datasets)."""

from __future__ import annotations

import numpy as np

from repro.core.index import build_index
from repro.core import search

from .common import Row, dataset, overall_ratio, recall, timeit


def run(scale: float = 0.05) -> list[Row]:
    rows = []
    k = 20
    for name in ("normal", "uniform"):
        spec, data, queries = dataset(name, scale)
        idx = build_index(data, spec.measure, m=8, kmeans_iters=4)
        exact = search.knn_batch(idx, queries, k)
        for p in (0.7, 0.8, 0.9):
            res = search.knn_batch(idx, queries, k, approx_p=p)
            us = timeit(lambda p=p: search.knn_batch(idx, queries, k,
                                                     approx_p=p), repeats=3)
            ors, recs = [], []
            for i in range(len(queries)):
                ors.append(overall_ratio(res.dists[i], exact.dists[i]))
                recs.append(recall(res.ids[i], exact.ids[i]))
            cand = float(np.mean(np.asarray(res.num_candidates)))
            rows.append(Row(
                "fig15_approx", f"{name}/p={p}", us / len(queries),
                {"overall_ratio": round(float(np.mean(ors)), 4),
                 "recall": round(float(np.mean(recs)), 3),
                 "candidates": round(cand, 1)}))
    return rows
