"""Batched-query throughput: fused knn_search_batch vs vmapped per-query.

Measures queries/sec for q in {1, 8, 64, 256} on one synthetic dataset so
BENCH json tracks batch throughput over time.  The baseline is the honest
pre-fusion batch path — ``jax.vmap`` of the single-query jit core at the
same static budget — which pays per-query cluster-pruning gathers and a
full-n budget top_k per query; the fused pipeline replaces those with one
broadcasted compare and a streaming scatter compaction.

Two streaming-specific columns ride every fused row (and the BENCH
trajectory): ``skip_rate`` — the fraction of (block, query) tiles pruned
by the corner-envelope gate before their per-point admit work — and
``peak_bytes`` — the compiled program's temp-buffer high-water mark
(XLA ``memory_analysis``, -1 where the backend hides it), next to
``mask_bytes``, the ~5 n*q bytes the retired mask/cumsum pipeline held at
the same shape.  A large-n clustered shape exercises exactly the regime
that used to thrash on the (n, q) mask and now skips whole blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bregman import get_family
from repro.core.index import build_index
from repro.core import search

from .common import Row, timeit

BATCH_SIZES = (1, 8, 64, 256)


@functools.partial(jax.jit, static_argnames=("k", "budget"))
def _vmapped_baseline(index, ys, k, budget):
    # validate=False: the host-side domain gate cannot run on a vmap
    # tracer (the synthetic queries are valid by construction here).
    return jax.vmap(lambda y: search.knn_search(index, y, k, budget,
                                                validate=False))(ys)


def _peak_temp_bytes(index, ys, k, budget, block_rows):
    """Temp high-water mark of the compiled fused program (-1 if hidden)."""
    try:
        compiled = search._knn_search_batch_jit.lower(
            index, ys, k, budget,
            search.resolve_block_rows(block_rows, index.n)).compile()
        return int(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:  # noqa: BLE001 — backend-dependent introspection
        return -1


def _stream_cols(index, ys, k, budget, block_rows=None):
    """Streaming telemetry columns for one fused shape.

    ``skip_rate``: measured fraction of (block, query) tiles the envelope
    gate rejected (each provably contributes no candidate); a block's
    per-point kernel still runs if any OTHER query admits it, so the
    compute actually avoided is ``block_skip_rate`` (whole blocks every
    query rejected).  ``peak_bytes``: the compiled program's total temp
    high-water mark (includes the refine gather both pipelines share).
    ``pair_bytes`` vs ``mask_bytes``: the per-point-query-pair
    intermediates of the prune+compact phase alone — what streaming
    removed — O(block_rows * q) streamed vs the retired ~5-byte (n, q)
    mask + (q, n) cumsum.
    """
    _, stats = search.knn_search_batch_stats(index, ys, k, budget,
                                             block_rows=block_rows)
    n, q = index.n, ys.shape[0]
    return {
        "skip_rate": round(stats["block_skip_rate"], 3),
        "block_skip_rate": round(stats["whole_block_skip_rate"], 3),
        "peak_bytes": _peak_temp_bytes(index, ys, k, budget, block_rows),
        "pair_bytes": 8 * stats["block_rows"] * q,
        "mask_bytes": 5 * n * q,      # the retired (n,q)+(q,n) intermediates
    }


def run(scale: float = 1.0):
    n = max(512, int(8192 * scale))
    d, m, k = 64, 8, 10
    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(0), (n, d), scale=1.0))
    index = build_index(data, "squared_euclidean", m=m, num_clusters=64,
                        seed=0)
    budget = search.default_budget(index, k)

    rows = []
    for q in BATCH_SIZES:
        ys = jnp.asarray(np.asarray(
            fam.sample(jax.random.PRNGKey(1), (q, d), scale=1.0)))
        us_base = timeit(lambda: _vmapped_baseline(index, ys, k, budget),
                         repeats=5)
        us_fused = timeit(
            lambda: search.knn_search_batch(index, ys, k, budget), repeats=5)
        qps_base = q / (us_base / 1e6)
        qps_fused = q / (us_fused / 1e6)
        rows.append(Row("batch_search", f"vmap_q{q}", us_base,
                        {"n": n, "qps": round(qps_base, 1)}))
        rows.append(Row("batch_search", f"fused_q{q}", us_fused,
                        {"n": n, "qps": round(qps_fused, 1),
                         "speedup": round(us_base / us_fused, 2),
                         **_stream_cols(index, ys, k, budget)}))

    # Large-n clustered shape: the regime that used to hold ~5 n*q bytes of
    # mask/cumsum (OOM/thrash territory as n*q grows) and where spatial
    # locality lets the envelope gate skip whole blocks.  The baseline here
    # is the kept mask/cumsum reference pipeline at the same shape, so the
    # json tracks streamed-vs-materialized directly.  Well-separated blobs
    # + blocks of ~1/32 of the table mean most blocks are blob-pure and
    # queries sitting on one blob let the gate drop the rest.
    n_l = max(4096, int(131072 * scale))
    q_l = 64
    rng = np.random.default_rng(2)
    # Blobs shifted on EVERY dim: the paper's P-tuple bound prunes by
    # per-subspace stats, so separation must be visible in each subspace
    # (an all-dims shift survives any partition) for Theorem 3 — and hence
    # the envelope gate — to drop other blobs' blocks wholesale.  128
    # small blobs keep each query's union (~ its own blob) serving-sized,
    # so the refine gather does not drown the prune-phase comparison.
    blob = rng.integers(0, 128, size=n_l)
    data_l = (rng.normal(size=(n_l, d)).astype(np.float32)
              + (6.0 * blob).astype(np.float32)[:, None])
    index_l = build_index(data_l, "squared_euclidean", m=m,
                          num_clusters=min(256, n_l // 16), seed=0)
    ys_l = jnp.asarray(data_l[np.where(blob == 0)[0][:q_l]] + 0.01)
    q_l = int(ys_l.shape[0])     # blob 0 may hold < 64 rows at small scales
    # A union is ~ the query's blob; cover it so both pipelines run exact
    # at identical static shapes, and scan in blob-fraction-sized blocks.
    budget_l = search.fitted_budget(index_l, k, n_l // 64)
    # Blob-fraction-sized blocks at full scale; floored at 2048 because on
    # the CPU ref backend each scan step has a fixed dispatch cost that
    # dwarfs sub-2k blocks (on TPU the floor is the VMEM tile, not this).
    br_l = max(2048, n_l // 32)
    us_ref = timeit(lambda: search.knn_search_batch_reference(
        index_l, ys_l, k, budget_l, block_rows=br_l), repeats=3)
    us_str = timeit(lambda: search.knn_search_batch(
        index_l, ys_l, k, budget_l, block_rows=br_l), repeats=3)
    try:
        ref_peak = int(search._knn_search_batch_ref_jit.lower(
            index_l, ys_l, k, budget_l,
            br_l).compile().memory_analysis().temp_size_in_bytes)
    except Exception:  # noqa: BLE001 — backend-dependent introspection
        ref_peak = -1
    rows.append(Row("batch_search", f"large_n_masked_q{q_l}", us_ref,
                    {"n": n_l, "qps": round(q_l / (us_ref / 1e6), 1),
                     "peak_bytes": ref_peak}))
    rows.append(Row("batch_search", f"large_n_streamed_q{q_l}", us_str,
                    {"n": n_l, "qps": round(q_l / (us_str / 1e6), 1),
                     "speedup": round(us_ref / us_str, 2),
                     **_stream_cols(index_l, ys_l, k, budget_l, br_l)}))

    # Fused vs unfused scan at the same large-n shape: the streamed row
    # above runs the fused filter+prune kernel with the hoisted envelope
    # gate; this A/B pins the old per-step gate + standalone prune kernel
    # so the BENCH trajectory tracks the fusion win in isolation (identical
    # results — tests/test_stream_prune.py asserts bit-parity).
    us_unf = timeit(lambda: search._knn_search_batch_unfused_jit(
        index_l, ys_l, k, budget_l, br_l), repeats=3)
    rows.append(Row("batch_search", f"large_n_unfused_q{q_l}", us_unf,
                    {"n": n_l, "qps": round(q_l / (us_unf / 1e6), 1),
                     "fused_speedup": round(us_unf / us_str, 2)}))

    # Tuned vs default block size: block_rows=None consults the checked-in
    # autotuner table (launch/autotune.py); DEFAULT_BLOCK_ROWS is what a
    # caller got before the table existed.  tuned_speedup > 1 means the
    # sweep's pick beats the hardcoded default at this shape.
    br_tuned = search.resolve_block_rows(None, index_l.n, q=q_l,
                                         storage=index_l.storage)
    us_def = timeit(lambda: search.knn_search_batch(
        index_l, ys_l, k, budget_l,
        block_rows=search.DEFAULT_BLOCK_ROWS), repeats=3)
    us_tuned = timeit(lambda: search.knn_search_batch(
        index_l, ys_l, k, budget_l), repeats=3)
    rows.append(Row("batch_search", f"large_n_tuned_q{q_l}", us_tuned,
                    {"n": n_l, "block_rows": br_tuned,
                     "default_block_rows": search.DEFAULT_BLOCK_ROWS,
                     "qps": round(q_l / (us_tuned / 1e6), 1),
                     "tuned_speedup": round(us_def / us_tuned, 2)}))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
