"""Batched-query throughput: fused knn_search_batch vs vmapped per-query.

Measures queries/sec for q in {1, 8, 64, 256} on one synthetic dataset so
BENCH json tracks batch throughput over time.  The baseline is the honest
pre-fusion batch path — ``jax.vmap`` of the single-query jit core at the
same static budget — which pays per-query cluster-pruning gathers and a
full-n budget top_k per query; the fused pipeline replaces those with one
broadcasted compare and a cumsum compaction (core/search.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bregman import get_family
from repro.core.index import build_index
from repro.core import search

from .common import Row, timeit

BATCH_SIZES = (1, 8, 64, 256)


@functools.partial(jax.jit, static_argnames=("k", "budget"))
def _vmapped_baseline(index, ys, k, budget):
    return jax.vmap(lambda y: search.knn_search(index, y, k, budget))(ys)


def run(scale: float = 1.0):
    n = max(512, int(8192 * scale))
    d, m, k = 64, 8, 10
    fam = get_family("squared_euclidean")
    data = np.asarray(fam.sample(jax.random.PRNGKey(0), (n, d), scale=1.0))
    index = build_index(data, "squared_euclidean", m=m, num_clusters=64,
                        seed=0)
    budget = search.default_budget(index, k)

    rows = []
    for q in BATCH_SIZES:
        ys = jnp.asarray(np.asarray(
            fam.sample(jax.random.PRNGKey(1), (q, d), scale=1.0)))
        us_base = timeit(lambda: _vmapped_baseline(index, ys, k, budget),
                         repeats=5)
        us_fused = timeit(
            lambda: search.knn_search_batch(index, ys, k, budget), repeats=5)
        qps_base = q / (us_base / 1e6)
        qps_fused = q / (us_fused / 1e6)
        rows.append(Row("batch_search", f"vmap_q{q}", us_base,
                        {"n": n, "qps": round(qps_base, 1)}))
        rows.append(Row("batch_search", f"fused_q{q}", us_fused,
                        {"n": n, "qps": round(qps_fused, 1),
                         "speedup": round(us_base / us_fused, 2)}))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
