"""Fig. 7 — index construction time: BP (BallForest) vs BBT vs VAF."""

from __future__ import annotations

from repro.core.baselines import BBTree, VAFile
from repro.core.index import build_index

from .common import Row, dataset, timeit


def run(scale: float = 0.02) -> list[Row]:
    rows = []
    for name in ("audio", "fonts", "deep", "sift"):
        spec, data, _ = dataset(name, scale)
        us_bp = timeit(lambda: build_index(data, spec.measure, m=8,
                                           kmeans_iters=4), repeats=1)
        us_bbt = timeit(lambda: BBTree(data, spec.measure), repeats=1)
        us_vaf = timeit(lambda: VAFile(data, spec.measure), repeats=1)
        n = data.shape[0]
        rows += [
            Row("fig7_construction", f"BP/{name}", us_bp, {"n": n}),
            Row("fig7_construction", f"BBT/{name}", us_bbt, {"n": n}),
            Row("fig7_construction", f"VAF/{name}", us_vaf, {"n": n}),
        ]
    return rows
