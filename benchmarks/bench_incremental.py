"""Incremental-update cost: streaming insert vs full rebuild (segments).

The serving claim behind core/segments.py: at a 10% append fraction,
insert-then-search must beat rebuild-then-search by >= 10x, because an
insert is one nearest-centroid pass over the new points while a rebuild
re-runs per-subspace Bregman k-means over everything.  Also times delete
(tombstoning) and both compaction modes so BENCH json tracks the whole
segment lifecycle over time.

All timings are steady-state: each variant is warmed once so jit
compilation is excluded (every repeat re-applies the same-shape mutation
to a fresh wrap of the same sealed forest and hits the compiled programs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bregman import get_family
from repro.core.index import build_index
from repro.core.segments import SegmentedForest
from repro.core import search

from .common import Row, timeit

APPEND_FRACTION = 0.1


def run(scale: float = 1.0):
    n = max(512, int(8192 * scale))
    a = max(8, int(n * APPEND_FRACTION))
    d, m, k, q = 64, 8, 10, 16
    family = "squared_euclidean"
    fam = get_family(family)
    data = np.asarray(fam.sample(jax.random.PRNGKey(0), (n + a, d),
                                 scale=1.0))
    ys = jnp.asarray(np.asarray(
        fam.sample(jax.random.PRNGKey(1), (q, d), scale=1.0)))
    base = build_index(data[:n], family, m=m, num_clusters=64, seed=0)
    budget = search.default_budget(base, k)

    def insert_search():
        sf = SegmentedForest.from_forest(base)
        sf.insert(data[n:], auto_compact=False)
        return search.knn_batch(sf, ys, k, budget=budget)

    def rebuild_search():
        forest = build_index(data, family, m=m, num_clusters=64, seed=0)
        return search.knn_batch(forest, ys, k, budget=budget)

    def delete_search():
        sf = SegmentedForest.from_forest(base)
        sf.delete(np.arange(0, n, 97), auto_compact=False)
        return search.knn_batch(sf, ys, k, budget=budget)

    def compact(mode):
        sf = SegmentedForest.from_forest(base)
        sf.insert(data[n:], auto_compact=False)
        sf.compact(mode)
        return sf.main.data

    us_insert = timeit(insert_search)
    us_rebuild = timeit(rebuild_search)
    us_delete = timeit(delete_search)
    us_merge = timeit(lambda: compact("merge"))
    us_rebuild_compact = timeit(lambda: compact("rebuild"))
    speedup = us_rebuild / us_insert
    return [
        Row("incremental", "insert10_search", us_insert,
            {"n": n, "appended": a, "speedup_vs_rebuild": round(speedup, 1)}),
        Row("incremental", "rebuild_search", us_rebuild, {"n": n + a}),
        Row("incremental", "delete_search", us_delete,
            {"n": n, "deleted": len(range(0, n, 97))}),
        Row("incremental", "compact_merge", us_merge, {"n": n + a}),
        Row("incremental", "compact_rebuild", us_rebuild_compact,
            {"n": n + a}),
    ]


if __name__ == "__main__":
    for row in run():
        print(row.csv())
