"""Fig. 12 — running time vs k: BP / BBT / VAF / linear scan."""

from __future__ import annotations

from repro.core.baselines import BBTree, VAFile, linear_scan
from repro.core.index import build_index
from repro.core import search

from .common import Row, dataset, timeit


def run(scale: float = 0.02) -> list[Row]:
    rows = []
    for name in ("audio", "deep"):
        spec, data, queries = dataset(name, scale)
        idx = build_index(data, spec.measure, m=8, kmeans_iters=4)
        bbt = BBTree(data, spec.measure)
        vaf = VAFile(data, spec.measure)
        for k in (20, 100):
            us_bp = timeit(lambda k=k: search.knn_batch(idx, queries, k),
                           repeats=3) / len(queries)
            us_bbt = timeit(lambda k=k: [bbt.knn(q, k) for q in queries],
                            repeats=1) / len(queries)
            us_vaf = timeit(lambda k=k: [vaf.knn(q, k) for q in queries],
                            repeats=1) / len(queries)
            us_lin = timeit(
                lambda k=k: [linear_scan(data, q, k, spec.measure)
                             for q in queries], repeats=1) / len(queries)
            rows += [
                Row("fig12_time", f"BP/{name}/k={k}", us_bp, {}),
                Row("fig12_time", f"BBT/{name}/k={k}", us_bbt, {}),
                Row("fig12_time", f"VAF/{name}/k={k}", us_vaf, {}),
                Row("fig12_time", f"LIN/{name}/k={k}", us_lin, {}),
            ]
    return rows
