"""Roofline analysis of the fused filter+prune kernel vs the two-kernel path.

Lowers the kernel programs (and the end-to-end streaming pipelines) through
XLA, runs the trip-count-aware HLO cost analyzer
(``repro.launch.hlo_analysis``) on the optimized module text, and derives
per-program roofline terms with the TPU v5e constants from
``repro.launch.mesh``:

    compute term  = HLO FLOPs / PEAK_FLOPS_BF16
    memory term   = HLO bytes / HBM_BW
    roofline fraction = compute term / max(compute, memory) — how close the
    program sits to the compute roof once its own HBM traffic is paid.

The "unfused" kernel cell is TWO compiled programs (the UB filter kernel
and the Theorem-3 prune kernel, costs summed) because that is how the
pre-fusion pipeline dispatched them: the query operands are read twice and
the UB tile round-trips HBM between the phases.  The fused cell is one
program producing both outputs from a single read of the shared operands —
``hbm_bytes_saved`` on the fused row is the measured difference.

Programs are lowered in ``ref`` impl mode so stock XLA (the backend this
container actually runs) produces the module; on TPU the same dispatcher
sends the shape to the Pallas kernel, whose VMEM residency can only improve
on the bytes modeled here.  Wall-clock columns are CPU medians — structural
sanity, not TPU perf.

CLI: ``python -m benchmarks.bench_kernel_roofline --summary BENCH.json``
renders the kernel_roofline rows of a bench artifact as a markdown table
(the CI job step appends it to ``$GITHUB_STEP_SUMMARY``).
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search
from repro.core.index import build_index
from repro.kernels import ops
from repro.launch import hlo_analysis
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

from .common import Row, timeit


def _analyze(jitted, *args) -> dict:
    """Compile one program and derive its roofline cell."""
    compiled = jitted.lower(*args).compile()
    costs = hlo_analysis.analyze_text(compiled.as_text())
    try:
        temp = int(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:  # noqa: BLE001 — backend-dependent introspection
        temp = -1
    return {"flops": costs.flops, "bytes": costs.bytes, "temp_bytes": temp}


def _terms(flops: float, nbytes: float) -> dict:
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    bound_s = max(compute_s, memory_s, 1e-30)
    return {
        "flops": int(flops),
        "bytes": int(nbytes),
        "intensity": round(flops / max(nbytes, 1.0), 3),
        "roofline_fraction": round(compute_s / bound_s, 4),
        "dominant": "compute" if compute_s >= memory_s else "memory",
    }


def _kernel_operands(rng, n, m, q):
    alpha = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    sg = jnp.abs(jnp.asarray(rng.normal(size=(n, m)), jnp.float32))
    amin = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    gmax = jnp.abs(jnp.asarray(rng.normal(size=(n, m)), jnp.float32))
    qc = jnp.asarray(rng.normal(size=(q, m)), jnp.float32)
    sd = jnp.abs(jnp.asarray(rng.normal(size=(q, m)), jnp.float32))
    qb = jnp.asarray(rng.normal(size=(q, m)) + 4.0, jnp.float32)
    return alpha, sg, amin, gmax, qc, sd, qb


def run(scale: float = 1.0) -> list[Row]:
    rng = np.random.default_rng(0)
    n = max(1024, int(8192 * scale))
    m, q = 8, 64
    alpha, sg, amin, gmax, qc, sd, qb = _kernel_operands(rng, n, m, q)

    # -- kernel level: one fused program vs the two-program dispatch --------
    ub_jit = jax.jit(lambda a, g, c, s: ops.bregman_ub_matrix(
        a, g, c, s, impl="ref"))
    prune_jit = jax.jit(lambda am, gm, c, s, b: ops.bregman_prune_block(
        am, gm, c, s, b, impl="ref"))
    fused_jit = jax.jit(
        lambda a, g, am, gm, c, s, b: ops.bregman_filter_prune_block(
            a, g, am, gm, c, s, b, impl="ref"))

    cell_ub = _analyze(ub_jit, alpha, sg, qc, sd)
    cell_pr = _analyze(prune_jit, amin, gmax, qc, sd, qb)
    cell_fu = _analyze(fused_jit, alpha, sg, amin, gmax, qc, sd, qb)
    unfused_flops = cell_ub["flops"] + cell_pr["flops"]
    unfused_bytes = cell_ub["bytes"] + cell_pr["bytes"]

    def _unfused_call():
        return (ub_jit(alpha, sg, qc, sd),
                prune_jit(amin, gmax, qc, sd, qb))

    us_unfused = timeit(_unfused_call, repeats=5)
    us_fused = timeit(
        lambda: fused_jit(alpha, sg, amin, gmax, qc, sd, qb), repeats=5)

    rows = [
        Row("kernel_roofline", "filter_prune_unfused", us_unfused,
            {"n": n, "q": q, **_terms(unfused_flops, unfused_bytes),
             "programs": 2}),
        Row("kernel_roofline", "filter_prune_fused", us_fused,
            {"n": n, "q": q, **_terms(cell_fu["flops"], cell_fu["bytes"]),
             "programs": 1,
             "hbm_bytes_saved": int(unfused_bytes - cell_fu["bytes"]),
             "speedup": round(us_unfused / max(us_fused, 1e-9), 2)}),
    ]

    # -- pipeline level: streamed search, fused vs unfused scan -------------
    d, k = 32, 10
    data = rng.normal(size=(n, d)).astype(np.float32)
    index = build_index(data, "squared_euclidean", m=m,
                        num_clusters=min(64, n // 16), seed=0)
    ys = jnp.asarray(rng.normal(size=(16, d)).astype(np.float32))
    budget = search.default_budget(index, k)
    br = search.resolve_block_rows(None, index.n, q=16,
                                   storage=index.storage)

    cell_pipe_f = _analyze(search._knn_search_batch_jit,
                           index, ys, k, budget, br)
    cell_pipe_u = _analyze(search._knn_search_batch_unfused_jit,
                           index, ys, k, budget, br)
    us_pipe_f = timeit(lambda: search._knn_search_batch_jit(
        index, ys, k, budget, br), repeats=3)
    us_pipe_u = timeit(lambda: search._knn_search_batch_unfused_jit(
        index, ys, k, budget, br), repeats=3)
    rows.append(Row(
        "kernel_roofline", "pipeline_unfused", us_pipe_u,
        {"n": index.n, "q": 16, "block_rows": br,
         **_terms(cell_pipe_u["flops"], cell_pipe_u["bytes"]),
         "temp_bytes": cell_pipe_u["temp_bytes"]}))
    rows.append(Row(
        "kernel_roofline", "pipeline_fused", us_pipe_f,
        {"n": index.n, "q": 16, "block_rows": br,
         **_terms(cell_pipe_f["flops"], cell_pipe_f["bytes"]),
         "temp_bytes": cell_pipe_f["temp_bytes"],
         "hbm_bytes_saved": int(cell_pipe_u["bytes"]
                                - cell_pipe_f["bytes"]),
         "speedup": round(us_pipe_u / max(us_pipe_f, 1e-9), 2)}))
    return rows


def summary_table(bench_json_path: str) -> str:
    """Markdown roofline table from a BENCH_*.json artifact."""
    payload = json.load(open(bench_json_path))
    rows = [r for r in payload.get("rows", [])
            if r.get("bench") == "kernel_roofline"]
    if not rows:
        return "no kernel_roofline rows in " + bench_json_path
    out = ["### Kernel roofline (fused filter+prune pass)", "",
           "| program | us/call | GFLOPs | MiB moved | flops/byte "
           "| roofline | bound | speedup |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        d = r["derived"]
        speed = d.get("speedup", "")
        out.append(
            f"| {r['name']} | {r['us_per_call']:.1f} "
            f"| {d['flops'] / 1e9:.4f} | {d['bytes'] / 2**20:.2f} "
            f"| {d['intensity']:.2f} | {d['roofline_fraction']:.3f} "
            f"| {d['dominant']} | {speed} |")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--summary", metavar="BENCH_JSON", default=None,
                    help="render kernel_roofline rows of a bench artifact "
                         "as markdown (for $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--scale", type=float, default=1.0)
    args = ap.parse_args(argv)
    if args.summary:
        print(summary_table(args.summary))
        return 0
    for row in run(args.scale):
        print(row.csv())
    return 0


if __name__ == "__main__":
    sys.path.insert(0, "src")
    raise SystemExit(main())
