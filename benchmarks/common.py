"""Shared benchmark harness: timing, CSV emission, dataset scaling.

Every bench module exposes ``run(scale) -> list[Row]``; benchmarks.run
aggregates.  Default scale keeps each module in seconds on one CPU core —
the paper's full dataset sizes are dry-run territory, not CPU-bench
territory (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.data.pipeline import PAPER_DATASETS, make_queries, make_vectors


@dataclasses.dataclass
class Row:
    bench: str
    name: str
    us_per_call: float
    derived: dict

    def csv(self) -> str:
        extra = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.bench},{self.name},{self.us_per_call:.1f},{extra}"


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (blocks jax async)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if _is_jax(r) else None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r) if _is_jax(r) else None
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def _is_jax(x) -> bool:
    return any(isinstance(leaf, jax.Array) for leaf in jax.tree.leaves(x))


def dataset(name: str, scale: float, seed: int = 0, cap: int | None = 4000):
    """CPU-sized slice of a paper dataset.

    ``cap`` bounds n so the pure-python baselines (BB-tree) stay in
    seconds; the paper's full n is dry-run/bench --scale territory.
    """
    spec = PAPER_DATASETS[name]
    data = make_vectors(spec, scale=scale, seed=seed)
    if cap is not None and data.shape[0] > cap:
        data = data[:cap]
    queries = make_queries(spec, num=10, scale=scale, data_seed=seed)
    if cap is not None:
        queries = queries[:10]
    return spec, data, queries


def recall(ids: np.ndarray, true_ids: np.ndarray) -> float:
    return len(set(np.asarray(ids).tolist())
               & set(np.asarray(true_ids).tolist())) / len(true_ids)


def overall_ratio(dists: np.ndarray, true_dists: np.ndarray) -> float:
    """The paper's OR metric: mean(D(p_i,q) / D(p*_i,q)) over rank i."""
    d = np.maximum(np.asarray(dists, np.float64), 1e-12)
    t = np.maximum(np.asarray(true_dists, np.float64), 1e-12)
    return float(np.mean(d / t))
