"""Chunked softmax cross-entropy — never materializes (B, S, V) at once.

For vocab sizes up to 256k a full-sequence logits tensor is the largest
buffer of the whole train step (often > the parameter shards).  We unroll
python-level sequence chunks (exact cost accounting, like the attention
chunks) and remat each chunk so its logits are recomputed in backward.

The vocab axis stays sharded (`vocab -> model`); log-sum-exp over a sharded
axis lowers to a tiny all-reduce pair under SPMD.  Optional z-loss
regularizes the partition function (PaLM-style).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

Array = jax.Array


def _chunk_nll(hidden_c: Array, labels_c: Array, table: Array,
               z_weight: float):
    """hidden (B, C, D), labels (B, C) -> (sum_nll, sum_z, sum_correct)."""
    hidden_c = constrain(hidden_c, ("batch", None, "embed"))
    # gather the fsdp-sharded table before the dot: without this anchor the
    # SPMD partitioner replicates the BATCH to keep the table's embed dim
    # sharded (observed: unsharded (256, 512, V/16) logits buffers + a
    # 172 GB/device all-reduce on qwen3-moe train_4k)
    table_g = constrain(table.astype(hidden_c.dtype), ("vocab", None))
    logits = jnp.einsum("bcd,vd->bcv", hidden_c, table_g,
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, ("batch", None, "vocab"))
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = jnp.sum(lse - tgt)
    z = jnp.sum(jnp.square(lse)) * z_weight
    # argmax-free accuracy (argmax materializes V-sized s32 iota buffers)
    correct = jnp.sum(tgt >= jnp.max(logits, axis=-1))
    return nll, z, correct


def chunked_cross_entropy(hidden: Array, labels: Array, table: Array, *,
                          chunk: int = 512, z_weight: float = 0.0):
    """Mean token NLL via sequence-chunked logits.

    Returns (loss, metrics) with metrics {nll, z_loss, accuracy}.
    """
    b, s, _ = hidden.shape
    hidden = constrain(hidden, ("batch", "seq", "embed"))
    chunk = min(chunk, s)
    body = jax.checkpoint(functools.partial(_chunk_nll, z_weight=z_weight))
    nll = 0.0
    zl = 0.0
    ncorrect = 0
    for c0 in range(0, s, chunk):
        c1 = min(c0 + chunk, s)
        n, z, corr = body(hidden[:, c0:c1], labels[:, c0:c1], table)
        nll = nll + n
        zl = zl + z
        ncorrect = ncorrect + corr
    denom = b * s
    loss = (nll + zl) / denom
    return loss, {"nll": nll / denom, "z_loss": zl / denom,
                  "accuracy": ncorrect / denom}
