"""Straggler detection + mitigation policy (documented simulation).

At pod scale, slow hosts (thermal throttling, failing HBM, noisy neighbors)
show up as a heavy per-step latency tail.  This monitor implements the
standard production loop:

  1. track per-step wall time (and, when available, per-host step times —
     on real multi-host JAX these come from
     ``jax.process_index()``-tagged timing all-gathers; in this single-
     process container the per-host times are SIMULATED by the tests);
  2. flag a step/host as a straggler when it exceeds
     ``median * tolerance`` over a sliding window;
  3. trip a mitigation once ``patience`` consecutive flags accumulate.

Mitigations are pluggable actions; the built-ins mirror what a real
launcher would do (documented in DESIGN.md §4):

* ``checkpoint_and_shrink`` — save, drop the slow host from the mesh, and
  resume elastically (train/checkpoint.py restores onto the smaller mesh);
* ``rebalance`` — shrink the slow host's data shard (skew the sampler);
* ``alert`` — record only.

The monitor itself is real and unit-tested; only the host-time *source* is
simulated on this container (no second host exists to be slow).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable


@dataclasses.dataclass
class StragglerConfig:
    window: int = 50              # sliding window of step times
    tolerance: float = 1.5        # flag if > tolerance * median
    patience: int = 5             # consecutive flags before mitigation
    warmup_steps: int = 10        # ignore compile/cache-warm steps


@dataclasses.dataclass
class StragglerEvent:
    step: int
    host: int
    step_time: float
    median: float
    action: str


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig | None = None,
                 num_hosts: int = 1,
                 mitigation: Callable[[StragglerEvent], None] | None = None):
        self.cfg = cfg if cfg is not None else StragglerConfig()
        self.num_hosts = num_hosts
        self.mitigation = mitigation
        self.times: list[deque] = [deque(maxlen=cfg.window)
                                   for _ in range(num_hosts)]
        self.flags = [0] * num_hosts
        self.events: list[StragglerEvent] = []
        self._step = 0
        self._t0 = None

    # -- wall-clock convenience for the training loop ------------------------
    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self, host_times: list[float] | None = None):
        """Record a step.  ``host_times`` overrides wall time per host
        (multi-host runs gather them; tests inject simulated values)."""
        elapsed = time.perf_counter() - self._t0 if self._t0 else 0.0
        if host_times is None:
            host_times = [elapsed] * self.num_hosts
        self._step += 1
        if self._step <= self.cfg.warmup_steps:
            return []
        fired = []
        for h, t in enumerate(host_times):
            self.times[h].append(t)
            med = _median(self.times[h])
            if len(self.times[h]) >= 5 and t > self.cfg.tolerance * med:
                self.flags[h] += 1
            else:
                self.flags[h] = 0
            if self.flags[h] >= self.cfg.patience:
                ev = StragglerEvent(step=self._step, host=h, step_time=t,
                                    median=med, action="mitigate")
                self.events.append(ev)
                self.flags[h] = 0
                if self.mitigation is not None:
                    self.mitigation(ev)
                fired.append(ev)
        return fired

    def summary(self) -> dict:
        med = [_median(t) if t else 0.0 for t in self.times]
        p99 = [_quantile(t, 0.99) if t else 0.0 for t in self.times]
        return {"median": med, "p99": p99,
                "events": len(self.events), "steps": self._step}


def _median(xs) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def _quantile(xs, q: float) -> float:
    s = sorted(xs)
    i = min(len(s) - 1, int(q * len(s)))
    return s[i]
