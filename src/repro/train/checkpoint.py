"""Fault-tolerant checkpointing: atomic shard files + elastic resharding.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json         tree structure, leaf shapes/dtypes, step meta
        shard_000.npz ...     leaves chunked along their axis-0 into
                              ``num_shards`` host files (multi-host analog:
                              one file per checkpointing host)

Guarantees:

* **atomic**: writes go to ``step_X.tmp-<nonce>`` and are renamed into
  place only after every shard + manifest is fsync'd — a crash mid-write
  can never yield a directory that ``latest_step`` would pick up;
* **elastic restore**: leaves are re-assembled to global arrays and
  ``device_put`` with the CURRENT mesh's NamedShardings — restoring onto a
  different device count / mesh shape than the writer's is the normal path
  (tested: 8 -> 4 -> 8 host devices in tests/test_checkpoint.py);
* **retention**: ``keep`` most recent steps survive a save.

The data pipeline is step-addressable (data/pipeline.py), so restart from
step k reproduces the exact batch sequence — restarts are bit-exact.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

SEP = "//"

_NATIVE_DTYPES = {
    "bool", "int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
    "uint64", "float16", "float32", "float64", "complex64", "complex128",
}


def _flatten_with_names(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        name = jax.tree_util.keystr(path)
        out[name] = leaf
    return out


def _treedef_template(tree):
    """JSON-able structure: replace leaves with their flat names."""
    return jax.tree_util.tree_structure(tree)


def save_checkpoint(directory: str, step: int, state, *,
                    num_shards: int = 4, keep: int = 3) -> str:
    """Write ``state`` (pytree of arrays) atomically.  Returns final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:09d}.tmp-", dir=directory)

    named = _flatten_with_names(state)
    manifest = {"step": step, "num_shards": num_shards, "leaves": {}}
    shards: list[dict] = [{} for _ in range(num_shards)]
    for name, leaf in named.items():
        arr = np.asarray(jax.device_get(leaf))
        meta = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        if arr.dtype.kind == "V" or str(arr.dtype) not in _NATIVE_DTYPES:
            # non-native dtypes (bfloat16, fp8): store raw bytes per shard
            meta["raw"] = True
            arr = np.frombuffer(arr.tobytes(), np.uint8).reshape(
                arr.shape + (arr.dtype.itemsize,)) if arr.ndim else \
                np.frombuffer(arr.tobytes(), np.uint8)
        manifest["leaves"][name] = meta
        if arr.ndim == 0 or arr.shape[0] < num_shards:
            shards[0][name] = arr
            meta["sharded"] = False
        else:
            meta["sharded"] = True
            for i, piece in enumerate(np.array_split(arr, num_shards, axis=0)):
                shards[i][name] = piece

    for i, shard in enumerate(shards):
        path = os.path.join(tmp, f"shard_{i:03d}.npz")
        with open(path, "wb") as f:
            np.savez(f, **{k.replace("/", SEP): v for k, v in shard.items()})
            f.flush()
            os.fsync(f.fileno())
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
    _apply_retention(directory, keep)
    return final


def _apply_retention(directory: str, keep: int):
    steps = sorted(list_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp-" not in name:
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int, target, *,
                       shardings=None):
    """Restore into the structure of ``target`` (pytree of arrays/structs).

    ``shardings``: optional congruent pytree of NamedShardings — the elastic
    path: the restored global arrays are placed onto the CURRENT mesh
    regardless of what the writer's mesh looked like.
    """
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    loaded: dict[str, list] = {}
    for i in range(manifest["num_shards"]):
        with np.load(os.path.join(path, f"shard_{i:03d}.npz")) as z:
            for key in z.files:
                loaded.setdefault(key.replace(SEP, "/"), []).append(z[key])

    named_target = _flatten_with_names(target)
    named_sh = (_flatten_with_names(shardings)
                if shardings is not None else {})
    out = {}
    for name, _tgt in named_target.items():
        meta = manifest["leaves"][name]
        pieces = loaded[name]
        arr = (np.concatenate(pieces, axis=0)
               if meta["sharded"] else pieces[0])
        if meta.get("raw"):
            import ml_dtypes  # ships with jax
            dt = np.dtype(getattr(ml_dtypes, meta["dtype"]))
            arr = np.frombuffer(arr.tobytes(), dt).reshape(meta["shape"])
        assert list(arr.shape) == meta["shape"], (name, arr.shape, meta)
        arr = arr.astype(arr.dtype if meta.get("raw") else meta["dtype"])
        if name in named_sh:
            out[name] = jax.device_put(arr, named_sh[name])
        else:
            out[name] = jnp.asarray(arr)
    treedef = jax.tree_util.tree_structure(target)
    order = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(target)[0]]
    return jax.tree_util.tree_unflatten(treedef, [out[n] for n in order])
