"""The SPMD train step: microbatched, remat'd, fully sharded in and out.

``make_train_step`` resolves every parameter / optimizer / batch array to a
NamedSharding from its logical axes (dist/sharding.py) and returns an AOT-
lowerable jitted step with EXPLICIT out_shardings — without them XLA SPMD
happily decides that replicating a 72B-parameter gradient tree per device is
acceptable (observed: +14 GB/device in the first dry-run of this repo).

Gradient accumulation: python loop over microbatches (static count),
averaged in f32.  Donation: the previous TrainState buffers are donated so
params/moments update in place (halves peak optimizer memory).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.configs.common import ShapeSpec, batch_axes
from . import losses, optimizer as opt_mod
from .optimizer import AdamWState, OptimizerConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    loss_chunk: int = 512
    z_weight: float = 1e-4
    opt: OptimizerConfig = OptimizerConfig()


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def state_shardings(bundle, mesh: Mesh, rules=None) -> TrainState:
    p_axes = bundle.param_axes()
    p_structs = bundle.param_structs()
    p_sh = shd.tree_shardings_for_structs(p_axes, p_structs, mesh, rules)
    return TrainState(
        params=p_sh,
        opt=AdamWState(step=NamedSharding(mesh, P()), mu=p_sh, nu=p_sh))


def batch_shardings(bundle, shape: ShapeSpec, mesh: Mesh, rules=None):
    from repro.configs.common import batch_structs
    return shd.tree_shardings_for_structs(
        batch_axes(bundle, shape), batch_structs(bundle, shape), mesh, rules)


def init_train_state(bundle, mesh: Mesh, key, rules=None) -> TrainState:
    """Initialize params + moments directly into their shardings."""
    sh = state_shardings(bundle, mesh, rules)

    def build(key):
        params = bundle.init(key)
        return TrainState(params=params, opt=opt_mod.init_state(params))

    return jax.jit(build, out_shardings=sh)(key)


def _split_micro(batch: dict, n: int, i: int) -> dict:
    def sl(x):
        mb = x.shape[0] // n
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
    return jax.tree.map(sl, batch)


def make_loss_fn(bundle, cfg: TrainConfig):
    def loss_fn(params, batch):
        hidden, aux = bundle.forward_train(params, batch)
        table = params["embed"] if bundle.cfg.tie_embeddings \
            else params["unembed"]
        loss, metrics = losses.chunked_cross_entropy(
            hidden, batch["labels"], table, chunk=cfg.loss_chunk,
            z_weight=cfg.z_weight)
        metrics["aux_loss"] = aux
        return loss + aux, metrics
    return loss_fn


def make_train_step(bundle, mesh: Mesh, cfg: TrainConfig, shape: ShapeSpec,
                    rules=None):
    """Build the jitted (state, batch) -> (state, metrics) step."""
    loss_fn = make_loss_fn(bundle, cfg)
    n_micro = cfg.microbatches

    def step(state: TrainState, batch: dict):
        # the activation-anchor context is live at trace time (see
        # dist/sharding.constrain) — without it XLA SPMD replicates batch
        # dims of the residual stream under fsdp weight sharding
        ctx = shd.activation_rules(mesh, rules)
        ctx.__enter__()
        try:
            return _step_inner(state, batch)
        finally:
            ctx.__exit__(None, None, None)

    def _step_inner(state: TrainState, batch: dict):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def one_micro(mb):
            (loss, metrics), grads = grad_fn(state.params, mb)
            return loss, metrics, grads

        if n_micro == 1:
            loss, metrics, grads = one_micro(batch)
            # anchor grads to the PARAM shardings: without this XLA emits
            # per-layer f32 all-reduces over the data axis (observed: 384
            # GB/device on qwen2.5 train) instead of reduce-scatters into
            # the fsdp shards the optimizer update actually needs
            grads = jax.lax.with_sharding_constraint(
                grads, state_sh_params)
        else:
            acc = None
            loss = 0.0
            metrics = None
            for i in range(n_micro):
                li, m, g = one_micro(_split_micro(batch, n_micro, i))
                g32 = jax.tree.map(lambda x: x.astype(jnp.float32), g)
                acc = g32 if acc is None else jax.tree.map(
                    jnp.add, acc, g32)
                loss = loss + li / n_micro
                metrics = m if metrics is None else jax.tree.map(
                    jnp.add, metrics, m)
            grads = jax.tree.map(lambda x: x / n_micro, acc)
            grads = jax.lax.with_sharding_constraint(grads, state_sh_params)
            metrics = jax.tree.map(lambda x: x / n_micro, metrics)

        new_params, new_opt, stats = opt_mod.apply_updates(
            state.params, grads, state.opt, cfg.opt)
        metrics = dict(metrics, loss=loss, **stats)
        return TrainState(new_params, new_opt), metrics

    state_sh = state_shardings(bundle, mesh, rules)
    state_sh_params = state_sh.params
    batch_sh = batch_shardings(bundle, shape, mesh, rules)
    metrics_sh = None  # replicated scalars
    return jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
    )


def lower_train_step(bundle, mesh: Mesh, cfg: TrainConfig, shape: ShapeSpec,
                     batch_structs: dict, rules=None):
    """AOT path for the dry-run: lower without allocating anything."""
    step = make_train_step(bundle, mesh, cfg, shape, rules)
    with mesh:
        return step.lower(_state_structs(bundle), batch_structs)


def _state_structs(bundle) -> TrainState:
    p = bundle.param_structs()

    def f32(t):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)

    return TrainState(
        params=p,
        opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       mu=f32(p), nu=f32(p)))
