"""AdamW with global-norm clipping and warmup-cosine schedule (pure JAX).

Optimizer state is a pytree congruent with the parameters, so under jit the
moments inherit the parameters' shardings (fsdp x tensor) — ZeRO-1/2
semantics fall out of the sharding rules rather than bespoke partitioning
code.  No optax dependency (offline container).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: Array      # () int32
    mu: Any          # first moments (pytree like params)
    nu: Any          # second moments


def init_state(params) -> AdamWState:
    def zeros(t):
        return jax.tree.map(jnp.zeros_like, t)

    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def schedule(cfg: OptimizerConfig, step: Array) -> Array:
    """Linear warmup then cosine decay to min_lr_ratio * peak."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * jnp.where(s < cfg.warmup_steps, warm, decayed)


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def _decay_mask(params):
    """Weight decay on matrices only (skip norms/bias/1-d tables)."""
    return jax.tree.map(lambda p: float(p.ndim >= 2), params)


def apply_updates(params, grads, state: AdamWState, cfg: OptimizerConfig):
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    grads, grad_norm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    mask = _decay_mask(params)

    def upd(p, g, m, v, wd_on):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * wd_on * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu, mask)
    new_params = jax.tree.map(lambda t3: t3[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t3: t3[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t3: t3[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    stats = {"lr": lr, "grad_norm": grad_norm,
             "param_norm": global_norm(new_params)}
    return new_params, AdamWState(step, new_mu, new_nu), stats
