# Training substrate: optimizer, losses, the SPMD train step, fault-tolerant
# checkpointing, and straggler monitoring.
