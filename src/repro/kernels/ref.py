"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors its kernel's signature; tests assert allclose between
kernel (interpret=True on CPU) and these references across shape/dtype
sweeps, and hypothesis drives the property tests on top of them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bregman import get_family
from repro.core import quantize as qz

Array = jax.Array


def bregman_ub_totals(alpha: Array, sqrt_gamma: Array, qconst: Array,
                      sqrt_delta: Array) -> Array:
    """Total UB per point for a single query.  (n, M),(n, M),(M,),(M,)->(n,)."""
    return (jnp.sum(alpha, -1) + jnp.sum(qconst, -1)
            + sqrt_gamma @ sqrt_delta)


def bregman_ub_matrix(alpha: Array, sqrt_gamma: Array, qconst: Array,
                      sqrt_delta: Array) -> Array:
    """UB totals for a query batch.  (n,M),(n,M),(q,M),(q,M) -> (n,q)."""
    return (jnp.sum(alpha, -1)[:, None] + jnp.sum(qconst, -1)[None, :]
            + sqrt_gamma @ sqrt_delta.T)


def bregman_ub_matrix_quant(alpha_q: Array, alpha_scale: Array,
                            alpha_zp: Array, sg_q: Array, sg_scale: Array,
                            sg_zp: Array, qconst: Array,
                            sqrt_delta: Array) -> Array:
    """UB totals from the int8 filter tables.  Codes (n, M) int8, per-row
    affine decode (n,), queries (q, M) -> (n, q).

    The per-row affine factors out of both reductions, so only the int8
    codes are streamed at full (n, M) width:

        rowsum(alpha_hat)  = alpha_scale * rowsum(alpha_q) + M * alpha_zp
        sg_hat . sd        = sg_scale * (sg_q . sd) + sg_zp * sum(sd)
    """
    m = alpha_q.shape[1]
    arow = alpha_scale * jnp.sum(alpha_q.astype(jnp.float32), -1) + m * alpha_zp
    qsum = jnp.sum(qconst, -1)                       # (q,)
    sdsum = jnp.sum(sqrt_delta, -1)                  # (q,)
    cauchy = (sg_scale[:, None] * (sg_q.astype(jnp.float32) @ sqrt_delta.T)
              + sg_zp[:, None] * sdsum[None, :])
    return arow[:, None] + qsum[None, :] + cauchy


def bregman_prune_mask(amin: Array, gmax: Array, qconst: Array,
                       sqrt_delta: Array, qb: Array) -> Array:
    """Theorem-3 per-point admit mask.  (n,M)x3 query (q,M) -> (n,q) int32.

    Admit point x for query y iff SOME subspace's tuple-space cluster
    lower bound (evaluated through the per-point corner view) is within
    that subspace's Alg.-4 searching bound — core/search._corner_admit,
    as a kernel oracle.  The (n, M, q) intermediate is fine here: the
    reference is only ever called on one block_rows-sized tile.
    """
    lb = (amin[:, :, None] + qconst.T[None, :, :]
          - gmax[:, :, None] * sqrt_delta.T[None, :, :])     # (n, M, q)
    return jnp.any(lb <= qb.T[None, :, :], axis=1).astype(jnp.int32)


def bregman_prune_mask_quant(amin_q: Array, amin_scale: Array,
                             amin_zp: Array, gmax_q: Array,
                             gmax_scale: Array, gmax_zp: Array,
                             qconst: Array, sqrt_delta: Array,
                             qb: Array) -> Array:
    """Admit mask from int8 corner codes + per-row affine decode.

    Decoding goes through core/quantize.dequantize_stats itself, so the
    (directed-rounded, conservative) corner values match what every other
    consumer of the int8 corner tables sees.
    """
    amin = qz.dequantize_stats(amin_q, amin_scale, amin_zp)
    gmax = qz.dequantize_stats(gmax_q, gmax_scale, gmax_zp)
    return bregman_prune_mask(amin, gmax, qconst, sqrt_delta, qb)


def bregman_filter_prune(alpha: Array, sqrt_gamma: Array, amin: Array,
                         gmax: Array, qconst: Array, sqrt_delta: Array,
                         qb: Array) -> tuple[Array, Array]:
    """Fused filter+prune oracle: (ub (n, q), admit (n, q)).

    Composes the two single-phase oracles verbatim, so the fused kernel's
    bit-parity with the two-kernel path is checked against EXACTLY the
    arithmetic the unfused pipeline runs — by construction, not by
    tolerance.
    """
    return (bregman_ub_matrix(alpha, sqrt_gamma, qconst, sqrt_delta),
            bregman_prune_mask(amin, gmax, qconst, sqrt_delta, qb))


def bregman_filter_prune_quant(alpha_q: Array, alpha_scale: Array,
                               alpha_zp: Array, sg_q: Array, sg_scale: Array,
                               sg_zp: Array, amin_q: Array, amin_scale: Array,
                               amin_zp: Array, gmax_q: Array,
                               gmax_scale: Array, gmax_zp: Array,
                               qconst: Array, sqrt_delta: Array,
                               qb: Array) -> tuple[Array, Array]:
    """Fused (ub, admit) oracle over the int8 filter + corner code tables."""
    return (bregman_ub_matrix_quant(alpha_q, alpha_scale, alpha_zp,
                                    sg_q, sg_scale, sg_zp,
                                    qconst, sqrt_delta),
            bregman_prune_mask_quant(amin_q, amin_scale, amin_zp,
                                     gmax_q, gmax_scale, gmax_zp,
                                     qconst, sqrt_delta, qb))


def bregman_refine_batch_quant(codes: Array, scale: Array, zp: Array,
                               grad: Array, c_y: Array, family: str) -> Array:
    """Fused dequantize + exact D_f over int8 candidate rows.

    (q,b,d) int8 codes + (q,b) per-row scale/zp -> (q,b).  Decoding goes
    through core/quantize.dequantize_rows itself, so the distances are
    exact over the int8 tier's point set by construction.
    """
    rows = qz.dequantize_rows(codes, scale, zp, get_family(family))
    return bregman_refine_batch(rows, grad, c_y, family)


def bregman_refine(rows: Array, grad: Array, c_y: Array, family: str) -> Array:
    """Exact D_f for selected rows.  (b,d),(d,),() -> (b,)."""
    fam = get_family(family)
    fx = jnp.sum(fam.phi(rows), axis=-1)
    return fx - rows @ grad + c_y


def bregman_refine_batch(rows: Array, grad: Array, c_y: Array,
                         family: str) -> Array:
    """Exact D_f per query's candidate rows.  (q,b,d),(q,d),(q,) -> (q,b)."""
    fam = get_family(family)
    fx = jnp.sum(fam.phi(rows), axis=-1)                  # (q, b)
    cross = jnp.einsum("qbd,qd->qb", rows, grad)
    return fx - cross + c_y[:, None]


def pccp_correlation(x: Array) -> Array:
    """|Pearson| correlation matrix with zeroed diagonal.  (n,d) -> (d,d)."""
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    std = jnp.sqrt(jnp.mean(xc * xc, axis=0))
    std = jnp.where(std < 1e-12, 1.0, std)
    corr = (xc.T @ xc) / (x.shape[0] * std[:, None] * std[None, :])
    corr = jnp.abs(corr)
    return corr * (1.0 - jnp.eye(x.shape[1], dtype=x.dtype))


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: int | None = None, scale: float | None = None) -> Array:
    """Naive GQA attention oracle.

    q: (B, H, Sq, D); k/v: (B, KH, Skv, D) with H % KH == 0.
    ``window``: sliding-window size (local attention) if given.
    """
    b, h, sq, d = q.shape
    kh = k.shape[1]
    rep = h // kh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    skv = k.shape[2]
    qi = jnp.arange(sq)[:, None] + (skv - sq)   # align ends (decode offsets)
    ki = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= (qi - ki) < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
