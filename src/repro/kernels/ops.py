"""Dispatching jit wrappers around the Pallas kernels.

Backend policy (per DESIGN.md): on TPU the compiled Pallas kernels run; on
CPU (this container) the pure-jnp references run by default so that jitted
programs (including the 512-device dry-run) lower through stock XLA, and
``impl='interpret'`` forces the Pallas interpreter for kernel validation.

Set env ``REPRO_KERNEL_IMPL`` to 'pallas' | 'interpret' | 'ref' to override.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref
from . import bregman_ub as _ub
from . import bregman_dist as _dist
from . import bregman_fused as _fused
from . import bregman_prune as _prune
from . import pccp_corr as _corr
from . import flash_attention as _flash


def _impl(override: str | None = None) -> str:
    if override:
        return override
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# ---------------------------------------------------------------------------
# BrePartition filter + refine
# ---------------------------------------------------------------------------

def bregman_ub_filter(alpha, sqrt_gamma, qconst, sqrt_delta, impl=None):
    """Total UBs for one query + a closure for the Alg.-4 kth components.

    Returns (totals (n,), comp_of(kth) -> (M,)).  Strictly single-query:
    ``qconst``/``sqrt_delta`` must be (M,).  A (q, M) batch must go through
    :func:`bregman_ub_matrix` — this used to fall back to the jnp reference
    silently, hiding the Pallas kernel from batch callers.
    """
    if qconst.ndim != 1 or sqrt_delta.ndim != 1:
        raise ValueError(
            "bregman_ub_filter is single-query: qconst/sqrt_delta must be "
            f"(M,), got {qconst.shape}/{sqrt_delta.shape}; use "
            "bregman_ub_matrix for query batches")
    mode = _impl(impl)
    if mode == "ref":
        totals = ref.bregman_ub_totals(alpha, sqrt_gamma, qconst, sqrt_delta)
    else:
        qsum = jnp.sum(qconst)[None]
        totals = _ub.bregman_ub_matrix(
            alpha, sqrt_gamma, qsum, sqrt_delta[None, :],
            interpret=(mode == "interpret"),
        )[:, 0]

    def comp_of(kth):
        a = jnp.take(alpha, kth, axis=0)
        sg = jnp.take(sqrt_gamma, kth, axis=0)
        return a + qconst + sg * sqrt_delta

    return totals, comp_of


def bregman_ub_matrix(alpha, sqrt_gamma, qconst, sqrt_delta, impl=None):
    """(n, q) UB totals for a query batch."""
    mode = _impl(impl)
    if mode == "ref":
        return ref.bregman_ub_matrix(alpha, sqrt_gamma, qconst, sqrt_delta)
    qsum = jnp.sum(qconst, axis=-1)
    return _ub.bregman_ub_matrix(alpha, sqrt_gamma, qsum, sqrt_delta,
                                 interpret=(mode == "interpret"))


def bregman_ub_matrix_quant(alpha_q, alpha_scale, alpha_zp, sg_q, sg_scale,
                            sg_zp, qconst, sqrt_delta, impl=None):
    """(n, q) UB totals from the int8 filter tables (per-row affine decode)."""
    if qconst.ndim != 2 or sqrt_delta.ndim != 2:
        raise ValueError(
            "bregman_ub_matrix_quant wants (q, M) query batches, got "
            f"{qconst.shape}/{sqrt_delta.shape}")
    mode = _impl(impl)
    if mode == "ref":
        return ref.bregman_ub_matrix_quant(alpha_q, alpha_scale, alpha_zp,
                                           sg_q, sg_scale, sg_zp,
                                           qconst, sqrt_delta)
    qsum = jnp.sum(qconst, axis=-1)
    return _ub.bregman_ub_matrix_quant(alpha_q, alpha_scale, alpha_zp,
                                       sg_q, sg_scale, sg_zp, qsum,
                                       sqrt_delta,
                                       interpret=(mode == "interpret"))


def bregman_prune_block(amin, gmax, qconst, sqrt_delta, qb, impl=None):
    """Theorem-3 admit mask for a row block.  (n,M)x2, (q,M)x3 -> (n,q) int32.

    The per-point stage of the streaming prune+compact scan
    (core/search._stream_prune_compact): one fused corner-compare pass per
    block, no (n, M, q) lower-bound tensor outside the kernel.
    """
    if qconst.ndim != 2 or sqrt_delta.ndim != 2 or qb.ndim != 2:
        raise ValueError(
            "bregman_prune_block wants (q, M) query operands, got "
            f"{qconst.shape}/{sqrt_delta.shape}/{qb.shape}")
    mode = _impl(impl)
    if mode == "ref":
        return ref.bregman_prune_mask(amin, gmax, qconst, sqrt_delta, qb)
    return _prune.bregman_prune_mask(amin, gmax, qconst, sqrt_delta, qb,
                                     interpret=(mode == "interpret"))


def bregman_prune_block_quant(amin_q, amin_scale, amin_zp, gmax_q,
                              gmax_scale, gmax_zp, qconst, sqrt_delta, qb,
                              impl=None):
    """Admit mask from int8 corner codes (per-row affine, directed-rounded)."""
    if qconst.ndim != 2 or sqrt_delta.ndim != 2 or qb.ndim != 2:
        raise ValueError(
            "bregman_prune_block_quant wants (q, M) query operands, got "
            f"{qconst.shape}/{sqrt_delta.shape}/{qb.shape}")
    mode = _impl(impl)
    if mode == "ref":
        return ref.bregman_prune_mask_quant(
            amin_q, amin_scale, amin_zp, gmax_q, gmax_scale, gmax_zp,
            qconst, sqrt_delta, qb)
    return _prune.bregman_prune_mask_quant(
        amin_q, amin_scale, amin_zp, gmax_q, gmax_scale, gmax_zp,
        qconst, sqrt_delta, qb, interpret=(mode == "interpret"))


def bregman_filter_prune_block(alpha, sqrt_gamma, amin, gmax, qconst,
                               sqrt_delta, qb, impl=None):
    """Fused filter UB + Theorem-3 admit for a row block -> (ub, admit).

    One VMEM-resident pass computes the (n, q) f32 upper-bound tile AND the
    (n, q) int32 admit mask (core/search._stream_prune_compact's fused
    path): the UB values never round-trip through HBM between the filter
    and prune phases, and the transposed ``sqrt_delta`` tile is read once
    for both.  Callers that only need the admit mask discard ``ub`` — in
    ``ref`` mode XLA dead-code-eliminates the matmul; on TPU the kernel
    computes it in the same pass (that is the point).
    """
    if qconst.ndim != 2 or sqrt_delta.ndim != 2 or qb.ndim != 2:
        raise ValueError(
            "bregman_filter_prune_block wants (q, M) query operands, got "
            f"{qconst.shape}/{sqrt_delta.shape}/{qb.shape}")
    if alpha.shape != amin.shape:
        raise ValueError(
            "filter and corner tables must share (n, M), got "
            f"{alpha.shape} vs {amin.shape}")
    mode = _impl(impl)
    if mode == "ref":
        return ref.bregman_filter_prune(alpha, sqrt_gamma, amin, gmax,
                                        qconst, sqrt_delta, qb)
    qsum = jnp.sum(qconst, axis=-1)
    return _fused.bregman_filter_prune(alpha, sqrt_gamma, amin, gmax, qsum,
                                       qconst, sqrt_delta, qb,
                                       interpret=(mode == "interpret"))


def bregman_filter_prune_block_quant(alpha_q, alpha_scale, alpha_zp, sg_q,
                                     sg_scale, sg_zp, amin_q, amin_scale,
                                     amin_zp, gmax_q, gmax_scale, gmax_zp,
                                     qconst, sqrt_delta, qb, impl=None):
    """Fused (ub, admit) from int8 filter + corner codes (per-row affine)."""
    if qconst.ndim != 2 or sqrt_delta.ndim != 2 or qb.ndim != 2:
        raise ValueError(
            "bregman_filter_prune_block_quant wants (q, M) query operands, "
            f"got {qconst.shape}/{sqrt_delta.shape}/{qb.shape}")
    mode = _impl(impl)
    if mode == "ref":
        return ref.bregman_filter_prune_quant(
            alpha_q, alpha_scale, alpha_zp, sg_q, sg_scale, sg_zp,
            amin_q, amin_scale, amin_zp, gmax_q, gmax_scale, gmax_zp,
            qconst, sqrt_delta, qb)
    qsum = jnp.sum(qconst, axis=-1)
    return _fused.bregman_filter_prune_quant(
        alpha_q, alpha_scale, alpha_zp, sg_q, sg_scale, sg_zp,
        amin_q, amin_scale, amin_zp, gmax_q, gmax_scale, gmax_zp,
        qsum, qconst, sqrt_delta, qb, interpret=(mode == "interpret"))


def bregman_refine(rows, grad, c_y, family: str, impl=None):
    mode = _impl(impl)
    if mode == "ref":
        return ref.bregman_refine(rows, grad, c_y, family)
    return _dist.bregman_refine(rows, grad, c_y, family,
                                interpret=(mode == "interpret"))


def bregman_refine_batch(rows, grad, c_y, family: str, impl=None):
    """Per-query exact distances.  (q,b,d),(q,d),(q,) -> (q,b)."""
    if rows.ndim != 3 or grad.ndim != 2:
        raise ValueError(
            "bregman_refine_batch wants (q,b,d)/(q,d), got "
            f"{rows.shape}/{grad.shape}; use bregman_refine for one query")
    mode = _impl(impl)
    if mode == "ref":
        return ref.bregman_refine_batch(rows, grad, c_y, family)
    return _dist.bregman_refine_batch(rows, grad, c_y, family,
                                      interpret=(mode == "interpret"))


def bregman_refine_batch_quant(codes, scale, zp, grad, c_y, family: str,
                               impl=None):
    """Fused dequantize + exact distances.  (q,b,d) int8,(q,b),(q,b) -> (q,b)."""
    if codes.ndim != 3 or scale.ndim != 2 or grad.ndim != 2:
        raise ValueError(
            "bregman_refine_batch_quant wants (q,b,d) codes with (q,b) "
            f"decode rows, got {codes.shape}/{scale.shape}/{grad.shape}")
    mode = _impl(impl)
    if mode == "ref":
        return ref.bregman_refine_batch_quant(codes, scale, zp, grad, c_y,
                                              family)
    return _dist.bregman_refine_batch_quant(codes, scale, zp, grad, c_y,
                                            family,
                                            interpret=(mode == "interpret"))


def pccp_correlation(x, impl=None):
    mode = _impl(impl)
    if mode == "ref":
        return ref.pccp_correlation(x)
    return _corr.pccp_correlation(x, interpret=(mode == "interpret"))


def flash_attention(q, k, v, *, causal=True, window=None, scale=None, impl=None):
    mode = _impl(impl)
    if mode == "ref":
        return ref.attention(q, k, v, causal=causal, window=window, scale=scale)
    return _flash.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=(mode == "interpret"))
