"""Pallas TPU kernel — fused exact Bregman refinement distance.

    D_f(x, y) = sum_j phi(x_j)  -  x . phi'(y)  +  c_y

for a tile of candidate rows: the elementwise generator runs on the VPU and
the gradient inner product on the MXU, accumulated over d-tiles so the VMEM
working set is (block_b x block_d) regardless of dimensionality.  The
generator phi is selected statically per Bregman family (closure), so each
family compiles its own fused kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bregman import get_family
from repro.core.quantize import DOMAIN_EPS, POSITIVE_FAMILIES

# phi implementations usable inside the kernel (elementwise, mask-aware:
# padded columns carry x=0 AND grad=0; `mask` zeroes the phi contribution).
_PHIS = {
    "squared_euclidean": lambda x: 0.5 * x * x,
    "itakura_saito": lambda x: -jnp.log(jnp.maximum(x, 1e-30)),
    "exponential": jnp.exp,
    "burg": lambda x: x - jnp.log(jnp.maximum(x, 1e-30)),
    "shannon": lambda x: x * jnp.log(jnp.maximum(x, 1e-30)),
}


def bregman_refine(
    rows: jax.Array,    # (b, d) candidate points
    grad: jax.Array,    # (d,)   phi'(y)
    c_y: jax.Array,     # ()     sum_j (y_j phi'(y_j) - phi(y_j))
    family: str,
    *,
    block_b: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Exact D_f(rows[i], y) -> (b,): the q=1 slice of the batch kernel.

    Delegating keeps ONE kernel body (accumulation, family-specific safe
    padding) serving both the single-query and batched search paths.
    """
    return bregman_refine_batch(
        rows[None], grad[None], c_y[None], family,
        block_b=block_b, block_d=block_d, interpret=interpret)[0]


def _make_batch_kernel(phi):
    def kernel(rows_ref, grad_ref, mask_ref, acc_ref):
        j = pl.program_id(2)

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        rows = rows_ref[0]                         # (bb, bd)
        grad = grad_ref[...]                       # (1, bd) — this query's tile
        mask = mask_ref[...]                       # (1, bd)
        fx = jnp.sum(phi(rows) * mask, axis=-1, keepdims=True)      # VPU
        cross = jnp.dot(rows, grad.T, preferred_element_type=jnp.float32)
        acc_ref[0] += fx - cross                   # (bb, 1)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("family", "block_b", "block_d", "interpret")
)
def bregman_refine_batch(
    rows: jax.Array,    # (q, b, d) per-query candidate rows
    grad: jax.Array,    # (q, d)    per-query phi'(y)
    c_y: jax.Array,     # (q,)      per-query additive constant
    family: str,
    *,
    block_b: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Exact D_f(rows[q, i], y_q) -> (q, b): one call refines the whole batch.

    The query axis rides the grid's outermost dimension, so every query's
    candidate tile reuses the same compiled body with its own grad/c_y tile —
    the batched analogue of :func:`bregman_refine` (one program, q x b rows).
    """
    fam = get_family(family)
    phi = _PHIS[fam.name]
    q, b, d = rows.shape
    bb = min(block_b, max(8, b))
    bd = min(block_d, max(128 if not interpret else 8, d))
    b_pad, d_pad = -b % bb, -d % bd

    # Padded columns: rows padded with a domain-safe value, masked out of phi;
    # grad padded with 0 so the matmul ignores them.
    safe = 1.0 if fam.name in ("itakura_saito", "burg", "shannon") else 0.0
    r = jnp.pad(rows, ((0, 0), (0, b_pad), (0, d_pad)), constant_values=safe)
    g = jnp.pad(grad, ((0, 0), (0, d_pad)))
    mask = jnp.pad(jnp.ones((1, d), rows.dtype), ((0, 0), (0, d_pad)))
    _, bp, dp = r.shape

    out = pl.pallas_call(
        _make_batch_kernel(phi),
        grid=(q, bp // bb, dp // bd),
        in_specs=[
            pl.BlockSpec((1, bb, bd), lambda qi, i, j: (qi, i, j)),
            pl.BlockSpec((1, bd), lambda qi, i, j: (qi, j)),
            pl.BlockSpec((1, bd), lambda qi, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bb, 1), lambda qi, i, j: (qi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, bp, 1), jnp.float32),
        interpret=interpret,
    )(r, g, mask)
    return out[:, :b, 0] + c_y[:, None]


def _make_quant_batch_kernel(phi, positive: bool):
    def kernel(codes_ref, scale_ref, zp_ref, grad_ref, mask_ref, acc_ref):
        j = pl.program_id(2)

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # Fused dequantize: the only HBM read of the candidate rows is the
        # int8 codes; the affine decode (+ the domain clamp shared with
        # core/quantize.dequantize_rows) happens on-chip per tile.
        x = codes_ref[0].astype(jnp.float32)       # (bb, bd)
        x = x * scale_ref[0][:, None] + zp_ref[0][:, None]
        if positive:
            x = jnp.maximum(x, DOMAIN_EPS)
        grad = grad_ref[...]                       # (1, bd)
        mask = mask_ref[...]                       # (1, bd)
        fx = jnp.sum(phi(x) * mask, axis=-1, keepdims=True)          # VPU
        cross = jnp.dot(x, grad.T, preferred_element_type=jnp.float32)
        acc_ref[0] += fx - cross                   # (bb, 1)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("family", "block_b", "block_d", "interpret")
)
def bregman_refine_batch_quant(
    codes: jax.Array,   # (q, b, d) int8 candidate-row codes
    scale: jax.Array,   # (q, b)    per-row affine scale
    zp: jax.Array,      # (q, b)    per-row affine zero-point
    grad: jax.Array,    # (q, d)    per-query phi'(y)
    c_y: jax.Array,     # (q,)      per-query additive constant
    family: str,
    *,
    block_b: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Dequantize + exact D_f over int8 candidate rows -> (q, b).

    The int8-tier sibling of :func:`bregman_refine_batch`: same grid, the
    row tile arrives as codes plus two per-row decode scalars, and the
    dequantized values match ``core/quantize.dequantize_rows`` bit for bit
    so the reported distances are exact over the stored point set.
    Padded rows decode via (scale 0, zp 1) to the domain-safe ones-row;
    padded columns carry code 0 with grad/mask 0.
    """
    fam = get_family(family)
    phi = _PHIS[fam.name]
    positive = fam.name in POSITIVE_FAMILIES
    q, b, d = codes.shape
    bb = min(block_b, max(32 if not interpret else 8, b))
    bd = min(block_d, max(128 if not interpret else 8, d))
    b_pad, d_pad = -b % bb, -d % bd

    r = jnp.pad(codes, ((0, 0), (0, b_pad), (0, d_pad)))
    s = jnp.pad(scale, ((0, 0), (0, b_pad)))
    z = jnp.pad(zp, ((0, 0), (0, b_pad)), constant_values=1.0)
    g = jnp.pad(grad, ((0, 0), (0, d_pad)))
    mask = jnp.pad(jnp.ones((1, d), jnp.float32), ((0, 0), (0, d_pad)))
    _, bp, dp = r.shape

    out = pl.pallas_call(
        _make_quant_batch_kernel(phi, positive),
        grid=(q, bp // bb, dp // bd),
        in_specs=[
            pl.BlockSpec((1, bb, bd), lambda qi, i, j: (qi, i, j)),
            pl.BlockSpec((1, bb), lambda qi, i, j: (qi, i)),
            pl.BlockSpec((1, bb), lambda qi, i, j: (qi, i)),
            pl.BlockSpec((1, bd), lambda qi, i, j: (qi, j)),
            pl.BlockSpec((1, bd), lambda qi, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bb, 1), lambda qi, i, j: (qi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, bp, 1), jnp.float32),
        interpret=interpret,
    )(r, s, z, g, mask)
    return out[:, :b, 0] + c_y[:, None]
