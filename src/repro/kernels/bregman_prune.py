"""Pallas TPU kernel — fused Theorem-3 per-point admission mask.

For a tile of points and a tile of queries, recompute the tuple-space
cluster lower bound from the per-point corner stats and emit the admit
mask in one VMEM-resident pass:

    lb[n, i, j] = amin[n, i] + qconst[j, i] - gmax[n, i] * sqrt_delta[j, i]
    admit[n, j] = any_i ( lb[n, i, j] <= qb[j, i] )

The (bn, M, q) lower-bound tensor never exists: the subspace axis is a
static in-kernel loop (M is a few dozen — paper Table 4), each iteration an
outer broadcast of a (bn, 1) point column against a (1, bq) query row with
an OR-accumulate, so the only tile that leaves the kernel is the
(bn, bq) int32 mask the streaming compaction consumes
(core/search._stream_prune_compact).

The quantized variant streams int8 corner CODES plus four per-row decode
scalars and dequantizes per column on-chip — the corner codes were
directed-rounded at encode (core/quantize.py), so the decoded bound is
conservative with no slack term.  Query operands arrive TRANSPOSED,
(M, q), so the per-subspace slice is a cheap sublane read.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(m_real: int):
    def kernel(amin_ref, gmax_ref, qc_ref, sd_ref, qb_ref, out_ref):
        amin = amin_ref[...]                # (bn, Mp)
        gmax = gmax_ref[...]
        qc = qc_ref[...]                    # (Mp, bq) transposed query operands
        sd = sd_ref[...]
        qb = qb_ref[...]
        hit = None
        # Static loop over the REAL subspaces only: padded lanes carry
        # zeros, which would otherwise admit everything (0 <= 0).
        for i in range(m_real):
            lb = (amin[:, i:i + 1] + qc[i:i + 1, :]
                  - gmax[:, i:i + 1] * sd[i:i + 1, :])        # (bn, bq)
            h = lb <= qb[i:i + 1, :]
            hit = h if hit is None else (hit | h)
        out_ref[...] = hit.astype(out_ref.dtype)

    return kernel


def _make_quant_kernel(m_real: int):
    def kernel(amq_ref, gmq_ref, as_ref, az_ref, gs_ref, gz_ref,
               qc_ref, sd_ref, qb_ref, out_ref):
        a_s, a_z = as_ref[...], az_ref[...]          # (bn, 1) row decode
        g_s, g_z = gs_ref[...], gz_ref[...]
        qc = qc_ref[...]                             # (Mp, bq)
        sd = sd_ref[...]
        qb = qb_ref[...]
        hit = None
        for i in range(m_real):
            # Fused per-column affine decode: the HBM stream is int8 codes
            # plus four f32 scalars per row, never a fp32 corner table.
            amin = amq_ref[:, i:i + 1].astype(jnp.float32) * a_s + a_z
            gmax = gmq_ref[:, i:i + 1].astype(jnp.float32) * g_s + g_z
            lb = amin + qc[i:i + 1, :] - gmax * sd[i:i + 1, :]
            h = lb <= qb[i:i + 1, :]
            hit = h if hit is None else (hit | h)
        out_ref[...] = hit.astype(out_ref.dtype)

    return kernel


# Padded point rows must never admit: +BIG alpha_min pushes the lower bound
# beyond any finite searching bound (mirrors core/index.PAD_CORNER).
_PAD_AMIN = 1e30


@functools.partial(jax.jit, static_argnames=("block_n", "block_q",
                                             "interpret"))
def bregman_prune_mask(
    amin: jax.Array,         # (n, M) per-point corner alpha_min
    gmax: jax.Array,         # (n, M) per-point corner sqrt_gamma_max
    qconst: jax.Array,       # (q, M)
    sqrt_delta: jax.Array,   # (q, M)
    qb: jax.Array,           # (q, M) Alg.-4 searching bounds
    *,
    block_n: int = 512,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(n, q) int32 Theorem-3 admit mask.  Pads n/q/M to tiles, strips after."""
    n, m = amin.shape
    q = qconst.shape[0]
    bn = min(block_n, max(8, n))
    bq = min(block_q, max(1, q))
    n_pad = -n % bn
    q_pad = -q % bq
    m_pad = -m % 128 if not interpret else 0

    a = jnp.pad(amin, ((0, n_pad), (0, m_pad)), constant_values=_PAD_AMIN)
    g = jnp.pad(gmax, ((0, n_pad), (0, m_pad)))
    qc = jnp.pad(qconst, ((0, q_pad), (0, m_pad))).T       # (M, q)
    sd = jnp.pad(sqrt_delta, ((0, q_pad), (0, m_pad))).T
    qbt = jnp.pad(qb, ((0, q_pad), (0, m_pad))).T
    np_, mp = a.shape
    qp = qc.shape[1]

    out = pl.pallas_call(
        _make_kernel(m),
        grid=(np_ // bn, qp // bq),
        in_specs=[
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, qp), jnp.int32),
        interpret=interpret,
    )(a, g, qc, sd, qbt)
    return out[:n, :q]


@functools.partial(jax.jit, static_argnames=("block_n", "block_q",
                                             "interpret"))
def bregman_prune_mask_quant(
    amin_q: jax.Array,       # (n, M) int8 corner codes (floor-rounded)
    amin_scale: jax.Array,   # (n,)
    amin_zp: jax.Array,      # (n,)
    gmax_q: jax.Array,       # (n, M) int8 corner codes (ceil-rounded)
    gmax_scale: jax.Array,   # (n,)
    gmax_zp: jax.Array,      # (n,)
    qconst: jax.Array,       # (q, M)
    sqrt_delta: jax.Array,   # (q, M)
    qb: jax.Array,           # (q, M)
    *,
    block_n: int = 512,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(n, q) int32 admit mask from int8 corner tables (kernels/ref oracle).

    Padded point rows get (scale 0, zp +BIG) for alpha_min — the int8
    analogue of the PAD_CORNER sentinel — so they fail every admission.
    int8 VMEM tiles want a 32-row sublane, so the row block floors at 32.
    """
    n, m = amin_q.shape
    q = qconst.shape[0]
    bn = min(block_n, max(32, n))
    bq = min(block_q, max(1, q))
    n_pad = -n % bn
    q_pad = -q % bq
    m_pad = -m % 128 if not interpret else 0

    def pad_rows(a, fill=0):
        return jnp.pad(a, ((0, n_pad),) + ((0, m_pad),) * (a.ndim - 1),
                       constant_values=fill)

    aq = pad_rows(amin_q)
    gq = pad_rows(gmax_q)
    a_s = pad_rows(amin_scale)[:, None]
    a_z = pad_rows(amin_zp, fill=_PAD_AMIN)[:, None]
    g_s = pad_rows(gmax_scale)[:, None]
    g_z = pad_rows(gmax_zp)[:, None]
    qc = jnp.pad(qconst, ((0, q_pad), (0, m_pad))).T
    sd = jnp.pad(sqrt_delta, ((0, q_pad), (0, m_pad))).T
    qbt = jnp.pad(qb, ((0, q_pad), (0, m_pad))).T
    np_, mp = aq.shape
    qp = qc.shape[1]

    out = pl.pallas_call(
        _make_quant_kernel(m),
        grid=(np_ // bn, qp // bq),
        in_specs=[
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, qp), jnp.int32),
        interpret=interpret,
    )(aq, gq, a_s, a_z, g_s, g_z, qc, sd, qbt)
    return out[:n, :q]
