"""Pallas TPU kernel — tiled |Pearson| correlation matrix for PCCP (paper §5.2).

corr = |Xc^T Xc| / (n sigma_i sigma_j), diagonal zeroed.  The Gram matrix is
a classic (d, n) x (n, d) tiled matmul accumulated over n-tiles; mean/std
are cheap (one pass) and fused outside.  128-aligned d-tiles feed the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(xi_ref, xj_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xi = xi_ref[...]                    # (bn, bd)
    xj = xj_ref[...]                    # (bn, bd)
    acc_ref[...] += jnp.dot(xi.T, xj, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block_d", "block_n", "interpret")
)
def pccp_correlation(
    x: jax.Array,        # (n, d)
    *,
    block_d: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(d, d) |Pearson| correlations, diagonal zeroed."""
    n, d = x.shape
    mean = jnp.mean(x, axis=0, keepdims=True)
    xc = x - mean
    std = jnp.sqrt(jnp.mean(xc * xc, axis=0))
    std = jnp.where(std < 1e-12, 1.0, std)

    bd = min(block_d, max(8, d))
    bn = min(block_n, max(8, n))
    d_pad, n_pad = -d % bd, -n % bn
    xp = jnp.pad(xc, ((0, n_pad), (0, d_pad)))
    np_, dp = xp.shape

    gram = pl.pallas_call(
        _gram_kernel,
        grid=(dp // bd, dp // bd, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (k, i)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, dp), jnp.float32),
        interpret=interpret,
    )(xp, xp)[:d, :d]

    corr = jnp.abs(gram / (n * std[:, None] * std[None, :]))
    return corr * (1.0 - jnp.eye(d, dtype=corr.dtype))
