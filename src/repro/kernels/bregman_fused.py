"""Pallas TPU kernel — fused Cauchy UB filter + Theorem-3 admit per block.

The streaming batched pipeline runs two scans over the same row blocks
(core/search): the filter scan computes the (block, q) upper-bound tile
(Alg. 1/4) and the prune scan recomputes per-point lower bounds against
the Alg.-4 searching bounds (Theorem 3).  Run separately, the UB tile is
materialized to HBM by the filter kernel and the prune kernel starts from
a cold VMEM tile.  This kernel computes BOTH tiles in one VMEM-resident
pass over a row block:

    ub[n, q]    = rowsum(alpha)[n] + qsum[q] + sqrt_gamma[n, :] . sd[q, :]
    admit[n, q] = any_i ( amin[n, i] + qconst[q, i]
                          - gmax[n, i] * sd[q, i] <= qb[q, i] )

so the query operand tile ``sd`` (transposed, (M, q)) is read from VMEM
once and feeds both the MXU contraction and the per-subspace admit loop,
and the UB values never round-trip through HBM between the two phases —
the prune scan gets them as a byproduct (core/search uses them for the
``tau_admit`` telemetry: the tightest upper bound among admitted rows).

The UB part is a (bn, M) x (M, bq) matmul with a fused rank-1 bias on the
MXU; the admit part is the static-M broadcast/OR-accumulate loop of
``bregman_prune.py`` (the (bn, M, q) lower-bound tensor never exists).
The int8 variant streams BOTH table pairs as codes (1 byte/entry) with
four decode scalars per row each, and keeps the Cauchy contraction
MXU-aligned by factoring the per-row affine out of the dot:

    sg_hat . sd = g_s * (sg_q . sd) + g_z * sum(sd)

Tiling, padding, and sentinels match the unfused kernels exactly
(bregman_ub.py / bregman_prune.py) so the fused path is bit-identical to
the two-kernel path — the parity tests in tests/test_kernels.py pin this.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bregman_prune import _PAD_AMIN


def _make_kernel(m_real: int):
    def kernel(alpha_ref, sg_ref, amin_ref, gmax_ref,
               qsum_ref, qc_ref, sd_ref, qb_ref, ub_ref, admit_ref):
        sd = sd_ref[...]                    # (Mp, bq) — shared by both phases
        alpha = alpha_ref[...]              # (bn, Mp)
        sg = sg_ref[...]
        rowsum = jnp.sum(alpha, axis=-1, keepdims=True)          # (bn, 1)
        cauchy = jnp.dot(sg, sd, preferred_element_type=jnp.float32)  # MXU
        ub_ref[...] = (rowsum + qsum_ref[...] + cauchy).astype(ub_ref.dtype)

        amin = amin_ref[...]                # (bn, Mp)
        gmax = gmax_ref[...]
        qc = qc_ref[...]                    # (Mp, bq)
        qb = qb_ref[...]
        hit = None
        # Static loop over the REAL subspaces only: padded lanes carry
        # zeros, which would otherwise admit everything (0 <= 0).
        for i in range(m_real):
            lb = (amin[:, i:i + 1] + qc[i:i + 1, :]
                  - gmax[:, i:i + 1] * sd[i:i + 1, :])           # (bn, bq)
            h = lb <= qb[i:i + 1, :]
            hit = h if hit is None else (hit | h)
        admit_ref[...] = hit.astype(admit_ref.dtype)

    return kernel


def _make_quant_kernel(m_real: int):
    def kernel(aq_ref, sgq_ref, as_ref, az_ref, gs_ref, gz_ref,
               amq_ref, gmq_ref, ams_ref, amz_ref, gms_ref, gmz_ref,
               qsum_ref, qc_ref, sd_ref, sdsum_ref, qb_ref,
               ub_ref, admit_ref):
        sd = sd_ref[...]                                 # (Mp, bq)
        aq = aq_ref[...].astype(jnp.float32)             # (bn, Mp) codes
        sgq = sgq_ref[...].astype(jnp.float32)
        a_s, a_z = as_ref[...], az_ref[...]              # (bn, 1) row decode
        g_s, g_z = gs_ref[...], gz_ref[...]
        # Per-row affine factored out of both reductions (bregman_ub.py):
        # the code matmul stays a clean int8-upcast MXU contraction.
        rowsum = a_s * jnp.sum(aq, axis=-1, keepdims=True) + m_real * a_z
        cauchy = (g_s * jnp.dot(sgq, sd, preferred_element_type=jnp.float32)
                  + g_z * sdsum_ref[...])                # (bn, bq)
        ub_ref[...] = (rowsum + qsum_ref[...] + cauchy).astype(ub_ref.dtype)

        am_s, am_z = ams_ref[...], amz_ref[...]
        gm_s, gm_z = gms_ref[...], gmz_ref[...]
        qc = qc_ref[...]
        qb = qb_ref[...]
        hit = None
        for i in range(m_real):
            # Fused per-column affine decode of the corner codes
            # (directed-rounded at encode, so the bound is conservative).
            amin = amq_ref[:, i:i + 1].astype(jnp.float32) * am_s + am_z
            gmax = gmq_ref[:, i:i + 1].astype(jnp.float32) * gm_s + gm_z
            lb = amin + qc[i:i + 1, :] - gmax * sd[i:i + 1, :]
            h = lb <= qb[i:i + 1, :]
            hit = h if hit is None else (hit | h)
        admit_ref[...] = hit.astype(admit_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("block_n", "block_q",
                                             "interpret"))
def bregman_filter_prune(
    alpha: jax.Array,        # (n, M) filter table
    sqrt_gamma: jax.Array,   # (n, M) filter table
    amin: jax.Array,         # (n, M) per-point corner alpha_min
    gmax: jax.Array,         # (n, M) per-point corner sqrt_gamma_max
    qsum: jax.Array,         # (q,)  sum over subspaces of qconst
    qconst: jax.Array,       # (q, M)
    sqrt_delta: jax.Array,   # (q, M)
    qb: jax.Array,           # (q, M) Alg.-4 searching bounds
    *,
    block_n: int = 512,
    block_q: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(ub (n, q) f32, admit (n, q) int32) in one pass.  Pads to tiles."""
    n, m = alpha.shape
    q = qsum.shape[0]
    bn = min(block_n, max(8, n))
    bq = min(block_q, max(1, q))
    n_pad = -n % bn
    q_pad = -q % bq
    m_pad = -m % 128 if not interpret else 0

    a = jnp.pad(alpha, ((0, n_pad), (0, m_pad)))
    sg = jnp.pad(sqrt_gamma, ((0, n_pad), (0, m_pad)))
    am = jnp.pad(amin, ((0, n_pad), (0, m_pad)), constant_values=_PAD_AMIN)
    gm = jnp.pad(gmax, ((0, n_pad), (0, m_pad)))
    qc = jnp.pad(qconst, ((0, q_pad), (0, m_pad))).T          # (M, q)
    sd = jnp.pad(sqrt_delta, ((0, q_pad), (0, m_pad))).T
    qbt = jnp.pad(qb, ((0, q_pad), (0, m_pad))).T
    qsm = jnp.pad(qsum, (0, q_pad))[None, :]                  # (1, q)
    np_, mp = a.shape
    qp = qc.shape[1]

    ub, admit = pl.pallas_call(
        _make_kernel(m),
        grid=(np_ // bn, qp // bq),
        in_specs=[
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, qp), jnp.float32),
            jax.ShapeDtypeStruct((np_, qp), jnp.int32),
        ],
        interpret=interpret,
    )(a, sg, am, gm, qsm, qc, sd, qbt)
    return ub[:n, :q], admit[:n, :q]


@functools.partial(jax.jit, static_argnames=("block_n", "block_q",
                                             "interpret"))
def bregman_filter_prune_quant(
    alpha_q: jax.Array,      # (n, M) int8 filter codes
    alpha_scale: jax.Array,  # (n,)
    alpha_zp: jax.Array,     # (n,)
    sg_q: jax.Array,         # (n, M) int8 filter codes
    sg_scale: jax.Array,     # (n,)
    sg_zp: jax.Array,        # (n,)
    amin_q: jax.Array,       # (n, M) int8 corner codes (floor-rounded)
    amin_scale: jax.Array,   # (n,)
    amin_zp: jax.Array,      # (n,)
    gmax_q: jax.Array,       # (n, M) int8 corner codes (ceil-rounded)
    gmax_scale: jax.Array,   # (n,)
    gmax_zp: jax.Array,      # (n,)
    qsum: jax.Array,         # (q,)
    qconst: jax.Array,       # (q, M)
    sqrt_delta: jax.Array,   # (q, M)
    qb: jax.Array,           # (q, M)
    *,
    block_n: int = 512,
    block_q: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused (ub, admit) from the int8 tables.  Padded rows decode to the
    PAD_CORNER sentinel (zero scale, +BIG alpha_min zero-point) and fail
    every admission; int8 VMEM tiles want a 32-row sublane, so the row
    block floors at 32.
    """
    n, m = alpha_q.shape
    q = qsum.shape[0]
    bn = min(block_n, max(32, n))
    bq = min(block_q, max(1, q))
    n_pad = -n % bn
    q_pad = -q % bq
    m_pad = -m % 128 if not interpret else 0

    def pad_rows(arr, fill=0):
        return jnp.pad(arr, ((0, n_pad),) + ((0, m_pad),) * (arr.ndim - 1),
                       constant_values=fill)

    aq = pad_rows(alpha_q)
    sgq = pad_rows(sg_q)
    a_s = pad_rows(alpha_scale)[:, None]
    a_z = pad_rows(alpha_zp)[:, None]
    g_s = pad_rows(sg_scale)[:, None]
    g_z = pad_rows(sg_zp)[:, None]
    amq = pad_rows(amin_q)
    gmq = pad_rows(gmax_q)
    am_s = pad_rows(amin_scale)[:, None]
    am_z = pad_rows(amin_zp, fill=_PAD_AMIN)[:, None]
    gm_s = pad_rows(gmax_scale)[:, None]
    gm_z = pad_rows(gmax_zp)[:, None]
    qc = jnp.pad(qconst, ((0, q_pad), (0, m_pad))).T          # (M, q)
    sd = jnp.pad(sqrt_delta, ((0, q_pad), (0, m_pad))).T
    qbt = jnp.pad(qb, ((0, q_pad), (0, m_pad))).T
    qsm = jnp.pad(qsum, (0, q_pad))[None, :]                  # (1, q)
    sds = jnp.pad(jnp.sum(sqrt_delta, -1), (0, q_pad))[None, :]
    np_, mp = aq.shape
    qp = qc.shape[1]

    row_tile = pl.BlockSpec((bn, mp), lambda i, j: (i, 0))
    row_col = pl.BlockSpec((bn, 1), lambda i, j: (i, 0))
    q_tile = pl.BlockSpec((mp, bq), lambda i, j: (0, j))
    q_row = pl.BlockSpec((1, bq), lambda i, j: (0, j))
    ub, admit = pl.pallas_call(
        _make_quant_kernel(m),
        grid=(np_ // bn, qp // bq),
        in_specs=[
            row_tile, row_tile, row_col, row_col, row_col, row_col,
            row_tile, row_tile, row_col, row_col, row_col, row_col,
            q_row, q_tile, q_tile, q_row, q_tile,
        ],
        out_specs=[
            pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, qp), jnp.float32),
            jax.ShapeDtypeStruct((np_, qp), jnp.int32),
        ],
        interpret=interpret,
    )(aq, sgq, a_s, a_z, g_s, g_z, amq, gmq, am_s, am_z, gm_s, gm_z,
      qsm, qc, sd, sds, qbt)
    return ub[:n, :q], admit[:n, :q]
