"""Pallas TPU kernel — fused Cauchy upper-bound filter (paper Alg. 1/4).

Computes, for every point tile, the total upper bound

    ub[n, q] = rowsum(alpha)[n] + qsum[q] + sqrt_gamma[n, :] . sqrt_delta[q, :]

i.e. a (n, M) x (M, q) matmul with a fused rank-1 bias — the filter phase of
BrePartition collapsed onto the MXU (DESIGN.md §3.1).  The VMEM tile
(``block_n`` x M_padded) is the TPU analogue of the paper's disk page.

Tiling: grid over n; the M (subspace) axis is kept whole per tile — M is a
few dozen in practice (paper Table 4: 22..50), padded to the 128 lane width
by the ops wrapper.  Queries are tiled along the lane axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(alpha_ref, sg_ref, qsum_ref, sd_ref, out_ref):
    alpha = alpha_ref[...]              # (bn, M)
    sg = sg_ref[...]                    # (bn, M)
    qsum = qsum_ref[...]                # (1, bq)
    sd = sd_ref[...]                    # (M, bq)
    rowsum = jnp.sum(alpha, axis=-1, keepdims=True)          # (bn, 1)
    cauchy = jnp.dot(sg, sd, preferred_element_type=jnp.float32)  # MXU
    out_ref[...] = (rowsum + qsum + cauchy).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def bregman_ub_matrix(
    alpha: jax.Array,        # (n, M)
    sqrt_gamma: jax.Array,   # (n, M)
    qsum: jax.Array,         # (q,)  sum over subspaces of qconst
    sqrt_delta: jax.Array,   # (q, M)
    *,
    block_n: int = 512,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(n, q) UB totals.  Pads n/q/M to tile multiples, strips after."""
    n, m = alpha.shape
    q = qsum.shape[0]
    bn = min(block_n, max(8, n))
    bq = min(block_q, max(1, q))
    n_pad = -n % bn
    q_pad = -q % bq
    m_pad = -m % 128 if not interpret else 0

    a = jnp.pad(alpha, ((0, n_pad), (0, m_pad)))
    sg = jnp.pad(sqrt_gamma, ((0, n_pad), (0, m_pad)))
    sd = jnp.pad(sqrt_delta, ((0, q_pad), (0, m_pad))).T      # (M, q)
    qs = jnp.pad(qsum, (0, q_pad))[None, :]                   # (1, q)
    np_, mp = a.shape
    qp = qs.shape[1]

    out = pl.pallas_call(
        _kernel,
        grid=(np_ // bn, qp // bq),
        in_specs=[
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, qp), jnp.float32),
        interpret=interpret,
    )(a, sg, qs, sd)
    return out[:n, :q]


def _make_quant_kernel(m_real: int):
    def kernel(aq_ref, sgq_ref, as_ref, az_ref, gs_ref, gz_ref,
               qsum_ref, sd_ref, sdsum_ref, out_ref):
        aq = aq_ref[...].astype(jnp.float32)          # (bn, M) decoded codes
        sgq = sgq_ref[...].astype(jnp.float32)
        a_s, a_z = as_ref[...], az_ref[...]           # (bn, 1) row decode
        g_s, g_z = gs_ref[...], gz_ref[...]
        # Per-row affine factored out of both reductions: the HBM stream is
        # int8 codes + four f32 scalars per row, not two (M,) f32 tables.
        rowsum = a_s * jnp.sum(aq, axis=-1, keepdims=True) + m_real * a_z
        cauchy = (g_s * jnp.dot(sgq, sd_ref[...],
                                preferred_element_type=jnp.float32)
                  + g_z * sdsum_ref[...])             # (bn, bq)
        out_ref[...] = (rowsum + qsum_ref[...] + cauchy).astype(out_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def bregman_ub_matrix_quant(
    alpha_q: jax.Array,      # (n, M) int8 codes
    alpha_scale: jax.Array,  # (n,)  per-row affine decode for alpha
    alpha_zp: jax.Array,     # (n,)
    sg_q: jax.Array,         # (n, M) int8 codes
    sg_scale: jax.Array,     # (n,)
    sg_zp: jax.Array,        # (n,)
    qsum: jax.Array,         # (q,)  sum over subspaces of qconst
    sqrt_delta: jax.Array,   # (q, M)
    *,
    block_n: int = 512,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(n, q) UB totals from the int8 filter tables (kernels/ref.py oracle).

    Same tiling as :func:`bregman_ub_matrix`; the per-row decode rides as
    (bn, 1) scalar columns.  int8 VMEM tiles want a 32-row sublane, so the
    row block floors at 32 (padded rows are stripped after).
    """
    n, m = alpha_q.shape
    q = qsum.shape[0]
    bn = min(block_n, max(32, n))
    bq = min(block_q, max(1, q))
    n_pad = -n % bn
    q_pad = -q % bq
    m_pad = -m % 128 if not interpret else 0

    def pad_rows(a, fill=0):
        return jnp.pad(a, ((0, n_pad),) + ((0, m_pad),) * (a.ndim - 1),
                       constant_values=fill)

    aq = pad_rows(alpha_q)
    sgq = pad_rows(sg_q)
    a_s = pad_rows(alpha_scale)[:, None]
    a_z = pad_rows(alpha_zp)[:, None]
    g_s = pad_rows(sg_scale)[:, None]
    g_z = pad_rows(sg_zp)[:, None]
    sd = jnp.pad(sqrt_delta, ((0, q_pad), (0, m_pad))).T      # (M, q)
    qs = jnp.pad(qsum, (0, q_pad))[None, :]                   # (1, q)
    sds = jnp.pad(jnp.sum(sqrt_delta, -1), (0, q_pad))[None, :]
    np_, mp = aq.shape
    qp = qs.shape[1]

    out = pl.pallas_call(
        _make_quant_kernel(m),
        grid=(np_ // bn, qp // bq),
        in_specs=[
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
            pl.BlockSpec((1, bq), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, qp), jnp.float32),
        interpret=interpret,
    )(aq, sgq, a_s, a_z, g_s, g_z, qs, sd, sds)
    return out[:n, :q]
