"""Pallas TPU kernel — fused Cauchy upper-bound filter (paper Alg. 1/4).

Computes, for every point tile, the total upper bound

    ub[n, q] = rowsum(alpha)[n] + qsum[q] + sqrt_gamma[n, :] . sqrt_delta[q, :]

i.e. a (n, M) x (M, q) matmul with a fused rank-1 bias — the filter phase of
BrePartition collapsed onto the MXU (DESIGN.md §3.1).  The VMEM tile
(``block_n`` x M_padded) is the TPU analogue of the paper's disk page.

Tiling: grid over n; the M (subspace) axis is kept whole per tile — M is a
few dozen in practice (paper Table 4: 22..50), padded to the 128 lane width
by the ops wrapper.  Queries are tiled along the lane axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(alpha_ref, sg_ref, qsum_ref, sd_ref, out_ref):
    alpha = alpha_ref[...]              # (bn, M)
    sg = sg_ref[...]                    # (bn, M)
    qsum = qsum_ref[...]                # (1, bq)
    sd = sd_ref[...]                    # (M, bq)
    rowsum = jnp.sum(alpha, axis=-1, keepdims=True)          # (bn, 1)
    cauchy = jnp.dot(sg, sd, preferred_element_type=jnp.float32)  # MXU
    out_ref[...] = (rowsum + qsum + cauchy).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def bregman_ub_matrix(
    alpha: jax.Array,        # (n, M)
    sqrt_gamma: jax.Array,   # (n, M)
    qsum: jax.Array,         # (q,)  sum over subspaces of qconst
    sqrt_delta: jax.Array,   # (q, M)
    *,
    block_n: int = 512,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(n, q) UB totals.  Pads n/q/M to tile multiples, strips after."""
    n, m = alpha.shape
    q = qsum.shape[0]
    bn = min(block_n, max(8, n))
    bq = min(block_q, max(1, q))
    n_pad = -n % bn
    q_pad = -q % bq
    m_pad = -m % 128 if not interpret else 0

    a = jnp.pad(alpha, ((0, n_pad), (0, m_pad)))
    sg = jnp.pad(sqrt_gamma, ((0, n_pad), (0, m_pad)))
    sd = jnp.pad(sqrt_delta, ((0, q_pad), (0, m_pad))).T      # (M, q)
    qs = jnp.pad(qsum, (0, q_pad))[None, :]                   # (1, q)
    np_, mp = a.shape
    qp = qs.shape[1]

    out = pl.pallas_call(
        _kernel,
        grid=(np_ // bn, qp // bq),
        in_specs=[
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, mp), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bq), lambda i, j: (0, j)),
            pl.BlockSpec((mp, bq), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, qp), jnp.float32),
        interpret=interpret,
    )(a, sg, qs, sd)
    return out[:n, :q]
