"""Pallas TPU kernel — causal GQA flash attention (online softmax).

Grid: (batch, q_heads, q_tiles, kv_tiles), kv innermost (sequential on TPU),
with running max / denominator / output accumulator in VMEM scratch.
Supports GQA (kv head = q head // rep via the k/v BlockSpec index maps),
causal masking, sliding-window (local) attention, and decode-style
end-aligned short query blocks (Sq < Skv).

Used by the LM stack as the TPU target; the XLA path (models/attention.py
chunked attention) is the portable fallback the dry-run compiles.  Note a
production kernel would also skip fully-masked kv tiles via the index map;
we keep the dense grid and mask (documented trade-off, §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(*, scale, causal, window, sq, skv, bq, bkv):
    def kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref):
        j = pl.program_id(3)
        nj = pl.num_programs(3)

        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, D)
        v = v_ref[0, 0].astype(jnp.float32)

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        i = pl.program_id(2)
        q_pos = (i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
                 + (skv - sq))                          # end-aligned
        k_pos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = k_pos < skv                              # kv padding
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...], l_ref[...] = m_new, l_new

        @pl.when(j == nj - 1)
        def _finalize():
            out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
            out_ref[0, 0] = out.astype(out_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_kv", "interpret"))
def flash_attention(
    q: jax.Array,           # (B, H, Sq, D)
    k: jax.Array,           # (B, KH, Skv, D)
    v: jax.Array,           # (B, KH, Skv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    assert h % kh == 0, "q heads must be a multiple of kv heads"
    rep = h // kh
    scale = float(scale) if scale is not None else 1.0 / float(d) ** 0.5

    bq = min(block_q, max(8, sq))
    bkv = min(block_kv, max(8, skv))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, -sq % bq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, -skv % bkv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, -skv % bkv), (0, 0)))
    sqp, skvp = qp.shape[2], kp.shape[2]

    kernel = _make_kernel(scale=scale, causal=causal, window=window,
                          sq=sq, skv=skv, bq=bq, bkv=bkv)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, sqp // bq, skvp // bkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :sq, :]
