"""AOT lowering of every (arch x shape x mesh) cell — shared by the dry-run
CLI, the roofline benchmarks and the perf-iteration harness.

Each cell lowers ONE program:

    train_4k     -> train_step (fwd + bwd + AdamW update, donated state)
    prefill_32k  -> prefill    (populate caches, return hidden + caches)
    decode_32k   -> decode_step (1 token against a seq_len cache, donated)
    long_500k    -> decode_step (sub-quadratic archs only)

All inputs are ShapeDtypeStructs — nothing allocates.  Serving params are
bf16 (production serving dtype) and shard over `model` only (SERVE_RULES);
training params are f32 and shard fsdp x model.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.common import SHAPES, ShapeSpec, batch_axes, batch_structs
from repro.dist import sharding as shd
from repro.models.registry import ModelBundle, build_model
from repro.train.train_loop import TrainConfig, lower_train_step


def serve_param_structs(bundle: ModelBundle):
    """bf16 serving weights (norm scales stay f32 for numerics)."""
    def cast(s):
        if s.dtype == jnp.float32 and len(s.shape) >= 2:
            return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        return s
    return jax.tree.map(cast, bundle.param_structs())


def cache_structs_for(bundle: ModelBundle, shape: ShapeSpec):
    return jax.eval_shape(
        lambda: bundle.init_cache(shape.global_batch, shape.seq_len))


def _cache_shardings(bundle, shape, mesh, rules):
    axes = bundle.cache_axes()
    structs = cache_structs_for(bundle, shape)
    return shd.tree_shardings_for_structs(axes, structs, mesh, rules)


def _batch_shardings(bundle, shape, mesh, rules):
    return shd.tree_shardings_for_structs(
        batch_axes(bundle, shape), batch_structs(bundle, shape), mesh, rules)


def _serve_param_shardings(bundle, mesh, rules):
    return shd.tree_shardings_for_structs(
        bundle.param_axes(), bundle.param_structs(), mesh, rules)


def lower_prefill(bundle: ModelBundle, mesh: Mesh, shape: ShapeSpec,
                  rules=None):
    rules = rules or shd.SERVE_RULES
    p_structs = serve_param_structs(bundle)
    p_sh = _serve_param_shardings(bundle, mesh, rules)
    b_structs = {k: v for k, v in batch_structs(bundle, shape).items()
                 if k != "lengths"}
    b_sh = {k: v for k, v in
            _batch_shardings(bundle, shape, mesh, rules).items()
            if k != "lengths"}
    c_structs = cache_structs_for(bundle, shape)
    c_sh = _cache_shardings(bundle, shape, mesh, rules)
    len_struct = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    len_sh = NamedSharding(mesh, P())
    hidden_sh = shd.spec_for_shape(
        ("batch", "seq", None),
        (shape.global_batch, shape.seq_len, bundle.cfg.d_model), mesh, rules)

    def wrapped(params, batch, caches, lengths):
        with shd.activation_rules(mesh, rules):
            return bundle.prefill(params, batch, caches, lengths)

    fn = jax.jit(
        wrapped,
        in_shardings=(p_sh, b_sh, c_sh, len_sh),
        out_shardings=(NamedSharding(mesh, hidden_sh), c_sh),
        donate_argnums=(2,),
    )
    with mesh:
        return fn.lower(p_structs, b_structs, c_structs, len_struct)


def lower_decode(bundle: ModelBundle, mesh: Mesh, shape: ShapeSpec,
                 rules=None):
    rules = rules or shd.SERVE_RULES
    p_structs = serve_param_structs(bundle)
    p_sh = _serve_param_shardings(bundle, mesh, rules)
    b = shape.global_batch
    bs = batch_structs(bundle, shape)
    tok_struct, pos_struct = bs["tokens"], bs["positions"]
    bsh = _batch_shardings(bundle, shape, mesh, rules)
    tok_sh, pos_sh = bsh["tokens"], bsh["positions"]
    c_structs = cache_structs_for(bundle, shape)
    c_sh = _cache_shardings(bundle, shape, mesh, rules)
    len_struct = jax.ShapeDtypeStruct((b,), jnp.int32)
    len_sh = NamedSharding(mesh, P())
    logits_sh = NamedSharding(mesh, shd.spec_for_shape(
        ("batch", "vocab"), (b, bundle.cfg.vocab_size), mesh, rules))
    hidden_sh = NamedSharding(mesh, shd.spec_for_shape(
        ("batch", None), (b, bundle.cfg.d_model), mesh, rules))

    def wrapped(params, token, positions, caches, lengths):
        with shd.activation_rules(mesh, rules):
            return bundle.decode_step(params, token, positions, caches,
                                      lengths)

    fn = jax.jit(
        wrapped,
        in_shardings=(p_sh, tok_sh, pos_sh, c_sh, len_sh),
        out_shardings=(logits_sh, hidden_sh, c_sh),
        donate_argnums=(3,),
    )
    with mesh:
        return fn.lower(p_structs, tok_struct, pos_struct, c_structs,
                        len_struct)


def lower_train(bundle: ModelBundle, mesh: Mesh, shape: ShapeSpec,
                rules=None, train_cfg: TrainConfig | None = None):
    cfg = train_cfg or TrainConfig()
    return lower_train_step(bundle, mesh, cfg, shape,
                            batch_structs(bundle, shape), rules)


def lower_cell(arch: str, shape_name: str, mesh: Mesh, rules=None,
               overrides: dict | None = None,
               train_cfg: TrainConfig | None = None,
               config=None, shape: ShapeSpec | None = None):
    """One dry-run cell -> jax Lowered.

    ``config``/``shape`` override the registry lookups (reduced-config
    smoke tests on small meshes).
    """
    shape = shape or SHAPES[shape_name]
    cfg = config if config is not None else configs.get_config(
        arch, **(overrides or {}))
    bundle = build_model(cfg)
    if shape.kind == "train":
        return lower_train(bundle, mesh, shape, rules, train_cfg)
    if shape.kind == "prefill":
        return lower_prefill(bundle, mesh, shape, rules)
    return lower_decode(bundle, mesh, shape, rules)


def serve_weight_bytes_per_device(bundle: ModelBundle, mesh: Mesh,
                                  rules=None) -> int:
    """Per-device bytes of the bf16 serving weights (for the documented
    CPU-backend adjustment: XLA CPU emulates bf16 dots by materializing f32
    copies of the weight operands — 2x these bytes of temp that do NOT
    exist on TPU; see EXPERIMENTS.md §Dry-run)."""
    rules = rules or shd.SERVE_RULES
    structs = serve_param_structs(bundle)
    shardings = shd.tree_shardings_for_structs(
        bundle.param_axes(), bundle.param_structs(), mesh, rules)
    total = 0
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))
    for s, sh in zip(jax.tree.leaves(structs),
                     jax.tree.leaves(shardings), strict=True):
        if s.dtype != jnp.bfloat16:
            continue
        n = 1
        for d in s.shape:
            n *= d
        denom = 1
        for ax in jax.tree.leaves(tuple(sh.spec)):
            if isinstance(ax, str):
                denom *= axis_sizes[ax]
        total += n * 2 // denom
    return total


def analytic_model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = step tokens.

    Serving steps: prefill processes B*S tokens with the 2*N forward only
    (no backward => 2*N*D); decode processes B tokens.
    """
    shape = SHAPES[shape_name]
    bundle = build_model(configs.get_config(arch))
    n_active = bundle.active_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch
