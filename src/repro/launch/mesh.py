"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set XLA_FLAGS
before anything initializes the backend.

Production target: TPU v5e pods, 256 chips each, 16x16 (data, model) per
pod; the multi-pod mesh adds a leading ``pod`` axis (2 x 16 x 16 = 512
chips) for cross-pod data parallelism over DCN.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh

# TPU v5e constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-direction)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1) -> Mesh:
    """Whatever this host has (tests / examples): (devices/model, model)."""
    n = len(jax.devices())
    assert n % model == 0
    if model > 1:
        return jax.make_mesh((n // model, model), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
    return jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))


def mesh_chips(mesh: Mesh) -> int:
    return int(mesh.devices.size)
