"""Trip-count-aware cost analysis over optimized HLO text.

Why not ``compiled.cost_analysis()``?  XLA's cost model counts a while-loop
body ONCE — measured on this container: a scan of 8 identical matmuls
reports 1/8 of the unrolled FLOPs.  Every production-sized model here scans
over layers, so the roofline would be off by ~num_layers.  This analyzer
parses the post-SPMD optimized HLO (``compiled.as_text()``), recovers each
while loop's trip count, and multiplies body costs through — and, in the
same pass, extracts per-collective byte volumes (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute), which cost_analysis does
not expose at all.

Counting conventions follow xla::HloCostAnalysis so the numbers are
comparable (validated against cost_analysis on unrolled modules in
tests/test_hlo_analysis.py):

* dot: 2 * prod(output shape) * prod(contraction dims)
* elementwise arithmetic: 1 flop / element (transcendentals tracked
  separately, like cost_analysis' "transcendentals" key)
* reduce: 1 flop per reduced-away element
* fusion: FLOPs of the fused computation's instructions; BYTES are the
  fusion's operands+outputs (fusion internals live in registers/VMEM —
  exactly the HBM-traffic model the memory roofline term wants)
* while: (body + condition) * trip_count; trip count from the
  ``known_trip_count`` backend_config XLA attaches after loop analysis,
  else from the canonical ``compare(counter, constant)`` condition pattern,
  else 1 (recorded in ``unknown_loops``).

The module text is PER-DEVICE under SPMD, so all outputs are per-device
values; the roofline multiplies/divides by chip counts explicitly.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "atan2", "is-finite", "popcnt", "stochastic-convert",
}
_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "cbrt", "tanh", "sine", "cosine", "tan", "power", "logistic",
    "erf",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}
_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id",
    "get-dimension-size", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "copy-start", "copy-done",
    "optimization-barrier",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*->.*\{")
_INSTR_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([\w\-]+)\((.*)$")


def _balanced_paren_end(text: str, start: int) -> int:
    """Index of the ')' closing the '(' at ``start`` (-1 if unbalanced)."""
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return -1


def _parse_shapes(text: str):
    """All 'f32[256,128]' shapes in ``text`` -> [(dtype, [dims])]."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES and dtype not in ("token",):
            continue
        shape = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dtype, shape))
    return out


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _bytes_of(parsed) -> float:
    return float(sum(_numel(s) * _DTYPE_BYTES.get(dt, 0)
                     for dt, s in parsed))


def _balanced_braces(text: str, start: int) -> str:
    """Return the {...} group starting at ``start`` with balanced braces."""
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
            if depth == 0:
                return text[start:i + 1]
    return text[start:]


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    out: list                    # [(dtype, shape)]
    operand_names: list
    attrs_text: str
    raw: str


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_in: float
    bytes_out: float
    multiplier: float            # product of enclosing trip counts
    group_size: int
    raw: str


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    collectives: list = dataclasses.field(default_factory=list)
    unknown_loops: int = 0

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes += other.bytes * mult
        self.unknown_loops += other.unknown_loops
        for c in other.collectives:
            self.collectives.append(
                dataclasses.replace(c, multiplier=c.multiplier * mult))

    @property
    def collective_bytes(self) -> float:
        return sum(c.bytes_in * c.multiplier for c in self.collectives)


def parse_computations(hlo_text: str):
    """-> (comps: name -> [Instruction], entry_name)."""
    comps: dict[str, list[Instruction]] = {}
    entry = None
    current = None
    for line in hlo_text.splitlines():
        if current is None:
            if "{" in line and "->" in line:
                m = _COMP_HEAD.match(line.strip())
                if m:
                    current = m.group(2)
                    comps[current] = []
                    if m.group(1):
                        entry = current
            continue
        stripped = line.strip()
        if stripped.startswith("}"):
            current = None
            continue
        m = _INSTR_HEAD.match(line)
        if not m:
            continue
        name, rest = m.groups()
        # output type: either a (tuple type ...) — possibly with /*index=N*/
        # comments — or a single shape token
        if rest.startswith("("):
            end = _balanced_paren_end(rest, 0)
            if end < 0:
                continue
            out_type, rest = rest[:end + 1], rest[end + 1:].lstrip()
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            out_type, rest = rest[:sp], rest[sp + 1:].lstrip()
        m2 = _OPCODE_RE.match(rest)
        if not m2:
            continue
        opcode, tail = m2.groups()
        # split call args from attrs at the balanced close paren
        args_end = _balanced_paren_end("(" + tail, 0) - 1
        if args_end < 0:
            args_end = len(tail)
        args = tail[:args_end]
        attrs_text = tail[args_end + 1:]
        comps[current].append(Instruction(
            name=name, opcode=opcode, out=_parse_shapes(out_type),
            operand_names=re.findall(r"%([\w\.\-]+)", args),
            attrs_text=attrs_text, raw=stripped))
    return comps, entry


def _called(instr: Instruction, key: str) -> str | None:
    m = re.search(key + r"=%([\w\.\-]+)", instr.attrs_text)
    return m.group(1) if m else None


def _calls_list(instr: Instruction) -> list[str]:
    out = []
    for key in ("calls", "to_apply", "body", "condition"):
        m = re.search(key + r"=(%[\w\.\-]+|\{[^}]*\})", instr.attrs_text)
        if m:
            out.extend(re.findall(r"%([\w\.\-]+)", m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", instr.attrs_text)
    if m:
        out.extend(re.findall(r"%([\w\.\-]+)", m.group(1)))
    return out


def _trip_count(instr: Instruction, comps) -> int | None:
    bi = instr.attrs_text.find("backend_config=")
    if bi >= 0:
        brace = instr.attrs_text.find("{", bi)
        if brace >= 0:
            try:
                cfg = json.loads(_balanced_braces(instr.attrs_text, brace))
                n = cfg.get("known_trip_count", {}).get("n")
                if n is not None:
                    return int(n)
            except (ValueError, TypeError):
                pass
    cond = _called(instr, "condition")
    if cond and cond in comps:
        const_val, direction = None, None
        for ci in comps[cond]:
            cm = re.search(r"constant\((-?\d+)\)", ci.raw)
            if cm and ci.opcode == "constant":
                const_val = int(cm.group(1))
            dm = re.search(r"direction=(\w+)", ci.attrs_text)
            if dm:
                direction = dm.group(1)
        if const_val is not None and direction in ("LT", "NE", "GT"):
            return max(abs(const_val), 1)
    return None


def _group_size(instr: Instruction) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.attrs_text)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", instr.attrs_text)
    if m:
        return len(m.group(1).split(","))
    return 0


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_computations(hlo_text)
        # symbol tables: comp -> instr name -> out shapes
        self.symtab = {
            cname: {i.name: i.out for i in instrs}
            for cname, instrs in self.comps.items()}
        self._memo: dict[str, Costs] = {}

    def analyze(self) -> Costs:
        if self.entry is None:
            return Costs()
        total = Costs()
        total.add(self._comp_costs(self.entry, top_level=True))
        return total

    def _operands(self, comp: str, instr: Instruction):
        tab = self.symtab[comp]
        out = []
        for n in instr.operand_names:
            out.extend(tab.get(n, []))
        return out

    # -- per-computation ------------------------------------------------------
    def _comp_costs(self, name: str, top_level: bool) -> Costs:
        key = f"{name}::{top_level}"
        if key in self._memo:
            return self._memo[key]
        costs = Costs()
        self._memo[key] = costs       # break cycles defensively
        for instr in self.comps.get(name, []):
            self._instr_costs(name, instr, costs, top_level)
        return costs

    def _instr_costs(self, comp: str, instr: Instruction, costs: Costs,
                     top_level: bool):
        op = instr.opcode
        if op in _ZERO_COST:
            return
        out_elems = sum(_numel(s) for _, s in instr.out)
        operands = self._operands(comp, instr)

        if op == "while":
            trip = _trip_count(instr, self.comps)
            if trip is None:
                trip = 1
                costs.unknown_loops += 1
            for key in ("body", "condition"):
                sub = _called(instr, key)
                if sub and sub in self.comps:
                    costs.add(self._comp_costs(sub, top_level), mult=trip)
            return

        if op == "conditional":
            branches = [c for c in _calls_list(instr) if c in self.comps]
            if branches:
                best = max((self._comp_costs(b, top_level) for b in branches),
                           key=lambda c: c.flops + c.bytes)
                costs.add(best)
            return

        if op in ("call", "async-start"):
            for c in _calls_list(instr):
                if c in self.comps:
                    costs.add(self._comp_costs(c, top_level))
            return

        if op == "fusion":
            for c in _calls_list(instr):
                if c in self.comps:
                    sub = self._comp_costs(c, top_level=False)
                    costs.flops += sub.flops
                    costs.transcendentals += sub.transcendentals
                    costs.collectives.extend(sub.collectives)
            if top_level:
                costs.bytes += _bytes_of(operands) + _bytes_of(instr.out)
            return

        if op in _COLLECTIVES:
            kind = op.replace("-start", "")
            costs.collectives.append(CollectiveOp(
                kind=kind, bytes_in=_bytes_of(operands),
                bytes_out=_bytes_of(instr.out), multiplier=1.0,
                group_size=_group_size(instr), raw=instr.raw[:200]))
            if top_level:
                costs.bytes += _bytes_of(operands) + _bytes_of(instr.out)
            return

        # -- plain compute ops -------------------------------------------------
        if op == "dot":
            contract = 1
            m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                          instr.attrs_text)
            if m and operands:
                lhs_shape = operands[0][1]
                for d in (int(x) for x in m.group(1).split(",") if x):
                    if d < len(lhs_shape):
                        contract *= lhs_shape[d]
            costs.flops += 2.0 * out_elems * contract
        elif op == "convolution":
            k_elems = _numel(operands[1][1]) if len(operands) > 1 else 1
            out_feat = instr.out[0][1][-1] if instr.out and instr.out[0][1] else 1
            costs.flops += 2.0 * out_elems * max(k_elems // max(out_feat, 1), 1)
        elif op in ("reduce", "reduce-window"):
            in_elems = _numel(operands[0][1]) if operands else 0
            costs.flops += float(max(in_elems - out_elems, 0))
        elif op in _TRANSCENDENTAL:
            costs.transcendentals += float(out_elems)
        elif op in _ELEMENTWISE:
            costs.flops += float(out_elems)
        # everything else (data movement, custom-call, sort, rng): 0 flops

        if top_level:
            costs.bytes += _bytes_of(operands) + _bytes_of(instr.out)


def analyze_text(hlo_text: str) -> Costs:
    return HloAnalyzer(hlo_text).analyze()


def collective_summary(costs: Costs) -> dict[str, dict]:
    """Aggregate collectives by kind: count, per-device bytes."""
    agg: dict[str, dict] = defaultdict(lambda: {"count": 0.0, "bytes_in": 0.0,
                                                "bytes_out": 0.0})
    for c in costs.collectives:
        a = agg[c.kind]
        a["count"] += c.multiplier
        a["bytes_in"] += c.bytes_in * c.multiplier
        a["bytes_out"] += c.bytes_out * c.multiplier
    return dict(agg)
