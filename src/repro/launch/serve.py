"""Serving launcher: batched engine, optional kNN-LM retrieval.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b \
        --reduced --requests 8 --knnlm
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models.registry import build_model
from repro.serve.engine import Engine, EngineConfig, Request
from repro.serve.knnlm import KNNLMHook, build_datastore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--knnlm", action="store_true")
    ap.add_argument("--knnlm-approx-p", type=float, default=None)
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    vocab = cfg.vocab_size
    rng = np.random.default_rng(0)

    hook = None
    if args.knnlm:
        corpus = rng.integers(1, vocab, (8, 2 * args.prompt_len))
        store = build_datastore(bundle, params, corpus)
        hook = KNNLMHook(store=store, k=8, lam=0.25,
                         approx_p=args.knnlm_approx_p)
        print(f"kNN-LM datastore: {store.index.n} keys, "
              f"M={store.index.m} subspaces")

    eng = Engine(bundle, params,
                 EngineConfig(slots=args.slots,
                              max_seq=args.prompt_len + args.new_tokens + 8,
                              prefill_len=args.prompt_len),
                 logits_hook=hook)
    for uid in range(args.requests):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(1, vocab, args.prompt_len),
                           max_new_tokens=args.new_tokens))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, {eng.ticks} ticks)")
    if hook:
        print(f"kNN queries served: {hook.queries_served}")


if __name__ == "__main__":
    main()
