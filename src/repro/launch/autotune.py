"""Block-size autotuner for the streaming batched kNN pipeline.

BrePartition sized its unit of I/O (the disk page) to the storage
hierarchy; our unit is the ``block_rows`` VMEM block of the two streaming
scans plus the ``env_block_rows`` granularity of the envelope gate
(core/search).  Both are pure performance knobs — every setting returns
bit-identical results (tests/test_stream_prune.py pins this) — so the
right values are an empirical property of (n, q, d, M, storage, backend),
exactly the kind of thing a table should record instead of a hand-picked
module constant.

The sweep measures each candidate two ways, mirroring how the knob
actually costs:

* ``memory_analysis`` on the compiled program (abstract
  ShapeDtypeStruct index arrays — no data, no k-means) bounds the peak
  temp bytes, used to REJECT candidates whose working set exceeds the
  ``--mem-cap`` budget before any timing runs;
* median wall clock of the full jitted pipeline on synthetic data picks
  the winner among the survivors.

Results land in a checked-in JSON artifact (``block_rows_table.json``
next to this module).  ``core.search.resolve_block_rows`` consults it
whenever a caller passes ``block_rows=None``, and the serving layer
(serve/retrieval.py tenant registration, serve/knnlm.py datastore build)
resolves and PINS the tuned value up front so every later launch reuses
the same compiled program.  Lookups are bucketed by round(log2(n)) and
round(log2(q)) and filtered by (backend, storage); a miss — including any
backend the table was not generated on — falls back to
``DEFAULT_BLOCK_ROWS``, so shipping a CPU-generated table can never
change TPU behavior until someone regenerates it there.

Regenerate with::

    PYTHONPATH=src python -m repro.launch.autotune \\
        --out src/repro/launch/block_rows_table.json

See docs/autotuning.md for the table format and workflow.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import math
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_TABLE_PATH = Path(__file__).resolve().parent / "block_rows_table.json"

# The candidate grid the sweep explores (clamped to the index size at
# layout time, so oversized candidates degenerate to one block).
CANDIDATE_BLOCK_ROWS = (1024, 2048, 4096, 8192, 16384)
CANDIDATE_ENV_BLOCK_ROWS = (256, 512, 1024)

# A tuned entry further than this (in log2 n) from the queried shape is
# treated as a miss: a block size tuned for n=4096 says nothing about
# n=10^8.
MAX_N_LOG2_DISTANCE = 2.0


# ---------------------------------------------------------------------------
# Abstract compile / memory analysis
# ---------------------------------------------------------------------------

def forest_spec(n: int, d: int = 32, m: int = 8, c: int = 64,
                storage: str = "f32",
                family: str = "squared_euclidean",
                beta_samples: int = 1024):
    """A shape-only BallForest for aval lowering (no data, no k-means).

    The int8 tier swaps the point tables to int8 codes and adds the
    per-row decode scalars, matching core/index.point_fields.
    """
    from repro.core.index import ENV_BLOCK_ROWS, QUANT_FIELDS, BallForest
    from repro.core.transform import make_partition
    part = make_partition(d, m)
    w = part.width
    ne = -(-n // ENV_BLOCK_ROWS)
    f32, i32, i8 = jnp.float32, jnp.int32, jnp.int8
    sds = jax.ShapeDtypeStruct
    pt = i8 if storage == "int8" else f32
    fields = dict(
        data=sds((n, d), pt),
        point_ids=sds((n,), i32),
        alpha=sds((n, m), pt),
        sqrt_gamma=sds((n, m), pt),
        assign=sds((n, m), i32),
        alpha_min=sds((m, c), f32),
        sqrt_gamma_max=sds((m, c), f32),
        counts=sds((m, c), i32),
        centers=sds((m, c, w), f32),
        beta_samples=sds((beta_samples,), f32),
        alpha_min_pt=sds((n, m), pt),
        sqrt_gamma_max_pt=sds((n, m), pt),
        gamma_edges=sds((m, 3), f32),
        env_alpha_min=sds((ne, m), f32),
        env_sqrt_gamma_max=sds((ne, m), f32),
    )
    if storage == "int8":
        fields.update({f: sds((n,), f32) for f in QUANT_FIELDS})
    return BallForest(family_name=family, partition=part, num_clusters=c,
                      storage=storage, **fields)


def measure_memory(n: int, q: int, d: int, m: int, storage: str,
                   block_rows: int, env_block_rows: int,
                   k: int = 10, budget: int = 256) -> int | None:
    """Peak temp bytes of the compiled pipeline at this config, or None
    when the backend exposes no compiled memory analysis."""
    from repro.core import search
    spec = forest_spec(n, d=d, m=m, storage=storage)
    ys = jax.ShapeDtypeStruct((q, d), jnp.float32)
    compiled = search._knn_search_batch_jit.lower(
        spec, ys, k, budget, block_rows, env_block_rows).compile()
    try:
        mem = compiled.memory_analysis()
        return int(mem.temp_size_in_bytes)
    except (AttributeError, NotImplementedError, jax.errors.JaxRuntimeError):
        return None


# ---------------------------------------------------------------------------
# Wall-clock sweep
# ---------------------------------------------------------------------------

def _synthetic_index(n: int, d: int, m: int, storage: str, seed: int = 0):
    """Blob data index at the bench shape family (bench_batch_search)."""
    from repro.core.index import build_index
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.5, 4.0, size=(128, d))
    rows = centers[rng.integers(0, 128, size=n)]
    rows = rows + rng.normal(0.0, 0.08, size=rows.shape)
    data = np.abs(rows) + 0.05
    return build_index(data, "squared_euclidean", m=m,
                       quantize=(storage == "int8"))


def time_config(index, ys, k: int, budget: int, block_rows: int,
                env_block_rows: int, repeats: int = 3) -> float:
    """Median seconds per call of the full jitted pipeline (post-warmup)."""
    from repro.core import search
    fn = functools.partial(search._knn_search_batch_jit, index, ys, k,
                           budget, block_rows, env_block_rows)
    jax.block_until_ready(fn())                       # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


@dataclasses.dataclass
class SweepConfig:
    ns: tuple = (4096, 16384, 65536)
    qs: tuple = (8, 64)
    d: int = 32
    m: int = 8
    k: int = 10
    storages: tuple = ("f32", "int8")
    block_rows_candidates: tuple = CANDIDATE_BLOCK_ROWS
    env_candidates: tuple = CANDIDATE_ENV_BLOCK_ROWS
    repeats: int = 3
    mem_cap_bytes: int | None = None
    time_it: bool = True


def sweep(cfg: SweepConfig, log=print) -> list[dict]:
    """Run the sweep; one winning entry per (n, q, storage) cell."""
    from repro.core import search
    backend = jax.default_backend()
    entries = []
    for storage in cfg.storages:
        for n in cfg.ns:
            index = (_synthetic_index(n, cfg.d, cfg.m, storage)
                     if cfg.time_it else None)
            for q in cfg.qs:
                rng = np.random.default_rng(1)
                ys = jnp.asarray(
                    np.abs(rng.normal(1.5, 0.5, size=(q, cfg.d))) + 0.05,
                    jnp.float32)
                budget = search.fitted_budget_for_n(n, cfg.k, n // 64)
                best = None
                for br in cfg.block_rows_candidates:
                    if br > 2 * n:
                        continue          # degenerate: > one block of slack
                    for eb in cfg.env_candidates:
                        temp = measure_memory(n, q, cfg.d, cfg.m, storage,
                                              br, eb, k=cfg.k, budget=budget)
                        if (cfg.mem_cap_bytes is not None and temp is not None
                                and temp > cfg.mem_cap_bytes):
                            log(f"  reject n={n} q={q} {storage} br={br} "
                                f"eb={eb}: temp {temp} > cap")
                            continue
                        sec = (time_config(index, ys, cfg.k, budget, br, eb,
                                           cfg.repeats)
                               if cfg.time_it else float("inf"))
                        cand = {"backend": backend, "storage": storage,
                                "n_log2": round(math.log2(n), 2),
                                "q_log2": round(math.log2(q), 2),
                                "d": cfg.d, "m": cfg.m,
                                "block_rows": br, "env_block_rows": eb,
                                "us_per_call": round(sec * 1e6, 1),
                                "temp_bytes": temp}
                        log(f"  n={n} q={q} {storage} br={br} eb={eb}: "
                            f"{cand['us_per_call']}us temp={temp}")
                        if best is None or sec < best["_sec"]:
                            best = {**cand, "_sec": sec}
                if best is not None:
                    best.pop("_sec")
                    entries.append(best)
                    log(f"-> n={n} q={q} {storage}: block_rows="
                        f"{best['block_rows']} env={best['env_block_rows']}")
    return entries


def write_table(entries: list[dict], path: str | Path,
                note: str = "") -> None:
    payload = {
        "version": 1,
        "note": note or ("swept via `python -m repro.launch.autotune`; "
                         "see docs/autotuning.md"),
        "jax": jax.__version__,
        "entries": entries,
    }
    Path(path).write_text(json.dumps(payload, indent=1) + "\n")
    _load_table_cached.cache_clear()


# ---------------------------------------------------------------------------
# Lookup (the consumer side: resolve_block_rows + the serving layer)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _load_table_cached(path_str: str) -> tuple:
    path = Path(path_str)
    if not path.exists():
        return ()
    try:
        payload = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return ()
    entries = payload.get("entries", [])
    return tuple(e for e in entries if isinstance(e, dict))


def load_table(path: str | Path | None = None) -> tuple:
    """The checked-in entries (cached); env REPRO_AUTOTUNE_TABLE overrides
    the path, an empty/missing/corrupt file reads as no entries."""
    if path is None:
        path = os.environ.get("REPRO_AUTOTUNE_TABLE", DEFAULT_TABLE_PATH)
    return _load_table_cached(str(path))


def lookup(n: int, q: int | None = None, *, storage: str | None = None,
           backend: str | None = None, table: tuple | None = None
           ) -> dict | None:
    """Nearest tuned entry for this shape, or None (= use the default).

    Entries are filtered to this backend and storage tier, then ranked by
    log2 distance in n (primary) and q (secondary, when the caller knows
    q).  Misses by more than MAX_N_LOG2_DISTANCE in n are rejected — a
    table generated at bench scale must not steer shapes far outside it.
    """
    if n < 1:
        return None
    entries = load_table() if table is None else table
    if not entries:
        return None
    backend = backend or jax.default_backend()
    storage = storage or "f32"
    n_l = math.log2(n)
    q_l = math.log2(q) if q else None
    best, best_key = None, None
    for e in entries:
        if e.get("backend") != backend or e.get("storage") != storage:
            continue
        try:
            dn = abs(n_l - float(e["n_log2"]))
            dq = (abs(q_l - float(e["q_log2"]))
                  if q_l is not None and "q_log2" in e else 0.0)
            br = int(e["block_rows"])
        except (KeyError, TypeError, ValueError):
            continue
        if dn > MAX_N_LOG2_DISTANCE or br < 8:
            continue
        key = (dn, dq)
        if best_key is None or key < best_key:
            best, best_key = e, key
    return best


def lookup_block_rows(n: int, q: int | None = None, *,
                      storage: str | None = None,
                      backend: str | None = None,
                      table: tuple | None = None) -> int | None:
    """Tuned ``block_rows`` for this shape, or None for the default."""
    e = lookup(n, q, storage=storage, backend=backend, table=table)
    return int(e["block_rows"]) if e is not None else None


def lookup_env_block_rows(n: int, q: int | None = None, *,
                          storage: str | None = None,
                          backend: str | None = None,
                          table: tuple | None = None) -> int | None:
    """Tuned envelope-gate granularity for this shape, or None."""
    e = lookup(n, q, storage=storage, backend=backend, table=table)
    if e is None or "env_block_rows" not in e:
        return None
    return int(e["env_block_rows"])


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--out", default=str(DEFAULT_TABLE_PATH))
    p.add_argument("--n", type=int, nargs="+", default=[4096, 16384, 65536])
    p.add_argument("--q", type=int, nargs="+", default=[8, 64])
    p.add_argument("--d", type=int, default=32)
    p.add_argument("--m", type=int, default=8)
    p.add_argument("--k", type=int, default=10)
    p.add_argument("--storages", nargs="+", default=["f32", "int8"])
    p.add_argument("--block-rows", type=int, nargs="+",
                   default=list(CANDIDATE_BLOCK_ROWS))
    p.add_argument("--env-block-rows", type=int, nargs="+",
                   default=list(CANDIDATE_ENV_BLOCK_ROWS))
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--mem-cap-mib", type=float, default=None,
                   help="reject candidates whose compiled temp bytes "
                        "exceed this (e.g. a VMEM/HBM budget)")
    p.add_argument("--no-time", action="store_true",
                   help="memory analysis only (records temp bytes, keeps "
                        "the first surviving candidate per cell)")
    args = p.parse_args(argv)

    cfg = SweepConfig(
        ns=tuple(args.n), qs=tuple(args.q), d=args.d, m=args.m, k=args.k,
        storages=tuple(args.storages),
        block_rows_candidates=tuple(args.block_rows),
        env_candidates=tuple(args.env_block_rows),
        repeats=args.repeats,
        mem_cap_bytes=(None if args.mem_cap_mib is None
                       else int(args.mem_cap_mib * 2**20)),
        time_it=not args.no_time,
    )
    print(f"sweeping on backend={jax.default_backend()} -> {args.out}")
    entries = sweep(cfg)
    write_table(entries, args.out)
    print(f"wrote {len(entries)} entries to {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
