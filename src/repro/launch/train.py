"""Training launcher: real run on whatever devices exist.

On this CPU container it trains CPU-sized configs (see
examples/train_lm.py for the end-to-end driver); on a pod the same entry
point runs the full config on the production mesh — the mesh/shape logic
is identical, only device count differs.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.configs.common import ShapeSpec
from repro.data.pipeline import TokenStreamConfig, token_batch
from repro.models.registry import build_model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptimizerConfig
from repro.train.straggler import StragglerMonitor
from repro.train.train_loop import (TrainConfig, init_train_state,
                                    make_train_step)
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    bundle = build_model(cfg)
    print(f"{args.arch}: {bundle.count_params/1e6:.1f}M params "
          f"({bundle.active_params/1e6:.1f}M active)")

    mesh = make_host_mesh()
    shape = ShapeSpec("train", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    tc = TrainConfig(
        microbatches=args.microbatches, loss_chunk=min(512, args.seq),
        opt=OptimizerConfig(peak_lr=args.lr, warmup_steps=args.steps // 10,
                            total_steps=args.steps))
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch)
    mrope = bool(getattr(cfg, "mrope_section", None))

    with mesh:
        step_fn = make_train_step(bundle, mesh, tc, shape)
        start = (ckpt.latest_step(args.ckpt_dir)
                 if args.ckpt_dir else None)
        state = init_train_state(bundle, mesh, jax.random.PRNGKey(0))
        if start is not None:
            structs = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state = ckpt.restore_checkpoint(args.ckpt_dir, start, structs)
            print(f"resumed from step {start}")
        start = start or 0

        mon = StragglerMonitor()
        t0 = time.time()
        for i in range(start, args.steps):
            mon.start_step()
            batch = token_batch(stream, i, mesh, mrope=mrope)
            for name, (shape_fn, dtype, _ax) in bundle.extra_inputs.items():
                batch[name] = jax.numpy.zeros(
                    shape_fn(args.batch, args.seq), dtype)
            state, metrics = step_fn(state, batch)
            mon.end_step()
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                ckpt.save_checkpoint(args.ckpt_dir, i + 1, state)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                      f"acc={float(metrics['accuracy']):.3f}")
        dt = time.time() - t0
        print(f"{args.steps - start} steps in {dt:.1f}s; "
              f"straggler: {mon.summary()}")


if __name__ == "__main__":
    main()
