import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import — jax locks the
# device count at first backend initialization (brief, MULTI-POD DRY-RUN).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without any TPU and without allocating a single
parameter:

  * the sharding contract is coherent (lower succeeds),
  * the program partitions onto the production mesh (compile succeeds),
  * it fits HBM (``memory_analysis`` per-device peak),
  * and it yields the roofline inputs: trip-count-corrected HLO FLOPs /
    bytes / per-collective volumes (launch/hlo_analysis.py) plus XLA's own
    cost_analysis for cross-checking.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod --out dryrun.json
    python -m repro.launch.dryrun --all --both-meshes --out dryrun.json
"""

import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, rules_name: str | None = None,
             microbatches: int = 1) -> dict:
    import jax  # noqa: F401 — forces jax init AFTER the env lock above
    from repro import configs
    from repro.dist import sharding as shd
    from repro.launch import hlo_analysis as ha
    from repro.launch import lowering
    from repro.launch.mesh import make_production_mesh, mesh_chips
    from repro.train.train_loop import TrainConfig

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = {"default": None, "serve": shd.SERVE_RULES,
             "context": shd.CONTEXT_RULES,
             "decode": shd.DECODE_RULES}.get(rules_name or "default")
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": mesh_chips(mesh), "ok": False,
    }
    t0 = time.time()
    try:
        lowered = lowering.lower_cell(
            arch, shape_name, mesh, rules=rules,
            train_cfg=TrainConfig(microbatches=microbatches))
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes),
        }
        if configs.SHAPES[shape_name].kind != "train":
            # XLA CPU emulates bf16 dots via f32 weight copies (2x bf16
            # bytes of temp that do not exist on TPU) — report both raw and
            # TPU-adjusted peaks.  Documented in EXPERIMENTS.md §Dry-run.
            from repro.models.registry import build_model
            bundle = build_model(configs.get_config(arch))
            adj = 2 * lowering.serve_weight_bytes_per_device(bundle, mesh)
            rec["memory"]["cpu_bf16_upcast_bytes"] = adj
            rec["memory"]["peak_bytes_tpu_adjusted"] = max(
                rec["memory"]["peak_bytes_est"] - adj,
                rec["memory"]["argument_bytes"]
                + rec["memory"]["output_bytes"]
                - rec["memory"]["alias_bytes"])
        xla_cost = compiled.cost_analysis()
        rec["xla_cost"] = {k: xla_cost.get(k) for k in
                           ("flops", "transcendentals", "bytes accessed")}

        costs = ha.analyze_text(compiled.as_text())
        rec["hlo"] = {
            "flops_per_device": costs.flops,
            "transcendentals_per_device": costs.transcendentals,
            "bytes_per_device": costs.bytes,
            "collective_bytes_per_device": costs.collective_bytes,
            "collectives": ha.collective_summary(costs),
            "unknown_loops": costs.unknown_loops,
        }
        rec["model_flops"] = lowering.analytic_model_flops(arch, shape_name)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id, or comma-separated list (all shapes)")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-archs", default="",
                    help="comma-separated archs to skip with --all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro import configs

    cells = []
    skip = set(a for a in args.skip_archs.split(",") if a)
    if args.all:
        for arch, shape, runnable, note in configs.arch_cells():
            if arch in skip:
                continue
            if runnable:
                cells.append((arch, shape))
            else:
                print(f"SKIP {arch} x {shape}: {note}", flush=True)
    elif args.arch and not args.shape:
        for a in args.arch.split(","):
            for arch, shape, runnable, _n in configs.arch_cells():
                if arch == a and runnable:
                    cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    records = []
    for multi_pod in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, multi_pod, rules_name=args.rules,
                           microbatches=args.microbatches)
            status = "OK " if rec["ok"] else "FAIL"
            peak = rec.get("memory", {}).get("peak_bytes_est", 0) / 2**30
            print(f"{status} {rec['mesh']:>8} {arch:24s} {shape:12s} "
                  f"lower={rec.get('lower_s', '-'):>6}s "
                  f"compile={rec.get('compile_s', '-'):>7}s "
                  f"peak/dev={peak:6.2f}GiB "
                  f"{rec.get('error', '')}", flush=True)
            records.append(rec)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)
    n_ok = sum(r["ok"] for r in records)
    print(f"\n{n_ok}/{len(records)} cells compiled", flush=True)
    return 0 if n_ok == len(records) else 1


if __name__ == "__main__":
    raise SystemExit(main())
