# Launch layer: production meshes, the multi-pod dry-run, the HLO cost
# analyzer (trip-count-aware), training and serving launchers.
