"""Encoder–decoder transformer (Whisper backbone; audio frontend stubbed).

Per the brief, ``[audio]`` entries specify the transformer BACKBONE only:
``input_specs()`` provides precomputed log-mel **frame embeddings**
(B, F, d_model) in place of the conv1d/stride-2 frontend (stub documented in
DESIGN.md §Arch-applicability).  Whisper-tiny: 4 encoder + 4 decoder layers,
LayerNorm, GeLU MLPs, MHA (kv = heads), sinusoidal encoder positions,
learned decoder positions, no RoPE.

Decode serving caches both the decoder self-attention KV *and* the
cross-attention KV (computed once from the encoder output at prefill).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

from . import attention as attn_mod
from .attention import KVCache
from .layers import (Spec, apply_mlp, apply_norm, axes_tree, embed_lookup,
                     embed_spec, init_tree, mlp_spec, norm_spec,
                     sinusoidal_positions, struct_tree, unembed_logits)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    encoder_layers: int
    decoder_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    num_frames: int = 1500            # encoder sequence length (stub output)
    act: str = "gelu"
    norm: str = "layernorm"
    max_position: int = 1 << 16
    compute_dtype: Any = jnp.bfloat16
    dense_attn_threshold: int = 2048
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: bool = True
    scan_layers: bool = False          # 4+4 layers: unrolled
    tie_embeddings: bool = True

    @property
    def num_layers(self) -> int:       # uniform accessor for tooling
        return self.encoder_layers + self.decoder_layers


def _attn_spec(cfg: EncDecConfig) -> dict:
    return attn_mod.attention_spec(cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim,
                                   qkv_bias=True, out_bias=True)


def param_specs(cfg: EncDecConfig) -> dict:
    def enc_layer():
        return {
            "norm1": norm_spec(cfg.d_model, cfg.norm),
            "attn": _attn_spec(cfg),
            "norm2": norm_spec(cfg.d_model, cfg.norm),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff, gated=False, bias=True),
        }

    def dec_layer():
        return {
            "norm1": norm_spec(cfg.d_model, cfg.norm),
            "self_attn": _attn_spec(cfg),
            "norm_x": norm_spec(cfg.d_model, cfg.norm),
            "cross_attn": _attn_spec(cfg),
            "norm2": norm_spec(cfg.d_model, cfg.norm),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff, gated=False, bias=True),
        }
    return {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "dec_pos": Spec((cfg.max_position, cfg.d_model), (None, "fsdp"),
                        scale=0.02),
        "encoder": [enc_layer() for _ in range(cfg.encoder_layers)],
        "enc_final_norm": norm_spec(cfg.d_model, cfg.norm),
        "decoder": [dec_layer() for _ in range(cfg.decoder_layers)],
        "dec_final_norm": norm_spec(cfg.d_model, cfg.norm),
    }


def _self_attention(cfg, p, x, positions, causal, cache=None, lengths=None,
                    window=None):
    q, k, v = attn_mod.qkv_project(p, x, positions=positions,
                                   rope_theta=1e4, use_rope=False)
    if cache is None:
        out = attn_mod.sdpa(q, k, v, causal=causal,
                            dense_threshold=cfg.dense_attn_threshold)
        new_cache = None
    elif x.shape[1] == 1:
        cache = attn_mod.cache_update(cache, k, v, lengths)
        out = attn_mod.decode_attend(q, cache, lengths + 1, window=window)
        new_cache = cache
    else:
        out = attn_mod.sdpa(q, k, v, causal=causal,
                            dense_threshold=cfg.dense_attn_threshold)
        new_cache = attn_mod.cache_update(cache, k, v, lengths)
    return attn_mod.out_project(p, out), new_cache


def _cross_attention(cfg, p, x, enc_out=None, kv_cache: KVCache | None = None):
    """Cross attention; KV from enc_out (train) or the fixed cache (decode)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt)) + p["bq"].astype(dt)
    if kv_cache is None:
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt)) + p["bk"].astype(dt)
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt)) + p["bv"].astype(dt)
    else:
        k, v = kv_cache.k.astype(dt), kv_cache.v.astype(dt)
    out = attn_mod.sdpa_dense(q, k, v, causal=False)
    return attn_mod.out_project(p, out), KVCache(k=k, v=v)


def encode(cfg: EncDecConfig, params: dict, frames: Array) -> Array:
    """frames (B, F, d_model) — stub frontend output. Returns (B, F, D)."""
    dt = cfg.compute_dtype
    x = frames.astype(dt) + sinusoidal_positions(
        frames.shape[1], cfg.d_model).astype(dt)[None]
    x = constrain(x, ("batch", "seq", "embed"))
    dummy_pos = jnp.zeros(frames.shape[:2], jnp.int32)
    for lp in params["encoder"]:
        def block(lp, x):
            h, _ = _self_attention(cfg, lp["attn"],
                                   apply_norm(lp["norm1"], x, cfg.norm),
                                   dummy_pos, causal=False)
            x = x + h
            return x + apply_mlp(lp["mlp"],
                                 apply_norm(lp["norm2"], x, cfg.norm),
                                 cfg.act)
        x = jax.checkpoint(block)(lp, x) if cfg.remat else block(lp, x)
        x = constrain(x, ("batch", "seq", "embed"))
    return apply_norm(params["enc_final_norm"], x, cfg.norm)


def _decoder_layer(cfg, lp, x, positions, enc_out, self_cache, cross_cache,
                   lengths):
    h, new_self = _self_attention(cfg, lp["self_attn"],
                                  apply_norm(lp["norm1"], x, cfg.norm),
                                  positions, causal=True,
                                  cache=self_cache, lengths=lengths)
    x = x + h
    h, new_cross = _cross_attention(cfg, lp["cross_attn"],
                                    apply_norm(lp["norm_x"], x, cfg.norm),
                                    enc_out=enc_out, kv_cache=cross_cache)
    x = x + h
    x = x + apply_mlp(lp["mlp"], apply_norm(lp["norm2"], x, cfg.norm), cfg.act)
    return x, new_self, new_cross


def forward_train(cfg: EncDecConfig, params: dict, tokens: Array,
                  positions: Array, frames: Array):
    """Teacher-forced decoder over encoded frames -> (hidden, aux=0)."""
    enc_out = encode(cfg, params, frames)
    dt = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dt)
    pos = positions if positions.ndim == 2 else positions[..., 0]
    x = x + jnp.take(params["dec_pos"], pos, axis=0).astype(dt)
    x = constrain(x, ("batch", "seq", "embed"))
    for lp in params["decoder"]:
        def block(lp, x):
            y, _, _ = _decoder_layer(cfg, lp, x, positions, enc_out,
                                     None, None, None)
            return y
        x = jax.checkpoint(block)(lp, x) if cfg.remat else block(lp, x)
        x = constrain(x, ("batch", "seq", "embed"))
    x = apply_norm(params["dec_final_norm"], x, cfg.norm)
    return x, 0.0


def logits_fn(cfg: EncDecConfig, params: dict, hidden: Array) -> Array:
    return unembed_logits(hidden, params["embed"])[..., : cfg.vocab_size]


def init_cache(cfg: EncDecConfig, batch: int, s_max: int):
    dt = cfg.compute_dtype
    return [{
        "self": KVCache.zeros(batch, s_max, cfg.num_kv_heads, cfg.head_dim, dt),
        "cross": KVCache.zeros(batch, cfg.num_frames, cfg.num_kv_heads,
                               cfg.head_dim, dt),
    } for _ in range(cfg.decoder_layers)]


def cache_axes(cfg: EncDecConfig):
    kv = KVCache.axes()
    return [{"self": kv, "cross": kv} for _ in range(cfg.decoder_layers)]


def prefill(cfg: EncDecConfig, params: dict, tokens: Array, positions: Array,
            caches, lengths: Array, frames: Array):
    """Encode + teacher-forced decoder prefill; populates self+cross caches."""
    enc_out = encode(cfg, params, frames)
    dt = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dt)
    pos = positions if positions.ndim == 2 else positions[..., 0]
    x = x + jnp.take(params["dec_pos"], pos, axis=0).astype(dt)
    x = constrain(x, ("batch", "seq", "embed"))
    new_caches = []
    for lp, cache in zip(params["decoder"], caches, strict=True):
        q, k, v = attn_mod.qkv_project(lp["self_attn"],
                                       apply_norm(lp["norm1"], x, cfg.norm),
                                       positions=positions, rope_theta=1e4,
                                       use_rope=False)
        new_self = attn_mod.cache_update(cache["self"], k, v, lengths)
        x, _, new_cross = _decoder_layer(cfg, lp, x, positions, enc_out,
                                         None, None, None)
        new_caches.append({"self": new_self, "cross": new_cross})
    x = apply_norm(params["dec_final_norm"], x, cfg.norm)
    return x, new_caches


def decode_step(cfg: EncDecConfig, params: dict, token: Array,
                positions: Array, caches, lengths: Array):
    dt = cfg.compute_dtype
    x = embed_lookup(params["embed"], token, dt)
    pos = positions if positions.ndim == 2 else positions[..., 0]
    x = x + jnp.take(params["dec_pos"], pos, axis=0).astype(dt)
    new_caches = []
    for lp, cache in zip(params["decoder"], caches, strict=True):
        x, new_self, new_cross = _decoder_layer(
            cfg, lp, x, positions, None, cache["self"], cache["cross"],
            lengths)
        new_caches.append({"self": new_self, "cross": new_cross})
    x = apply_norm(params["dec_final_norm"], x, cfg.norm)
    hidden = x[:, 0]
    return logits_fn(cfg, params, hidden), hidden, new_caches


def init_params(cfg: EncDecConfig, key):
    return init_tree(key, param_specs(cfg))


def param_structs(cfg: EncDecConfig):
    return struct_tree(param_specs(cfg))


def param_axes(cfg: EncDecConfig):
    return axes_tree(param_specs(cfg))
