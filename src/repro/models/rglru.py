"""RG-LRU recurrence block (RecurrentGemma / Griffin, De et al. 2024).

The recurrence is a *diagonal* data-dependent linear RNN:

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)  (per-channel decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Because the recurrence is elementwise-diagonal it is associative, so the
whole sequence runs as one ``jax.lax.associative_scan`` — log-depth, fully
parallel, **no while loop** (so the dry-run ``cost_analysis()`` counts it
exactly; scan bodies are counted once — see models/attention.py docstring).

The full Griffin recurrent block wraps the RG-LRU with the temporal conv1d
(width 4) and the gated linear projections, matching the paper's block:

    x -> [linear -> conv1d -> RG-LRU] * gelu(linear gate) -> linear out

Numerics follow the paper: gates/recurrence in f32, ``a_t`` computed in
log-space (``a = exp(log_a)``, ``sqrt(1-a^2)`` via ``-expm1(2 log_a)``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Spec

Array = jax.Array

_C = 8.0  # the paper's fixed decay sharpness


def rglru_spec(d: int, width: int, conv_width: int = 4) -> dict:
    """Griffin recurrent block parameters.  d = d_model, width = lru_width."""
    return {
        "w_in": Spec((d, width), ("fsdp", "state")),
        "w_gate": Spec((d, width), ("fsdp", "state")),
        "w_out": Spec((width, d), ("state", "fsdp")),
        "conv_w": Spec((conv_width, width), (None, "state"), scale=0.3),
        "conv_b": Spec((width,), ("state",), init="zeros"),
        "lam": Spec((width,), ("state",), init="uniform_lambda"),
        "w_a": Spec((width, width), ("state", None), scale=None),
        "b_a": Spec((width,), ("state",), init="zeros"),
        "w_x": Spec((width, width), ("state", None), scale=None),
        "b_x": Spec((width,), ("state",), init="zeros"),
    }


def _lambda_init(lam_raw: Array) -> Array:
    """Map an init-normal param to the paper's a in [0.9, 0.999] range."""
    u = jax.nn.sigmoid(lam_raw)                 # (0,1)
    a_target = 0.9 + 0.099 * u
    # softplus(Lambda) = -log(a)/c  =>  Lambda = softplus^-1(-log a / c)
    sp = -jnp.log(a_target) / _C
    return jnp.log(jnp.expm1(jnp.maximum(sp, 1e-8)))


def conv1d_causal(x: Array, w: Array, b: Array,
                  state: Array | None = None):
    """Causal temporal conv. x (B,S,W), w (K,W).  Returns (y, new_state).

    ``state`` carries the trailing K-1 steps for decode; None = zero history
    (training start-of-sequence).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)    # (B, S+K-1, W)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y + b.astype(x.dtype), new_state


def rglru_scan(x: Array, r: Array, i: Array, lam: Array,
               h0: Array | None = None):
    """The RG-LRU recurrence over a full sequence via associative_scan.

    x/r/i: (B, S, W); lam: (W,) raw parameter; h0: (B, W) carried state.
    Returns (h (B,S,W) f32, h_last (B,W)).
    """
    xf = x.astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * r  # (B,S,W) <=0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))                    # sqrt(1-a^2)
    u = beta * (i * xf)                                          # input term
    if h0 is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        u = jnp.concatenate([h0.astype(jnp.float32)[:, None], u], axis=1)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, a2 * u1 + u2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h, h[:, -1]


def rglru_step(x: Array, r: Array, i: Array, lam: Array, h: Array):
    """Single decode step.  x/r/i (B, W); h (B, W) -> (out, h_new)."""
    xf = x.astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    h_new = a * h.astype(jnp.float32) + beta * (i * xf)
    return h_new, h_new


def apply_rglru_block(p: dict, x: Array, state: dict | None = None,
                      act=jax.nn.gelu):
    """Full Griffin recurrent block.  x (B, S, D) -> (y (B,S,D), new_state).

    ``state``: {"h": (B,W), "conv": (B,K-1,W)} or None (training, zeros).
    """
    dt = x.dtype
    gate = act(x @ p["w_gate"].astype(dt))                  # (B,S,W)
    u = x @ p["w_in"].astype(dt)
    u, conv_state = conv1d_causal(
        u, p["conv_w"], p["conv_b"],
        None if state is None else state["conv"])

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(uf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    lam = _lambda_init(p["lam"])

    h0 = None if state is None else state["h"]
    if x.shape[1] == 1 and state is not None:               # decode fast path
        h_seq, h_last = rglru_step(u[:, 0], r[:, 0], i[:, 0], lam, h0)
        h_seq = h_seq[:, None]
    else:
        h_seq, h_last = rglru_scan(u, r, i, lam, h0)

    y = (h_seq.astype(dt) * gate) @ p["w_out"].astype(dt)
    new_state = {"h": h_last, "conv": conv_state}
    return y, new_state


def rglru_state_zeros(b: int, width: int, conv_width: int = 4,
                      dtype=jnp.float32) -> dict:
    return {"h": jnp.zeros((b, width), jnp.float32),
            "conv": jnp.zeros((b, conv_width - 1, width), dtype)}


def rglru_state_axes() -> dict:
    return {"h": ("batch", "state"), "conv": ("batch", None, "state")}


def rglru_flops_per_token(d: int, width: int, conv_width: int = 4) -> int:
    """Matmul FLOPs/token: 3 d×W projections + 2 W×W gates + conv."""
    return 2 * (3 * d * width + 2 * width * width) + 2 * conv_width * width
