"""Uniform model interface (ModelBundle) over decoder-only and enc-dec stacks.

Every architecture exposes the same five callables regardless of family, so
the training loop, serving engine, dry-run and benchmarks are model-agnostic:

    bundle.forward_train(params, batch)          -> (hidden, aux_loss)
    bundle.logits(params, hidden)                -> logits
    bundle.init_cache(batch_size, s_max)         -> caches
    bundle.prefill(params, batch, caches, lens)  -> (last_hidden, caches)
    bundle.decode_step(params, token, pos, caches, lens) -> (logits, caches)

``batch`` is a dict: tokens (B,S) int32, positions (B,S) or (B,S,3) int32,
plus modality-stub extras (frames / patch_embeds) where the config declares
them.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import encdec, transformer
from .encdec import EncDecConfig
from .transformer import LMConfig


class ModelBundle(NamedTuple):
    cfg: Any
    init: Callable
    param_specs: Callable
    param_structs: Callable
    param_axes: Callable
    forward_train: Callable
    logits: Callable
    init_cache: Callable
    cache_axes: Callable
    prefill: Callable
    decode_step: Callable
    count_params: int
    active_params: int
    extra_inputs: dict  # name -> (shape_fn(B, S) -> shape, dtype, axes)


def _lm_bundle(cfg: LMConfig) -> ModelBundle:
    extras = {}
    if cfg.num_patch_tokens:
        extras["patch_embeds"] = (
            lambda b, s: (b, cfg.num_patch_tokens, cfg.d_model),
            jnp.float32, ("batch", None, "embed"))

    def forward_train(params, batch):
        return transformer.forward_train(
            cfg, params, batch["tokens"], batch["positions"],
            batch.get("patch_embeds"))

    def prefill(params, batch, caches, lengths):
        return transformer.prefill(
            cfg, params, batch["tokens"], batch["positions"], caches,
            lengths, batch.get("patch_embeds"))

    n = transformer.count_params(cfg)
    return ModelBundle(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        param_specs=lambda: transformer.param_specs(cfg),
        param_structs=lambda: transformer.param_structs(cfg),
        param_axes=lambda: transformer.param_axes(cfg),
        forward_train=forward_train,
        logits=lambda params, h: transformer.logits_fn(cfg, params, h),
        init_cache=lambda b, s: transformer.init_cache(cfg, b, s),
        cache_axes=lambda: transformer.cache_axes(cfg),
        prefill=prefill,
        decode_step=lambda params, tok, pos, caches, lens:
            transformer.decode_step(cfg, params, tok, pos, caches, lens),
        count_params=n,
        active_params=transformer.active_params(cfg),
        extra_inputs=extras,
    )


def _encdec_bundle(cfg: EncDecConfig) -> ModelBundle:
    extras = {"frames": (lambda b, s: (b, cfg.num_frames, cfg.d_model),
                         jnp.float32, ("batch", None, "embed"))}

    def forward_train(params, batch):
        return encdec.forward_train(cfg, params, batch["tokens"],
                                    batch["positions"], batch["frames"])

    def prefill(params, batch, caches, lengths):
        return encdec.prefill(cfg, params, batch["tokens"],
                              batch["positions"], caches, lengths,
                              batch["frames"])

    spec = encdec.param_specs(cfg)
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        spec, is_leaf=lambda x: hasattr(x, "materialize")))
    return ModelBundle(
        cfg=cfg,
        init=lambda key: encdec.init_params(cfg, key),
        param_specs=lambda: encdec.param_specs(cfg),
        param_structs=lambda: encdec.param_structs(cfg),
        param_axes=lambda: encdec.param_axes(cfg),
        forward_train=forward_train,
        logits=lambda params, h: encdec.logits_fn(cfg, params, h),
        init_cache=lambda b, s: encdec.init_cache(cfg, b, s),
        cache_axes=lambda: encdec.cache_axes(cfg),
        prefill=prefill,
        decode_step=lambda params, tok, pos, caches, lens:
            encdec.decode_step(cfg, params, tok, pos, caches, lens),
        count_params=n,
        active_params=n,
        extra_inputs=extras,
    )


def build_model(cfg) -> ModelBundle:
    if isinstance(cfg, EncDecConfig):
        return _encdec_bundle(cfg)
    if isinstance(cfg, LMConfig):
        return _lm_bundle(cfg)
    raise TypeError(f"unknown config type {type(cfg)}")


def with_overrides(cfg, **kw):
    """dataclasses.replace that tolerates either config type."""
    return dataclasses.replace(cfg, **kw)
