"""GQA attention: projections, chunked-flash SDPA, and KV-cache decode.

Three execution regimes, one semantics (oracle: kernels/ref.attention):

* ``sdpa_dense``   — materializes (…, Sq, Skv) logits.  Used for short
  sequences (tests, smoke configs) where the quadratic buffer is trivial.
* ``sdpa_chunked`` — flash-attention semantics in pure jnp: python-unrolled
  q/kv chunk loops with online softmax and a remat'd chunk body.  No scan →
  the compiled HLO carries every chunk's FLOPs, so ``cost_analysis()`` on
  the dry-run counts attention exactly (lax.scan bodies are counted ONCE by
  XLA's cost model — measured, see EXPERIMENTS.md §Dry-run), and the peak
  buffer is (…, q_chunk, kv_chunk).
* ``decode_attend`` — single-step decode against a (B, S_max, KH, D) cache;
  dense over the cache (the kv_seq axis may be sharded over `model`; the
  softmax reductions then turn into tiny all-reduces under SPMD).

On TPU the Pallas kernel (kernels/flash_attention.py) replaces sdpa_chunked
via kernels/ops.flash_attention dispatch; shapes/layout match.

Layout: activations (B, S, H, D); grouped-query handled without repeating
KV — q is reshaped to (B, S, KH, G, D) and logits einsums carry the group
axis, so KV stays at KH heads in memory.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import constrain

from .layers import Spec, apply_rope, rms_norm

Array = jax.Array

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def attention_spec(d: int, heads: int, kv_heads: int, head_dim: int,
                   qkv_bias: bool = False, qk_norm: bool = False,
                   out_bias: bool = False) -> dict:
    spec = {
        "wq": Spec((d, heads, head_dim), ("fsdp", "heads", "head_dim")),
        "wk": Spec((d, kv_heads, head_dim), ("fsdp", "kv_heads", "head_dim")),
        "wv": Spec((d, kv_heads, head_dim), ("fsdp", "kv_heads", "head_dim")),
        "wo": Spec((heads, head_dim, d), ("heads", "head_dim", "fsdp")),
    }
    if qkv_bias:
        spec["bq"] = Spec((heads, head_dim), ("heads", "head_dim"), init="zeros")
        spec["bk"] = Spec((kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = Spec((kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")
    if out_bias:
        spec["bo"] = Spec((d,), ("embed",), init="zeros")
    if qk_norm:
        spec["q_norm"] = Spec((head_dim,), ("head_dim",), init="ones")
        spec["k_norm"] = Spec((head_dim,), ("head_dim",), init="ones")
    return spec


def qkv_project(p: dict, x: Array, *, positions: Array, rope_theta: float,
                mrope_section=None, use_rope: bool = True):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,S,KH,hd), with bias/qk-norm/rope."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if "q_norm" in p:  # per-head RMS norm (Qwen3)
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if use_rope:
        q = apply_rope(q, positions, rope_theta, mrope_section)
        k = apply_rope(k, positions, rope_theta, mrope_section)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def out_project(p: dict, attn: Array) -> Array:
    out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(attn.dtype))
    if "bo" in p:
        out = out + p["bo"].astype(attn.dtype)
    return out


# ---------------------------------------------------------------------------
# SDPA — dense (short sequences)
# ---------------------------------------------------------------------------

def _grouped(q: Array, kv_heads: int) -> Array:
    """(B,S,H,D) -> (B,S,KH,G,D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, kv_heads, h // kv_heads, d)


def sdpa_dense(q: Array, k: Array, v: Array, *, causal: bool = True,
               window: int | None = None, q_offset: Array | int = 0,
               kv_len: Array | None = None) -> Array:
    """Reference-shaped attention with full logits. q (B,Sq,H,D), k/v (B,Skv,KH,D).

    ``q_offset``: absolute position of q[0] (decode: cache length so far).
    ``kv_len``: per-batch valid cache length (B,) — None means all valid.
    """
    b, sq, h, d = q.shape
    kh = k.shape[2]
    qg = _grouped(q, kh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    qi = jnp.arange(sq)[:, None] + q_offset                # (Sq, Skv) abs pos
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= (qi - ki) < window
    mask = mask[None, None, None]
    if kv_len is not None:
        mask = mask & (ki[None] < kv_len[:, None, None])[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, d)


# ---------------------------------------------------------------------------
# SDPA — chunked flash (long sequences; python-unrolled, remat'd body)
# ---------------------------------------------------------------------------

def _flash_chunk(qg, kj, vj, acc, m, den, qpos, kpos, causal, window, scale):
    """Online-softmax update for one (q_chunk, kv_chunk) tile.

    qg (B,Cq,KH,G,D); kj/vj (B,Ck,KH,D); acc (B,Cq,KH,G,D) f32;
    m/den (B,Cq,KH,G) f32; qpos (Cq,), kpos (Ck,) absolute positions.
    """
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kj,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    den_new = den * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bqhgk,bkhd->bqhgd", p.astype(qg.dtype), vj,
        preferred_element_type=jnp.float32)
    return acc_new, m_new, den_new


def sdpa_chunked(q: Array, k: Array, v: Array, *, causal: bool = True,
                 window: int | None = None, q_offset: int = 0,
                 q_chunk: int = 512, kv_chunk: int = 1024) -> Array:
    """Flash-semantics SDPA; peak buffer (B, q_chunk, H, kv_chunk) per tile.

    Python-unrolled over chunk tiles (exact cost_analysis, static shapes);
    the tile body is remat'd so backward recomputes p instead of saving it.
    Fully-masked tiles (outside causal/window reach) are skipped at trace
    time — the same work-skipping a Pallas grid would do.
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kh = k.shape[2]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = -(-sq // q_chunk)
    nk = -(-skv // kv_chunk)
    scale = d ** -0.5
    qg = _grouped(q, kh)
    chunk_fn = jax.checkpoint(functools.partial(
        _flash_chunk, causal=causal, window=window, scale=scale))

    outs = []
    for i in range(nq):
        q0, q1 = i * q_chunk, min((i + 1) * q_chunk, sq)
        qi = qg[:, q0:q1]
        cq = q1 - q0
        qpos = jnp.arange(q0, q1) + q_offset
        acc = jnp.zeros((b, cq, kh, h // kh, d), jnp.float32)
        m = jnp.full((b, cq, kh, h // kh), NEG_INF, jnp.float32)
        den = jnp.zeros((b, cq, kh, h // kh), jnp.float32)
        for j in range(nk):
            k0, k1 = j * kv_chunk, min((j + 1) * kv_chunk, skv)
            # trace-time tile skipping (static positions)
            lo_q, hi_q = q0 + q_offset, q1 - 1 + q_offset
            if causal and k0 > hi_q:
                continue
            if window is not None and (k1 - 1) < lo_q - window + 1:
                continue
            acc, m, den = chunk_fn(qi, k[:, k0:k1], v[:, k0:k1], acc, m,
                                   den, qpos, jnp.arange(k0, k1))
        out = acc / jnp.maximum(den, 1e-30)[..., None]
        outs.append(out.reshape(b, cq, h, d).astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def sdpa(q: Array, k: Array, v: Array, *, causal: bool = True,
         window: int | None = None, q_offset: int = 0,
         dense_threshold: int = 2048, q_chunk: int = 512,
         kv_chunk: int = 1024) -> Array:
    """Dispatch: dense for small Sq*Skv, chunked flash otherwise.

    Chunk sizes scale with sequence length (>= S/16 x S/8) so the python-
    unrolled tile grid stays ~O(100) bodies — a 32k prefill at fixed
    512x1024 tiles would emit ~2k tile bodies per layer and blow compile
    time (observed: whisper prefill_32k hung XLA for >10 min).
    """
    if q.shape[1] * k.shape[1] <= dense_threshold * dense_threshold:
        return sdpa_dense(q, k, v, causal=causal, window=window,
                          q_offset=q_offset)
    q_chunk = max(q_chunk, -(-q.shape[1] // 8))
    kv_chunk = max(kv_chunk, -(-k.shape[1] // 8))
    return sdpa_chunked(q, k, v, causal=causal, window=window,
                        q_offset=q_offset, q_chunk=q_chunk,
                        kv_chunk=kv_chunk)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array  # (B, S_max, KH, D)
    v: Array  # (B, S_max, KH, D)

    @staticmethod
    def zeros(b: int, s_max: int, kh: int, d: int, dtype=jnp.bfloat16):
        z = jnp.zeros((b, s_max, kh, d), dtype)
        return KVCache(k=z, v=z)

    @staticmethod
    def axes():
        ax = ("batch", "kv_seq", "kv_heads", "head_dim")
        return KVCache(k=ax, v=ax)


def cache_update(cache: KVCache, k_new: Array, v_new: Array,
                 lengths: Array) -> KVCache:
    """Write S_new steps at per-sequence offsets ``lengths`` (B,) int32.

    One-hot matmul scatter: TPU-friendly (no data-dependent dynamic slices
    across a sharded kv_seq axis), works for prefill (lengths=0, S_new=S)
    and decode (S_new=1) alike.
    """
    b, s_new = k_new.shape[:2]
    s_max = cache.k.shape[1]
    # positions each new step lands at: (B, S_new)
    tgt = lengths[:, None] + jnp.arange(s_new)[None, :]
    oh = jax.nn.one_hot(tgt, s_max, dtype=cache.k.dtype)   # (B, S_new, S_max)
    keep = 1.0 - jnp.sum(oh, axis=1)                       # (B, S_max)
    k = cache.k * keep[..., None, None] + jnp.einsum(
        "bns,bnhd->bshd", oh, k_new.astype(cache.k.dtype))
    v = cache.v * keep[..., None, None] + jnp.einsum(
        "bns,bnhd->bshd", oh, v_new.astype(cache.v.dtype))
    return KVCache(k=k, v=v)


def decode_attend(q: Array, cache: KVCache, lengths: Array, *,
                  window: int | None = None) -> Array:
    """One-token attention over the cache.  q (B,1,H,D); lengths (B,) is the
    number of valid cache entries INCLUDING the new token already written."""
    b, _, h, d = q.shape
    kh = cache.k.shape[2]
    qg = _grouped(q, kh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, cache.k.astype(q.dtype),
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    ki = jnp.arange(cache.k.shape[1])[None, :]             # (1, S_max)
    mask = ki < lengths[:, None]
    if window is not None:
        mask &= ki >= (lengths[:, None] - window)
    logits = jnp.where(mask[:, None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, cache.v.astype(q.dtype))
    return out.reshape(b, 1, h, d)
