# LM substrate for the assigned architectures: layers, attention variants,
# MoE, linear-recurrence mixers (RG-LRU, RWKV-6), decoder-only / enc-dec
# model assembly, and the config registry.
