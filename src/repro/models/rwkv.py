"""RWKV-6 "Finch" (Peng et al. 2024) — attention-free time mixing with
data-dependent per-channel decay.

Per head (head size N), with row vectors r_t, k_t, v_t and decay w_t:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (state: N_key x N_value)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)      (u = per-channel bonus)

Training runs the **chunked parallel form** (python-unrolled chunk loop,
remat'd bodies — no while loops, so dry-run cost_analysis is exact):

* within a chunk, cumulative log-decays L_t = sum_{s<=t} log w_s are
  computed once; intra-chunk pair terms use exp(Lprev_t - L_s) with s <= t,
  where the EXPONENT DIFFERENCE is formed first (always <= 0 for valid
  pairs) — numerically safe for arbitrarily strong decay, unlike the
  exp(L)·exp(-L) matmul factorization which overflows;
* inter-chunk contributions flow through the carried state S with factors
  exp(L) <= 1.

Decode runs the O(1) recurrence directly.

Token-shift ("ddlerp") follows the RWKV-6 low-rank form: a shared first
lerp, then a 5-way LoRA producing per-projection mix deltas for r/k/v/w/g.
The decay LoRA gives w_t = exp(-exp(w0 + tanh(x_w A_w) B_w)) per channel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Spec

Array = jax.Array

_MIX = ("r", "k", "v", "w", "g")


def rwkv_time_spec(d: int, head_dim: int, lora_r: int = 32,
                   decay_lora: int = 64) -> dict:
    h = d // head_dim
    return {
        "mu_first": Spec((d,), ("embed",), init="zeros"),
        "mu": Spec((5, d), (None, "embed"), init="zeros"),
        "lora_a": Spec((d, 5 * lora_r), ("fsdp", None), scale=0.01),
        "lora_b": Spec((5, lora_r, d), (None, None, "embed"), scale=0.01),
        "w_r": Spec((d, d), ("fsdp", "heads")),
        "w_k": Spec((d, d), ("fsdp", "heads")),
        "w_v": Spec((d, d), ("fsdp", "heads")),
        "w_g": Spec((d, d), ("fsdp", "heads")),
        "w_o": Spec((d, d), ("heads", "fsdp")),
        "decay_w0": Spec((d,), ("heads",), init="zeros"),
        "decay_a": Spec((d, decay_lora), ("fsdp", None), scale=0.01),
        "decay_b": Spec((decay_lora, d), (None, "heads"), scale=0.01),
        "bonus_u": Spec((d,), ("heads",), init="zeros"),
        "ln_scale": Spec((d,), ("heads",), init="ones"),
        "ln_bias": Spec((d,), ("heads",), init="zeros"),
    }


def rwkv_channel_spec(d: int, f: int) -> dict:
    return {
        "mu_k": Spec((d,), ("embed",), init="zeros"),
        "mu_r": Spec((d,), ("embed",), init="zeros"),
        "w_k": Spec((d, f), ("fsdp", "mlp")),
        "w_v": Spec((f, d), ("mlp", "fsdp")),
        "w_r": Spec((d, d), ("fsdp", None)),
    }


def _token_shift(x: Array, x_prev: Array | None):
    """(B,S,D) -> previous-step tensor with carried boundary state (B,D)."""
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, 0])
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    return shifted, x[:, -1]


def _ddlerp(p: dict, x: Array, shifted: Array):
    """RWKV-6 data-dependent lerp: 5 mixed inputs (r,k,v,w,g)."""
    dt = x.dtype
    xx = shifted - x
    base = x + xx * p["mu_first"].astype(dt)
    lr = p["lora_a"].shape[1] // 5
    lo = jnp.tanh(base @ p["lora_a"].astype(dt))            # (B,S,5r)
    lo = lo.reshape(*lo.shape[:-1], 5, lr)
    delta = jnp.einsum("bsnr,nrd->bsnd", lo, p["lora_b"].astype(dt))
    mixes = {}
    for n, name in enumerate(_MIX):
        mu = p["mu"][n].astype(dt) + delta[..., n, :]
        mixes[name] = x + xx * mu
    return mixes


def _group_norm(x: Array, scale: Array, bias: Array, head_dim: int,
                eps: float = 64e-5):
    """Per-head LayerNorm over the head channels (RWKV's GroupNorm)."""
    shape = x.shape
    xh = x.reshape(*shape[:-1], shape[-1] // head_dim, head_dim)
    xf = xh.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    nrm = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(shape)
    return (nrm * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# WKV — chunked parallel form
# ---------------------------------------------------------------------------

def _wkv_chunk(r, k, v, logw, u, state):
    """One chunk of the WKV recurrence.

    r/k/v: (B, C, H, N); logw: (B, C, H, N) (<= 0, f32); u: (H, N);
    state: (B, H, N, N) f32.  Returns (o (B,C,H,N) f32, new_state).
    """
    logw = logw.astype(jnp.float32)
    el = jnp.cumsum(logw, axis=1)                           # L_t
    el_prev = el - logw                                     # L_{t-1}
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # inter-chunk: o_t += (r_t . exp(L_{t-1})) @ S
    o = jnp.einsum("bchn,bhnm->bchm", rf * jnp.exp(el_prev), state)

    # intra-chunk pairs s<t: A[t,s] = sum_n r[t,n] k[s,n] exp(Lprev[t,n]-L[s,n])
    diff = el_prev[:, :, None] - el[:, None, :]             # (B,C,C,H,N) <=0 valid
    c = r.shape[1]
    causal = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])
    decay = jnp.exp(jnp.where(causal[None, :, :, None, None], diff, -jnp.inf))
    att = jnp.einsum("bthn,bshn,btshn->bths", rf, kf, decay)
    # diagonal (s=t) carries the bonus u instead of decay
    att_diag = jnp.einsum("bthn,bthn->bth", rf * u.astype(jnp.float32), kf)
    att = att + att_diag[:, :, :, None] * jnp.eye(c)[None, :, None, :]
    o = o + jnp.einsum("bths,bshn->bthn", att, vf)

    # state update: S' = diag(exp(L_C)) S + sum_s (k_s * exp(L_C - L_s))^T v_s
    tail = el[:, -1:, :]                                    # (B,1,H,N)
    k_scaled = kf * jnp.exp(tail - el)                      # <=1 factors
    new_state = (jnp.exp(tail[:, 0])[..., None] * state
                 + jnp.einsum("bshn,bshm->bhnm", k_scaled, vf))
    return o, new_state


def wkv_chunked(r, k, v, logw, u, state, chunk: int = 128):
    """Full-sequence WKV.  All of r/k/v/logw: (B, S, H, N)."""
    s = r.shape[1]
    chunk = min(chunk, s)
    outs = []
    body = jax.checkpoint(_wkv_chunk)
    for c0 in range(0, s, chunk):
        c1 = min(c0 + chunk, s)
        o, state = body(r[:, c0:c1], k[:, c0:c1], v[:, c0:c1],
                        logw[:, c0:c1], u, state)
        outs.append(o)
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out, state


def wkv_step(r, k, v, logw, u, state):
    """Decode step.  r/k/v/logw (B,H,N); state (B,H,N,N) f32."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = kf[..., :, None] * vf[..., None, :]                # (B,H,N,N)
    o = jnp.einsum("bhn,bhnm->bhm", rf,
                   state + u.astype(jnp.float32)[..., None] * kv)
    new_state = jnp.exp(logw.astype(jnp.float32))[..., None] * state + kv
    return o, new_state


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def apply_rwkv_time(p: dict, x: Array, head_dim: int,
                    state: dict | None = None, chunk: int = 128):
    """Time-mix block.  x (B,S,D) -> (y, new_state).

    state: {"shift": (B,D), "wkv": (B,H,N,N) f32} or None.
    """
    b, s, d = x.shape
    h = d // head_dim
    dt = x.dtype
    shifted, shift_out = _token_shift(
        x, None if state is None else state["shift"])
    mx = _ddlerp(p, x, shifted)

    r = (mx["r"] @ p["w_r"].astype(dt)).reshape(b, s, h, head_dim)
    k = (mx["k"] @ p["w_k"].astype(dt)).reshape(b, s, h, head_dim)
    v = (mx["v"] @ p["w_v"].astype(dt)).reshape(b, s, h, head_dim)
    g = jax.nn.silu(mx["g"] @ p["w_g"].astype(dt))

    dw = jnp.tanh(mx["w"] @ p["decay_a"].astype(dt)) @ p["decay_b"].astype(dt)
    logw = -jnp.exp(jnp.clip(
        p["decay_w0"].astype(jnp.float32) + dw.astype(jnp.float32),
        -12.0, 6.0))                                        # (B,S,D) <= 0
    logw = logw.reshape(b, s, h, head_dim)
    u = p["bonus_u"].astype(jnp.float32).reshape(h, head_dim)

    wkv0 = (jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
            if state is None else state["wkv"])
    if s == 1 and state is not None:
        o, wkv = wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], u, wkv0)
        o = o[:, None]
    else:
        o, wkv = wkv_chunked(r, k, v, logw, u, wkv0, chunk=chunk)

    o = o.reshape(b, s, d).astype(dt)
    o = _group_norm(o, p["ln_scale"], p["ln_bias"], head_dim) * g
    y = o @ p["w_o"].astype(dt)
    return y, {"shift": shift_out, "wkv": wkv}


def apply_rwkv_channel(p: dict, x: Array, state: dict | None = None):
    """Channel-mix block (squared-ReLU FFN with token shift)."""
    dt = x.dtype
    shifted, shift_out = _token_shift(
        x, None if state is None else state["shift"])
    xx = shifted - x
    xk = x + xx * p["mu_k"].astype(dt)
    xr = x + xx * p["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(dt)))
    rr = jax.nn.sigmoid(xr @ p["w_r"].astype(dt))
    return rr * (kk @ p["w_v"].astype(dt)), {"shift": shift_out}


def rwkv_state_zeros(b: int, d: int, head_dim: int, dtype=jnp.bfloat16):
    h = d // head_dim
    return {
        "time": {"shift": jnp.zeros((b, d), dtype),
                 "wkv": jnp.zeros((b, h, head_dim, head_dim), jnp.float32)},
        "channel": {"shift": jnp.zeros((b, d), dtype)},
    }


def rwkv_state_axes():
    return {
        "time": {"shift": ("batch", "embed"),
                 "wkv": ("batch", "heads", None, None)},
        "channel": {"shift": ("batch", "embed")},
    }


def rwkv_flops_per_token(d: int, f: int, head_dim: int,
                         lora_r: int = 32, decay_lora: int = 64) -> int:
    """Matmul FLOPs/token (WKV recurrence itself adds ~4N per channel)."""
    proj = 2 * d * d * 5                        # r,k,v,g,o
    lora = 2 * d * (5 * lora_r) + 2 * 5 * lora_r * d + 2 * d * decay_lora * 2
    wkv = 4 * d * head_dim                      # state update + readout
    chan = 2 * d * f * 2 + 2 * d * d
    return proj + lora + wkv + chan
