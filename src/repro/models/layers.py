"""Parameter specs and common layers (norms, embeddings, RoPE, MLPs).

Parameters are plain pytrees of jnp arrays.  Their *shapes, logical sharding
axes and initializers* are declared once as a pytree of :class:`Spec`; from
that single declaration we derive

* ``init_tree``    — materialized parameters (host or per-device),
* ``struct_tree``  — ShapeDtypeStructs (the dry-run's no-allocation path),
* ``axes_tree``    — logical-axis tuples consumed by dist/sharding.py.

Logical axis names used by the models (resolved by DEFAULT_RULES):
``embed`` (residual stream), ``heads``, ``kv_heads``, ``head_dim``, ``mlp``,
``vocab``, ``experts``, ``layers``, ``state`` and the fsdp-style weight axis
``fsdp`` (mapped to the data axis; XLA SPMD all-gathers weights per layer —
ZeRO-3 semantics).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declarative parameter: shape + logical axes + initializer."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | uniform_scaled
    scale: float | None = None    # stddev; default 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def materialize(self, key: Array) -> Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        scale = self.scale
        if scale is None:
            fan_in = self.shape[0] if self.shape else 1
            scale = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape, jnp.float32) * scale
                ).astype(self.dtype)

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def init_tree(key: Array, specs) -> Any:
    """Materialize a Spec pytree (deterministic per-leaf key folding)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [s.materialize(k) for s, k in zip(leaves, keys, strict=True)]
    return jax.tree.unflatten(treedef, vals)


def struct_tree(specs) -> Any:
    return jax.tree.map(lambda s: s.struct(), specs, is_leaf=is_spec)


def axes_tree(specs) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def stack_specs(spec: Spec, n: int) -> Spec:
    """A per-layer Spec stacked for scan-over-layers: leading `layers` axis."""
    return dataclasses.replace(
        spec, shape=(n,) + spec.shape, axes=("layers",) + spec.axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    """RMSNorm in f32 accumulation (returns x.dtype)."""
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    nrm = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (nrm * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def norm_spec(d: int, kind: str) -> Any:
    if kind == "rmsnorm":
        return {"scale": Spec((d,), ("embed",), init="ones")}
    return {"scale": Spec((d,), ("embed",), init="ones"),
            "bias": Spec((d,), ("embed",), init="zeros")}


def apply_norm(p: dict, x: Array, kind: str) -> Array:
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def padded_vocab(vocab: int, multiple: int = 128) -> int:
    """Vocab tables are padded so the vocab axis shards on any mesh
    (51865 -> 51968 etc.); pad logits are masked at the sampling boundary
    (logits_fn) and act as never-labeled extra entries in the loss —
    standard MaxText-style padding."""
    return -(-vocab // multiple) * multiple


def embed_spec(vocab: int, d: int) -> Spec:
    return Spec((padded_vocab(vocab), d), ("vocab", "fsdp"), scale=1.0)


def embed_lookup(table: Array, tokens: Array, compute_dtype,
                 chunk: int = 512) -> Array:
    """Token embedding lookup via sequence-chunked one-hot matmul.

    take() on a vocab-sharded table gathers poorly under SPMD; the one-hot
    matmul form keeps the (V, D) table sharded and emits a small psum over
    the vocab axis instead — the standard TPU idiom.  The one-hot buffer is
    (B, chunk, V), so it must be chunked over the sequence (a 32k-token
    prefill with a 152k vocab would otherwise be a multi-TB buffer) and
    remat'd so backward rebuilds it instead of saving it.
    """
    v = table.shape[0]
    b, s = tokens.shape

    @jax.checkpoint
    def one_chunk(toks, table):
        from repro.dist.sharding import constrain
        oh = jax.nn.one_hot(toks, v, dtype=compute_dtype)
        # fsdp-gather the table for the dot (see train/losses.py)
        table_g = constrain(table.astype(compute_dtype), ("vocab", None))
        return oh @ table_g

    if s <= chunk:
        return one_chunk(tokens, table)
    outs = [one_chunk(tokens[:, c0:c0 + chunk], table)
            for c0 in range(0, s, chunk)]
    return jnp.concatenate(outs, axis=1)


def unembed_logits(x: Array, table: Array) -> Array:
    """(..., d) @ (V, d)^T in f32 accumulation -> (..., V)."""
    from repro.dist.sharding import constrain
    table_g = constrain(table.astype(x.dtype), ("vocab", None))
    return jnp.einsum("...d,vd->...v", x, table_g,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    """(head_dim//2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float = 1e4,
               mrope_section: tuple[int, ...] | None = None) -> Array:
    """Rotary embedding, optionally multimodal (M-RoPE, Qwen2-VL §3.1).

    x: (B, S, H, D); positions: (B, S) int — or (B, S, 3) for M-RoPE
    (temporal, height, width components; text tokens carry equal values,
    making M-RoPE degenerate to 1-D RoPE on text).

    M-RoPE splits the D/2 frequency channels into 3 sections; section ``i``
    rotates by positions[..., i].
    """
    b, s, h, d = x.shape
    half = d // 2
    inv = rope_frequencies(d, theta)                       # (half,)
    if mrope_section is not None:
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[..., None], (b, s, 3))
        sec = np.asarray(mrope_section)
        assert sec.sum() == half, (mrope_section, half)
        comp = np.repeat(np.arange(3), sec)                # (half,) -> section id
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.asarray(comp)[None, None, :].repeat(b, 0).repeat(s, 1), axis=-1
        )                                                  # (B, S, half)
    else:
        if positions.ndim == 3:
            positions = positions[..., 0]
        pos = positions.astype(jnp.float32)[..., None]     # (B, S, 1)
    angle = pos * inv[None, None, :]                       # (B, S, half)
    sin = jnp.sin(angle)[:, :, None, :].astype(x.dtype)    # (B, S, 1, half)
    cos = jnp.cos(angle)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoidal_positions(seq: int, d: int) -> Array:
    """Whisper-style fixed sinusoid table (S, d)."""
    half = d // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------

def mlp_spec(d: int, f: int, gated: bool, bias: bool = False) -> dict:
    spec = {
        "w_in": Spec((d, f), ("fsdp", "mlp")),
        "w_out": Spec((f, d), ("mlp", "fsdp")),
    }
    if gated:
        spec["w_gate"] = Spec((d, f), ("fsdp", "mlp"))
    if bias:
        spec["b_in"] = Spec((f,), ("mlp",), init="zeros")
        spec["b_out"] = Spec((d,), ("embed",), init="zeros")
    return spec


def _act(name: str) -> Callable[[Array], Array]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu_sq": lambda x: jnp.square(jax.nn.relu(x))}[name]


def apply_mlp(p: dict, x: Array, act: str) -> Array:
    """Gated (SwiGLU/GeGLU) or plain 2-layer MLP; matmuls in x.dtype."""
    dt = x.dtype
    h = x @ p["w_in"].astype(dt)
    if "b_in" in p:
        h = h + p["b_in"].astype(dt)
    h = _act(act)(h)
    if "w_gate" in p:
        h = h * (x @ p["w_gate"].astype(dt))
    out = h @ p["w_out"].astype(dt)
    if "b_out" in p:
        out = out + p["b_out"].astype(dt)
    return out


def mlp_flops(d: int, f: int, gated: bool) -> int:
    """Per-token matmul FLOPs (for the analytic roofline)."""
    return 2 * d * f * (3 if gated else 2)
