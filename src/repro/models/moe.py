"""Mixture-of-Experts FFN — GShard-style grouped top-k dispatch (EP).

Formulation (Lepikhin et al., adapted to einsum-on-mesh):

* tokens are reshaped to (G, S', D) groups; each group dispatches at most
  ``capacity = S' * top_k * capacity_factor / E`` tokens to each expert
  (static shapes — overflow drops, standard GShard semantics; the router
  aux loss keeps load balanced so drops are rare);
* ``dispatch`` (G, S', E, C) one-hot routes tokens to expert slots; the
  dispatched einsum reshards tokens from the data axis to the expert
  (model) axis — XLA SPMD realizes it as an all-to-all, the canonical EP
  collective;
* experts are (E, D, F) weight stacks sharded E -> model;
* ``combine`` (G, S', E, C) carries router weights back (second all-to-all).

Group size trades memory for balance: the dispatch tensor is
G*S'*E*C = S'^2 * top_k * cf per group-row — small groups keep it tiny
(DESIGN.md §4).  llama4-style shared expert is a plain dense MLP added to
every token's output.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

from .layers import Spec, apply_mlp, _act

Array = jax.Array


def moe_spec(d: int, f: int, num_experts: int, gated: bool = True,
             router_dtype=jnp.float32) -> dict:
    spec = {
        "router": Spec((d, num_experts), ("fsdp", None), dtype=router_dtype),
        "w_in": Spec((num_experts, d, f), ("experts", "fsdp", "expert_mlp")),
        "w_out": Spec((num_experts, f, d), ("experts", "expert_mlp", "fsdp")),
    }
    if gated:
        spec["w_gate"] = Spec((num_experts, d, f),
                              ("experts", "fsdp", "expert_mlp"))
    return spec


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_tokens: int = 512        # S' — tokens per dispatch group
    router_softmax_order: str = "topk_then_softmax"  # qwen3 renormalizes
    aux_loss_weight: float = 1e-2


def _group_size(total_tokens: int, target: int) -> int:
    """Largest divisor of total_tokens that is <= target (static shapes)."""
    for sp in range(min(target, total_tokens), 0, -1):
        if total_tokens % sp == 0:
            return sp
    return 1


def _capacity(cfg: MoEConfig, group_tokens: int | None = None) -> int:
    s = cfg.group_tokens if group_tokens is None else group_tokens
    c = int(s * cfg.top_k * cfg.capacity_factor // cfg.num_experts)
    return max(c, 1)


def route(router_logits: Array, cfg: MoEConfig):
    """Top-k routing weights. logits (G, S, E) f32 ->
    (weights (G,S,K), expert_idx (G,S,K) int32, aux_loss ())."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_softmax_order == "topk_then_softmax":
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch/GShard load-balancing loss: E * <fraction routed> . <mean prob>
    e = cfg.num_experts
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    one_hot_top1 = jax.nn.one_hot(top_i[..., 0], e, dtype=probs.dtype)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return top_w, top_i, aux


def dispatch_combine(top_w: Array, top_i: Array, cfg: MoEConfig):
    """Build one-hot dispatch/combine tensors (G, S, E, C).

    Slot assignment: position-in-expert = cumulative count of earlier tokens
    in the same group routed to the same expert (per k, counted across k
    levels in order — GShard's sequential-greedy semantics).
    """
    g, s, k = top_w.shape
    e, c = cfg.num_experts, _capacity(cfg, s)
    # (G, S, K, E) one-hot of assignments
    oh = jax.nn.one_hot(top_i, e, dtype=jnp.float32)
    # sequential position: flatten (S, K) in priority order (token-major)
    flat = oh.reshape(g, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                   # count of earlier
    pos = pos.reshape(g, s, k, e)
    within = jnp.sum(pos * oh, axis=-1).astype(jnp.int32)   # (G, S, K)
    keep = (within < c) & (top_w > 0)
    slot_oh = jax.nn.one_hot(within, c, dtype=jnp.float32)  # (G, S, K, C)
    disp = jnp.einsum("gske,gskc,gsk->gsec", oh, slot_oh,
                      keep.astype(jnp.float32))
    comb = jnp.einsum("gske,gskc,gsk->gsec", oh, slot_oh,
                      jnp.where(keep, top_w, 0.0).astype(jnp.float32))
    return disp, comb


def apply_moe(p: dict, x: Array, cfg: MoEConfig, act: str = "silu",
              shared_mlp: dict | None = None):
    """MoE FFN.  x (B, T, D) -> (y (B, T, D), aux_loss ()).

    Internally regroups to (G, S', D); B*T must be divisible by
    ``cfg.group_tokens`` (configs choose divisible shapes).
    """
    b, t, d = x.shape
    dt = x.dtype
    sp = _group_size(b * t, cfg.group_tokens)
    g = (b * t) // sp
    xg = x.reshape(g, sp, d)

    xg = constrain(xg, ("batch", None, "embed"))
    logits = (xg.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))            # (G, S, E) f32
    logits = constrain(logits, ("batch", None, None))
    top_w, top_i, aux = route(logits, cfg)
    disp, comb = dispatch_combine(top_w, top_i, cfg)
    disp = constrain(disp, ("batch", None, "experts", None))
    comb = constrain(comb, ("batch", None, "experts", None))

    # all-to-all #1: tokens -> expert slots (E on the model axis)
    xe = jnp.einsum("gsd,gsec->egcd", xg, disp.astype(dt))  # (E, G, C, D)
    xe = constrain(xe, ("experts", "batch", None, "embed"))
    # fsdp-gather expert weights for use (E stays on the model axis)
    w_in = constrain(p["w_in"].astype(dt), ("experts", None, "expert_mlp"))
    h = jnp.einsum("egcd,edf->egcf", xe, w_in)
    h = _act(act)(h)
    if "w_gate" in p:
        w_gate = constrain(p["w_gate"].astype(dt),
                           ("experts", None, "expert_mlp"))
        h = h * jnp.einsum("egcd,edf->egcf", xe, w_gate)
    w_out = constrain(p["w_out"].astype(dt), ("experts", "expert_mlp", None))
    ye = jnp.einsum("egcf,efd->egcd", h, w_out)
    ye = constrain(ye, ("experts", "batch", None, "embed"))
    # all-to-all #2: expert slots -> tokens, weighted by router probs
    y = jnp.einsum("egcd,gsec->gsd", ye, comb.astype(dt))

    y = y.reshape(b, t, d)
    if shared_mlp is not None:                              # llama4 shared expert
        y = y + apply_mlp(shared_mlp, x, act)
    return y, aux * cfg.aux_loss_weight


def moe_flops_per_token(d: int, f: int, cfg: MoEConfig, gated: bool = True,
                        shared_f: int = 0) -> int:
    """Active matmul FLOPs per token (for 6·N_active·D roofline)."""
    per_expert = 2 * d * f * (3 if gated else 2)
    shared = 2 * d * shared_f * 3 if shared_f else 0
    return cfg.top_k * per_expert + shared + 2 * d * cfg.num_experts
