"""Decoder-only LM assembly: dense / MoE / hybrid (RG-LRU) / RWKV stacks.

One config-driven implementation covers 9 of the 10 assigned architectures
(whisper's encoder-decoder lives in encdec.py).  A layer is

    x = x + mixer(norm1(x))     mixer in {attn, local_attn, rglru, rwkv_time}
    x = x + ffn(norm2(x))       ffn   in {gated/plain MLP, MoE, rwkv_channel}

with the per-layer kind taken from ``cfg.block_pattern`` cycled over depth.

Execution modes:

* ``forward_train`` — full-sequence teacher forcing; optional
  scan-over-layers (homogeneous stacks; stacked params) with remat;
  chunked-flash attention for long sequences.  Loss is computed by the
  caller (train/losses.py) against the returned hidden states so the giant
  (B, S, V) logits tensor is never materialized at once.
* ``prefill`` — same forward but writes KV/recurrent caches and returns the
  last-position hidden state (serving: first token of the response).
* ``decode_step`` — one token against the caches. Never scanned (layer loop
  is python; decode programs are small).

Sharding is by logical axes only (layers.Spec); the launcher resolves them
against whatever mesh is active (dist/sharding.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import constrain

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv as rwkv_mod
from .attention import KVCache
from .layers import (Spec, apply_mlp, apply_norm, axes_tree, embed_lookup,
                     embed_spec, init_tree, mlp_spec, norm_spec, stack_specs,
                     struct_tree, unembed_logits)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer pattern, cycled over depth
    block_pattern: tuple[str, ...] = ("attn",)
    ffn_kind: str = "gated"              # gated | plain | moe | rwkv_channel
    act: str = "silu"
    norm: str = "rmsnorm"
    # attention details
    qkv_bias: bool = False
    out_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope_section: tuple[int, ...] | None = None
    window: int | None = None            # sliding window for local_attn
    # MoE
    moe: moe_mod.MoEConfig | None = None
    moe_d_ff: int = 0
    shared_expert_ff: int = 0
    # recurrent widths
    lru_width: int = 0
    conv_width: int = 4
    rwkv_head_dim: int = 64
    # embeddings / head
    tie_embeddings: bool = True
    pos_embedding: str = "rope"          # rope | learned | none
    max_position: int = 1 << 20
    # multimodal stub
    num_patch_tokens: int = 0            # vlm: first P positions are patches
    # execution
    scan_layers: bool = False
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots | offloadable-dots
    compute_dtype: Any = jnp.bfloat16
    q_chunk: int = 512
    kv_chunk: int = 1024
    dense_attn_threshold: int = 2048
    rwkv_chunk: int = 128

    # -- derived -----------------------------------------------------------
    def layer_kinds(self) -> tuple[str, ...]:
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def homogeneous(self) -> bool:
        return len(set(self.layer_kinds())) == 1

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _mixer_spec(cfg: LMConfig, kind: str) -> dict:
    if kind in ("attn", "local_attn"):
        return attn_mod.attention_spec(
            cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, out_bias=cfg.out_bias)
    if kind == "rglru":
        return rglru_mod.rglru_spec(cfg.d_model, cfg.lru_width, cfg.conv_width)
    if kind == "rwkv":
        return rwkv_mod.rwkv_time_spec(cfg.d_model, cfg.rwkv_head_dim)
    raise ValueError(kind)


def _ffn_spec(cfg: LMConfig) -> dict:
    if cfg.ffn_kind == "moe":
        spec = moe_mod.moe_spec(cfg.d_model, cfg.moe_d_ff, cfg.moe.num_experts)
        if cfg.shared_expert_ff:
            spec["shared"] = mlp_spec(cfg.d_model, cfg.shared_expert_ff,
                                      gated=True)
        return spec
    if cfg.ffn_kind == "rwkv_channel":
        return rwkv_mod.rwkv_channel_spec(cfg.d_model, cfg.d_ff)
    return mlp_spec(cfg.d_model, cfg.d_ff, gated=(cfg.ffn_kind == "gated"),
                    bias=cfg.mlp_bias)


def _layer_spec(cfg: LMConfig, kind: str) -> dict:
    return {
        "norm1": norm_spec(cfg.d_model, cfg.norm),
        "mixer": _mixer_spec(cfg, kind),
        "norm2": norm_spec(cfg.d_model, cfg.norm),
        "ffn": _ffn_spec(cfg),
    }


def param_specs(cfg: LMConfig) -> dict:
    spec: dict = {"embed": embed_spec(cfg.vocab_size, cfg.d_model),
                  "final_norm": norm_spec(cfg.d_model, cfg.norm)}
    if not cfg.tie_embeddings:
        spec["unembed"] = embed_spec(cfg.vocab_size, cfg.d_model)
    if cfg.pos_embedding == "learned":
        spec["pos_embed"] = Spec((cfg.max_position, cfg.d_model),
                                 (None, "fsdp"), scale=0.02)
    kinds = cfg.layer_kinds()
    if cfg.scan_layers and cfg.homogeneous():
        one = _layer_spec(cfg, kinds[0])
        spec["layers"] = jax.tree.map(
            lambda s: stack_specs(s, cfg.num_layers), one,
            is_leaf=lambda x: isinstance(x, Spec))
    else:
        spec["layers"] = [_layer_spec(cfg, k) for k in kinds]
    return spec


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_mixer(cfg: LMConfig, kind: str, p: dict, x: Array, *,
                 positions: Array, cache, lengths):
    """Returns (y, new_cache).  cache semantics per kind (None = training)."""
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else None
        use_rope = cfg.pos_embedding == "rope"
        q, k, v = attn_mod.qkv_project(
            p, x, positions=positions, rope_theta=cfg.rope_theta,
            mrope_section=cfg.mrope_section, use_rope=use_rope)
        if cache is None:                                   # training
            out = attn_mod.sdpa(q, k, v, causal=True, window=window,
                                dense_threshold=cfg.dense_attn_threshold,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
            new_cache = None
        elif x.shape[1] == 1:                               # decode step
            cache = _cache_write(cache, k, v, lengths, window)
            if window is not None and cache.k.shape[1] <= window:
                # ring buffer: every filled slot is inside the window by
                # construction; slot indices are permuted so the positional
                # window mask must not apply (attention is order-free).
                filled = jnp.minimum(lengths + 1, cache.k.shape[1])
                out = attn_mod.decode_attend(q, cache, filled, window=None)
            else:
                out = attn_mod.decode_attend(q, cache, lengths + 1,
                                             window=window)
            new_cache = cache
        else:                                               # prefill
            out = attn_mod.sdpa(q, k, v, causal=True, window=window,
                                dense_threshold=cfg.dense_attn_threshold,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
            new_cache = _cache_write(cache, k, v, lengths, window)
        return attn_mod.out_project(p, out), new_cache
    if kind == "rglru":
        return rglru_mod.apply_rglru_block(p, x, cache)
    if kind == "rwkv":
        return rwkv_mod.apply_rwkv_time(p, x, cfg.rwkv_head_dim, cache,
                                        chunk=cfg.rwkv_chunk)
    raise ValueError(kind)


def _cache_write(cache: KVCache, k: Array, v: Array, lengths: Array,
                 window: int | None) -> KVCache:
    """Write new KV; local-attention caches are ring buffers of size W."""
    s_max = cache.k.shape[1]
    s_new = k.shape[1]
    if window is not None and s_max <= window:
        # ring buffer: only the trailing min(s_new, W) steps can survive
        keep = min(s_new, s_max)
        k, v = k[:, -keep:], v[:, -keep:]
        start = lengths + (s_new - keep)
        tgt = (start[:, None] + jnp.arange(keep)[None, :]) % s_max
        oh = jax.nn.one_hot(tgt, s_max, dtype=cache.k.dtype)
        keep_mask = 1.0 - jnp.sum(oh, axis=1)
        new_k = cache.k * keep_mask[..., None, None] + jnp.einsum(
            "bns,bnhd->bshd", oh, k.astype(cache.k.dtype))
        new_v = cache.v * keep_mask[..., None, None] + jnp.einsum(
            "bns,bnhd->bshd", oh, v.astype(cache.v.dtype))
        return KVCache(k=new_k, v=new_v)
    return attn_mod.cache_update(cache, k, v, lengths)


def _apply_ffn(cfg: LMConfig, p: dict, x: Array, cache):
    """Returns (y, aux_loss, new_cache)."""
    if cfg.ffn_kind == "moe":
        shared = p.get("shared")
        y, aux = moe_mod.apply_moe(p, x, cfg.moe, act=cfg.act,
                                   shared_mlp=shared)
        return y, aux, cache
    if cfg.ffn_kind == "rwkv_channel":
        y, new_cache = rwkv_mod.apply_rwkv_channel(p, x, cache)
        return y, 0.0, new_cache
    return apply_mlp(p, x, cfg.act), 0.0, cache


def _apply_layer(cfg: LMConfig, kind: str, p: dict, x: Array, *,
                 positions, cache, lengths):
    """cache: {"mixer": ..., "ffn": ...} or None."""
    mixer_cache = None if cache is None else cache["mixer"]
    ffn_cache = None if cache is None else cache.get("ffn")
    h, new_mx = _apply_mixer(cfg, kind, p["mixer"],
                             apply_norm(p["norm1"], x, cfg.norm),
                             positions=positions, cache=mixer_cache,
                             lengths=lengths)
    x = x + h
    h, aux, new_ffn = _apply_ffn(cfg, p["ffn"],
                                 apply_norm(p["norm2"], x, cfg.norm),
                                 ffn_cache)
    x = x + h
    new_cache = None if cache is None else {"mixer": new_mx, "ffn": new_ffn}
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Embedding front
# ---------------------------------------------------------------------------

def embed_inputs(cfg: LMConfig, params: dict, tokens: Array,
                 positions: Array, patch_embeds: Array | None = None) -> Array:
    dt = cfg.compute_dtype
    x = embed_lookup(params["embed"], tokens, dt)
    if cfg.num_patch_tokens and patch_embeds is not None:
        # VLM stub: first P positions carry precomputed patch embeddings.
        p = patch_embeds.shape[1]
        is_patch = (jnp.arange(tokens.shape[1]) < p)[None, :, None]
        pe = jnp.zeros_like(x).at[:, :p].set(patch_embeds.astype(dt))
        x = jnp.where(is_patch, pe, x)
    if cfg.pos_embedding == "learned":
        pos = positions if positions.ndim == 2 else positions[..., 0]
        pe = jnp.take(params["pos_embed"], pos, axis=0).astype(dt)
        x = x + pe
    return x


# ---------------------------------------------------------------------------
# Full forward passes
# ---------------------------------------------------------------------------

def forward_train(cfg: LMConfig, params: dict, tokens: Array,
                  positions: Array, patch_embeds: Array | None = None):
    """(B, S) tokens -> (hidden (B, S, D), aux_loss)."""
    x = embed_inputs(cfg, params, tokens, positions, patch_embeds)
    x = constrain(x, ("batch", "seq", "embed"))
    kinds = cfg.layer_kinds()

    if cfg.scan_layers and cfg.homogeneous():
        kind = kinds[0]

        def body(carry, layer_p):
            x, aux = carry
            y, a, _ = _apply_layer(cfg, kind, layer_p, x,
                                   positions=positions, cache=None,
                                   lengths=None)
            y = constrain(y, ("batch", "seq", "embed"))
            return (y, aux + a), None

        body = _remat(cfg, body)
        (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    else:
        aux = 0.0
        for kind, lp in zip(kinds, params["layers"], strict=True):
            fn = _remat(cfg, functools.partial(_apply_layer, cfg, kind))
            x, a, _ = fn(lp, x, positions=positions, cache=None, lengths=None)
            x = constrain(x, ("batch", "seq", "embed"))
            aux = aux + a
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def logits_fn(cfg: LMConfig, params: dict, hidden: Array) -> Array:
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed_logits(hidden, table)
    return logits[..., : cfg.vocab_size]   # strip vocab padding (sampling)


# -- caches ------------------------------------------------------------------

def _one_layer_cache(cfg: LMConfig, kind: str, batch: int, s_max: int):
    dt = cfg.compute_dtype
    if kind == "attn":
        mx = KVCache.zeros(batch, s_max, cfg.num_kv_heads, cfg.head_dim, dt)
    elif kind == "local_attn":
        size = min(s_max, cfg.window)
        mx = KVCache.zeros(batch, size, cfg.num_kv_heads, cfg.head_dim, dt)
    elif kind == "rglru":
        mx = rglru_mod.rglru_state_zeros(batch, cfg.lru_width,
                                         cfg.conv_width, dt)
    elif kind == "rwkv":
        st = rwkv_mod.rwkv_state_zeros(batch, cfg.d_model,
                                       cfg.rwkv_head_dim, dt)
        return {"mixer": st["time"], "ffn": st["channel"]}
    return {"mixer": mx,
            "ffn": {"shift": jnp.zeros((batch, cfg.d_model), dt)}
            if cfg.ffn_kind == "rwkv_channel" else None}


def _scan_serving(cfg: LMConfig) -> bool:
    """Homogeneous scanned stacks also scan prefill/decode (stacked caches);
    a python layer loop at 80 layers x chunked attention explodes compile
    time (observed: qwen2-vl prefill_32k > 10 min unrolled)."""
    return cfg.scan_layers and cfg.homogeneous()


def init_cache(cfg: LMConfig, batch: int, s_max: int):
    """Decode caches: stacked (L, ...) pytree for scanned homogeneous
    stacks, else a per-layer list."""
    kinds = cfg.layer_kinds()
    if _scan_serving(cfg):
        one = _one_layer_cache(cfg, kinds[0], batch, s_max)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
            one)
    return [_one_layer_cache(cfg, k, batch, s_max) for k in kinds]


def _one_layer_cache_axes(cfg: LMConfig, kind: str):
    if kind in ("attn", "local_attn"):
        mx = KVCache.axes()
    elif kind == "rglru":
        mx = rglru_mod.rglru_state_axes()
    elif kind == "rwkv":
        st = rwkv_mod.rwkv_state_axes()
        return {"mixer": st["time"], "ffn": st["channel"]}
    return {"mixer": mx,
            "ffn": {"shift": ("batch", "embed")}
            if cfg.ffn_kind == "rwkv_channel" else None}


def cache_axes(cfg: LMConfig):
    """Logical-axis pytree matching init_cache (for sharding resolution)."""
    kinds = cfg.layer_kinds()
    if _scan_serving(cfg):
        one = _one_layer_cache_axes(cfg, kinds[0])

        def is_axes(x):
            return isinstance(x, tuple) and all(
                isinstance(a, (str, type(None))) for a in x)

        return jax.tree.map(lambda ax: ("layers",) + ax, one,
                            is_leaf=is_axes)
    return [_one_layer_cache_axes(cfg, k) for k in kinds]


def _remat(cfg: LMConfig, fn):
    """Wrap a layer body per the config's remat policy.

    "nothing": recompute everything in backward (min memory, +2·fwd FLOPs
    of recompute); "dots": keep matmul outputs (no recompute of the
    MXU-bound work — the §Perf compute-term lever, at activation-memory
    cost).
    """
    if not cfg.remat:
        return fn
    policy = {
        "nothing": None,
        "dots": jax.checkpoint_policies.dots_saveable,
        "dots_no_batch": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]
    return jax.checkpoint(fn, policy=policy)


def _layer_params(cfg: LMConfig, params: dict, i: int):
    if cfg.scan_layers and cfg.homogeneous():
        return jax.tree.map(lambda a: a[i], params["layers"])
    return params["layers"][i]


def prefill(cfg: LMConfig, params: dict, tokens: Array, positions: Array,
            caches, lengths: Array, patch_embeds: Array | None = None):
    """Teacher-forced forward that also populates the caches.

    Returns (hidden (B, S, D), new_caches).  ``lengths``: (B,) number of
    valid cache entries BEFORE this call (0 for a fresh prefill).  The full
    hidden sequence is returned so the serving engine can sample at each
    slot's true last-prompt position (right-padded batched prefill).
    """
    x = embed_inputs(cfg, params, tokens, positions, patch_embeds)
    x = constrain(x, ("batch", "seq", "embed"))
    kinds = cfg.layer_kinds()
    if _scan_serving(cfg):
        def body(x, layer):
            lp, cache_l = layer
            y, _, nc = _apply_layer(cfg, kinds[0], lp, x,
                                    positions=positions, cache=cache_l,
                                    lengths=lengths)
            y = constrain(y, ("batch", "seq", "embed"))
            return y, nc
        x, new_caches = jax.lax.scan(_remat(cfg, body), x,
                                     (params["layers"], caches))
    else:
        new_caches = []
        for i, kind in enumerate(kinds):
            lp = _layer_params(cfg, params, i)
            x, _, nc = _apply_layer(cfg, kind, lp, x, positions=positions,
                                    cache=caches[i], lengths=lengths)
            x = constrain(x, ("batch", "seq", "embed"))
            new_caches.append(nc)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, new_caches


def decode_step(cfg: LMConfig, params: dict, token: Array, positions: Array,
                caches, lengths: Array):
    """One decode step.  token (B, 1); lengths (B,) = cache fill before step.

    Returns (logits (B, V), hidden (B, D), new_caches) — the hidden state
    feeds the kNN-LM datastore lookup (serve/knnlm.py).
    """
    x = embed_inputs(cfg, params, token, positions)
    x = constrain(x, ("batch", "seq", "embed"))
    kinds = cfg.layer_kinds()
    if _scan_serving(cfg):
        def body(x, layer):
            lp, cache_l = layer
            y, _, nc = _apply_layer(cfg, kinds[0], lp, x,
                                    positions=positions, cache=cache_l,
                                    lengths=lengths)
            return y, nc
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        new_caches = []
        for i, kind in enumerate(kinds):
            lp = _layer_params(cfg, params, i)
            x, _, nc = _apply_layer(cfg, kind, lp, x, positions=positions,
                                    cache=caches[i], lengths=lengths)
            x = constrain(x, ("batch", "seq", "embed"))
            new_caches.append(nc)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    hidden = x[:, 0]
    return logits_fn(cfg, params, hidden), hidden, new_caches


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def init_params(cfg: LMConfig, key: Array):
    return init_tree(key, param_specs(cfg))


def param_structs(cfg: LMConfig):
    return struct_tree(param_specs(cfg))


def param_axes(cfg: LMConfig):
    return axes_tree(param_specs(cfg))


def count_params(cfg: LMConfig) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(param_specs(cfg),
                                        is_leaf=lambda x: isinstance(x, Spec)))


def active_params(cfg: LMConfig) -> int:
    """Parameters touched per token (MoE: top-k experts only) — for 6·N·D."""
    total = count_params(cfg)
    if cfg.ffn_kind != "moe":
        return total
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    per_expert = cfg.d_model * cfg.moe_d_ff * 3
    inactive = cfg.num_layers * (e - k) * per_expert
    return total - inactive
