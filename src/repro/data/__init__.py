# Deterministic, step-addressable synthetic data pipelines (tokens for LM
# training; correlated vectors for the paper's kNN workload).
