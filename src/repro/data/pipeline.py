"""Deterministic synthetic data: LM token batches + the paper's vector sets.

Determinism contract (what makes checkpoint-restart bit-exact):

* every batch is a pure function of ``(seed, step)`` — nothing is consumed
  from a stateful iterator, so skipping to step k after a restore replays
  the identical stream (tested in tests/test_checkpoint.py);
* sharding: the batch is built shard-by-shard with
  ``jax.make_array_from_callback``; each data shard derives its slice from
  global indices, so the same (seed, step) produces the same GLOBAL batch
  on any mesh shape — elastic restarts keep the stream stable.

Vector datasets reproduce the *statistical shape* of the paper's six
benchmarks (Table 4) — correlated Gaussian mixtures so PCCP has structure
to find; real downloads are unavailable offline (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bregman import get_family


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so the LM has something to learn
    num_patterns: int = 512
    pattern_len: int = 16


def _batch_np(cfg: TokenStreamConfig, step: int, rows: np.ndarray):
    """Generate the given global row indices of batch ``step`` (pure)."""
    out_tok = np.empty((len(rows), cfg.seq_len + 1), np.int32)
    pat_rng = np.random.default_rng(cfg.seed ^ 0x5EED)
    patterns = pat_rng.integers(
        0, cfg.vocab_size, (cfg.num_patterns, cfg.pattern_len))
    for i, r in enumerate(rows):
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 131_071 + int(r))
        seq = []
        while len(seq) < cfg.seq_len + 1:
            pid = int(rng.integers(cfg.num_patterns))
            seq.extend(patterns[pid])
            if rng.random() < 0.1:  # noise token breaks pure copying
                seq.append(int(rng.integers(cfg.vocab_size)))
        out_tok[i] = seq[: cfg.seq_len + 1]
    return out_tok


def token_batch(cfg: TokenStreamConfig, step: int, mesh: Mesh | None = None,
                mrope: bool = False) -> dict:
    """Batch dict {tokens, labels, positions} for ``step`` (global arrays).

    With a mesh, arrays are built shard-wise (batch -> pod/data axes).
    """
    b, s = cfg.global_batch, cfg.seq_len

    def make(shape, gen):
        if mesh is None or np.prod(mesh.devices.shape) == 1:
            return jnp.asarray(gen(np.arange(b)))
        pts = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        sh = NamedSharding(mesh, P(pts if len(pts) > 1 else pts[0]
                                   if pts else None))

        def cb(index):
            rows = np.arange(b)[index[0]]
            return gen(rows)

        return jax.make_array_from_callback(shape, sh, cb)

    toks = make((b, s + 1), lambda rows: _batch_np(cfg, step, rows))
    pos = np.arange(s, dtype=np.int32)[None, :].repeat(b, 0)
    if mrope:
        pos = np.repeat(pos[..., None], 3, axis=-1)
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
        "positions": jnp.asarray(pos),
    }


# ---------------------------------------------------------------------------
# Paper vector datasets (Table 4 stand-ins)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VectorDatasetSpec:
    name: str
    n: int
    d: int
    measure: str          # bregman family alias
    paper_m: int          # the paper's reported partition count


PAPER_DATASETS = {
    "audio": VectorDatasetSpec("audio", 54_387, 192, "ed", 28),
    "fonts": VectorDatasetSpec("fonts", 745_000, 400, "isd", 50),
    "deep": VectorDatasetSpec("deep", 1_000_000, 256, "ed", 37),
    "sift": VectorDatasetSpec("sift", 11_164_866, 128, "ed", 22),
    "normal": VectorDatasetSpec("normal", 50_000, 200, "ed", 25),
    "uniform": VectorDatasetSpec("uniform", 50_000, 200, "isd", 21),
}


def make_vectors(spec: VectorDatasetSpec, scale: float = 1.0,
                 seed: int = 0) -> np.ndarray:
    """Correlated mixture with the dataset's (n, d) scaled by ``scale``.

    Structure matches the paper's real datasets, not a centered Gaussian:
    SIFT/Audio/Deep/Fonts features are NON-NEGATIVE (histograms / spectral
    energies) with strongly heterogeneous magnitudes across clusters.
    That heterogeneity is what the Cauchy ball bounds discriminate on —
    centered equal-norm blobs are the bound's degenerate worst case (all
    points at the same radius).  k Gaussian blobs with low-rank covariance
    (inter-dim correlations for PCCP), folded positive, with per-cluster
    energy scales spanning ~6x.
    """
    n = max(int(spec.n * scale), 64)
    d = spec.d
    rng = np.random.default_rng(seed + hash(spec.name) % (1 << 30))
    if spec.name == "uniform":
        data = rng.uniform(0.0, 100.0, (n, d))
    elif spec.name == "normal":
        data = rng.normal(size=(n, d))
    else:
        k = 16
        rank = max(d // 8, 4)
        centers = np.abs(rng.normal(size=(k, d))) * 2.0
        # per-cluster x per-dim energy pattern: heterogeneity must show up
        # INSIDE every subspace for the per-subspace bounds to discriminate
        scales = (rng.uniform(0.5, 3.0, size=(k, 1))
                  * np.exp(0.5 * rng.normal(size=(k, d))))
        mix = rng.integers(0, k, n)
        factors = rng.normal(size=(k, d, rank)) / np.sqrt(rank)
        z = rng.normal(size=(n, rank))
        data = centers[mix] + np.einsum("nr,ndr->nd", z, factors[mix]) \
            + 0.1 * rng.normal(size=(n, d))
        data = np.abs(data) * scales[mix]
    fam = get_family(spec.measure)
    if fam.name in ("itakura_saito", "burg", "shannon"):
        data = np.abs(data) + 0.1
    if fam.name == "exponential":
        # keep e^x terms in a numerically sane band: the tuple-split form
        # fx - x.grad + c_y cancels catastrophically in f32 beyond |x|~6
        data = 5.0 * data / max(np.percentile(data, 99.5), 1e-9)
    return data.astype(np.float32)


def make_queries(spec: VectorDatasetSpec, num: int = 50, scale: float = 1.0,
                 data_seed: int = 0, seed: int = 1) -> np.ndarray:
    """The paper's protocol: 50 points randomly drawn from the dataset."""
    data = make_vectors(spec, scale=scale, seed=data_seed)
    rng = np.random.default_rng(seed)
    idx = rng.choice(data.shape[0], size=min(num, data.shape[0]),
                     replace=False)
    return data[idx]
