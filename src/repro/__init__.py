"""repro — BrePartition reproduction (core search, kernels, serving, dist).

Importing any ``repro.*`` module pulls this in first, which installs the
jax forward-compat aliases (``jax.shard_map`` / ``jax.sharding.AxisType``
/ ``jax.make_mesh(axis_types=...)``) that the model and launch layers use
unconditionally — see :mod:`repro.dist.compat`.
"""

from . import dist as _dist  # noqa: F401 — side effect: compat install
