"""Forward-compat aliases so the dist layer runs on old and new jax.

The repo (and its tests) are written against the modern public API:
``jax.shard_map(..., check_vma=...)``, ``jax.sharding.AxisType`` and
``jax.make_mesh(..., axis_types=...)``.  The container's pinned jax
predates all three; each has a 1:1 older spelling:

* ``jax.shard_map``            -> ``jax.experimental.shard_map.shard_map``
  (``check_vma`` was called ``check_rep``),
* ``jax.sharding.AxisType``    -> absent; every mesh axis behaved as the
  modern ``Auto`` type, so a placeholder enum is semantically exact,
* ``jax.make_mesh(axis_types)``-> absent; dropping the kwarg is safe for
  the same reason (this repo only ever passes ``Auto``).

:func:`install` patches the missing names into the jax namespace ONCE,
never overwriting an attribute that exists — on a modern jax it is a
no-op.  It runs from ``repro/__init__`` so any ``repro.*`` import makes
the modern spellings available before model/test code uses them.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax


class AxisType(enum.Enum):
    """Placeholder for jax.sharding.AxisType on old jax (all axes Auto)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=True,
                      **kwargs):
    from jax.experimental.shard_map import shard_map as _smap
    kwargs.pop("axis_names", None)  # modern-only arg, default covers us
    return _smap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                 check_rep=check_vma, **kwargs)


def install() -> None:
    """Idempotently add modern jax spellings missing from an old install."""
    if not hasattr(jax, "shard_map"):
        _shard_map_compat._repro_compat = True
        jax.shard_map = _shard_map_compat

    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = AxisType

    _install_cost_analysis_unwrap()

    try:
        has_axis_types = "axis_types" in inspect.signature(
            jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover — exotic builds
        has_axis_types = True
    if not has_axis_types:
        orig = jax.make_mesh

        @functools.wraps(orig)
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types  # pre-AxisType jax: every axis is Auto already
            return orig(axis_shapes, axis_names, devices=devices)

        make_mesh._repro_compat = True
        jax.make_mesh = make_mesh


def _install_cost_analysis_unwrap() -> None:
    """Old jax returns ``[dict]`` from ``Compiled.cost_analysis``; modern
    jax returns the dict itself.  Unwrap the 1-element list so callers
    (launch/dryrun.py, tests) can index by metric name on either."""
    compiled_cls = getattr(jax.stages, "Compiled", None)
    orig = getattr(compiled_cls, "cost_analysis", None)
    if compiled_cls is None or orig is None or getattr(
            orig, "_repro_compat", False):
        return

    @functools.wraps(orig)
    def cost_analysis(self):
        out = orig(self)
        if isinstance(out, list) and len(out) == 1:
            return out[0]
        return out

    cost_analysis._repro_compat = True
    compiled_cls.cost_analysis = cost_analysis
