"""Sharded BrePartition search: the fused pipeline as one SPMD program.

The partition-filter-refinement framework decomposes over disjoint point
blocks: subspace UB totals are per-point (one row of the filter matmul),
the Theorem-3 corner test is per-point, and exact refinement distances are
per-point.  So a ``BallForest`` split point-major across a ``data`` mesh
axis runs the entire fused pipeline of ``core/search.py`` *locally* per
shard, and only two tiny collectives touch the wire per query block:

1. **Bound exchange** — each shard's local k smallest UB totals (plus the
   corresponding P-tuples) are all-gathered (``p * k`` scalars + tuples
   per query) and merged, so every shard prunes against the GLOBAL Alg.-4
   bound ``qb``, not a loose local one.  Using a subset's k-th UB would
   still be *correct* (it is an upper bound on the global k-th), but the
   global bound keeps per-shard candidate unions small.
2. **Top-k merge** — each shard refines its own candidates exactly and the
   per-shard (q, k) results are merged with one k-way all-gather + top-k.

Exactness survives sharding for the same reason (decomposability): each
shard's local top-k is exact over its points whenever its union fits its
budget, and the merge of exact local top-ks is the exact global top-k.
``exact`` is the AND over shards; the host wrapper retries overflowing
blocks with a grown budget exactly like ``knn_batch``, topping out at the
per-shard point count (where the union always fits), so the flag is
truthful without any brute-force escape hatch.

The per-shard phases are the REUSED batched-pipeline helpers
(``_batch_filter_topk`` / ``_stream_prune_compact`` / ``_refine_batch``) —
one implementation of the math, two launch shapes.  The prune+compact is
the same streaming scan as the single-host path: per-shard peak memory is
O(block_rows * q + q * budget), never O(local_n * q), and the block-level
corner-envelope gate skips dead (block, query) tiles per shard.  The
envelope tables (``env_alpha_min``/``env_sqrt_gamma_max``) are GLOBAL and
replicated (they ride ``REPLICATED_FIELDS``); each shard addresses its
own slice with ``axis_index * local_n``, so envelope rows straddling a
shard boundary are simply read by both neighbors — an envelope over a
superset of rows is still a dominator, so the skip stays loss-free at any
alignment.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import bounds
from repro.core.bregman import get_family
from repro.core.calibrate import resolve_p_guarantee
from repro.core.index import (BallForest, REPLICATED_FIELDS, pad_points,
                              point_fields, refresh_envelopes)
from repro.core.quantize import ub_slack
from repro.core.search import (MAX_BUDGET_DOUBLINGS,
                               SearchResult, _batch_filter_topk,
                               _cdf_shrink, _refine_batch,
                               _stream_prune_compact, _tuple_rows,
                               fitted_budget_for_n, resolve_block_rows,
                               resolve_budget, validate_p_guarantee)
from repro.core.transform import Partition, q_transform_views
from . import sharding as shd

Array = jax.Array

_QS_FIELDS = ("qconst", "sqrt_delta", "grad", "c_y")


class LaunchTimeout(TimeoutError):
    """A distributed launch blocked past its ``launch_timeout_s``.

    Raised AFTER the launch completes (an in-flight XLA program cannot be
    preempted), so the timeout is cooperative: it bounds how long a slow
    shard can silently inflate tail latency before the caller learns about
    it.  serve/retrieval.py treats it as a circuit-breaker failure and
    degrades the tenant rather than retrying blindly.  The completed
    result rides on the exception (:attr:`result`, :attr:`elapsed_s`) so
    callers that still meet their deadline may choose to use it.
    """

    def __init__(self, msg: str, result=None, elapsed_s: float = 0.0):
        super().__init__(msg)
        self.result = result
        self.elapsed_s = elapsed_s


class QueryView(NamedTuple):
    """A query block plus its pre-gathered per-subspace view.

    The O(q*d) gather is query preprocessing — done once on the host by
    :func:`query_subview` — while ``y`` (original dim order) feeds the
    refine constants.  Both are replicated to every shard.
    """

    y: Array        # (q, d) original dim order
    sub: Array      # (q, M, w) subspace view (partition.gather(y))


def query_subview(partition: Partition, ys: Array) -> QueryView:
    """Pre-gather a (q, d) query block's subspace view for the shards."""
    ys = jnp.asarray(ys, jnp.float32)
    if ys.ndim != 2:
        raise ValueError(f"expected (q, d) queries, got {ys.shape}")
    return QueryView(y=ys, sub=partition.gather(ys))


@dataclasses.dataclass(frozen=True)
class ShardedForest:
    """A BallForest laid out point-major across one mesh axis.

    ``forest`` is the padded index with point-major arrays device_put over
    ``mesh[axis]`` and the per-cluster/sample arrays replicated; ``global_n``
    is the real (pre-padding) point count and ``live_n`` the count of
    non-tombstoned points (== ``global_n`` unless the shard came from a
    mutable SegmentedForest with deletions).
    """

    forest: BallForest
    mesh: Mesh
    axis: str
    global_n: int
    live_n: int | None = None

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def local_n(self) -> int:
        return self.forest.n // self.num_shards

    @property
    def global_live_n(self) -> int:
        return self.global_n if self.live_n is None else self.live_n


def shard_index(forest, mesh: Mesh, axis: str = "data") -> ShardedForest:
    """Split an index point-major across ``mesh[axis]``.

    ``forest`` is a BallForest or a mutable SegmentedForest
    (core/segments.py) — the latter is snapshotted to its one-BallForest
    view, so each shard's slice carries its share of the append segments
    and tombstones and the per-shard fused pipeline needs no new code.
    Points are padded to a multiple of the axis size with search-inert
    rows (core/index.pad_points), then every point-major array is
    device_put with spec ``P(axis)`` and everything else replicated.

    A mutating index does NOT auto-reshard: re-call after insert/delete
    (the snapshot is immutable, exactly like a filesystem LSM level).
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no {axis!r} axis")
    live_n = getattr(forest, "live_n", None)
    view = getattr(forest, "view", None)
    if callable(view):
        forest = view()
    if forest.env_alpha_min is None:
        # Hand-assembled forest without envelope tables: derive them here
        # so every shard program can rely on the replicated global tables.
        forest = refresh_envelopes(forest)
    padded = pad_points(forest, int(mesh.shape[axis]))

    def put(a, spec):
        return jax.device_put(a, NamedSharding(mesh, spec))

    placed = dataclasses.replace(
        padded,
        **{f: put(getattr(padded, f), P(axis)) for f in point_fields(padded)},
        **{f: put(getattr(padded, f), P()) for f in REPLICATED_FIELDS
           if getattr(padded, f) is not None})
    return ShardedForest(forest=placed, mesh=mesh, axis=axis,
                         global_n=forest.n, live_n=live_n)


@functools.lru_cache(maxsize=128)
def _dist_knn_program(mesh: Mesh, axis: str, family_name: str,
                      partition: Partition, num_clusters: int, storage: str,
                      k: int, budget: int, block_rows: int, approx: bool):
    """One jitted SPMD program per (mesh x index-static x k/budget) cell."""
    fam = get_family(family_name)

    def per_shard(arrs: dict, qs: dict, p_guarantee):
        # arrs carries exactly the dynamic BallForest fields; the statics
        # come from the program cell, so this IS the local shard's index.
        local = BallForest(family_name, partition, num_clusters,
                           storage=storage, **arrs)
        # ---- local filter + GLOBAL Alg.-4 bound via the k-way exchange ----
        vals, idx = _batch_filter_topk(local, qs, k, block_rows)
        tup = _tuple_rows(local, idx)                   # decoded in int8 tier
        a_k, g_k = tup["alpha"], tup["sqrt_gamma"]      # (q, k, M)
        vals_g = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        a_g = jax.lax.all_gather(a_k, axis, axis=1, tiled=True)
        g_g = jax.lax.all_gather(g_k, axis, axis=1, tiled=True)
        if storage == "int8":
            # Ship each local top-k row's stat scales with its tuple: the
            # global bound must carry the rounding slack of whichever
            # shard's rows set the global k-th UB (docs/quantization.md).
            sa_g = jax.lax.all_gather(
                jnp.take(local.alpha_scale, idx), axis, axis=1, tiled=True)
            sg_g = jax.lax.all_gather(
                jnp.take(local.sg_scale, idx), axis, axis=1, tiled=True)
        neg, sel = jax.lax.top_k(-vals_g, k)            # global k smallest
        kth = sel[:, -1:, None]                         # (q, 1, 1)
        m = a_g.shape[-1]

        def take_kth(t):
            return jnp.take_along_axis(
                t, jnp.broadcast_to(kth, kth.shape[:1] + (1, m)), axis=1)[:, 0]
        kth_tuple = {"alpha": take_kth(a_g), "sqrt_gamma": take_kth(g_g)}
        qb = bounds.ub_components(kth_tuple, qs)        # (q, M)
        if storage == "int8":
            a_s = jnp.max(jnp.take_along_axis(sa_g, sel, axis=1), axis=-1)
            g_s = jnp.max(jnp.take_along_axis(sg_g, sel, axis=1), axis=-1)
            qb = qb + ub_slack(a_s, g_s, qs["sqrt_delta"])
        if approx:                                      # §8 shrink, batched
            sqrt_term = kth_tuple["sqrt_gamma"] * qs["sqrt_delta"]
            kappa_i = qb - sqrt_term
            c = _cdf_shrink(local.beta_samples, jnp.sum(sqrt_term, -1),
                            jnp.sum(kappa_i, -1), p_guarantee)
            qb = kappa_i + c[:, None] * sqrt_term

        # ---- local streaming prune + compact + refine (reused phases) ----
        # The replicated envelope tables are GLOBAL; this shard's rows
        # start at axis_index * local_n of the padded global layout.
        offset = jax.lax.axis_index(axis).astype(jnp.int32) * local.n
        sel_c, valid, ncand, _, _, _ = _stream_prune_compact(
            local, qs, qb, budget, block_rows, row_offset=offset)
        ids, dists = _refine_batch(local, qs, sel_c, valid, k)

        # ---- k-way merge + exactness/union-size reductions ----
        ids_g = jax.lax.all_gather(ids, axis, axis=1, tiled=True)
        d_g = jax.lax.all_gather(dists, axis, axis=1, tiled=True)
        negd, pos = jax.lax.top_k(-d_g, k)
        overflowed = jax.lax.psum((ncand > budget).astype(jnp.int32), axis)
        return (jnp.take_along_axis(ids_g, pos, axis=1), -negd,
                overflowed == 0, jax.lax.psum(ncand, axis),
                jax.lax.pmax(ncand, axis))

    arr_specs = {**{f: P(axis) for f in point_fields(storage)},
                 **{f: P() for f in REPLICATED_FIELDS}}
    qs_specs = {f: P() for f in _QS_FIELDS}
    in_specs = (arr_specs, qs_specs, P()) if approx else (arr_specs, qs_specs)
    body = shd.shard_map(
        per_shard if approx else (lambda arrs, qs: per_shard(arrs, qs, None)),
        mesh=mesh, in_specs=in_specs, out_specs=P(), check=False)

    def program(arrs, y, sub, *p_guarantee):
        q = q_transform_views(sub, partition.subspace_mask(), fam)
        q.update(bounds.query_refine_constants(y, fam))
        qs = {f: q[f] for f in _QS_FIELDS}
        return body(arrs, qs, *p_guarantee)

    return jax.jit(program)


def distributed_knn(sharded: ShardedForest, queries, *, family: str, k: int,
                    budget: int, mesh: Mesh | None = None,
                    approx_p: float | None = None,
                    target_recall: float | None = None,
                    block_rows: int | None = None,
                    max_doublings: int = MAX_BUDGET_DOUBLINGS,
                    launch_timeout_s: float | None = None,
                    launch_hook=None, stop_retry=None,
                    clock=time.monotonic) -> SearchResult:
    """Batched kNN over a sharded index — the distributed ``knn_batch``.

    ``queries`` is a (q, d) block or a prebuilt :class:`QueryView`;
    ``budget`` is the PER-SHARD refine budget (clamped to the shard size);
    ``block_rows`` tunes the per-shard streaming scans exactly like the
    single-host pipeline (``core.search.resolve_block_rows``).
    Returns the usual ``(ids, dists, exact, num_candidates)`` with
    ``num_candidates`` the global Theorem-3 union size per query.  On
    overflow the whole block retries with a budget fitted to the largest
    per-shard union (same power-of-two rule as the single-host wrapper);
    the loop ends at ``budget == local_n`` where the union always fits, so
    exact mode stays exact and ``exact`` is always truthful.

    **Robustness wiring** (serve/retrieval.py): every retry is its own
    blocking LAUNCH.  ``launch_hook(elapsed_s)`` observes each launch's
    wall time (feeding the service's cost model); ``launch_timeout_s``
    raises :class:`LaunchTimeout` — carrying the completed result — when
    a launch blocks longer than that (a cooperative, post-hoc timeout: a
    running XLA program cannot be preempted, so this bounds DETECTION
    latency, not the launch itself).  ``stop_retry`` (no-arg -> bool) is
    consulted before each ADDITIONAL launch, exactly like
    ``core.search.knn_batch``: True returns the budget-capped partial
    result (overflowed queries keep ``exact=False``) instead of retrying
    past a deadline.  ``clock`` is injectable for deterministic tests.

    ``target_recall`` (mutually exclusive with ``approx_p``) runs the
    approximate mode at a CALIBRATED shrink: the fitted recall curve
    (carried on the sharded forest — it rides shard_index's
    ``dataclasses.replace``) is inverted ON THE HOST before the launch,
    so the SPMD program sees only the resolved ``p_guarantee`` scalar and
    stays bit-identical to the single-host calibrated path.
    """
    mesh = mesh or sharded.mesh
    forest = sharded.forest
    if target_recall is not None:
        if approx_p is not None:
            raise ValueError("pass at most one of approx_p / target_recall")
        approx_p, _ = resolve_p_guarantee(forest, target_recall)
    validate_p_guarantee(approx_p)
    if family != forest.family_name:
        raise ValueError(
            f"family {family!r} does not match index {forest.family_name!r}")
    if k > sharded.global_live_n:
        raise ValueError(
            f"k={k} exceeds live index size n={sharded.global_live_n}")
    qv = (queries if isinstance(queries, QueryView)
          else query_subview(forest.partition, queries))
    local_n = sharded.local_n
    block_rows = resolve_block_rows(block_rows, sharded.global_live_n,
                                    q=qv.y.shape[0],
                                    storage=forest.storage)
    # Per-shard budget: the global knob resolved against the LOCAL row
    # count (each shard refines its own candidate slots).
    b = resolve_budget(budget, local_n, k)
    arrs = {f: getattr(forest, f)
            for f in point_fields(forest) + REPLICATED_FIELDS}
    extra = () if approx_p is None else (jnp.float32(approx_p),)

    for attempt in range(max_doublings + 1):
        prog = _dist_knn_program(mesh, sharded.axis, forest.family_name,
                                 forest.partition, forest.num_clusters,
                                 forest.storage, k, b,
                                 block_rows, approx_p is not None)
        t0 = clock()
        out = jax.block_until_ready(prog(arrs, qv.y, qv.sub, *extra))
        elapsed = clock() - t0
        if launch_hook is not None:
            launch_hook(elapsed)
        ids, dists, exact, ncand, need = out
        res = SearchResult(ids=ids, dists=dists, exact=exact,
                           num_candidates=ncand)
        if launch_timeout_s is not None and elapsed > launch_timeout_s:
            raise LaunchTimeout(
                f"distributed_knn launch (budget={b}, attempt={attempt}) "
                f"blocked {elapsed:.3f}s > launch_timeout_s="
                f"{launch_timeout_s:.3f}s", result=res, elapsed_s=elapsed)
        if bool(jnp.all(exact)) or b >= local_n or attempt == max_doublings:
            break
        if stop_retry is not None and stop_retry():
            break
        b = fitted_budget_for_n(local_n, k, int(jnp.max(need)))
    return res
