"""repro.dist — sharding rules and the distributed execution substrates.

Submodules:

* :mod:`repro.dist.sharding` — logical-axis -> mesh-axis resolution
  (``spec_for_shape``), the ``constrain`` activation anchor, and mesh
  construction.
* :mod:`repro.dist.knn` — the sharded BrePartition search
  (``shard_index`` / ``distributed_knn``).
* :mod:`repro.dist.collective_matmul` — ring all-gather / reduce-scatter
  matmuls.
* :mod:`repro.dist.compression` — int8 gradient compression with error
  feedback.
* :mod:`repro.dist.pipeline` — microbatch pipeline-parallel schedule.

Importing the package installs the jax forward-compat aliases (see
:mod:`repro.dist.compat`) so all of the above use one API spelling on
old and new jax alike.
"""

from . import compat as _compat

_compat.install()
