"""Ring collective matmuls (latency-hiding all-gather / reduce-scatter).

These are the shard_map-level building blocks for tensor-parallel layers:
instead of materializing a full all-gather (or all-reduce) and THEN doing
the matmul, the ring forms overlap one chunk's transfer with the previous
chunk's matmul — on TPU the ICI transfer hides entirely behind the MXU.

All functions are written to run INSIDE ``shard_map`` over one named mesh
axis; operands are the per-device shards.

* :func:`ag_matmul`      — x row-sharded over ``axis``, w replicated ->
  full ``all_gather(x) @ w``, value-replicated on every device.
* :func:`ag_matmul_reference` — same contract via a plain ``all_gather``
  (the oracle the ring is checked against).
* :func:`matmul_rs`      — x col-sharded / w row-sharded over ``axis``
  (a contraction-split matmul) -> partial products reduce-scattered so
  each device ends with its row block of the true product.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ag_matmul(x_local: Array, w: Array, axis: str) -> Array:
    """Ring all-gather matmul: returns the FULL ``gather(x) @ w`` per device.

    Each of the ``p`` steps multiplies the currently-held row chunk on the
    MXU while (conceptually) the next chunk is in flight on the ring; the
    output is value-replicated because every chunk visits every device.
    """
    p = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    rows = x_local.shape[0]
    out = jnp.zeros((p * rows, w.shape[1]),
                    jnp.promote_types(x_local.dtype, w.dtype))
    # receive from the next device: after i hops we hold chunk (idx + i)
    perm = [(j, (j - 1) % p) for j in range(p)]
    chunk = x_local
    for i in range(p):
        src = (idx + i) % p
        out = jax.lax.dynamic_update_slice_in_dim(
            out, chunk @ w, src * rows, axis=0)
        if i < p - 1:
            chunk = jax.lax.ppermute(chunk, axis, perm)
    return out


def ag_matmul_reference(x_local: Array, w: Array, axis: str) -> Array:
    """Oracle for :func:`ag_matmul`: one bulk all-gather, then the matmul."""
    return jax.lax.all_gather(x_local, axis, axis=0, tiled=True) @ w


def matmul_rs(x_local: Array, w_local: Array, axis: str) -> Array:
    """Ring reduce-scatter matmul for contraction-split operands.

    ``x_local (m, k/p)`` and ``w_local (k/p, n)`` hold matching slices of
    the contraction dim, so ``x_local @ w_local`` is a full-shape partial
    product; the ring accumulates partials so device ``i`` ends with rows
    ``[i*m/p, (i+1)*m/p)`` of the true ``x @ w`` (out spec ``P(axis, None)``).
    """
    p = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    partial = x_local @ w_local                     # (m, n) partial sum
    m = partial.shape[0]
    if m % p:
        raise ValueError(f"rows {m} not divisible by axis size {p}")
    rows = m // p

    def take(c):
        return jax.lax.dynamic_slice_in_dim(
            partial, (c % p) * rows, rows, axis=0)

    perm = [(j, (j + 1) % p) for j in range(p)]
    # start with the chunk that is farthest (p-1 hops) from its home device
    acc = take(idx - 1)
    for s in range(p - 1):
        acc = jax.lax.ppermute(acc, axis, perm)
        acc = acc + take(idx - 2 - s)
    return acc
