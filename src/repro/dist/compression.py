"""int8 gradient compression with error feedback (EF-SGD style).

Data-parallel all-reduces move f32 gradients; compressing the wire format
to int8 (code + one f32 scale per tensor per device) cuts DCN/ICI bytes
4x.  Plain quantization biases the update, so every shard keeps a
**residual**: the quantization error of step ``t`` is added back into the
gradient of step ``t+1`` (error feedback), making the *accumulated*
applied update track the true mean — the standard convergence argument
for compressed SGD.

All functions run INSIDE ``shard_map``; tensors are per-device shards and
``axis`` is the data-parallel mesh axis.  The int8 code + scale pair is
exactly what a wire implementation would ship; here the dequantized f32
value enters the ``pmean`` (the arithmetic is identical to summing scaled
int8 codes with per-device scales).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class EFState(NamedTuple):
    """Per-shard error-feedback residuals, one leaf per gradient leaf."""

    residual: Any


def init_ef_state(grads: Any) -> EFState:
    """Zero residuals shaped like one shard's gradient tree."""
    return EFState(residual=jax.tree.map(jnp.zeros_like, grads))


def _quantize_int8(x: Array) -> tuple[Array, Array]:
    """Symmetric per-tensor int8: (code, scale) with x ~= code * scale."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-30)              # all-zero tensor guard
    code = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return code, scale


def compressed_psum_mean(g: Array, axis: str,
                         residual: Array) -> tuple[Array, Array]:
    """Mean of ``g`` over ``axis`` through an int8 wire, with error feedback.

    Returns ``(mean_estimate, new_residual)``: the estimate is replicated
    in value across ``axis`` (it is a pmean); the residual is this shard's
    quantization error, to be fed back on the next call.
    """
    x = g + residual
    code, scale = _quantize_int8(x)
    deq = code.astype(jnp.float32) * scale
    new_residual = x - deq
    mean = jax.lax.pmean(deq, axis)
    return mean, new_residual


def compressed_grad_allreduce(grads: Any, axis: str,
                              ef: EFState) -> tuple[Any, EFState]:
    """Tree-level :func:`compressed_psum_mean` over a gradient pytree."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    means, residuals = [], []
    for g, r in zip(flat_g, flat_r, strict=True):
        m, nr = compressed_psum_mean(g, axis, r)
        means.append(m)
        residuals.append(nr)
    return (jax.tree.unflatten(treedef, means),
            EFState(residual=jax.tree.unflatten(treedef, residuals)))
