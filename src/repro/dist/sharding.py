"""Logical-axis sharding resolution (the repo's one sharding vocabulary).

Model and launch code never name mesh axes directly.  Parameters, batches
and activations carry *logical* axis names (``batch``, ``seq``, ``heads``,
``vocab``, ``fsdp``, ...); a **rules table** maps each logical name to the
mesh axes it may shard over, and :func:`spec_for_shape` resolves a concrete
``PartitionSpec`` for one array shape on one mesh.

Resolution contract (property-tested in tests/test_sharding.py):

* **Claim order is rules-table order.**  Logical names claim mesh axes in
  the order they appear in the rules dict, so ``heads`` takes ``model``
  before ``seq`` can (context-parallel is the *fallback* when the head
  count is indivisible, not the default).
* **Divisibility is mandatory.**  A mesh axis is only taken when the dim
  is divisible by the product of all axes taken so far for that dim;
  otherwise the candidate is skipped (never a ragged shard).
* **Each mesh axis is used at most once** per spec.
* Candidate axes missing from the mesh (``pod`` on a single-pod mesh) are
  skipped silently, so one rules table serves every mesh shape.

:func:`constrain` is the activation anchor: inside an
:func:`activation_rules` context it resolves the logical axes against the
active (mesh, rules) and applies ``with_sharding_constraint``; outside any
context it returns its input unchanged, so pure-library use (single host,
no mesh) pays nothing.
"""

from __future__ import annotations

import contextlib
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat as _compat

_compat.install()

Array = jax.Array

# Logical axis -> candidate mesh axes, in claim-priority order (dict order
# IS the priority).  Zero-candidate entries are documentation: those axes
# stay replicated on purpose (embed = sequence-parallel residual stream,
# head_dim = always small, layers = scan axis).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert_mlp": ("model",),
    "experts": ("model",),
    "state": ("model",),
    "seq": ("model",),          # context-parallel fallback (after heads)
    "embed": (),
    "head_dim": (),
    "layers": (),
}

# Serving: weights shard over `model` only (no fsdp — ZeRO gathers would
# serialize every decode step).
SERVE_RULES: dict[str, tuple[str, ...]] = dict(DEFAULT_RULES, fsdp=())

# Long-context serving: sequence parallelism outranks head parallelism.
CONTEXT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),
    **{k: v for k, v in SERVE_RULES.items() if k not in ("batch", "seq")},
}

# Single-token decode: there is no sequence axis worth sharding.
DECODE_RULES: dict[str, tuple[str, ...]] = dict(SERVE_RULES, seq=())


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices=None) -> Mesh:
    """A mesh with Auto axis types on every jax version."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), devices=devices,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def spec_for_shape(names: Sequence[str | None], shape: Sequence[int],
                   mesh: Mesh, rules: dict | None = None) -> P:
    """Resolve logical axis names for one array shape to a PartitionSpec."""
    rules = DEFAULT_RULES if rules is None else rules
    if len(names) != len(shape):
        raise ValueError(f"axes {names} do not match shape {tuple(shape)}")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))
    rank = {name: i for i, name in enumerate(rules)}
    order = sorted(
        (i for i, nm in enumerate(names) if nm is not None and nm in rules),
        key=lambda i: rank[names[i]])
    used: set[str] = set()
    entries: list[Any] = [None] * len(names)
    for i in order:
        got: list[str] = []
        prod = 1
        for ax in rules[names[i]]:
            if ax not in sizes or ax in used:
                continue
            if shape[i] % (prod * sizes[ax]) != 0:
                continue
            got.append(ax)
            prod *= sizes[ax]
        used.update(got)
        if got:
            entries[i] = got[0] if len(got) == 1 else tuple(got)
    return P(*entries)


# ---------------------------------------------------------------------------
# Activation anchoring (constrain) — trace-time context
# ---------------------------------------------------------------------------

# Stack of (mesh, rules) pushed by activation_rules; constrain reads the top.
_ACTIVE: list[tuple[Mesh, dict | None]] = []


class _ActivationRules(contextlib.AbstractContextManager):
    def __init__(self, mesh: Mesh, rules: dict | None):
        self._item = (mesh, rules)

    def __enter__(self):
        _ACTIVE.append(self._item)
        return self

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def activation_rules(mesh: Mesh, rules: dict | None = None):
    """Context manager enabling :func:`constrain` at trace time."""
    return _ActivationRules(mesh, rules)


def constrain(x: Array, axes: Sequence[str | None]) -> Array:
    """Anchor an activation to its logical-axis sharding.

    Identity (returns ``x`` itself) outside an :func:`activation_rules`
    context, so model code can call it unconditionally.
    """
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = spec_for_shape(tuple(axes), x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _is_axes_leaf(x) -> bool:
    return x is None or (isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x))


def tree_shardings_for_structs(axes: Any, structs: Any, mesh: Mesh,
                               rules: dict | None = None) -> Any:
    """NamedShardings for a pytree of structs from its logical-axes tree.

    ``axes`` leaves are tuples of logical names (or None = replicated),
    mirroring ``structs``'s tree of ShapeDtypeStructs/arrays.
    """
    def resolve(a, s):
        if s is None:
            return None
        if a is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, spec_for_shape(a, s.shape, mesh, rules))

    return jax.tree.map(resolve, axes, structs, is_leaf=_is_axes_leaf)


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """One shard_map spelling for old and new jax.

    ``check=False`` by default: the dist substrates all produce
    value-replicated outputs via explicit collectives that replication
    inference cannot always see through (ring loops especially).
    """
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    except TypeError:  # pre-check_vma spelling
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check)
