"""Pipeline parallelism: a GPipe-style microbatch schedule over a mesh axis.

Stage ``s`` lives on mesh slice ``s`` of the ``axis``; microbatches flow
down the ring with one ``ppermute`` per step.  With ``p`` stages and
``n_micro`` microbatches the schedule runs ``n_micro + p - 1`` steps:
stage 0 injects microbatch ``t`` at step ``t``, stage ``s`` processes it
at step ``s + t``, and the last stage emits it at step ``p - 1 + t`` (the
classic (p-1)-step fill/drain bubble).  Every device executes the same
program each step — bubble slots compute on zeros and are discarded — so
the whole schedule is one SPMD program with static shapes.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .sharding import shard_map

Array = jax.Array


def pipeline_apply(stage_fn: Callable, mesh: Mesh, axis: str,
                   stage_params: Array, microbatches: Array) -> Array:
    """Apply ``p`` stacked stages to ``n_micro`` microbatches on a pipeline.

    Args:
      stage_fn: ``(w, x) -> y`` with ``y.shape == x.shape`` (stages chain).
      stage_params: ``(p, ...)`` per-stage parameters, sharded over ``axis``.
      microbatches: ``(n_micro, ...)`` inputs, replicated.
    Returns the ``(n_micro, ...)`` outputs of the final stage, replicated.
    """
    p = mesh.shape[axis]
    n_micro = microbatches.shape[0]

    def run(ws_local, xs):
        w = jax.tree.map(lambda a: a[0], ws_local)      # this device's stage
        idx = jax.lax.axis_index(axis)
        perm = [(j, (j + 1) % p) for j in range(p)]     # stage s -> s + 1
        recv = jnp.zeros_like(xs[0])
        ys = jnp.zeros_like(xs)
        for t in range(n_micro + p - 1):
            feed = xs[t] if t < n_micro else jnp.zeros_like(xs[0])
            out = stage_fn(w, jnp.where(idx == 0, feed, recv))
            done = t - (p - 1)                          # microbatch leaving
            if done >= 0:
                ys = ys.at[done].set(jnp.where(idx == p - 1, out, ys[done]))
            recv = jax.lax.ppermute(out, axis, perm)
        # only the last stage holds real outputs; psum replicates them
        return jax.lax.psum(
            jnp.where(idx == p - 1, ys, jnp.zeros_like(ys)), axis)

    fn = shard_map(run, mesh=mesh, in_specs=(P(axis), P()), out_specs=P())
    return jax.jit(fn)(stage_params, microbatches)
