"""kNN-LM over a Bregman datastore — the paper's technique as a first-class
serving feature.

A datastore maps LM hidden states h_t to the token that FOLLOWED them in a
reference corpus (Khandelwal et al. 2020).  At decode time the current
hidden state queries the store's k nearest neighbors and the LM distribution
is interpolated with the kNN distribution:

    p(y) = (1 - lam) * p_LM(y) + lam * softmax_over_knn(-D(h, h_i) / T)

Euclidean kNN is standard; exp-family embeddings motivate Bregman
divergences, and this is precisely the paper's workload: hundreds of
dimensions (d_model), millions of keys, exact-or-guaranteed retrieval.
BrePartition's partition-filter-refine pipeline (core/search.py) serves the
queries; the distributed path (dist/knn.py) shards the datastore over
(pod, data) with subspaces on the model axis.

``build_datastore`` runs teacher-forced prefills over a corpus and records
(hidden, next_token) pairs; ``KNNLMHook`` plugs into serve/engine.py's
``logits_hook``.  ``Datastore.grow``/``Datastore.evict`` mutate the store
online via the segmented index (core/segments.py) — streaming ingestion
and retirement with no rebuild and no serving pause (see
docs/index_updates.md for the contract).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as bp_search
from repro.core.index import BallForest, build_index
from repro.core.segments import SegmentedForest

Array = jax.Array


@dataclasses.dataclass
class Datastore:
    """kNN-LM key/value store over a BrePartition index.

    ``index`` is a BallForest, or — after the first :meth:`grow`/
    :meth:`evict` — the mutable SegmentedForest.  ``next_tokens`` is
    indexed by ORIGINAL point id; ids are never reused (tombstones keep
    theirs, compaction preserves them), so the table is append-only and
    stays valid across every mutation.
    """

    index: BallForest | SegmentedForest
    next_tokens: np.ndarray     # (next_id,) int32 — token following each key
    hidden_dim: int
    version: int = 0            # bumped on every mutation (cache invalidation)
    # Streaming block size for searches over this store (None = pipeline
    # default).  Deployment-level knob: smaller blocks cap the per-tick
    # peak intermediate bytes (O(block_rows * q)), larger blocks amortize
    # scan overhead — see core.search.resolve_block_rows.  Hooks read it
    # as their default; per-hook overrides win.
    block_rows: int | None = None
    # Threshold-triggered compaction runs a CostModel fit (and possibly a
    # full rebuild) synchronously inside grow()/evict(); serving
    # deployments that cannot absorb that pause on the request path set
    # False and call index.compact() from a maintenance tick instead.
    auto_compact: bool = True
    # Out-of-core residency (core/tiered.py): with a byte budget, lookups
    # run against a TieredPointStore snapshot — cold key blocks in host
    # RAM, fetched on envelope admission — so the value of n is capped by
    # host RAM, not HBM.  None keeps the store fully device-resident.
    resident_bytes: int | None = None
    prefetch_depth: int | None = None
    _tiered: object = dataclasses.field(default=None, init=False,
                                        repr=False)
    _tiered_version: int = dataclasses.field(default=-1, init=False,
                                             repr=False)

    @property
    def storage(self) -> str:
        """Key-table storage tier ("f32" | "int8" — see build_datastore)."""
        return self.index.storage

    def search_index(self):
        """The object lookups should search: the index itself, or — with
        a ``resident_bytes`` budget — a TieredPointStore snapshot of it,
        rebuilt lazily whenever :attr:`version` moves (the store freezes
        its snapshot at construction, so a grow/evict invalidates it the
        same way it invalidates the device value table)."""
        if self.resident_bytes is None:
            return self.index
        if self._tiered is None or self._tiered_version != self.version:
            from repro.core.tiered import TieredPointStore
            old, self._tiered = self._tiered, None
            if old is not None:
                old.close()
            self._tiered = TieredPointStore.from_index(
                self.index, resident_bytes=self.resident_bytes,
                prefetch_depth=self.prefetch_depth,
                block_rows=self.block_rows)
            self._tiered_version = self.version
        return self._tiered

    def _mutable(self) -> SegmentedForest:
        if not isinstance(self.index, SegmentedForest):
            self.index = SegmentedForest.from_forest(self.index)
        return self.index

    def grow(self, keys: np.ndarray, next_tokens: np.ndarray) -> np.ndarray:
        """Online ingestion: append (hidden, next-token) pairs; returns ids.

        One nearest-centroid pass against the sealed index — no rebuild on
        the insert itself.  The new keys are retrievable by the very next
        hook call (the snapshot row count changes, so that call compiles a
        fresh program; batch your grows).  With :attr:`auto_compact` the
        call that crosses the stale-fraction threshold additionally pays
        for the compaction inline.
        """
        keys = np.asarray(keys, np.float32)
        toks = np.asarray(next_tokens, np.int32)
        if keys.ndim != 2 or keys.shape[1] != self.hidden_dim:
            raise ValueError(
                f"expected (a, {self.hidden_dim}) keys, got {keys.shape}")
        if toks.shape != (keys.shape[0],):
            raise ValueError("one next-token per key required")
        store = self._mutable()
        if store.next_id != self.next_tokens.shape[0]:
            raise ValueError("datastore ids out of sync with value table")
        ids = store.insert(keys, auto_compact=self.auto_compact)
        self.next_tokens = np.concatenate([self.next_tokens, toks])
        self.version += 1
        return ids

    def evict(self, ids) -> int:
        """Retire keys (stale users, rolled-over corpora) by tombstone."""
        removed = self._mutable().delete(ids,
                                         auto_compact=self.auto_compact)
        if removed:
            self.version += 1
        return removed


def build_datastore(bundle, params, corpus_tokens: np.ndarray, *,
                    family: str = "squared_euclidean",
                    m: int | None = None, quantize: bool = False,
                    block_rows: int | None = None,
                    calibrate: bool = False, calibrate_k: int = 8,
                    resident_bytes: int | None = None,
                    prefetch_depth: int | None = None,
                    seed: int = 0) -> Datastore:
    """Teacher-forced pass over (num_seqs, seq_len) tokens -> datastore.

    Keys: hidden state at position t; values: token at t+1.

    ``quantize=True`` stores the keys in the int8 BallForest tier —
    ~4x smaller key table for large value stores, with retrieval still
    exact over the stored (decoded) keys; grows quantize their keys the
    same way (docs/quantization.md).  d_model-sized hidden states are
    exactly the "hundreds of dimensions, millions of keys" regime the
    memory win targets.

    ``calibrate=True`` fits the recall-calibration curve over held-out
    jittered keys at build time (core/calibrate.py), enabling
    ``KNNLMHook(target_recall=...)`` — approximate decode-time retrieval
    at a MEASURED recall level; ``calibrate_k`` should match the hook's
    ``k`` (default 8 matches the hook default).

    ``resident_bytes`` tiers the key table out-of-core (core/tiered.py):
    cold key blocks live in host RAM under that device-cache budget, so
    datastore capacity is bounded by host RAM instead of HBM;
    ``prefetch_depth`` sets the fetch double-buffer depth
    (docs/tiered_storage.md).
    """
    from repro.core.tiered import resolve_prefetch_depth, resolve_resident_bytes
    resident_bytes = resolve_resident_bytes(resident_bytes)
    prefetch_depth = resolve_prefetch_depth(prefetch_depth)
    num, s = corpus_tokens.shape
    pos = np.arange(s, dtype=np.int32)[None, :].repeat(num, 0)
    if getattr(bundle.cfg, "mrope_section", None):
        pos = np.repeat(pos[..., None], 3, -1)
    batch = {"tokens": jnp.asarray(corpus_tokens, jnp.int32),
             "positions": jnp.asarray(pos)}
    for name, (shape_fn, dtype, _ax) in bundle.extra_inputs.items():
        batch[name] = jnp.zeros(shape_fn(num, s), dtype)
    hidden, _ = jax.jit(bundle.forward_train)(params, batch)
    keys = np.asarray(hidden[:, :-1].reshape(-1, hidden.shape[-1]),
                      np.float32)
    vals = np.asarray(corpus_tokens[:, 1:].reshape(-1), np.int32)
    index = build_index(keys, family, m=m, quantize=quantize,
                        calibrate=calibrate, calibrate_k=calibrate_k,
                        seed=seed)
    if block_rows is None:
        # Pin the autotuned streaming block size once at build time (same
        # policy as serve.retrieval.register_tenant): hook batches are
        # small, so key the lookup on a typical decode-tick row count.
        from repro.launch import autotune
        block_rows = autotune.lookup_block_rows(
            max(index.n, 1), 8, storage=index.storage)
    return Datastore(index=index, next_tokens=vals,
                     hidden_dim=keys.shape[-1], block_rows=block_rows,
                     resident_bytes=resident_bytes,
                     prefetch_depth=prefetch_depth)


@dataclasses.dataclass
class KNNLMHook:
    """``logits_hook`` for serve.engine.Engine: Bregman-kNN interpolation.

    The engine passes the sampled slots' rows (logits (A, V), hidden
    (A, D) — active slots on decode ticks, admitted slots on the prefill
    path, never a dead slot's garbage row); the hook retrieves
    each row's k nearest datastore keys with BrePartition and mixes the
    neighbor next-token distribution into the LM distribution.
    """

    store: Datastore
    k: int = 8
    lam: float = 0.25
    temperature: float = 1.0
    approx_p: float | None = None   # paper §8 approximate mode (raw knob)
    # Calibrated alternative to approx_p: retrieve at a MEASURED recall
    # level by inverting the datastore's calibration curve (fit it with
    # build_datastore(calibrate=True)).  Mutually exclusive with approx_p;
    # uncalibrated stores fall back to p = target_recall with a one-time
    # warning (core/calibrate.py).
    target_recall: float | None = None
    budget: int | None = None       # pinned refine budget (stable jit cache)
    block_rows: int | None = None   # streaming block size (None -> store's)
    # Optional robustness front end (serve/retrieval.py).  When set, every
    # lookup routes through the service's admission gate + degradation
    # ladder under ``deadline_s``: the store is (re-)registered as tenant
    # ``service_tenant`` whenever ``store.version`` moves, and rows the
    # service degraded past approx (partial/shed) fall back to the pure LM
    # distribution — a slow or faulty datastore costs retrieval quality,
    # never decode liveness.  Unset, lookups call knn_batch directly (the
    # bare-metal path: no deadlines, but also no service in the loop).
    service: object = None          # RetrievalService | None
    service_tenant: str = "knnlm"
    deadline_s: float | None = None
    queries_served: int = 0
    # Structured budget-retry telemetry (replaces grepping logs): total
    # budget escalations taken, full linear-scan fallbacks, and the budget
    # the most recent launch actually ran with.
    escalations: int = 0
    scan_fallbacks: int = 0
    budget_final: int = 0
    # next_tokens cached on device (lazy, refreshed when the store mutates)
    _next_dev: Array | None = dataclasses.field(
        default=None, init=False, repr=False)
    _next_version: int = dataclasses.field(
        default=-1, init=False, repr=False)
    _svc_version: int = dataclasses.field(
        default=-1, init=False, repr=False)

    def _service_lookup(self, h: np.ndarray):
        """Route one lookup through the retrieval service.

        Returns ``(ids, dists, use_rows)`` or None for "serve pure LM".
        ``use_rows`` keeps exact/approx rows; partial and shed rows fall
        back to the LM distribution (a truncated neighbor set would bias
        the mixture — the same policy as the inexact-row gate below).
        """
        svc = self.service
        name = self.service_tenant
        if name not in svc.tenants or self._svc_version != self.store.version:
            # (Re-)register on every store mutation: the service revalidates
            # the live rows and refreshes its tenant record.  approx_p is
            # the tenant's raw §8 knob; target_recall rides each request and
            # inverts the store's calibration curve service-side — the two
            # are different quantities and must not be conflated.
            svc.register_tenant(name, self.store.index,
                                p_guarantee=self.approx_p,
                                resident_bytes=self.store.resident_bytes,
                                prefetch_depth=self.store.prefetch_depth)
            self._svc_version = self.store.version
        resp = svc.search_sync(name, h, self.k, deadline_s=self.deadline_s,
                               target_recall=self.target_recall)
        use = np.array([q in ("exact", "approx") for q in resp.row_quality])
        if not use.any():
            return None
        return resp.ids, resp.dists, use

    def __call__(self, logits: Array, hidden: Array | None) -> Array:
        if hidden is None:
            return logits
        # Eviction can shrink the store below k mid-serving; retrieval is
        # then impossible, so degrade to the pure LM distribution (the same
        # fallback the inexact-row gate uses) instead of raising.
        live = getattr(self.store.index, "live_n", self.store.index.n)
        if live < self.k:
            return logits
        h = jnp.asarray(hidden, jnp.float32)
        if self.service is not None:
            out = self._service_lookup(np.asarray(h))
            self.queries_served += int(h.shape[0])
            if out is None:
                return logits
            ids, dists, use = out
            ids = jnp.asarray(np.maximum(ids, 0))      # shed rows hold -1
            dists = jnp.asarray(np.where(use[:, None], dists, 0.0))
            use = jnp.asarray(use)
        else:
            # The engine hands the LIVE rows (A, D) at every sampling step —
            # active slots on decode ticks, admitted slots on the prefill
            # path; dead slots' garbage rows never reach retrieval — so each
            # step is ONE fused knn_search_batch program: one filter matmul,
            # one prune, one refine for all sampled slots.  Pinning the
            # budget keeps the refine shape stable; the batch axis still
            # varies with the live-slot count (bounded by the engine's slot
            # pool, so the jit cache holds at most `slots` programs per k).
            # Rare union overflows fall back to the capped sized retry.
            res, stats = bp_search.knn_batch(
                self.store.search_index(), h, self.k, budget=self.budget,
                approx_p=self.approx_p, target_recall=self.target_recall,
                block_rows=(self.block_rows or self.store.block_rows),
                return_stats=True)
            self.queries_served += int(h.shape[0])
            self.escalations += stats.escalations
            self.scan_fallbacks += int(stats.escalated_to_scan)
            self.budget_final = stats.budget_final
            # Grow-only budget adaptation: only when this step's unions
            # outgrew the effective budget (no pin is installed while the
            # default suffices — one program, no mid-serving recompile).  On
            # overflow the pin uses the shared fitted_budget sizing so it
            # lands on the same static shapes knn_batch's retries compile.
            # The pin is bounded: one pathological row (a stale slot's
            # hidden state, a degenerate union ~ n) must not permanently
            # inflate every future step's refine gather to (B, n, d) —
            # beyond the (power-of-two aligned) cap we accept the
            # occasional retry instead.
            default = bp_search.default_budget(self.store.index, self.k)
            needed = int(jnp.max(res.num_candidates))
            current = self.budget or default
            if needed > current:
                cap = bp_search.fitted_budget(self.store.index, self.k,
                                              8 * default)
                fitted = bp_search.fitted_budget(self.store.index, self.k,
                                                 needed)
                self.budget = max(current, min(fitted, cap))  # never shrink
            # Defense in depth: knn_batch escalates to a full refine on cap
            # exhaustion so inexact rows shouldn't occur, but if one ever
            # does its neighbors are an arbitrary union prefix — serve the
            # pure LM distribution for it instead of a biased mixture.
            ids, dists, use = res.ids, res.dists, res.exact
        # Upload the value table once per store version, not per tick; a
        # grow/evict bumps store.version and forces a re-upload so appended
        # ids resolve and evicted ids (which never surface) age out.
        if self._next_dev is None or self._next_version != self.store.version:
            self._next_dev = jnp.asarray(self.store.next_tokens)
            self._next_version = self.store.version
        knn_tokens = self._next_dev[ids]                        # (B, k)
        w = jax.nn.softmax(-dists / self.temperature, axis=-1)  # (B, k)
        vocab = logits.shape[-1]
        p_knn = jax.vmap(
            lambda t, ww: jnp.zeros((vocab,), jnp.float32).at[t].add(ww)
        )(knn_tokens, w)
        p_lm = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        mix = (1.0 - self.lam) * p_lm + self.lam * p_knn
        mix = jnp.where(use[:, None], mix, p_lm)
        return jnp.log(jnp.maximum(mix, 1e-30))
