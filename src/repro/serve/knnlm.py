"""kNN-LM over a Bregman datastore — the paper's technique as a first-class
serving feature.

A datastore maps LM hidden states h_t to the token that FOLLOWED them in a
reference corpus (Khandelwal et al. 2020).  At decode time the current
hidden state queries the store's k nearest neighbors and the LM distribution
is interpolated with the kNN distribution:

    p(y) = (1 - lam) * p_LM(y) + lam * softmax_over_knn(-D(h, h_i) / T)

Euclidean kNN is standard; exp-family embeddings motivate Bregman
divergences, and this is precisely the paper's workload: hundreds of
dimensions (d_model), millions of keys, exact-or-guaranteed retrieval.
BrePartition's partition-filter-refine pipeline (core/search.py) serves the
queries; the distributed path (dist/knn.py) shards the datastore over
(pod, data) with subspaces on the model axis.

``build_datastore`` runs teacher-forced prefills over a corpus and records
(hidden, next_token) pairs; ``KNNLMHook`` plugs into serve/engine.py's
``logits_hook``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as bp_search
from repro.core.index import BallForest, build_index

Array = jax.Array


@dataclasses.dataclass
class Datastore:
    index: BallForest
    next_tokens: np.ndarray     # (n,) int32 — token following each key
    hidden_dim: int


def build_datastore(bundle, params, corpus_tokens: np.ndarray, *,
                    family: str = "squared_euclidean",
                    m: int | None = None, seed: int = 0) -> Datastore:
    """Teacher-forced pass over (num_seqs, seq_len) tokens -> datastore.

    Keys: hidden state at position t; values: token at t+1.
    """
    num, s = corpus_tokens.shape
    pos = np.arange(s, dtype=np.int32)[None, :].repeat(num, 0)
    if getattr(bundle.cfg, "mrope_section", None):
        pos = np.repeat(pos[..., None], 3, -1)
    batch = {"tokens": jnp.asarray(corpus_tokens, jnp.int32),
             "positions": jnp.asarray(pos)}
    for name, (shape_fn, dtype, _ax) in bundle.extra_inputs.items():
        batch[name] = jnp.zeros(shape_fn(num, s), dtype)
    hidden, _ = jax.jit(bundle.forward_train)(params, batch)
    keys = np.asarray(hidden[:, :-1].reshape(-1, hidden.shape[-1]),
                      np.float32)
    vals = np.asarray(corpus_tokens[:, 1:].reshape(-1), np.int32)
    index = build_index(keys, family, m=m, seed=seed)
    return Datastore(index=index, next_tokens=vals,
                     hidden_dim=keys.shape[-1])


@dataclasses.dataclass
class KNNLMHook:
    """``logits_hook`` for serve.engine.Engine: Bregman-kNN interpolation.

    The engine passes (logits (B, V), hidden (B, D)); the hook retrieves
    each row's k nearest datastore keys with BrePartition and mixes the
    neighbor next-token distribution into the LM distribution.
    """

    store: Datastore
    k: int = 8
    lam: float = 0.25
    temperature: float = 1.0
    approx_p: float | None = None   # paper §8 approximate mode
    budget: int | None = None       # pinned refine budget (stable jit cache)
    queries_served: int = 0
    # next_tokens cached on device (lazy, internal)
    _next_dev: Array | None = dataclasses.field(
        default=None, init=False, repr=False)

    def __call__(self, logits: Array, hidden: Array | None) -> Array:
        if hidden is None:
            return logits
        h = jnp.asarray(hidden, jnp.float32)
        # The engine hands the full (slots, D) hidden batch at every
        # sampling step (each decode tick, plus once when admissions
        # prefill), so each step is ONE fused knn_search_batch program: one
        # filter matmul, one prune, one refine for all slots.  Pinning the
        # budget keeps the jit cache to a single program per (slots, k);
        # rare union overflows fall back to the capped sized retry.
        res = bp_search.knn_batch(self.store.index, h, self.k,
                                  budget=self.budget,
                                  approx_p=self.approx_p)
        self.queries_served += int(h.shape[0])
        # Grow-only budget adaptation: only when this step's unions outgrew
        # the effective budget (no pin is installed while the default
        # suffices — one program, no mid-serving recompile).  On overflow
        # the pin uses the shared fitted_budget sizing so it lands on the
        # same static shapes knn_batch's retries compile.  The pin is
        # bounded: one pathological row (a stale slot's hidden state, a
        # degenerate union ~ n) must not permanently inflate every future
        # step's refine gather to (B, n, d) — beyond the (power-of-two
        # aligned) cap we accept the occasional retry instead.
        default = bp_search.default_budget(self.store.index, self.k)
        needed = int(jnp.max(res.num_candidates))
        current = self.budget or default
        if needed > current:
            cap = bp_search.fitted_budget(self.store.index, self.k,
                                          8 * default)
            fitted = bp_search.fitted_budget(self.store.index, self.k,
                                             needed)
            self.budget = max(current, min(fitted, cap))  # never shrink
        if self._next_dev is None:      # upload the value table once, not per tick
            self._next_dev = jnp.asarray(self.store.next_tokens)
        knn_tokens = self._next_dev[res.ids]                        # (B, k)
        w = jax.nn.softmax(-res.dists / self.temperature, axis=-1)  # (B, k)
        vocab = logits.shape[-1]
        p_knn = jax.vmap(
            lambda t, ww: jnp.zeros((vocab,), jnp.float32).at[t].add(ww)
        )(knn_tokens, w)
        p_lm = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        mix = (1.0 - self.lam) * p_lm + self.lam * p_knn
        # Defense in depth: knn_batch escalates to a full refine on cap
        # exhaustion so inexact rows shouldn't occur, but if one ever does
        # its neighbors are an arbitrary union prefix — serve the pure LM
        # distribution for it instead of a biased mixture.
        mix = jnp.where(res.exact[:, None], mix, p_lm)
        return jnp.log(jnp.maximum(mix, 1e-30))
