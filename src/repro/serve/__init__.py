# Serving substrate: batched prefill/decode engine, the BrePartition
# kNN-LM datastore integration (the paper's technique at the serving
# layer), and the fault-tolerant retrieval front end (deadlines,
# admission control, degradation ladder) with its fault-injection
# harness.

from .faults import (  # noqa: F401
    CompactDuringSearch,
    FaultEvent,
    FaultPlan,
    InjectedLaunchError,
    LatencySpike,
    LaunchError,
    OffsetClock,
    PoisonQuery,
    ShardStall,
    SystemClock,
    VirtualClock,
    jittered_backoff,
)
from .retrieval import (  # noqa: F401
    CircuitBreaker,
    LaunchCostModel,
    RetrievalResponse,
    RetrievalService,
    ServiceConfig,
    Tenant,
    Ticket,
)
