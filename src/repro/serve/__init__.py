# Serving substrate: batched prefill/decode engine + the BrePartition
# kNN-LM datastore integration (the paper's technique at the serving layer).
