"""Batched serving engine: continuous prefill/decode over a fixed slot pool.

Production shape: a pool of B sequence slots, each with its own KV/state
cache region and length counter.  New requests prefill into free slots;
every engine tick runs ONE decode step for all slots (continuous batching a
la Orca/vLLM, with static shapes — TPU programs can't grow).

Two jitted programs, shared across all requests:

    prefill_fn(params, batch, caches, lengths)  -> (hidden (B,S,D), caches)
    decode_fn(params, token, pos, caches, lens) -> (logits, caches)

Padding policy: prompts are RIGHT-padded to ``prefill_len``.  Attention
caches tolerate trailing garbage (decode masks ``ki < length``); recurrent
states (RG-LRU / RWKV) would integrate the padding, so recurrent archs
require exact-length prompts (asserted) — production engines solve this
with per-bucket prefill programs, a launcher concern out of scope here.

Slot isolation: batched prefill touches every slot's cache region, so the
engine re-merges old cache values for non-admitted slots (one select per
leaf) — active sequences are never perturbed (tested).

Logits hooks: ``logits_hook(logits (A, V), hidden (A, D))`` is invoked
once per sampling step with the rows of the slots being sampled — every
ACTIVE slot on a decode tick, every ADMITTED slot on the prefill sampling
path — never per slot, and never with a dead slot's row: a free slot's
cache holds garbage (e.g. ``last_idx = 0`` hidden states on admit ticks)
and must not reach retrieval hooks.  Hooks that do retrieval
(serve/knnlm.py) ride the fused batched kNN pipeline
(core/search.knn_search_batch): one filter matmul, one prune, one refine
for all sampled slots per invocation.  The hook's batch axis varies with
the live-slot count, so hook-side jitted programs compile once per
distinct count — a warmup cost bounded by ``slots`` programs, accepted in
exchange for never running retrieval on garbage rows.  See
docs/batched_serving.md.

Termination: a request finishes as soon as its output hits
``max_new_tokens``, its sampled token equals ``cfg.eos_token``, or its
cache fills — checked after EVERY sampled token, including the one the
prefill path samples at admission.  ``max_new_tokens=1`` therefore emits
exactly one token and never occupies a slot across a decode tick, and an
EOS sampled from the prompt finishes the request immediately.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    slots: int                    # max concurrent sequences (batch size)
    max_seq: int                  # cache capacity per slot
    prefill_len: int              # static prompt padding length
    eos_token: int = -1           # -1: never stop on a token
    greedy: bool = True
    temperature: float = 1.0


def _is_recurrent(bundle) -> bool:
    kinds = getattr(bundle.cfg, "layer_kinds", lambda: ("attn",))()
    return any(k in ("rglru", "rwkv") for k in kinds)


class Engine:
    """Host-side slot manager around the two jitted device programs."""

    def __init__(self, bundle, params, cfg: EngineConfig,
                 logits_hook: Callable | None = None, seed: int = 0):
        self.bundle = bundle
        self.params = params
        self.cfg = cfg
        self.logits_hook = logits_hook      # e.g. kNN-LM interpolation
        self.caches = bundle.init_cache(cfg.slots, cfg.max_seq)
        self.lengths = np.zeros((cfg.slots,), np.int32)
        self.slot_req: list[Request | None] = [None] * cfg.slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._rng = jax.random.PRNGKey(seed)
        self._mrope = bool(getattr(bundle.cfg, "mrope_section", None))
        self._recurrent = _is_recurrent(bundle)
        self.ticks = 0

        self._decode = jax.jit(bundle.decode_step)
        self._prefill = jax.jit(bundle.prefill)
        self._merge = jax.jit(
            lambda new, old, mask: jax.tree.map(
                lambda n, o: jnp.where(
                    mask.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
                new, old))

    # -- request lifecycle ----------------------------------------------------
    def submit(self, req: Request):
        if self._recurrent and len(req.prompt) != self.cfg.prefill_len:
            raise ValueError(
                "recurrent archs need exact-length prompts "
                f"({len(req.prompt)} != prefill_len={self.cfg.prefill_len}); "
                "see engine docstring")
        if len(req.prompt) > self.cfg.prefill_len:
            raise ValueError("prompt longer than prefill_len")
        self.queue.append(req)

    def _positions(self, pos: Array) -> Array:
        if self._mrope:
            return pos[..., None].repeat(3, -1)
        return pos

    def _admit(self):
        """Prefill queued requests into free slots (one batched prefill)."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if not free or not self.queue:
            return
        take = min(len(free), len(self.queue))
        slots = free[:take]
        reqs = [self.queue.pop(0) for _ in range(take)]
        b, pl = self.cfg.slots, self.cfg.prefill_len
        tokens = np.zeros((b, pl), np.int32)
        admitted = np.zeros((b,), bool)
        for s, r in zip(slots, reqs, strict=True):
            tokens[s, : len(r.prompt)] = r.prompt      # right-pad
            admitted[s] = True
            self.slot_req[s] = r
        pos = np.arange(pl, dtype=np.int32)[None, :].repeat(b, 0)
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": self._positions(jnp.asarray(pos))}
        for name, (shape_fn, dtype, _ax) in self.bundle.extra_inputs.items():
            batch[name] = jnp.zeros(shape_fn(b, pl), dtype)

        old_caches = self.caches
        hidden, new_caches = self._prefill(
            self.params, batch, old_caches, jnp.zeros((b,), jnp.int32))
        # non-admitted slots keep their previous cache (slot isolation)
        self.caches = self._merge(new_caches, old_caches,
                                  jnp.asarray(admitted))
        # Sample ONLY the admitted slots, each at its true last-prompt
        # position.  Non-admitted slots are dropped before the logits head
        # and the hook: their hidden rows are whatever the batched prefill
        # left at position 0 — garbage that must not trigger hook work
        # (e.g. kNN retrieval) or sampling.
        last_idx = np.array([len(r.prompt) - 1 for r in reqs])
        last_hidden = hidden[jnp.asarray(np.array(slots)),
                             jnp.asarray(last_idx)]
        logits = self.bundle.logits(self.params, last_hidden)
        first = self._sample(logits, last_hidden)
        for j, (s, r) in enumerate(zip(slots, reqs, strict=True)):
            r.output.append(int(first[j]))
            self.lengths[s] = len(r.prompt)
            # The prefill-sampled token counts against the budget and is
            # checked against EOS like any decoded token; without this a
            # max_new_tokens=1 request would decode a second token and an
            # EOS-opening request would run to its full budget.
            self._finish_if_done(s, at_admit=True)

    def _sample(self, logits: Array, hidden: Array | None = None) -> np.ndarray:
        """Sample the given rows (already restricted to live slots)."""
        if self.logits_hook is not None:
            logits = self.logits_hook(logits, hidden)
        if self.cfg.greedy:
            return np.asarray(jnp.argmax(logits, -1))
        self._rng, k = jax.random.split(self._rng)
        return np.asarray(jax.random.categorical(
            k, logits / self.cfg.temperature, axis=-1))

    def _finish_if_done(self, i: int, at_admit: bool = False) -> bool:
        """Retire slot ``i`` if its newest token terminates the request.

        THE termination check — budget, EOS, and cache capacity — shared
        by the decode tick and the prefill sampling path, so every sampled
        token (including the admission-sampled first token) is judged by
        the same rule.  Capacity keeps the decode path's one-slot margin
        (``lengths + 1 >= max_seq``, pre-existing); at admission the
        margin is zero — a prompt of length ``max_seq - 1`` still has room
        for its one decode write, and retiring it here would drop a token
        the decode path would have produced.
        """
        r = self.slot_req[i]
        hit_eos = r.output[-1] == self.cfg.eos_token
        margin = 0 if at_admit else 1
        full = (len(r.output) >= r.max_new_tokens
                or self.lengths[i] + margin >= self.cfg.max_seq)
        if hit_eos or full:
            r.done = True
            self.finished.append(r)
            self.slot_req[i] = None
            self.lengths[i] = 0
            return True
        return False

    def step(self) -> bool:
        """One engine tick: admit, then one decode step for active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        self.ticks += 1
        last = np.zeros((self.cfg.slots, 1), np.int32)
        for i in active:
            last[i, 0] = self.slot_req[i].output[-1]
        pos = jnp.asarray(self.lengths[:, None], jnp.int32)
        logits, hidden, self.caches = self._decode(
            self.params, jnp.asarray(last), self._positions(pos),
            self.caches, jnp.asarray(self.lengths))
        # Free slots decode garbage rows (the batch is slot-shaped); drop
        # them before sampling so hooks only ever see live sequences.
        rows = jnp.asarray(np.array(active))
        nxt = self._sample(logits[rows],
                           None if hidden is None else hidden[rows])
        for j, i in enumerate(active):
            self.slot_req[i].output.append(int(nxt[j]))
            self.lengths[i] += 1
            self._finish_if_done(i)
        return True

    def run(self, max_ticks: int = 1000):
        """Drive until queue + slots drain (or tick budget)."""
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
        return self.finished
