"""Deterministic, seedable fault injection for the retrieval service.

Chaos testing a TPU serving stack is only useful when a failing run can be
replayed: every fault here is a pure function of (plan seed, event
counter), never of wall time or thread timing.  The service
(serve/retrieval.py) threads a :class:`FaultPlan` through three hook
points —

* ``on_submit`` — fires as a request enters admission, BEFORE the query
  domain gate, so injected poison exercises the real validation path;
* ``before_launch`` — fires after the microbatch snapshot is taken and
  immediately before a compiled search program launches.  A fault may
  RAISE (injected launch failure / shard loss) or return extra seconds of
  latency, which the service adds through its injectable clock (so a
  latency spike is visible to deadlines and the cost model without
  wall-clock sleeping);
* ``after_launch`` — observation point for invariants.

Clocks live here too: the service never reads ``time`` directly, it reads
an injectable clock with ``now()``/``sleep(dt)``.  :class:`SystemClock`
is production; :class:`VirtualClock` makes tests fully deterministic
(latency exists only where a fault injects it); :class:`OffsetClock`
layers injected latency on top of real launch cost for chaos benchmarks —
measured latencies then include both the real compute and the simulated
spikes, while the process never actually sleeps.

Every fault that fires appends a :class:`FaultEvent` to ``plan.events``,
so tests assert "the poison DID fire and only row r degraded" rather than
hoping.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------

class SystemClock:
    """Wall time; ``sleep`` really sleeps (production backoff)."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class VirtualClock:
    """Fully simulated time — deterministic tests.

    Real launches take ZERO virtual time; only explicit ``sleep``/
    ``advance`` calls (backoff, injected latency) move the clock, so a
    test controls exactly how much of a request's deadline each fault
    consumes.
    """

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def now(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.advance(dt)

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"cannot rewind the clock (dt={dt})")
        self.t += dt


class OffsetClock:
    """Wall time plus an accumulated offset; ``sleep`` only adds offset.

    The chaos-bench clock: launch costs are real (``now`` advances with
    the actual compute), injected latency and backoff advance the offset
    instantly — observed latencies are realistic, CI wall time is not
    inflated by the injected spikes.
    """

    def __init__(self):
        self.offset = 0.0

    def now(self) -> float:
        return time.monotonic() + self.offset

    def sleep(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"cannot rewind the clock (dt={dt})")
        self.offset += dt


# ---------------------------------------------------------------------------
# Hook contexts + event log
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SubmitCtx:
    """Admission-time hook context; ``queries`` is mutated in place."""

    index: int                  # global submit counter
    tenant: str
    queries: np.ndarray         # (q, d) float32, poisonable


@dataclasses.dataclass
class LaunchCtx:
    """Launch-time hook context.

    ``tenant_obj`` is the service's live tenant record — its ``index`` is
    the MUTABLE index, not the snapshot the in-flight launch reads, which
    is exactly what compaction/ingestion races need.
    """

    index: int                  # global launch counter
    tenant: str
    tier: str                   # "exact" | "approx" | "partial"
    attempt: int                # retry ordinal within the microbatch
    tenant_obj: object = None
    service: object = None


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str                   # e.g. "latency", "error", "poison", "compact"
    where: str                  # "submit" | "launch"
    index: int                  # the counter value when it fired
    tenant: str
    detail: str = ""


class InjectedLaunchError(RuntimeError):
    """The default exception type for injected launch failures."""


# ---------------------------------------------------------------------------
# Faults
# ---------------------------------------------------------------------------

class Fault:
    """Base: no-op hooks.  Subclasses override what they inject.

    ``before_launch`` returns extra SECONDS of latency (0.0 for none) or
    raises to simulate a failed launch.  ``rng`` is the plan's seeded
    generator — faults must draw randomness ONLY from it.
    """

    def on_submit(self, ctx: SubmitCtx, rng, record) -> None:
        pass

    def before_launch(self, ctx: LaunchCtx, rng, record) -> float:
        return 0.0

    def after_launch(self, ctx: LaunchCtx, rng, record) -> None:
        pass


def _matches(sel, index: int) -> bool:
    """Launch/submit selector: None = every, int = one, iterable = set."""
    if sel is None:
        return True
    if isinstance(sel, int):
        return index == sel
    return index in sel


@dataclasses.dataclass
class LatencySpike(Fault):
    """Add ``extra_s`` (+ jittered ``jitter_s``) to matching launches."""

    extra_s: float
    jitter_s: float = 0.0
    at_launches: object = None      # None = every launch
    every: int = 1                  # ... or every n-th matching launch
    tenant: str | None = None

    def before_launch(self, ctx, rng, record) -> float:
        if self.tenant is not None and ctx.tenant != self.tenant:
            return 0.0
        if not _matches(self.at_launches, ctx.index):
            return 0.0
        if self.every > 1 and ctx.index % self.every:
            return 0.0
        extra = self.extra_s + self.jitter_s * float(rng.random())
        record(FaultEvent("latency", "launch", ctx.index, ctx.tenant,
                          f"+{extra:.3f}s tier={ctx.tier}"))
        return extra


@dataclasses.dataclass
class ShardStall(Fault):
    """A straggling shard: the whole SPMD launch blocks on it.

    Mechanically identical to a latency spike (an SPMD program is as slow
    as its slowest shard), but logged as a stall so chaos reports can
    distinguish "everything slow" from "one shard wedged".
    """

    stall_s: float
    at_launches: object = None
    shard: int = 0
    tenant: str | None = None

    def before_launch(self, ctx, rng, record) -> float:
        if self.tenant is not None and ctx.tenant != self.tenant:
            return 0.0
        if not _matches(self.at_launches, ctx.index):
            return 0.0
        record(FaultEvent("shard_stall", "launch", ctx.index, ctx.tenant,
                          f"shard={self.shard} +{self.stall_s:.3f}s"))
        return self.stall_s


@dataclasses.dataclass
class FetchStall(Fault):
    """A slow/wedged host->device cold-block fetch on a TIERED tenant.

    Applies only when the tenant record carries a TieredPointStore
    (``tenant_obj.tiered`` — core/tiered.py); fully-resident tenants have
    no fetch to stall, so the fault is a no-op there.  Two regimes,
    matching what a real stalled DMA does:

    * ``stall_s`` within the store's ``fetch_timeout_s``: the copy is
      merely slow — the launch completes and the stall rides the clock
      like a latency spike, so deadlines and the cost model see it.
    * ``stall_s`` beyond ``fetch_timeout_s``: the store would abandon the
      wait and raise ``FetchTimeout`` — this fault does exactly that
      (after charging the timeout window to the clock), so the service's
      containment (retry/backoff/breaker, then the degradation ladder)
      is exercised instead of a microbatch wedging on the copy.
    """

    stall_s: float
    at_launches: object = None
    tenant: str | None = None

    def before_launch(self, ctx, rng, record) -> float:
        if self.tenant is not None and ctx.tenant != self.tenant:
            return 0.0
        if not _matches(self.at_launches, ctx.index):
            return 0.0
        store = getattr(ctx.tenant_obj, "tiered", None)
        if store is None:
            return 0.0
        timeout = getattr(store, "fetch_timeout_s", None)
        if timeout is not None and self.stall_s > timeout:
            record(FaultEvent(
                "fetch_stall", "launch", ctx.index, ctx.tenant,
                f"+{self.stall_s:.3f}s > fetch_timeout_s={timeout:.3f}s "
                f"-> FetchTimeout"))
            if ctx.service is not None:
                # A real timed-out fetch still costs the full wait window.
                ctx.service.clock.sleep(timeout)
            from repro.core.tiered import FetchTimeout
            raise FetchTimeout(
                f"injected: host->device fetch stalled {self.stall_s:.3f}s, "
                f"exceeding fetch_timeout_s={timeout:.3f}s "
                f"(launch {ctx.index}, tier {ctx.tier})")
        record(FaultEvent("fetch_stall", "launch", ctx.index, ctx.tenant,
                          f"+{self.stall_s:.3f}s tier={ctx.tier}"))
        return self.stall_s


@dataclasses.dataclass
class LaunchError(Fault):
    """Raise on matching launches (device loss, OOM, compile failure)."""

    at_launches: object = None
    tenant: str | None = None
    message: str = "injected launch failure"

    def before_launch(self, ctx, rng, record) -> float:
        if self.tenant is not None and ctx.tenant != self.tenant:
            return 0.0
        if not _matches(self.at_launches, ctx.index):
            return 0.0
        record(FaultEvent("error", "launch", ctx.index, ctx.tenant,
                          self.message))
        raise InjectedLaunchError(
            f"{self.message} (launch {ctx.index}, tier {ctx.tier})")


@dataclasses.dataclass
class PoisonQuery(Fault):
    """Corrupt one row of a matching submission's query block in place."""

    at_submits: object = 0
    row: int = 0
    value: float = float("nan")
    tenant: str | None = None

    def on_submit(self, ctx, rng, record) -> None:
        if self.tenant is not None and ctx.tenant != self.tenant:
            return
        if not _matches(self.at_submits, ctx.index):
            return
        r = min(self.row, ctx.queries.shape[0] - 1)
        ctx.queries[r, :] = self.value
        record(FaultEvent("poison", "submit", ctx.index, ctx.tenant,
                          f"row={r} value={self.value}"))


@dataclasses.dataclass
class CompactDuringSearch(Fault):
    """Compact (or mutate) the tenant's index between snapshot and launch.

    The service snapshots ``view()`` before launching, so a correct
    implementation returns bit-identical-to-snapshot results even though
    the index compacted underneath it mid-request; this fault makes that
    race happen on demand.  ``insert_rows > 0`` additionally appends that
    many copies of the index's first live row before compacting, so the
    compaction actually has segments to fold.
    """

    at_launches: object = 0
    tenant: str | None = None
    insert_rows: int = 0

    def before_launch(self, ctx, rng, record) -> float:
        if self.tenant is not None and ctx.tenant != self.tenant:
            return 0.0
        if not _matches(self.at_launches, ctx.index):
            return 0.0
        idx = getattr(ctx.tenant_obj, "index", None)
        if idx is None or not hasattr(idx, "compact"):
            return 0.0
        if self.insert_rows > 0:
            rows = np.asarray(idx.view().rows_view())[:1]
            idx.insert(np.repeat(rows, self.insert_rows, axis=0),
                       auto_compact=False)
        mode = idx.compact()
        record(FaultEvent("compact", "launch", ctx.index, ctx.tenant,
                          f"mode={mode} insert_rows={self.insert_rows}"))
        return 0.0


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------

class FaultPlan:
    """A composable, seeded set of faults plus the counters they key on.

    One plan = one deterministic chaos scenario: the n-th submit and the
    n-th launch of a run always see the same injections for the same
    seed, regardless of wall time.  ``events`` records everything that
    fired, newest last.
    """

    def __init__(self, faults=(), seed: int = 0):
        self.faults = list(faults)
        self.rng = np.random.default_rng(seed)
        self.submits = 0
        self.launches = 0
        self.events: list[FaultEvent] = []

    def _record(self, event: FaultEvent) -> None:
        self.events.append(event)

    def on_submit(self, tenant: str, queries: np.ndarray) -> None:
        ctx = SubmitCtx(index=self.submits, tenant=tenant, queries=queries)
        self.submits += 1
        for f in self.faults:
            f.on_submit(ctx, self.rng, self._record)

    def before_launch(self, tenant: str, tier: str, attempt: int,
                      tenant_obj=None, service=None) -> float:
        """Total injected latency for this launch; may raise instead."""
        ctx = LaunchCtx(index=self.launches, tenant=tenant, tier=tier,
                        attempt=attempt, tenant_obj=tenant_obj,
                        service=service)
        self.launches += 1
        extra = 0.0
        for f in self.faults:
            extra += float(f.before_launch(ctx, self.rng, self._record))
        return extra

    def after_launch(self, tenant: str, tier: str, attempt: int,
                     tenant_obj=None, service=None) -> None:
        ctx = LaunchCtx(index=self.launches - 1, tenant=tenant, tier=tier,
                        attempt=attempt, tenant_obj=tenant_obj,
                        service=service)
        for f in self.faults:
            f.after_launch(ctx, self.rng, self._record)

    def fired(self, kind: str) -> list[FaultEvent]:
        return [e for e in self.events if e.kind == kind]


def jittered_backoff(base_s: float, attempt: int, max_s: float,
                     rng) -> float:
    """Exponential backoff with full jitter: U(0.5, 1) * base * 2^attempt.

    THE backoff rule for launch retries (service and clients): capped at
    ``max_s``, jitter drawn from the caller's seeded generator so replays
    are deterministic and concurrent tenants never thundering-herd onto
    the same retry tick.
    """
    span = min(base_s * (2 ** attempt), max_s)
    return span * (0.5 + 0.5 * float(rng.random()))


Clock = Callable  # documentation alias: anything with now()/sleep(dt)
