"""Fault-tolerant multi-tenant retrieval front end over BrePartition search.

This is the layer between clients and ``knn_search_batch`` /
``distributed_knn`` that the engine-room code deliberately does not
provide: deadlines, admission control, graceful degradation, and failure
containment.  Robustness is the CONTRACT here, not a best effort:

* **Request lifecycle.**  ``submit(tenant, queries, k, deadline_s,
  target_recall)`` admits into a BOUNDED queue; a full queue rejects with
  ``retry_after`` (explicit backpressure — the service never buffers
  unboundedly).  ``k`` is validated against the tenant's LIVE point count
  and query rows against the Bregman family's domain
  (``core.search.validate_queries``) at admission, so malformed requests
  fail fast with a named row instead of deep in a compiled program.
  ``step()`` drains the queue by CROSS-REQUEST MICROBATCHING: requests
  sharing (tenant, k, target_recall) concatenate into one
  ``knn_search_batch`` launch whose query count is padded to a configured
  bucket size, so repeated traffic reuses compiled programs instead of
  compiling per request shape.

* **Degradation ladder** (paper §8 + Abdullah et al., arXiv 1108.0835 —
  trade accuracy for time instead of timing out):

      exact  ->  approx (§8 CDF shrink)  ->  partial (budget-capped)  ->  shed

  The ladder is COST-DRIVEN: a per-tenant launch-cost model (peak-tracking
  EWMA of observed launch seconds) prices each tier, and the microbatch
  enters at the highest tier whose price fits the remaining deadline.
  Exact-tier budget retries reuse ``fitted_budget`` but are capped by the
  remaining deadline instead of doubling forever; when time runs out the
  last capped result is returned as-is.  Every response carries a
  ``quality`` label (``exact | approx | partial | shed``) derived from
  what ACTUALLY happened — the per-row ``exact`` flags and the pipeline
  that ran — never from what was planned, so degradation is observable
  and truthful (tests compare exact-labeled responses bit-for-bit against
  a fault-free oracle).

* **Failure containment.**  Launches run behind a per-tenant CIRCUIT
  BREAKER (closed -> open after ``breaker_threshold`` consecutive
  failures -> half-open probe after ``breaker_cooldown_s`` -> closed on
  success); an open breaker sheds with ``retry_after`` instead of queuing
  doomed work.  Launch failures back off with seeded jittered exponential
  delays (``faults.jittered_backoff``), re-entering the ladder at
  whatever tier the post-backoff remaining deadline affords.  A launch
  that blocks past ``launch_timeout_s`` counts as a breaker failure even
  though its (completed) result is still used — slow shards open the
  breaker before they melt the queue.  Distributed tenants wire
  ``dist.knn.distributed_knn``'s per-launch timeout/hook parameters for
  the same behavior per internal retry.

* **Consistency under mutation.**  Each microbatch searches a SNAPSHOT
  (``view()``) taken before its first launch, so background
  insert/delete/compact on the mutable index never races an in-flight
  search — results are bit-identical to searching the snapshot.
  Poisoned INDEX rows (NaN / domain violations) found at registration are
  quarantined (tombstoned) and the tenant is marked degraded — contained,
  not crashed; poisoned QUERY rows are shed individually
  (``row_quality``), never dragging down their batchmates.

* **Determinism.**  The service reads time only through an injectable
  clock and takes an optional ``faults.FaultPlan``, so chaos scenarios
  (latency spikes, launch exceptions, poisoned queries,
  compaction-during-search, shard stalls) are seeded and replayable —
  see serve/faults.py and tests/test_retrieval_service.py.

See docs/serving_robustness.md for the lifecycle diagram and tuning guide.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque

import jax
import numpy as np

from repro.core import calibrate as breg_cal
from repro.core import search as bp
from repro.core.bregman import validate_rows
from repro.core.segments import SegmentedForest
from repro.core import tiered as tiered_store
from repro.dist import knn as dist_knn
from repro.launch import autotune

from .faults import FaultPlan, SystemClock, jittered_backoff

QUALITY_EXACT = "exact"
QUALITY_APPROX = "approx"
QUALITY_PARTIAL = "partial"
QUALITY_SHED = "shed"
_QORDER = {QUALITY_EXACT: 0, QUALITY_APPROX: 1, QUALITY_PARTIAL: 2,
           QUALITY_SHED: 3}
_LADDER = (QUALITY_EXACT, QUALITY_APPROX, QUALITY_PARTIAL)


def resolve_deadline_s(deadline_s, default_s: float) -> float:
    """THE per-request deadline resolver (brelint knob-contract).

    ``None`` picks the service default; an explicit deadline must be a
    finite positive number of seconds — zero/negative/NaN deadlines would
    make every request deadline-shed before its first launch, a config
    error worth rejecting at submission.
    """
    if deadline_s is None:
        return float(default_s)
    d = float(deadline_s)
    if not math.isfinite(d) or d <= 0.0:
        raise ValueError(
            f"deadline_s must be a finite positive number, got {deadline_s!r}")
    return d


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Tuning knobs — see docs/serving_robustness.md for guidance."""

    queue_depth: int = 64           # bounded admission queue (backpressure)
    max_batch: int = 32             # query rows per microbatch launch
    buckets: tuple = (1, 2, 4, 8, 16, 32)   # padded q shapes (program reuse)
    default_deadline_s: float = 1.0
    launch_timeout_s: float | None = 5.0    # breaker-failure threshold
    default_p_guarantee: float = 0.9        # approx tier's §8 p
    breaker_threshold: int = 3      # consecutive failures -> open
    breaker_cooldown_s: float = 2.0  # open -> half-open probe delay
    max_retries: int = 2            # failed-launch retries per microbatch
    backoff_base_s: float = 0.05
    backoff_max_s: float = 1.0
    # Cost floors, as multiples of the estimated launch cost: a tier is
    # only entered when the remaining deadline exceeds its floor.  Exact
    # needs headroom for a possible budget retry; partial runs one
    # minimal-budget launch.
    exact_margin: float = 2.0
    approx_margin: float = 1.0
    partial_margin: float = 0.5
    # A microbatch runs on its TIGHTEST member's deadline, so coupling a
    # fresh request to a nearly-expired one would degrade (or shed) the
    # fresh one.  A request only joins a batch while the batch's
    # max/min remaining-deadline ratio stays within this factor;
    # incompatible requests wait for the next tick's batch instead.
    deadline_spread: float = 2.0
    validate_index: bool = True     # quarantine poisoned rows at register
    record_snapshots: bool = False  # keep per-batch snapshot in meta (tests)


class CircuitBreaker:
    """closed -> open (threshold consecutive failures) -> half-open -> ...

    ``allow(now)`` answers "may a launch go out right now" and is
    SIDE-EFFECT-FREE: an open breaker says no until ``cooldown_s`` has
    passed, then answers yes.  The open -> half_open transition happens in
    ``begin_probe``, called only when a launch is ACTUALLY attempted — a
    caller that asks permission and then sheds anyway (deadline ran out
    between the check and the launch) leaves the breaker open with its
    cooldown clock intact instead of wedging it in a probe-in-flight
    state that nothing will ever resolve.  Half-open admits exactly one
    probe; the probe's outcome closes or re-opens.  Success in any state
    resets to closed.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 2.0):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.state = "closed"
        self.failures = 0
        self.opened_at = -math.inf
        self.opens = 0              # telemetry: times the breaker tripped

    def allow(self, now: float) -> bool:
        if self.state == "closed":
            return True
        if self.state == "open":
            return now - self.opened_at >= self.cooldown_s
        return False                # half-open probe in flight

    def begin_probe(self) -> None:
        """A launch is going out while open: mark it as the probe."""
        if self.state == "open":
            self.state = "half_open"

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0

    def record_failure(self, now: float) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.threshold:
            self.state = "open"
            self.opened_at = now
            self.failures = 0
            self.opens += 1

    def retry_after(self, now: float) -> float:
        if self.state == "open":
            return max(0.0, self.opened_at + self.cooldown_s - now)
        if self.state == "half_open":
            return self.cooldown_s  # probe in flight; retry after it lands
        return 0.0


class LaunchCostModel:
    """Peak-tracking launch-cost estimate in seconds.

    ``max(latest, 0.7 * est + 0.3 * latest)``: jumps to a spike
    immediately (deadline decisions must react to the FIRST slow launch,
    not the EWMA-smoothed fifth) and decays as healthy launches return.
    Starts optimistic (0.0): the first launch is always attempted and
    teaches the model; a too-early deadline is then missed by at most
    that one launch, which is the service's documented guarantee.
    """

    def __init__(self, decay: float = 0.7):
        self.decay = decay
        self._est: float | None = None

    def observe(self, dt: float) -> None:
        dt = float(dt)
        if self._est is None:
            self._est = dt
        else:
            self._est = max(dt, self.decay * self._est
                            + (1.0 - self.decay) * dt)

    def estimate(self) -> float:
        return 0.0 if self._est is None else self._est


@dataclasses.dataclass
class Tenant:
    """Per-tenant registry entry: index + isolation state."""

    name: str
    index: object                   # BallForest | SegmentedForest
    family: object
    family_name: str
    breaker: CircuitBreaker
    cost: LaunchCostModel
    p_guarantee: float
    degraded: bool = False          # poisoned rows were quarantined
    quarantined: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty((0,), np.int32))
    sharded: object = None          # dist.knn.ShardedForest | None
    mesh: object = None
    # Streaming-scan block size, resolved from the autotuner table ONCE at
    # registration (launch/autotune.py) so every launch for this tenant
    # reuses the same compiled program; None = DEFAULT_BLOCK_ROWS.
    block_rows: int | None = None
    # Out-of-core residency (core/tiered.py): a TieredPointStore snapshot
    # frozen at registration, used as the launch snapshot in place of
    # _as_forest(index).  None = fully device-resident.
    tiered: object = None

    @property
    def live_n(self) -> int:
        return int(getattr(self.index, "live_n", self.index.n))


@dataclasses.dataclass
class RetrievalResponse:
    """What a ticket resolves to.  ``quality`` is the headline label.

    ``quality`` describes the retrieval tier of the NON-flagged rows
    (worst row wins: exact < approx < partial < shed); rows the admission
    gate flagged as poisoned are listed in ``flagged_rows`` and carry
    ``row_quality == "shed"`` with ids -1 / dists inf — a poisoned row
    never degrades its batchmates, only itself.  ``retry_after`` is set
    on backpressure sheds (full queue, open breaker).
    """

    uid: int
    tenant: str
    quality: str
    ids: np.ndarray                 # (q, k) int32, -1 for shed rows
    dists: np.ndarray               # (q, k) float32, inf for shed rows
    row_quality: list
    flagged_rows: list
    shed_reason: str | None = None
    retry_after: float | None = None
    error: str | None = None
    tenant_degraded: bool = False
    latency_s: float = 0.0
    deadline_met: bool = True
    # Measured recall estimate for ``quality="approx"`` responses: the
    # calibration curve's value at the shrink level that actually ran
    # (core/calibrate.py).  None for exact responses (recall is 1.0 by
    # construction) and for approx responses of uncalibrated tenants
    # (nothing was measured — the honest answer is "unknown").
    expected_recall: float | None = None
    meta: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Ticket:
    uid: int
    done: bool = False
    response: RetrievalResponse | None = None


@dataclasses.dataclass
class _Request:
    uid: int
    tenant: str
    queries: np.ndarray             # (q, d) float32, poisoned rows replaced
    k: int
    deadline: float                 # absolute clock time
    target_recall: float | None
    submitted_at: float
    ok_rows: np.ndarray             # (q,) bool — admission gate verdict
    ticket: Ticket


class RetrievalService:
    """The multi-tenant front end.  Single-threaded and deterministic:
    ``submit`` enqueues, ``step`` forms and runs microbatches.  A real
    deployment calls ``step`` from its event loop; tests drive it
    directly with a virtual clock.
    """

    def __init__(self, config: ServiceConfig | None = None, *,
                 clock=None, faults: FaultPlan | None = None,
                 seed: int = 0):
        self.config = config or ServiceConfig()
        self.clock = clock or SystemClock()
        self.faults = faults
        self.tenants: dict[str, Tenant] = {}
        self.queue: deque[_Request] = deque()
        self._uid = 0
        self._rng = np.random.default_rng(seed)
        self.counters = {
            "submitted": 0, "rejected_queue_full": 0, "rejected_bad_k": 0,
            "completed": 0, "launches": 0, "launch_failures": 0,
            "launch_timeouts": 0, "escalations": 0, "breaker_sheds": 0,
            "deadline_sheds": 0, "poisoned_rows": 0,
            QUALITY_EXACT: 0, QUALITY_APPROX: 0, QUALITY_PARTIAL: 0,
            QUALITY_SHED: 0,
        }

    # -- tenants ------------------------------------------------------------

    def register_tenant(self, name: str, index, *, mesh=None, axis="data",
                        p_guarantee: float | None = None,
                        calibrate: bool = False,
                        calibrate_k: int = 10,
                        resident_bytes: int | None = None,
                        prefetch_depth: int | None = None) -> Tenant:
        """Admit an index into the registry, quarantining poisoned rows.

        With ``config.validate_index`` every live row is checked against
        the family domain (NaN / open-bound violations).  Offenders are
        TOMBSTONED — an immutable BallForest is first wrapped into a
        :class:`SegmentedForest` so the quarantine is a mutation, not a
        rebuild — and the tenant is marked ``degraded`` with the
        quarantined ids kept for audit.  Searches then run exact over the
        clean live set; every response advertises ``tenant_degraded``.

        ``calibrate=True`` fits a recall-calibration curve at registration
        when the index does not already carry one (the preferred place is
        ``build_index(calibrate=True)`` — this is the catch-up path for
        indexes built before calibration existed).  The fit runs AFTER
        quarantine (measured over the clean live set) and BEFORE sharding
        (the sharded snapshot carries the curve).

        ``mesh`` shards the (validated) index point-major for
        ``distributed_knn`` launches; the sharded snapshot is FROZEN at
        registration — re-register after mutating to reshard.

        ``resident_bytes`` tiers the tenant out-of-core (core/tiered.py):
        cold point blocks live in host RAM behind that device-cache
        budget and launches run against the TieredPointStore snapshot —
        frozen at registration, exactly the sharding policy.
        ``prefetch_depth`` sets its double-buffer depth.  Mutually
        exclusive with ``mesh`` (a shard IS a residency decision).
        """
        bp.validate_p_guarantee(p_guarantee)
        resident_bytes = tiered_store.resolve_resident_bytes(resident_bytes)
        prefetch_depth = tiered_store.resolve_prefetch_depth(prefetch_depth)
        if mesh is not None and resident_bytes is not None:
            raise ValueError(
                "resident_bytes and mesh are mutually exclusive: a sharded "
                "tenant's residency is the shard layout")
        fam = index.family
        quarantined = np.empty((0,), np.int32)
        if self.config.validate_index:
            if not isinstance(index, SegmentedForest):
                rows = np.asarray(index.rows_view())
                live = np.asarray(index.point_ids) >= 0
                ok = validate_rows(fam, rows, mode="mask")
                if bool((live & ~ok).any()):
                    index = SegmentedForest.from_forest(index)
            if isinstance(index, SegmentedForest):
                quarantined = index.quarantine()
        if calibrate:
            index = breg_cal.ensure_calibration(index, k=calibrate_k)
        sharded = None
        if mesh is not None:
            sharded = dist_knn.shard_index(index, mesh, axis)
        # Pin the tuned block size now: the table lookup keys on the live
        # row count, the service's largest query bucket (the steady-state
        # heavy-traffic shape) and the storage tier.  A table miss pins
        # None and the search layer uses its default.
        live_n = int(getattr(index, "live_n", index.n))
        block_rows = autotune.lookup_block_rows(
            max(live_n, 1), max(self.config.buckets),
            storage=getattr(index, "storage", None))
        tiered = None
        if resident_bytes is not None:
            # Snapshot AFTER quarantine/calibration so the store serves
            # the same clean live set as a resident launch would; a
            # wedged fetch surfaces within one launch-timeout window.
            tiered = tiered_store.TieredPointStore.from_index(
                index, resident_bytes=resident_bytes,
                prefetch_depth=prefetch_depth, block_rows=block_rows,
                fetch_timeout_s=self.config.launch_timeout_s)
        tenant = Tenant(
            name=name, index=index, family=fam,
            family_name=index.family_name,
            breaker=CircuitBreaker(self.config.breaker_threshold,
                                   self.config.breaker_cooldown_s),
            cost=LaunchCostModel(),
            p_guarantee=(self.config.default_p_guarantee
                         if p_guarantee is None else float(p_guarantee)),
            degraded=quarantined.size > 0, quarantined=quarantined,
            sharded=sharded, mesh=mesh, block_rows=block_rows,
            tiered=tiered)
        self.tenants[name] = tenant
        return tenant

    def warm(self, tenant: str, shapes=None) -> dict:
        """Pre-compile the launch programs a tenant's traffic will hit.

        A cold first launch is dominated by jit compilation (~1s), which
        both blows the first requests' deadlines AND teaches the launch
        cost model that every launch costs a second — the ladder then
        sheds healthy traffic (docs/serving_robustness.md).  Production
        deployments warmed buckets by replaying synthetic requests
        through ``search_sync``; this is that idiom as a first-class API,
        minus the side effects: launches run DIRECTLY against the
        tenant's snapshot, so no counters, breaker state, or cost-model
        observations are touched.

        ``shapes`` is an iterable of ``(q, k)`` pairs mirroring expected
        traffic; each ``q`` is rounded up to its service bucket (the
        shape real microbatches launch at) and both ladder entry tiers —
        exact and §8 approx at the tenant's ``p_guarantee`` — are
        compiled.  Default: every configured bucket at k=10.

        For a tiered tenant (``resident_bytes``) this also pre-populates
        the device-side block cache up to the residency budget
        (``TieredPointStore.warm_cache``), so first queries pay neither
        compilation nor host->device transfer.
        """
        t = self.tenants[tenant]
        if shapes is None:
            shapes = [(b, 10) for b in self.config.buckets]
        snapshot = (t.tiered if t.tiered is not None
                    else bp._as_forest(t.index))
        # Ones-rows are inside every family's domain (the same reasoning
        # as the index's inert fill), so synthetic warmup queries are
        # domain-safe without sampling tenant data.
        programs = []
        for q, k in shapes:
            q, k = int(q), int(k)
            bucket = next((b for b in self.config.buckets if b >= q), q)
            if (bucket, k) in programs:
                continue
            programs.append((bucket, k))
            ys = np.ones((bucket, snapshot.d), np.float32)
            budget = bp.default_budget(snapshot, k)
            if t.sharded is not None:
                # Sharded tenants launch distributed_knn, so warm THAT
                # program, not the single-host pipeline.
                for ap in (None, np.float32(t.p_guarantee)):
                    res = dist_knn.distributed_knn(
                        t.sharded, ys, family=t.family_name, k=k,
                        budget=budget, block_rows=t.block_rows,
                        approx_p=ap)
                    jax.block_until_ready((res.ids, res.dists))
                continue
            res = bp.knn_search_batch(snapshot, ys, k, budget,
                                      block_rows=t.block_rows,
                                      validate=False)
            jax.block_until_ready((res.ids, res.dists))
            res = bp.knn_search_batch_approx(
                snapshot, ys, k, budget, np.float32(t.p_guarantee),
                block_rows=t.block_rows, validate=False)
            jax.block_until_ready((res.ids, res.dists))
        out = {"tenant": tenant, "programs": programs, "tiered": None}
        if t.tiered is not None:
            out["tiered"] = t.tiered.warm_cache()
        return out

    # -- admission ----------------------------------------------------------

    def submit(self, tenant: str, queries, k: int, *,
               deadline_s: float | None = None,
               target_recall: float | None = None) -> Ticket:
        """Admit one request; returns a :class:`Ticket`.

        Backpressure and validation failures resolve the ticket
        IMMEDIATELY (``quality == "shed"`` with ``shed_reason`` /
        ``retry_after``) rather than raising — rejection is part of the
        response contract, not an exception.  Unknown tenants and
        malformed knobs (``target_recall`` outside [0, 1], non-positive
        ``deadline_s``) are the programming errors that raise.
        """
        if tenant not in self.tenants:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"registered: {sorted(self.tenants)}")
        breg_cal.validate_target_recall(target_recall)
        t = self.tenants[tenant]
        now = self.clock.now()
        qs = np.array(queries, np.float32, copy=True)
        if qs.ndim == 1:
            qs = qs[None, :]
        uid = self._uid
        self._uid += 1
        self.counters["submitted"] += 1
        ticket = Ticket(uid=uid)

        if k < 1 or k > t.live_n:
            # Up-front k validation: k > live_n would otherwise surface as
            # a ValueError deep inside the pipeline (or, worse, as padded
            # sentinel rows in the result).
            self.counters["rejected_bad_k"] += 1
            self._resolve_shed(
                ticket, uid, tenant, qs.shape[0], k, now, now,
                reason="bad_k",
                error=(f"k={k} is outside [1, live_n={t.live_n}] for "
                       f"tenant {tenant!r}"))
            return ticket

        if self.faults is not None:
            self.faults.on_submit(tenant, qs)   # may poison rows in place

        ok = validate_rows(t.family, qs, mode="mask")
        self.counters["poisoned_rows"] += int((~ok).sum())
        if len(self.queue) >= self.config.queue_depth:
            # Reject-with-retry-after: the queue is the ONLY buffer, and
            # it is bounded.  The hint prices the backlog with the cost
            # model so well-behaved clients spread their retries.
            self.counters["rejected_queue_full"] += 1
            est = max(self.tenants[tenant].cost.estimate(),
                      self.config.backoff_base_s)
            batches = math.ceil(len(self.queue) / self.config.max_batch)
            self._resolve_shed(
                ticket, uid, tenant, qs.shape[0], k, now, now,
                reason="queue_full", retry_after=est * batches)
            return ticket

        deadline = now + resolve_deadline_s(
            deadline_s, self.config.default_deadline_s)
        self.queue.append(_Request(
            uid=uid, tenant=tenant, queries=qs, k=int(k), deadline=deadline,
            target_recall=target_recall, submitted_at=now, ok_rows=ok,
            ticket=ticket))
        return ticket

    # -- the service loop ---------------------------------------------------

    def step(self) -> int:
        """One scheduling tick: shed expired work, launch microbatches.

        Returns the number of requests resolved this tick.
        """
        resolved = 0
        now = self.clock.now()
        # Expire queued requests whose deadline already passed — shedding
        # in O(1) beats launching work nobody is waiting for.
        still = deque()
        for req in self.queue:
            if req.deadline <= now:
                self.counters["deadline_sheds"] += 1
                self._resolve_shed(req.ticket, req.uid, req.tenant,
                                   req.queries.shape[0], req.k,
                                   req.submitted_at, now, reason="deadline",
                                   deadline=req.deadline)
                resolved += 1
            else:
                still.append(req)
        self.queue = still

        # Microbatch: FIFO within (tenant, k, target_recall) groups, up to
        # max_batch query rows per launch group.  The TENANT component is
        # load-bearing for correctness, not just isolation: target_recall
        # resolves to a per-tenant shrink factor through each index's own
        # calibration curve, so two tenants sharing a target must never
        # share a launch (tests/test_calibration.py pins this down).
        groups: dict[tuple, list[_Request]] = {}
        order: list[tuple] = []
        for req in self.queue:
            key = (req.tenant, req.k, req.target_recall)
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(req)
        spread = self.config.deadline_spread
        for key in order:
            reqs, rows = [], 0
            min_rem = max_rem = 0.0
            for req in groups[key]:
                rem = req.deadline - now    # > 0: expiry sweep ran above
                if reqs:
                    if rows + req.queries.shape[0] > self.config.max_batch:
                        break
                    # Deadline-compatibility guard: the batch runs on its
                    # tightest deadline, so don't couple requests whose
                    # remaining deadlines differ by more than the
                    # configured spread — the rest of the group waits for
                    # the next tick rather than degrading with this one.
                    if max(max_rem, rem) > spread * min(min_rem, rem):
                        break
                reqs.append(req)
                rows += req.queries.shape[0]
                min_rem = min(min_rem, rem) if len(reqs) > 1 else rem
                max_rem = max(max_rem, rem) if len(reqs) > 1 else rem
            for req in reqs:
                self.queue.remove(req)
            resolved += self._run_microbatch(self.tenants[key[0]], reqs,
                                             key[2])
        return resolved

    def run_until_drained(self, max_steps: int = 1000) -> None:
        """Drive ``step`` until the queue empties (bounded — never hangs)."""
        for _ in range(max_steps):
            if not self.queue:
                return
            self.step()
        raise RuntimeError(
            f"queue not drained after {max_steps} steps "
            f"({len(self.queue)} requests left) — scheduler stuck?")

    def search_sync(self, tenant: str, queries, k: int, *,
                    deadline_s: float | None = None,
                    target_recall: float | None = None) -> RetrievalResponse:
        """Blocking convenience path: submit + step until resolved.

        The route in-process hooks use (serve/knnlm.py): one caller, no
        event loop, but the same admission gate, ladder, and labels.
        """
        ticket = self.submit(tenant, queries, k, deadline_s=deadline_s,
                             target_recall=target_recall)
        steps = 0
        while not ticket.done:
            self.step()
            steps += 1
            if steps > 1000:
                raise RuntimeError("search_sync: ticket never resolved")
        return ticket.response

    def stats(self) -> dict:
        """Counter snapshot plus per-tenant breaker/degradation state."""
        out = dict(self.counters)
        out["queued"] = len(self.queue)
        out["tenants"] = {
            name: {"breaker": t.breaker.state,
                   "breaker_opens": t.breaker.opens,
                   "degraded": t.degraded,
                   "quarantined": int(t.quarantined.size),
                   "est_launch_s": t.cost.estimate(),
                   "live_n": t.live_n}
            for name, t in self.tenants.items()}
        return out

    # -- microbatch execution -----------------------------------------------

    def _run_microbatch(self, tenant: Tenant, reqs: list,
                        target_recall) -> int:
        """Run one microbatch; returns how many requests were RESOLVED
        (a deadline shed requeues batchmates whose own deadlines still
        have slack, so the count can be less than ``len(reqs)``)."""
        cfg = self.config
        now = self.clock.now()
        deadline = min(r.deadline for r in reqs)

        # Assemble the query block: poisoned rows are replaced by the
        # first valid row in the batch (the launch math must stay finite)
        # and masked out of the results afterwards.
        blocks = [r.queries for r in reqs]
        ys = np.concatenate(blocks, axis=0)
        ok = np.concatenate([r.ok_rows for r in reqs])
        if not ok.any():
            for r in reqs:
                self._resolve_shed(r.ticket, r.uid, r.tenant,
                                   r.queries.shape[0], r.k, r.submitted_at,
                                   now, reason="poisoned",
                                   deadline=r.deadline)
            return len(reqs)
        filler = ys[int(np.argmax(ok))]
        ys[~ok] = filler
        q_total = ys.shape[0]
        bucket = next((b for b in cfg.buckets if b >= q_total), q_total)
        if bucket > q_total:
            ys = np.concatenate(
                [ys, np.broadcast_to(filler, (bucket - q_total,
                                              ys.shape[1]))])

        if not tenant.breaker.allow(now):
            self.counters["breaker_sheds"] += 1
            retry = tenant.breaker.retry_after(now)
            for r in reqs:
                self._resolve_shed(r.ticket, r.uid, r.tenant,
                                   r.queries.shape[0], r.k, r.submitted_at,
                                   now, reason="breaker_open",
                                   retry_after=retry, deadline=r.deadline)
            return len(reqs)

        # Snapshot BEFORE any launch: background insert/delete/compact on
        # the mutable index (including fault-injected compactions) cannot
        # perturb this microbatch's results.  A tiered tenant launches
        # against its (construction-time-frozen) TieredPointStore — same
        # results bit-for-bit, cold rows fetched on envelope admission.
        snapshot = (tenant.tiered if tenant.tiered is not None
                    else bp._as_forest(tenant.index))
        k = reqs[0].k
        # Resolve the §8 shrink level from THIS tenant's snapshot: a
        # client target_recall inverts the index's measured calibration
        # curve (core/calibrate.py; uncalibrated indexes fall back to
        # p = target, the historical behavior, with a one-time warning) —
        # target_recall and p_guarantee are different quantities and are
        # never conflated on a calibrated index.  Two tenants sharing a
        # target_recall may resolve to different p: the microbatch key in
        # step() is tenant-scoped, so each batch reaches here with one
        # tenant and one resolved shrink.
        cal = getattr(snapshot, "calibration", None)
        if target_recall is None:
            p = tenant.p_guarantee
            expected = None if cal is None else cal.expected_recall(p)
        else:
            p, expected = breg_cal.resolve_p_guarantee(snapshot,
                                                       target_recall)

        meta: dict = {"bucket": bucket, "attempts": 0, "tier_path": [],
                      "p_guarantee": p}
        if expected is not None:
            meta["expected_recall"] = expected
        if cfg.record_snapshots:
            meta["snapshot"] = snapshot
        res, used_approx, error = None, False, None
        failures = 0
        while True:
            now = self.clock.now()
            tier = self._choose_tier(tenant, deadline - now, target_recall)
            if tier == QUALITY_SHED:
                break
            meta["tier_path"].append(tier)
            meta["attempts"] += 1
            try:
                res, used_approx, budget = self._run_tier(
                    tenant, snapshot, ys, k, tier, p, deadline)
                meta["budget"] = budget
                break
            except Exception as e:  # noqa: BLE001 — containment layer
                failures += 1
                self.counters["launch_failures"] += 1
                tenant.breaker.record_failure(self.clock.now())
                error = f"{type(e).__name__}: {e}"
                if failures > cfg.max_retries:
                    break
                if not tenant.breaker.allow(self.clock.now()):
                    break
                back = jittered_backoff(cfg.backoff_base_s, failures - 1,
                                        cfg.backoff_max_s, self._rng)
                self.clock.sleep(
                    min(back, max(0.0, deadline - self.clock.now())))

        finished = self.clock.now()
        if res is None:
            reason = "launch_failed" if error else "deadline"
            if not error:
                self.counters["deadline_sheds"] += 1
            retry = (tenant.breaker.retry_after(finished)
                     if tenant.breaker.state == "open" else None)
            resolved = 0
            requeue = []
            for r in reqs:
                if (reason == "deadline" and r.deadline > deadline
                        and r.deadline > finished):
                    # The BATCH deadline (its tightest member) ran out,
                    # not this request's: requeue it so it retries on its
                    # own, later, deadline instead of shedding healthy
                    # traffic.  The batch min strictly increases each
                    # round, so this terminates.
                    requeue.append(r)
                    continue
                self._resolve_shed(r.ticket, r.uid, r.tenant,
                                   r.queries.shape[0], r.k, r.submitted_at,
                                   finished, reason=reason, error=error,
                                   retry_after=retry, meta=dict(meta),
                                   deadline=r.deadline)
                resolved += 1
            for r in reversed(requeue):     # back to the head, FIFO order
                self.queue.appendleft(r)
            return resolved

        ids = np.asarray(res.ids)[:q_total]
        dists = np.asarray(res.dists)[:q_total]
        exact = np.asarray(res.exact)[:q_total]
        row = 0
        for r in reqs:
            q = r.queries.shape[0]
            sl = slice(row, row + q)
            self._resolve(r, ids[sl].copy(), dists[sl].copy(), exact[sl],
                          ok[sl], used_approx, finished, dict(meta),
                          expected_recall=(expected if used_approx
                                           else None))
            row += q
        return len(reqs)

    def _choose_tier(self, tenant: Tenant, remaining: float,
                     target_recall) -> str:
        """Highest ladder tier whose cost floor fits the remaining time."""
        cfg = self.config
        est = tenant.cost.estimate()
        floors = {QUALITY_EXACT: cfg.exact_margin * est,
                  QUALITY_APPROX: cfg.approx_margin * est,
                  QUALITY_PARTIAL: cfg.partial_margin * est}
        start = 0
        if target_recall is not None and target_recall < 1.0:
            start = 1               # the client asked for the §8 trade
        if remaining <= 0:
            return QUALITY_SHED
        for tier in _LADDER[start:]:
            if remaining >= floors[tier]:
                return tier
        return QUALITY_SHED

    def _run_tier(self, tenant: Tenant, snapshot, ys, k: int, tier: str,
                  p: float, deadline: float):
        """Run one ladder tier to completion; returns (result, used_approx,
        budget).  Budget retries inside the exact/approx tiers reuse the
        ``fitted_budget`` machinery but stop when the NEXT launch would
        not fit the remaining deadline — the budget-capped partial path.
        """
        cfg = self.config
        approx = tier == QUALITY_APPROX

        def stop_retry() -> bool:
            return (self.clock.now() + tenant.cost.estimate()) > deadline

        if tenant.sharded is not None:
            budget = bp.default_budget(snapshot, k)
            if tier == QUALITY_PARTIAL:
                budget = bp.fitted_budget(snapshot, k, 2 * k)
            res = self._launch(
                tenant, tier,
                lambda: dist_knn.distributed_knn(
                    tenant.sharded, ys,
                    family=tenant.family_name, k=k, budget=budget,
                    block_rows=tenant.block_rows,
                    approx_p=(p if approx else None),
                    stop_retry=stop_retry,
                    launch_hook=tenant.cost.observe,
                    launch_timeout_s=cfg.launch_timeout_s,
                    clock=self.clock.now))
            return res, approx, budget

        if tier == QUALITY_PARTIAL:
            budget = bp.fitted_budget(snapshot, k, 2 * k)
            res = self._launch(
                tenant, tier,
                lambda: bp.knn_search_batch(snapshot, ys, k, budget,
                                            block_rows=tenant.block_rows,
                                            validate=False))
            return res, False, budget

        budget = bp.default_budget(snapshot, k)
        while True:
            b = budget
            if approx:
                res = self._launch(
                    tenant, tier,
                    lambda: bp.knn_search_batch_approx(
                        snapshot, ys, k, b, np.float32(p),
                        block_rows=tenant.block_rows, validate=False))
            else:
                res = self._launch(
                    tenant, tier,
                    lambda: bp.knn_search_batch(snapshot, ys, k, b,
                                                block_rows=tenant.block_rows,
                                                validate=False))
            if bool(np.asarray(res.exact).all()) or budget >= snapshot.n:
                return res, approx, budget
            if stop_retry():
                # Deadline-capped: keep the partial result instead of
                # doubling forever (the rows that fit are still exact).
                return res, approx, budget
            self.counters["escalations"] += 1
            budget = bp.fitted_budget(
                snapshot, k, int(np.asarray(res.num_candidates).max()))

    def _launch(self, tenant: Tenant, tier: str, thunk):
        """One guarded launch: faults, timing, cost model, breaker."""
        cfg = self.config
        attempt = self.counters["launches"]
        # A launch is really going out now: if the breaker was open (and
        # past cooldown — _run_microbatch checked allow()), this is the
        # half-open probe.  Any exception from here on reaches the
        # caller's record_failure, so the probe always resolves.
        tenant.breaker.begin_probe()
        # The timer starts BEFORE the fault hook: anything that stalls the
        # launch path synchronously (an injected compaction, a seized GIL)
        # is launch cost as far as deadlines and the cost model are
        # concerned — unattributed stalls would silently erode the
        # "deadline + one launch" guarantee.
        t0 = self.clock.now()
        extra = 0.0
        if self.faults is not None:
            extra = self.faults.before_launch(
                tenant.name, tier, attempt, tenant_obj=tenant, service=self)
        timed_out = False
        try:
            res = thunk()
            jax.block_until_ready(res)
        except dist_knn.LaunchTimeout as e:
            # The launch COMPLETED but blocked past the timeout: use the
            # result, count the failure (slow shards must trip the
            # breaker before they wedge the queue).
            if e.result is None:
                raise
            res, timed_out = e.result, True
        if extra > 0:
            self.clock.sleep(extra)
        elapsed = self.clock.now() - t0
        tenant.cost.observe(elapsed)
        self.counters["launches"] += 1
        if self.faults is not None:
            self.faults.after_launch(tenant.name, tier, attempt,
                                     tenant_obj=tenant, service=self)
        if timed_out or (cfg.launch_timeout_s is not None
                         and elapsed > cfg.launch_timeout_s):
            self.counters["launch_timeouts"] += 1
            tenant.breaker.record_failure(self.clock.now())
        else:
            tenant.breaker.record_success()
        return res

    # -- response assembly --------------------------------------------------

    def _resolve(self, req: _Request, ids, dists, exact, ok, used_approx,
                 finished: float, meta: dict,
                 expected_recall: float | None = None) -> None:
        tenant = self.tenants[req.tenant]
        row_quality = []
        for i in range(ids.shape[0]):
            if not ok[i]:
                row_quality.append(QUALITY_SHED)
                ids[i, :] = -1
                dists[i, :] = np.inf
            elif bool(exact[i]):
                row_quality.append(QUALITY_APPROX if used_approx
                                   else QUALITY_EXACT)
            else:
                row_quality.append(QUALITY_PARTIAL)
        flagged = [i for i, o in enumerate(ok) if not o]
        valid = [q for i, q in enumerate(row_quality) if ok[i]]
        quality = (max(valid, key=_QORDER.__getitem__) if valid
                   else QUALITY_SHED)
        self.counters[quality] += 1
        self.counters["completed"] += 1
        req.ticket.response = RetrievalResponse(
            uid=req.uid, tenant=req.tenant, quality=quality, ids=ids,
            dists=dists, row_quality=row_quality, flagged_rows=flagged,
            tenant_degraded=tenant.degraded,
            latency_s=finished - req.submitted_at,
            deadline_met=finished <= req.deadline,
            expected_recall=expected_recall, meta=meta)
        req.ticket.done = True

    def _resolve_shed(self, ticket: Ticket, uid: int, tenant: str, q: int,
                      k: int, submitted: float, finished: float, *,
                      reason: str, retry_after: float | None = None,
                      error: str | None = None, meta: dict | None = None,
                      deadline: float | None = None) -> None:
        t = self.tenants.get(tenant)
        self.counters[QUALITY_SHED] += 1
        self.counters["completed"] += 1
        # Clamp the sentinel shape: ``k`` may be the UNVALIDATED value a
        # bad_k rejection is bouncing (k=1e9 must not allocate its own
        # rejection into an OOM); admitted requests have k <= live_n, so
        # their shape is unchanged.
        kk = max(1, min(int(k), t.live_n)) if t is not None else 1
        ticket.response = RetrievalResponse(
            uid=uid, tenant=tenant, quality=QUALITY_SHED,
            ids=np.full((q, kk), -1, np.int32),
            dists=np.full((q, kk), np.inf, np.float32),
            row_quality=[QUALITY_SHED] * q, flagged_rows=[],
            shed_reason=reason, retry_after=retry_after, error=error,
            tenant_degraded=bool(t.degraded) if t else False,
            latency_s=finished - submitted,
            deadline_met=(True if deadline is None
                          else bool(finished <= deadline)),
            meta=meta or {})
        ticket.done = True
