"""BallForest — the TPU-native BB-forest (paper §6, adapted per DESIGN.md §2).

One flat Bregman-ball table per subspace (IVF-style, no pointer chasing),
all tables indexing the SAME physical point order.  The shared order is the
paper's BB-forest layout trick: points are sorted by the reference
subspace's cluster id, so candidate gathers from different subspaces touch
overlapping regions (the TPU analogue of shared disk pages, boosted by PCCP
making subspace clusterings similar).

Pruning uses the tuple-space cluster lower bound (DESIGN.md §3.3):

    LB_cluster(i) = alpha_min[c,i] + qconst[i] - sqrt_gamma_max[c,i]*sqrt_delta[i]
                  <= min_{x in c} D_f(x_i., y_i.)

so "LB_cluster > qb_i" prunes cluster c in subspace i without any member
distance evaluation, and never prunes a true Theorem-3 candidate.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .bregman import BregmanFamily, get_family
from .transform import Partition, make_partition, p_transform
from .partition import build_pccp_partition, fit_cost_model
from .clustering import kmeans, cluster_stats
from . import quantize as qz

Array = jax.Array


@dataclasses.dataclass
class BallForest:
    """Immutable search index. All arrays live on device (or sharded).

    Two storage tiers share this one dataclass (``storage`` is static):

    * ``"f32"`` — the original layout: every point-major table fp32.
    * ``"int8"`` — ``data``/``alpha``/``sqrt_gamma``/``alpha_min_pt``/
      ``sqrt_gamma_max_pt`` hold int8 CODES and the ``*_scale``/``*_zp``
      companions hold the per-row affine decode (core/quantize.py).  The
      index's point set is the DEQUANTIZED rows (:meth:`rows_view`); the
      search pipeline stays exact over that set because filter bounds are
      inflated by the stat rounding error and corner stats are
      directed-rounded (conservative) at build time.

    Never read ``data``/``alpha``/... raw in new code — go through
    :meth:`rows_view` / the dequant helpers in core/search.py, which are
    the single place the storage variants branch.
    """

    family_name: str
    partition: Partition
    num_clusters: int
    data: Array           # (n, d)  points in shared layout order (codes in int8)
    point_ids: Array      # (n,)    original ids (layout -> original)
    alpha: Array          # (n, M)  P-tuple alpha (codes in int8)
    sqrt_gamma: Array     # (n, M)  P-tuple sqrt(gamma) (codes in int8)
    assign: Array         # (n, M)  cluster id of each point per subspace
    alpha_min: Array      # (M, C)  per-cluster min alpha
    sqrt_gamma_max: Array # (M, C)  per-cluster max sqrt(gamma)
    counts: Array         # (M, C)
    centers: Array        # (M, C, w) cluster centers (diagnostics/benchmarks)
    beta_samples: Array   # (S,) sorted empirical beta_xy sample (approx search)
    alpha_min_pt: Array       # (n, M)  own-cluster corner alpha_min per point
    sqrt_gamma_max_pt: Array  # (n, M)  own-cluster corner sqrt_gamma_max per point
    gamma_edges: Array    # (M, nb-1) gamma-bucket quantile edges (for appends)
    storage: str = "f32"      # "f32" | "int8" — static (jit cache key)
    # Per-block corner envelopes over ENV_BLOCK_ROWS-row groups of the
    # layout: row e holds the tightest alpha_min / loosest sqrt_gamma_max of
    # rows [e*ENV_BLOCK_ROWS, (e+1)*ENV_BLOCK_ROWS) — always fp32 (in the
    # int8 tier they are reduced over the DECODED directed-rounded corners,
    # so they dominate exactly what the per-point test decodes).  The
    # streaming batched prune tests a whole block against these before
    # touching its per-point tile and skips blocks no query admits
    # (core/search._stream_prune_compact).  Tiny (n / ENV_BLOCK_ROWS rows),
    # replicated on every shard.
    env_alpha_min: Array | None = None        # (nE, M) fp32
    env_sqrt_gamma_max: Array | None = None   # (nE, M) fp32
    data_scale: Array | None = None   # (n,) data row affine scale (int8 tier)
    data_zp: Array | None = None      # (n,) data row affine zero-point
    alpha_scale: Array | None = None  # (n,) filter-stat decode, round-nearest
    alpha_zp: Array | None = None
    sg_scale: Array | None = None
    sg_zp: Array | None = None
    amin_scale: Array | None = None   # (n,) corner decode, floor-rounded
    amin_zp: Array | None = None
    gmax_scale: Array | None = None   # (n,) corner decode, ceil-rounded
    gmax_zp: Array | None = None
    # Host-only recall calibration (core/calibrate.py RecallCalibration) —
    # deliberately NOT part of the pytree flatten: traced code never reads
    # it (a target_recall inverts the curve on the HOST before any launch),
    # and keeping it out of the statics/leaves means attaching or swapping
    # a curve can never fragment a jit cache.  It rides along through every
    # dataclasses.replace-based index op (pad / slice / concat / shard /
    # tombstone / quantize / envelope refresh) and comes back None from
    # tree_unflatten — i.e. it does not survive a raw jax.tree.map
    # round-trip, which only traced internals perform.
    calibration: object | None = None

    # Fields deliberately excluded from BOTH flatten sides: host-only
    # payload that does not survive a jax.tree.map round-trip (the
    # brelint pytree-contract pass requires every dataclass field to be
    # dynamic, static aux, or listed here — docs/static_analysis.md).
    HOST_ONLY_FIELDS = ("calibration",)

    @property
    def family(self) -> BregmanFamily:
        return get_family(self.family_name)

    @property
    def n(self) -> int:
        return self.data.shape[0]

    @property
    def d(self) -> int:
        return self.data.shape[1]

    @property
    def m(self) -> int:
        return self.partition.num_subspaces

    def rows_view(self) -> Array:
        """(n, d) fp32 point rows — THE point set this index searches.

        In the int8 tier this dequantizes the whole table; use it for
        oracles, cost-model fits, and rebuilds, never on the per-query
        path (refinement dequantizes only the candidate rows).
        """
        if self.storage == "f32":
            return self.data
        return qz.dequantize_rows(self.data, self.data_scale, self.data_zp,
                                  self.family)

    def tree_flatten(self):
        dyn = (self.data, self.point_ids, self.alpha, self.sqrt_gamma,
               self.assign, self.alpha_min, self.sqrt_gamma_max, self.counts,
               self.centers, self.beta_samples, self.alpha_min_pt,
               self.sqrt_gamma_max_pt, self.gamma_edges,
               self.env_alpha_min, self.env_sqrt_gamma_max,
               self.data_scale, self.data_zp, self.alpha_scale, self.alpha_zp,
               self.sg_scale, self.sg_zp, self.amin_scale, self.amin_zp,
               self.gmax_scale, self.gmax_zp)
        static = (self.family_name, self.partition, self.num_clusters,
                  self.storage)
        return dyn, static

    @classmethod
    def tree_unflatten(cls, static, dyn):
        return cls(static[0], static[1], static[2], *dyn[:13],
                   storage=static[3],
                   env_alpha_min=dyn[13], env_sqrt_gamma_max=dyn[14],
                   data_scale=dyn[15], data_zp=dyn[16],
                   alpha_scale=dyn[17], alpha_zp=dyn[18],
                   sg_scale=dyn[19], sg_zp=dyn[20],
                   amin_scale=dyn[21], amin_zp=dyn[22],
                   gmax_scale=dyn[23], gmax_zp=dyn[24])


jax.tree_util.register_pytree_node(
    BallForest, BallForest.tree_flatten, BallForest.tree_unflatten
)


# Row-group size of the precomputed corner envelopes: one envelope row
# summarizes this many layout rows.  A streaming-scan block of B rows
# covers at most ceil(B / ENV_BLOCK_ROWS) + 1 envelope rows at any
# alignment, which is how the per-block skip test stays cheap for every
# ``block_rows`` setting (core/search.py).
ENV_BLOCK_ROWS = 256

# Point-major (n, ...) fields — the arrays a data-parallel shard slices.
# Everything else (per-cluster corners, centers, beta samples, block
# envelopes) is small and replicated on every shard.  The int8 storage tier
# adds the per-row decode fields; every consumer that walks point-major
# arrays must go through point_fields(forest), not the bare f32 tuple.
# The envelope tables are NOT point-major (their leading axis counts
# ENV_BLOCK_ROWS-row groups, not rows), so pad/slice/concat/tombstone
# maintain them explicitly rather than through the point_fields walk.
POINT_FIELDS = ("data", "point_ids", "alpha", "sqrt_gamma", "assign",
                "alpha_min_pt", "sqrt_gamma_max_pt")
ENV_FIELDS = ("env_alpha_min", "env_sqrt_gamma_max")
QUANT_FIELDS = ("data_scale", "data_zp", "alpha_scale", "alpha_zp",
                "sg_scale", "sg_zp", "amin_scale", "amin_zp",
                "gmax_scale", "gmax_zp")
REPLICATED_FIELDS = ("alpha_min", "sqrt_gamma_max", "counts", "centers",
                     "beta_samples", "gamma_edges") + ENV_FIELDS


def point_fields(index_or_storage) -> tuple:
    """The point-major field names of an index (storage-variant aware)."""
    storage = getattr(index_or_storage, "storage", index_or_storage)
    return POINT_FIELDS + QUANT_FIELDS if storage == "int8" else POINT_FIELDS


# Residency tiers (core/tiered.py).  The COLD point-major fields are the
# ones only the post-filter stages touch — the (n, d) rows the refine
# kernel reads and the (n, M) per-point corners the Theorem-3 prune reads
# — exactly the tables the hoisted envelope gate can veto a block of
# before any fetch.  Everything else is HOT: the filter phase streams
# alpha/sqrt_gamma for every row of every query, point_ids resolves the
# final top-k, and the replicated/envelope tables are O(n/256) small.
COLD_POINT_FIELDS = ("data", "alpha_min_pt", "sqrt_gamma_max_pt")
COLD_QUANT_FIELDS = ("data_scale", "data_zp", "amin_scale", "amin_zp",
                     "gmax_scale", "gmax_zp")


def cold_point_fields(index_or_storage) -> tuple:
    """Field names eligible for the host-RAM cold tier (storage-aware)."""
    storage = getattr(index_or_storage, "storage", index_or_storage)
    if storage == "int8":
        return COLD_POINT_FIELDS + COLD_QUANT_FIELDS
    return COLD_POINT_FIELDS


# Corner sentinel for padded rows: an alpha_min_pt of +PAD_CORNER makes the
# tuple-space lower bound exceed any finite search bound, so a padded row
# can never enter a Theorem-3 candidate set; the same value in alpha keeps
# it out of every filter top-k.
PAD_CORNER = 1e30

# The search-inert row: PAD_CORNER corners/filter stats (never admitted,
# never in a top-k), point_ids -1, data rows of ones (inside every family's
# domain, so inert rows are numerically harmless even if a kernel touches
# them).  Shared by padding (pad_points) and tombstoning (tombstone_rows):
# a deleted point IS a pad row that happens to sit mid-array.
INERT_FILL = {"data": 1.0, "point_ids": -1, "alpha": PAD_CORNER,
              "sqrt_gamma": 0.0, "assign": 0, "alpha_min_pt": PAD_CORNER,
              "sqrt_gamma_max_pt": 0.0}

# Int8-tier inert row: all codes zero; the sentinels move into the per-row
# decode fields (zero scales so an inert row adds no bound slack, PAD_CORNER
# zero-points where the f32 fill is PAD_CORNER, data_zp 1.0 so the
# dequantized row is the same domain-safe ones-row as the f32 fill).
INERT_FILL_INT8 = {
    "data": 0, "point_ids": -1, "alpha": 0, "sqrt_gamma": 0, "assign": 0,
    "alpha_min_pt": 0, "sqrt_gamma_max_pt": 0,
    "data_scale": 0.0, "data_zp": 1.0,
    "alpha_scale": 0.0, "alpha_zp": PAD_CORNER,
    "sg_scale": 0.0, "sg_zp": 0.0,
    "amin_scale": 0.0, "amin_zp": PAD_CORNER,
    "gmax_scale": 0.0, "gmax_zp": 0.0,
}


def inert_fill(index_or_storage) -> dict:
    """Per-field inert fill values for an index's storage tier."""
    storage = getattr(index_or_storage, "storage", index_or_storage)
    return INERT_FILL_INT8 if storage == "int8" else INERT_FILL


def corner_envelopes(amin_pt: Array, gmax_pt: Array) -> tuple[Array, Array]:
    """Block envelopes of (n, M) fp32 corner tables -> ((nE, M), (nE, M)).

    Row e is the componentwise min/max over layout rows
    ``[e*ENV_BLOCK_ROWS, (e+1)*ENV_BLOCK_ROWS)``; a short tail group is
    completed with the inert corner (``alpha_min`` PAD_CORNER,
    ``sqrt_gamma_max`` 0), which contributes nothing to either reduction —
    the same reason padded/tombstoned rows never loosen an envelope.
    """
    n, m = amin_pt.shape
    ne = max(-(-n // ENV_BLOCK_ROWS), 1)
    pad = ne * ENV_BLOCK_ROWS - n
    a = jnp.pad(amin_pt, ((0, pad), (0, 0)), constant_values=PAD_CORNER)
    g = jnp.pad(gmax_pt, ((0, pad), (0, 0)), constant_values=0.0)
    return (jnp.min(a.reshape(ne, ENV_BLOCK_ROWS, m), axis=1),
            jnp.max(g.reshape(ne, ENV_BLOCK_ROWS, m), axis=1))


def refresh_envelopes(forest: BallForest) -> BallForest:
    """Recompute the block-envelope tables from the per-point corners.

    In the int8 tier the reduction runs over the DECODED (directed-rounded,
    conservative) corners, so the envelope of a block always dominates the
    values the per-point Theorem-3 test will decode for its rows — the
    invariant that makes envelope-level block skipping loss-free.
    """
    amin, gmax = qz.decoded_corner_tables(forest)
    ea, eg = corner_envelopes(amin, gmax)
    return dataclasses.replace(forest, env_alpha_min=ea,
                               env_sqrt_gamma_max=eg)


def _pad_envelopes(forest: BallForest, padded_n: int) -> dict:
    """ENV_FIELDS updates covering ``padded_n`` rows with inert tail rows."""
    if forest.env_alpha_min is None:
        return {}
    ne_new = max(-(-padded_n // ENV_BLOCK_ROWS), 1)
    grow = ne_new - forest.env_alpha_min.shape[0]
    if grow <= 0:
        return {}
    m = forest.env_alpha_min.shape[1]
    # The boundary group's existing envelope stays valid: the appended rows
    # are inert (PAD_CORNER corners) and move neither reduction.
    return {
        "env_alpha_min": jnp.concatenate(
            [forest.env_alpha_min,
             jnp.full((grow, m), PAD_CORNER, jnp.float32)]),
        "env_sqrt_gamma_max": jnp.concatenate(
            [forest.env_sqrt_gamma_max, jnp.zeros((grow, m), jnp.float32)]),
    }


def pad_points(forest: BallForest, multiple: int) -> BallForest:
    """Pad the point-major arrays with inert rows so ``n % multiple == 0``."""
    pad = (-forest.n) % multiple
    if pad == 0:
        return forest
    fill = inert_fill(forest)

    def pad_rows(a, v):
        return jnp.concatenate(
            [a, jnp.full((pad,) + a.shape[1:], v, a.dtype)], axis=0)

    return dataclasses.replace(forest, **{
        f: pad_rows(getattr(forest, f), fill[f])
        for f in point_fields(forest)},
        **_pad_envelopes(forest, forest.n + pad))


def tombstone_rows(forest: BallForest, dead: Array) -> BallForest:
    """Overwrite the rows where ``dead`` is True with the inert fill.

    This is how the mutable index (core/segments.py) deletes: the row stays
    physically present (static shapes, no recompile) but its filter stats
    put it beyond any finite top-k and its corner stats fail every
    Theorem-3 admission, so the filter, prune, and refine phases of all
    three search paths skip it without knowing deletions exist.

    The block-envelope tables are left untouched: removing a row can only
    TIGHTEN a block's true envelope, so the stored one stays a valid
    (merely looser) dominator and block skipping stays loss-free.
    Compaction recomputes them exactly.
    """
    dead = jnp.asarray(dead, bool)
    fill = inert_fill(forest)

    def patch(a, v):
        d = dead.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(d, jnp.asarray(v, a.dtype), a)

    return dataclasses.replace(forest, **{
        f: patch(getattr(forest, f), fill[f]) for f in point_fields(forest)})


def concat_points(forests) -> BallForest:
    """Concatenate point-major arrays of segments sharing one sealed layout.

    All inputs must agree on the static fields and share the first
    segment's replicated (per-cluster / sample) arrays — exactly the shape
    of a SegmentedForest's main + append segments.  The result is a plain
    searchable :class:`BallForest` view.
    """
    forests = list(forests)
    head = forests[0]
    for f in forests[1:]:
        if (f.family_name != head.family_name
                or f.partition != head.partition
                or f.num_clusters != head.num_clusters
                or f.storage != head.storage):
            raise ValueError("concat_points needs segments of one index")
    if len(forests) == 1:
        return head
    out = dataclasses.replace(head, **{
        f: jnp.concatenate([getattr(seg, f) for seg in forests], axis=0)
        for f in point_fields(head)})
    # Segment boundaries rarely align with ENV_BLOCK_ROWS, so the result's
    # envelope groups straddle segments; recompute from the concatenated
    # per-point corners instead of stitching per-segment tables (O(n * M),
    # paid once per snapshot — view() caches the result).
    if head.env_alpha_min is not None:
        out = refresh_envelopes(out)
    return out


def slice_points(forest: BallForest, start: int, size: int) -> BallForest:
    """The ``[start, start+size)`` point-shard view of a forest.

    This is the host-side mirror of what one device sees under the
    ``shard_map`` in dist/knn.py: point-major arrays sliced, per-cluster /
    sample arrays shared.  (The real sharded path keeps the GLOBAL envelope
    tables replicated and indexes them by shard offset; this standalone
    view re-derives envelopes for its own row range so it is a complete
    self-consistent index.)
    """
    out = dataclasses.replace(forest, **{
        f: jax.lax.slice_in_dim(getattr(forest, f), start, start + size,
                                axis=0)
        for f in point_fields(forest)})
    if forest.env_alpha_min is not None:
        out = refresh_envelopes(out)
    return out


def default_num_clusters(n: int) -> int:
    return int(np.clip(n // 32, 8, 8192))


def quantize_point_tables(forest: BallForest, data_codes: Array,
                          data_scale: Array, data_zp: Array) -> BallForest:
    """Swap a built fp32 forest's point-major tables for the int8 tier.

    ``data_codes``/``data_scale``/``data_zp`` must dequantize EXACTLY to
    ``forest.data`` (the forest was built over the dequantized rows, so the
    stats/corners being re-encoded here were computed from the point set
    the codes decode to).  Filter stats round to nearest (covered by the
    `_qb_slack` bound inflation at query time); corner stats round
    directionally so the Theorem-3 test stays conservative with no
    query-time correction.
    """
    if forest.storage != "f32":
        raise ValueError("quantize_point_tables wants an f32 forest")
    out = dataclasses.replace(
        forest, storage="int8",
        data=data_codes, data_scale=data_scale, data_zp=data_zp,
        **qz.encode_stat_tables(forest.alpha, forest.sqrt_gamma,
                                forest.alpha_min_pt,
                                forest.sqrt_gamma_max_pt))
    # The corner re-encode just moved every per-point corner by up to one
    # directed-rounding step, so any envelopes carried in from the fp32
    # forest no longer dominate the DECODED corners — refit them here so
    # the invariant holds for every caller, not just build_index.
    return refresh_envelopes(out)


def build_index(
    data,
    family: str | BregmanFamily,
    *,
    m: int | None = None,
    pccp: bool = True,
    num_clusters: int | None = None,
    kmeans_iters: int = 12,
    beta_sample_size: int = 4096,
    gamma_buckets: int = 4,
    quantize: bool = False,
    calibrate: bool = False,
    calibrate_k: int = 10,
    calibration_queries: int = 64,
    seed: int = 0,
) -> BallForest:
    """Offline precomputation (paper Alg. 5): partition -> transform -> forest.

    ``m=None`` fits the Theorem-4 cost model and uses M*.

    ``gamma_buckets`` (beyond-paper tightening): within each ball, members
    are split into gamma-quantile buckets and each bucket contributes its
    own (alpha_min, sqrt_gamma_max) corner, so the cluster lower bound
    LB = alpha_min + qconst - sqrt_gamma_max*sqrt_delta is evaluated on
    buckets whose gamma spread is ~1/gamma_buckets of the ball's — strictly
    tighter, still conservative (each point belongs to exactly one bucket
    and its bucket's corner lower-bounds its distance).

    ``calibrate=True`` additionally fits the per-index recall-calibration
    curve (core/calibrate.py): measured recall@``calibrate_k`` over a
    ``p_guarantee`` grid on ``calibration_queries`` held-out jittered
    rows, stored host-side on :attr:`BallForest.calibration` so
    ``target_recall`` requests can invert it (docs/accuracy.md).

    ``quantize=True`` builds the int8 storage tier: ``data`` is snapped to
    per-row int8 FIRST and the whole index (clustering, transforms,
    corners, beta samples) is built over the dequantized rows, so every
    stored stat describes exactly the point set search will refine against
    (docs/quantization.md).  Search over the result is exact w.r.t. those
    dequantized points — identical ids/distances to an fp32 index built
    over ``rows_view()``.
    """
    fam = get_family(family) if isinstance(family, str) else family
    data = jnp.asarray(data, dtype=jnp.float32)
    if quantize:
        data_codes, data_scale, data_zp = qz.quantize_rows(data)
        data = qz.dequantize_rows(data_codes, data_scale, data_zp, fam)
    n, d = data.shape
    data_np = np.asarray(data)

    if m is None:
        m = fit_cost_model(data_np, fam, seed=seed).m_star()
    m = int(np.clip(m, 1, d))

    if pccp and m < d:
        part = build_pccp_partition(data_np, m, seed=seed)
    else:
        part = make_partition(d, m)

    c = num_clusters or default_num_clusters(n)
    c = int(min(c, n))
    key = jax.random.PRNGKey(seed)

    # Per-subspace Bregman k-means over the (n, w) subspace views.  The jit
    # cache is shared across subspaces (same shapes / family).
    sub_views = part.gather(data)                   # (n, M, w)
    mask = part.subspace_mask()                     # (M, w)
    centers_list, assign_list = [], []
    for i in range(m):
        ki = jax.random.fold_in(key, i)
        cen, asg = kmeans(
            sub_views[:, i, :], mask[i], ki,
            family=fam, num_clusters=c, iters=kmeans_iters,
        )
        centers_list.append(cen)
        assign_list.append(asg)
    centers = jnp.stack(centers_list)               # (M, C, w)
    assign = jnp.stack(assign_list, axis=1)         # (n, M)

    # Shared layout: order points by the reference subspace's cluster id.
    order = jnp.argsort(assign[:, 0], stable=True)
    data_l = data[order]
    assign_l = assign[order]
    point_ids = order.astype(jnp.int32)

    p = p_transform(data_l, part, fam)
    alpha, sqrt_gamma = p["alpha"], p["sqrt_gamma"]

    # gamma-bucketed corners: effective segment id = ball * nb + bucket,
    # bucket = global per-subspace gamma quantile of the member
    nb = max(int(gamma_buckets), 1)
    assign_eff, edges = [], []
    for i in range(m):
        qs = jnp.quantile(sqrt_gamma[:, i],
                          jnp.linspace(0.0, 1.0, nb + 1)[1:-1])
        bucket = jnp.searchsorted(qs, sqrt_gamma[:, i]).astype(jnp.int32)
        assign_eff.append(assign_l[:, i] * nb + bucket)
        edges.append(qs)
    assign_eff = jnp.stack(assign_eff, axis=1)      # (n, M) in [0, C*nb)
    gamma_edges = jnp.stack(edges)                  # (M, nb-1) bucket edges
    c_eff = c * nb

    amin = jnp.stack([
        cluster_stats(alpha[:, i], assign_eff[:, i], c_eff)["min"]
        for i in range(m)
    ])                                              # (M, C*nb)
    gmax = jnp.stack([
        cluster_stats(sqrt_gamma[:, i], assign_eff[:, i], c_eff)["max"]
        for i in range(m)
    ])
    counts = jnp.stack([
        cluster_stats(alpha[:, i], assign_eff[:, i], c_eff)["count"]
        for i in range(m)
    ])

    # Per-point view of the bucketed corners: alpha_min_pt[p, i] is the
    # corner of the bucket point p lives in for subspace i.  Gathering this
    # ONCE at build time makes the batched query-time cluster pruning
    # (core/search.py knn_search_batch) a pure elementwise compare — no
    # query-time gathers over (n, M, q).
    amin_pt = jax.vmap(lambda a, s: a[s], in_axes=(0, 1), out_axes=1)(
        amin, assign_eff)                           # (n, M)
    gmax_pt = jax.vmap(lambda a, s: a[s], in_axes=(0, 1), out_axes=1)(
        gmax, assign_eff)                           # (n, M)

    # Empirical beta_xy sample for the approximate search (Prop. 1): the CDF
    # of the cross term over random (data, query) pairs.
    rng = np.random.default_rng(seed)
    s = min(beta_sample_size, n * n)
    xi = rng.integers(0, n, size=s)
    yi = rng.integers(0, n, size=s)
    grads = fam.phi_prime(data_np[yi])
    betas = -np.sum(data_np[xi] * grads, axis=-1)
    beta_samples = jnp.sort(jnp.asarray(betas, dtype=jnp.float32))

    forest = BallForest(
        family_name=fam.name,
        partition=part,
        num_clusters=c_eff,
        data=data_l,
        point_ids=point_ids,
        alpha=alpha,
        sqrt_gamma=sqrt_gamma,
        assign=assign_eff,
        alpha_min=amin,
        sqrt_gamma_max=gmax,
        counts=counts,
        centers=centers,
        beta_samples=beta_samples,
        alpha_min_pt=amin_pt,
        sqrt_gamma_max_pt=gmax_pt,
        gamma_edges=gamma_edges,
    )
    if quantize:
        forest = quantize_point_tables(
            forest, data_codes[order], data_scale[order], data_zp[order])
    # Envelopes come LAST so the int8 tier reduces over the decoded
    # directed-rounded corners it will serve, not the pre-encode fp32 ones
    # (whose floor-rounding could otherwise dip below the envelope).
    forest = refresh_envelopes(forest)
    if calibrate:
        # Fit over the finished index (lazy import: calibrate drives the
        # search entry points, which import this module).
        from . import calibrate as _calibrate
        forest = dataclasses.replace(
            forest,
            calibration=_calibrate.fit_calibration(
                forest, k=min(calibrate_k, n),
                num_queries=calibration_queries, seed=seed))
    return forest
