"""Cauchy upper/lower bounds and search-bound determination (Theorems 1-3, Alg. 1 & 4).

Per subspace ``i``:

  UB_i(x, y) = alpha_x + alpha_y + beta_yy + sqrt(gamma_x * delta_y)
             >= D_f(x_i., y_i.)                                    (Theorem 1)
  LB_i(x, y) = alpha_x + alpha_y + beta_yy - sqrt(gamma_x * delta_y)
             <= D_f(x_i., y_i.)

(the LB uses the other side of Cauchy-Schwarz on the cross term
``beta_xy = -sum_j x_j f'(y)_j``, i.e. ``|beta_xy| <= sqrt(gamma_x delta_y)``;
the paper only needs the UB, the LB powers our branch-free ball pruning —
DESIGN.md §3.3).

Summing over subspaces bounds the full distance (Theorem 2).  The k-th
smallest total UB yields per-subspace searching bounds ``qb`` (Alg. 4); the
union of subspace range queries with those bounds provably contains the true
kNN (Theorem 3).

MXU form: because ``sqrt(gamma_x*delta_y) = sqrt(gamma_x)*sqrt(delta_y)``
elementwise over subspaces, the (n x q) total-UB matrix is

    UB_total = rowsum(alpha_x)[:, None] + rowsum(qconst)[None, :]
             + sqrt_gamma @ sqrt_delta^T

one (n, M) x (M, q) matmul plus rank-1 bias — see kernels/bregman_ub.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ub_components(p: dict, q: dict) -> Array:
    """Per-subspace upper bounds UB_i. Shapes broadcast: p (..., M), q (..., M)."""
    return p["alpha"] + q["qconst"] + p["sqrt_gamma"] * q["sqrt_delta"]


def lb_components(p: dict, q: dict) -> Array:
    """Per-subspace lower bounds LB_i (other Cauchy side)."""
    return p["alpha"] + q["qconst"] - p["sqrt_gamma"] * q["sqrt_delta"]


def ub_total(p: dict, q: dict) -> Array:
    return jnp.sum(ub_components(p, q), axis=-1)


def ub_matrix(p: dict, q: dict) -> Array:
    """Total upper bounds for all (point, query) pairs in MXU matmul form.

    p fields: (n, M); q fields: (qn, M).  Returns (n, qn).
    """
    bias_p = jnp.sum(p["alpha"], axis=-1)          # (n,)
    bias_q = jnp.sum(q["qconst"], axis=-1)         # (qn,)
    cauchy = p["sqrt_gamma"] @ q["sqrt_delta"].T   # (n, qn) — the MXU matmul
    return bias_p[:, None] + bias_q[None, :] + cauchy


def kth_smallest_ub(p: dict, q: dict, k: int) -> tuple[Array, Array]:
    """Alg. 4 — index and value of the k-th smallest total UB for one query.

    p fields (n, M), q fields (M,).  Returns (kth_index, kth_value).
    """
    totals = ub_total(p, {k_: v[None, :] for k_, v in q.items() if v.ndim == 1})
    neg_vals, idx = jax.lax.top_k(-totals, k)
    return idx[-1], -neg_vals[-1]


def qb_determine(p: dict, q: dict, k: int) -> dict:
    """Alg. 4 — per-subspace searching bounds from the k-th smallest total UB.

    Args:
      p: data tuples with fields of shape (n, M).
      q: one query triple with fields of shape (M,).
    Returns dict with
      qb:  (M,) per-subspace searching bounds (components of the k-th UB)
      tau: () the k-th smallest total UB (global refinement threshold)
      kth: () index of the k-th point.
    """
    q1 = {name: v[None, :] for name, v in q.items() if v.ndim == 1}
    comp = ub_components(p, q1)                     # (n, M)
    totals = jnp.sum(comp, axis=-1)                 # (n,)
    neg_vals, idx = jax.lax.top_k(-totals, k)
    kth = idx[-1]
    qb = comp[kth]                                  # (M,)
    return {"qb": qb, "tau": -neg_vals[-1], "kth": kth}


def refine_distance(x: Array, q: dict, family, y: Array | None = None) -> Array:
    """Exact D_f(x, y) in the fused "rowsum(f) - x . f'(y) + c_y" form.

    ``D_f(x,y) = sum_j f(x_j) - x . grad + c_y`` with
    ``c_y = sum_j (y_j grad_j - f(y_j))``.  The matmul-friendly split lets the
    refinement kernel run the gradient inner product on the MXU
    (kernels/bregman_dist.py).  ``q`` must carry 'grad' (d,) and 'f_y' ().
    ``y`` is unused (kept for signature parity with the oracle).
    """
    grad = q["grad"]
    c_y = jnp.sum(q["_y_grad"], axis=-1) if "_y_grad" in q else q["c_y"]
    fx = jnp.sum(family.phi(x), axis=-1)
    return fx - x @ grad + c_y


def query_refine_constants(y: Array, family) -> dict:
    """Precompute grad/f'(y) and the additive constant for refine_distance."""
    grad = family.phi_prime(y)
    c_y = jnp.sum(y * grad, axis=-1) - family.f(y)
    return {"grad": grad, "c_y": c_y}
