"""Faithful CPU baselines the paper compares against (Table 1 / §9.1.1).

* ``BBTree``  — Cayton's Bregman-ball tree ("BBT"): hierarchical 2-means, best-
  first kNN with ball lower bounds.  Two bound implementations: the exact
  geodesic bisection from Cayton '08 (``bound='geodesic'``) and our tuple-
  space Cauchy bound (``bound='tuple'``, DESIGN.md §3.3).
* ``VAFile``  — Zhang et al.'s VA-file ("VAF"): per-dim scalar quantization,
  two-phase scan (approximation bounds, then exact refinement).
* ``linear_scan`` — the floor.

These run in numpy on the host: they are the *paper-fidelity* comparison
points for benchmarks (Figs. 7, 11-14), not the accelerated path.  Each
search returns (ids, dists, stats) where stats carries the I/O-cost proxy
(bytes of data touched) and candidate counts so the paper's I/O figures can
be reproduced without a disk.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .bregman import get_family

F32 = 4  # bytes per float


def _phi(fam, x):
    return np.asarray(fam.phi(x))


def _phi_prime(fam, x):
    return np.asarray(fam.phi_prime(x))


def _distance(fam, xs, y):
    return np.asarray(fam.distance(xs, y[None] if y.ndim == 1 else y))


def linear_scan(data: np.ndarray, y: np.ndarray, k: int, family) -> tuple:
    fam = get_family(family) if isinstance(family, str) else family
    dist = _distance(fam, data, y)
    idx = np.argpartition(dist, min(k, len(dist) - 1))[:k]
    order = np.argsort(dist[idx])
    stats = {"bytes_moved": data.size * F32, "candidates": len(data),
             "distance_evals": len(data)}
    return idx[order], dist[idx][order], stats


# ---------------------------------------------------------------------------
# BB-tree (Cayton 2008; range search per Cayton 2009)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Node:
    center: np.ndarray
    radius: float
    alpha_min: float          # min over members of sum phi(x)
    sqrt_gamma_max: float     # max over members of ||x||
    points: np.ndarray | None = None   # leaf: member ids
    left: "._Node | None" = None
    right: "._Node | None" = None

    @property
    def is_leaf(self):
        return self.points is not None


class BBTree:
    """Memory-resident Bregman ball tree with best-first exact kNN."""

    def __init__(self, data, family, leaf_size: int = 32, seed: int = 0,
                 bound: str = "geodesic"):
        self.data = np.asarray(data, dtype=np.float64)
        self.family = get_family(family) if isinstance(family, str) else family
        self.leaf_size = leaf_size
        self.bound = bound
        self._rng = np.random.default_rng(seed)
        self._phi_sums = _phi(self.family, self.data).sum(-1)
        self._norms = np.sqrt((self.data ** 2).sum(-1))
        self.root = self._build(np.arange(len(self.data)))
        self.nodes_built = self._count(self.root)

    # -- construction ------------------------------------------------------
    def _make_node(self, ids):
        pts = self.data[ids]
        center = pts.mean(0)
        radius = float(_distance(self.family, pts, center).max())
        return _Node(center=center, radius=radius,
                     alpha_min=float(self._phi_sums[ids].min()),
                     sqrt_gamma_max=float(self._norms[ids].max()))

    def _build(self, ids):
        node = self._make_node(ids)
        if len(ids) <= self.leaf_size:
            node.points = ids
            return node
        # 2-means split (Bregman assignment, mean update)
        pts = self.data[ids]
        ci = self._rng.choice(len(ids), 2, replace=False)
        centers = pts[ci].copy()
        for _ in range(8):
            d0 = _distance(self.family, pts, centers[0])
            d1 = _distance(self.family, pts, centers[1])
            lab = (d1 < d0)
            if lab.all() or (~lab).all():
                break
            centers[0] = pts[~lab].mean(0)
            centers[1] = pts[lab].mean(0)
        if lab.all() or (~lab).all():      # degenerate: split by median norm
            lab = self._norms[ids] > np.median(self._norms[ids])
            if lab.all() or (~lab).all():
                node.points = ids
                return node
        node.left = self._build(ids[~lab])
        node.right = self._build(ids[lab])
        return node

    def _count(self, node):
        if node is None:
            return 0
        return 1 + self._count(node.left) + self._count(node.right)

    # -- bounds --------------------------------------------------------------
    def _lb_tuple(self, node, qstruct):
        qconst, sqrt_delta = qstruct["qconst"], qstruct["sqrt_delta"]
        return node.alpha_min + qconst - node.sqrt_gamma_max * sqrt_delta

    def _lb_geodesic(self, node, y, iters: int = 24):
        """Cayton's bisection on the dual geodesic between q and the center."""
        fam = self.family
        gy = _phi_prime(fam, y)
        gc = _phi_prime(fam, node.center)
        if float(_distance(fam, node.center[None], y)[0]) <= node.radius:
            return 0.0
        lo, hi = 0.0, 1.0   # theta: 0 -> query side, 1 -> center
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            x = np.asarray(fam.phi_prime_inv(mid * gc + (1 - mid) * gy))
            inside = float(_distance(fam, x[None], node.center)[0]) <= node.radius
            if inside:
                hi = mid
            else:
                lo = mid
        x = np.asarray(fam.phi_prime_inv(hi * gc + (1 - hi) * gy))
        return max(0.0, float(_distance(fam, x[None], y)[0]))

    def _lb(self, node, y, qstruct):
        if self.bound == "tuple":
            return self._lb_tuple(node, qstruct)
        return self._lb_geodesic(node, y)

    def _qstruct(self, y):
        g = _phi_prime(self.family, y)
        return {
            "qconst": float(-_phi(self.family, y).sum() + (y * g).sum()),
            "sqrt_delta": float(np.sqrt((g * g).sum())),
        }

    # -- queries -------------------------------------------------------------
    def knn(self, y, k):
        y = np.asarray(y, dtype=np.float64)
        qs = self._qstruct(y)
        heap = [(self._lb(self.root, y, qs), 0, self.root)]
        best: list = []          # max-heap of (-dist, id)
        tick = 1
        stats = {"bytes_moved": 0, "candidates": 0, "distance_evals": 0,
                 "nodes_visited": 0}
        while heap:
            lb, _, node = heapq.heappop(heap)
            if len(best) == k and lb > -best[0][0]:
                continue
            stats["nodes_visited"] += 1
            if node.is_leaf:
                d = _distance(self.family, self.data[node.points], y)
                stats["distance_evals"] += len(node.points)
                stats["candidates"] += len(node.points)
                stats["bytes_moved"] += len(node.points) * self.data.shape[1] * F32
                for di, pid in zip(d, node.points, strict=True):
                    if len(best) < k:
                        heapq.heappush(best, (-di, pid))
                    elif di < -best[0][0]:
                        heapq.heapreplace(best, (-di, pid))
            else:
                for child in (node.left, node.right):
                    clb = self._lb(child, y, qs)
                    if len(best) < k or clb <= -best[0][0]:
                        heapq.heappush(heap, (clb, tick, child))
                        tick += 1
        out = sorted([(-nd, pid) for nd, pid in best])
        ids = np.array([pid for _, pid in out])
        dists = np.array([d for d, _ in out])
        return ids, dists, stats

    def range_query(self, y, r):
        """Cayton '09-style range search; returns ids with D_f(x, y) <= r."""
        y = np.asarray(y, dtype=np.float64)
        qs = self._qstruct(y)
        out, stack = [], [self.root]
        stats = {"bytes_moved": 0, "candidates": 0, "nodes_visited": 0}
        while stack:
            node = stack.pop()
            if self._lb(node, y, qs) > r:
                continue
            stats["nodes_visited"] += 1
            if node.is_leaf:
                d = _distance(self.family, self.data[node.points], y)
                stats["candidates"] += len(node.points)
                stats["bytes_moved"] += len(node.points) * self.data.shape[1] * F32
                out.extend(node.points[d <= r].tolist())
            else:
                stack.extend([node.left, node.right])
        return np.asarray(sorted(out), dtype=np.int64), stats


# ---------------------------------------------------------------------------
# VA-file (Zhang et al. 2009 — extended-space scalar quantization)
# ---------------------------------------------------------------------------

class VAFile:
    """Per-dimension quantile grid; two-phase exact kNN scan."""

    def __init__(self, data, family, bits: int = 4):
        self.data = np.asarray(data, dtype=np.float64)
        self.family = get_family(family) if isinstance(family, str) else family
        self.bits = bits
        n, d = self.data.shape
        cells = 1 << bits
        qs = np.linspace(0, 1, cells + 1)
        # (d, cells+1) boundaries via per-dim quantiles
        self.bounds = np.quantile(self.data, qs, axis=0).T
        self.bounds[:, 0] -= 1e-9
        self.bounds[:, -1] += 1e-9
        self.cells = np.empty((n, d), dtype=np.int16)
        for j in range(d):
            self.cells[:, j] = np.clip(
                np.searchsorted(self.bounds[j], self.data[:, j], side="right") - 1,
                0, cells - 1)
        self.approx_bytes = n * d * bits / 8.0

    def _cell_tables(self, y):
        """Per-(dim, cell) min/max of the per-dim distance term (convex in x)."""
        fam = self.family
        lo, hi = self.bounds[:, :-1], self.bounds[:, 1:]       # (d, cells)
        yj = y[:, None]
        gy = _phi_prime(fam, yj)
        phiy = _phi(fam, yj)

        def term(x):
            return _phi(fam, x) - phiy - gy * (x - yj)

        t_lo, t_hi = term(lo), term(hi)
        # min of a convex fn on [lo, hi]: at clamp(y); max: at an endpoint
        inside = (yj >= lo) & (yj <= hi)
        tmin = np.where(inside, 0.0, np.minimum(t_lo, t_hi))
        tmax = np.maximum(t_lo, t_hi)
        return tmin, tmax

    def knn(self, y, k):
        y = np.asarray(y, dtype=np.float64)
        n, d = self.data.shape
        tmin, tmax = self._cell_tables(y)                      # (d, cells)
        cols = np.arange(d)
        lb = tmin[cols, self.cells].sum(-1)                    # (n,)
        ub = tmax[cols, self.cells].sum(-1)
        tau = np.partition(ub, min(k - 1, n - 1))[min(k - 1, n - 1)]
        cand = np.flatnonzero(lb <= tau)
        dist = _distance(self.family, self.data[cand], y)
        idx = np.argpartition(dist, min(k - 1, len(cand) - 1))[:k]
        order = np.argsort(dist[idx])
        stats = {
            "bytes_moved": self.approx_bytes + cand.size * d * F32,
            "candidates": int(cand.size),
            "distance_evals": int(cand.size),
        }
        return cand[idx[order]], dist[idx[order]], stats
