"""Int8 storage tier for the BallForest: per-row quantizers + error bounds.

Memory is the binding constraint at "millions of users" scale: the (n, d)
point table plus the four (n, M) filter/corner tables are all fp32, and the
filter matmul's HBM traffic is what the batched pipeline streams per query
block.  This module provides the lossy-storage side of the fix; the search
pipeline stays *provably admissible* because every bound the pruning math
consumes is inflated (or directly rounded) to cover the quantization error —
the same bound-slack tactic used to survive a missing triangle inequality in
approximate Bregman search (Abdullah et al.) and decomposable-divergence
kd-trees (Pham & Wagner).  See docs/quantization.md for the derivation.

Contract (the one sentence everything below serves):

    The int8 index's point set IS the dequantized rows ``x_hat``; search
    over the int8 tier returns the EXACT kNN of ``x_hat`` — identical ids
    and distances to a fp32 BallForest built over the same ``x_hat``.

Three quantizer shapes, all per-row (so mutation never needs global refits
and a row's error bound travels with the row):

* **data rows** — affine int8 over each (d,) row: ``x_hat = codes * scale
  + zp``, clamped into the family domain.  Refinement dequantizes only the
  surviving candidate rows (kernels/bregman_dist.bregman_refine_batch_quant).
* **filter stats** (``alpha``/``sqrt_gamma``) — affine int8 over each (M,)
  row, round-to-nearest, so ``|stat_hat - stat| <= scale/2``.  The Alg.-4
  searching bounds are inflated by :data:`UB_SLACK` * (alpha_scale +
  sqrt_gamma_scale * sqrt_delta_i) maximized over the filter's top-k rows
  — enough to cover the worst-case rounding of any row that determined the
  k-th upper bound (core/search.py `_qb_slack`).
* **corner stats** (``alpha_min_pt``/``sqrt_gamma_max_pt``) — affine int8
  with DIRECTED rounding: alpha_min floors, sqrt_gamma_max ceils, so the
  dequantized corner is always on the conservative side of the true corner
  and the Theorem-3 cluster lower bound can only get smaller.  No
  query-time slack needed for the prune.

Quantizing a row of identical values stores ``scale = 0`` (codes all zero,
``zp`` carries the exact value), which doubles as the search-inert fill:
a tombstoned/padded int8 row has zero scales — contributing nothing to any
bound slack — and sentinel zero-points (core/index.inert_fill).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .bregman import BregmanFamily

Array = jax.Array

# Families whose generator domain is the open positive axis; dequantized
# rows are clamped to >= DOMAIN_EPS there (matching BregmanFamily.project)
# so rounding can never push a stored point out of the domain.
POSITIVE_FAMILIES = frozenset({"itakura_saito", "burg", "shannon"})
DOMAIN_EPS = 1e-6

# Half-step rounding bound with a small float-evaluation safety margin; the
# factor multiplies a stored per-row scale, so the slack it adds to the
# Alg.-4 bounds is ~the quantization step — negligible against the bounds
# themselves, but enough to absorb fp32 round-off in the dequant chain.
UB_SLACK = 0.5 * (1.0 + 1e-3)

# Affine range: codes live in [-127, 127] (255 levels).  The symmetric
# range keeps the directed-rounding headroom: a ceil can land on +127 and a
# floor on -128 without leaving int8.
_LEVELS = 254.0
# Directed rounding needs the row extremes strictly inside the code range
# so float fuzz in (v - zp) / scale cannot ceil past +127.
_DIRECTED_PAD = 1.0 + 1e-6


def _row_affine(v: Array, pad: float = 1.0) -> tuple[Array, Array]:
    """Per-row (scale, zero_point) covering [min, max] of the trailing axis.

    Constant rows get ``scale = 0`` — codes are zero and ``zp`` is exact.
    """
    lo = jnp.min(v, axis=-1)
    hi = jnp.max(v, axis=-1)
    zp = 0.5 * (hi + lo)
    scale = (hi - lo) * (pad / _LEVELS)
    return scale, zp


def _encode(v: Array, scale: Array, zp: Array, rounding: str) -> Array:
    div = jnp.where(scale > 0, scale, 1.0)
    t = (v - zp[..., None]) / div[..., None]
    if rounding == "nearest":
        t = jnp.round(t)
    elif rounding == "floor":
        t = jnp.floor(t)
    elif rounding == "ceil":
        t = jnp.ceil(t)
    else:
        raise ValueError(f"unknown rounding {rounding!r}")
    return jnp.clip(t, -128, 127).astype(jnp.int8)


def quantize_rows(x: Array) -> tuple[Array, Array, Array]:
    """Affine int8 per (d,) row: (codes (n, d) int8, scale (n,), zp (n,))."""
    x = jnp.asarray(x, jnp.float32)
    scale, zp = _row_affine(x)
    return _encode(x, scale, zp, "nearest"), scale, zp


def dequantize_rows(codes: Array, scale: Array, zp: Array,
                    family: BregmanFamily) -> Array:
    """``x_hat``: the int8 tier's point set, clamped into the family domain.

    This expression is THE definition of the stored points — the refine
    kernels (ref, Pallas, interpret) reproduce it term for term so the
    distances they report are exact over ``x_hat``.
    """
    x = codes.astype(jnp.float32) * scale[..., None] + zp[..., None]
    return clamp_domain(x, family.name)


def clamp_domain(x: Array, family_name: str) -> Array:
    """Domain projection shared by dequantize_rows and the refine kernels."""
    if family_name in POSITIVE_FAMILIES:
        return jnp.maximum(x, DOMAIN_EPS)
    return x


def quantize_stats(v: Array, rounding: str = "nearest",
                   ) -> tuple[Array, Array, Array]:
    """Affine int8 per (M,) stat row: (codes int8, scale (n,), zp (n,)).

    ``rounding='nearest'`` (filter stats): ``|dequant - v| <= scale / 2``.
    ``rounding='floor'``/``'ceil'`` (corner stats): the dequantized value is
    <= / >= the true value — conservative by construction, so the pruning
    lower bound needs no query-time correction.
    """
    v = jnp.asarray(v, jnp.float32)
    pad = 1.0 if rounding == "nearest" else _DIRECTED_PAD
    scale, zp = _row_affine(v, pad=pad)
    return _encode(v, scale, zp, rounding), scale, zp


def dequantize_stats(codes: Array, scale: Array, zp: Array) -> Array:
    """Per-row affine decode for the (n, M) stat tables."""
    return codes.astype(jnp.float32) * scale[..., None] + zp[..., None]


def decoded_corner_tables(forest) -> tuple[Array, Array]:
    """Full (n, M) fp32 corner tables of an index (decoded in the int8 tier).

    The int8 corners were DIRECTED-rounded at encode (alpha_min floored,
    sqrt_gamma_max ceiled), so the values returned here are conservative
    and every consumer — the per-point Theorem-3 test, and the block
    envelopes reduced over these exact values (core/index.corner_envelopes)
    — needs no further slack.  Duck-typed over anything with the
    BallForest corner fields so core/index.py and core/search.py share one
    decode.
    """
    amin, gmax = forest.alpha_min_pt, forest.sqrt_gamma_max_pt
    if forest.storage == "int8":
        amin = dequantize_stats(amin, forest.amin_scale, forest.amin_zp)
        gmax = dequantize_stats(gmax, forest.gmax_scale, forest.gmax_zp)
    return amin, gmax


def ub_slack(alpha_scale: Array, sg_scale: Array, sqrt_delta: Array) -> Array:
    """Alg.-4 bound inflation from filter-stat scales — THE slack formula.

    ``alpha_scale``/``sg_scale`` are the (…,) per-query maxima of the
    stat scales over the filter's top-k rows; ``sqrt_delta`` is (…, M).
    Returns the (…, M) componentwise inflation whose row sum dominates
    the worst-case decoded-vs-true UB error of any row that could have
    determined the k-th bound (docs/quantization.md).  Shared by the
    single-query, batched, and distributed bound computations so the
    admissibility-critical expression exists exactly once.
    """
    return UB_SLACK * (alpha_scale[..., None]
                       + sg_scale[..., None] * sqrt_delta)


def encode_corner_tables(alpha_min_pt: Array,
                         sqrt_gamma_max_pt: Array) -> dict:
    """Directed-rounded int8 corner fields (the Theorem-3 invariant).

    alpha_min FLOORS and sqrt_gamma_max CEILS — the one rule that keeps
    the decoded cluster lower bound conservative.  Every site that
    (re-)encodes corners goes through here so the direction can never be
    transposed in one copy.  Returns the BallForest field dict.
    """
    am_q, am_s, am_z = quantize_stats(alpha_min_pt, "floor")
    gm_q, gm_s, gm_z = quantize_stats(sqrt_gamma_max_pt, "ceil")
    return {"alpha_min_pt": am_q, "amin_scale": am_s, "amin_zp": am_z,
            "sqrt_gamma_max_pt": gm_q, "gmax_scale": gm_s, "gmax_zp": gm_z}


def encode_stat_tables(alpha: Array, sqrt_gamma: Array, alpha_min_pt: Array,
                       sqrt_gamma_max_pt: Array) -> dict:
    """Int8 field dict for all four (n, M) stat tables of a point block.

    Filter stats round to nearest (covered by :func:`ub_slack` at query
    time); corners go through :func:`encode_corner_tables`.
    """
    a_q, a_s, a_z = quantize_stats(alpha, "nearest")
    g_q, g_s, g_z = quantize_stats(sqrt_gamma, "nearest")
    return {"alpha": a_q, "alpha_scale": a_s, "alpha_zp": a_z,
            "sqrt_gamma": g_q, "sg_scale": g_s, "sg_zp": g_z,
            **encode_corner_tables(alpha_min_pt, sqrt_gamma_max_pt)}
