"""Partition-count optimization (Theorem 4) and PCCP (paper §5).

Everything here is offline precomputation, so it runs in numpy on the host;
the correlation matrix itself can be computed with the Pallas kernel
(kernels/pccp_corr.py) when the dataset is large.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bregman import BregmanFamily
from .transform import Partition, make_partition, p_transform, q_transform
from . import bounds


# ---------------------------------------------------------------------------
# Theorem 4 — optimized number of partitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostModel:
    """Fitted parameters of the paper's online cost model.

    UB(M) = A * alpha**M   (exponential bound decay; paper §5.1)
    lambda = beta * UB     (pruning fraction proportional to the bound)
    """

    a: float
    alpha: float
    beta: float
    n: int
    d: int

    def candidates(self, m: int) -> float:
        """Expected candidate-set size at M partitions: beta*A*alpha^M*n."""
        return self.beta * self.a * (self.alpha ** m) * self.n

    def online_cost(self, m: int, k: int = 1) -> float:
        """T(M) = d + M n + n log k + beta A alpha^M n (d + log k)."""
        logk = np.log(max(k, 2))
        cand = self.candidates(m)
        return self.d + m * self.n + self.n * logk + cand * (self.d + logk)

    def build_cost(self, m: int, kmeans_iters: int = 12) -> float:
        """Offline rebuild cost (paper Alg. 5 dominant term): per-subspace
        Bregman k-means is ``iters`` (n, w) x (w, C) matmuls per subspace,
        i.e. ~ iters * n * d * C flops-per-dim with C ~ n/32."""
        c = float(np.clip(self.n // 32, 8, 8192))
        return kmeans_iters * self.n * (self.d / max(m, 1)) * c * m

    def m_star(self, k: int = 1) -> int:
        """Theorem 4: M* = log_alpha( 2n / (-mu ln(alpha) (d + log k)) ).

        mu = beta*A*n.  The paper sets k=1 offline (log k negligible vs n).
        The closed form may be fractional / out of range; per §5.1 we
        evaluate the cost at floor and ceil and clamp to [1, d].
        """
        mu = self.beta * self.a * self.n
        logk = np.log(max(k, 2)) if k > 1 else 0.0
        inner = 2.0 * self.n / (-mu * np.log(self.alpha) * (self.d + logk))
        if inner <= 0:
            return max(1, min(self.d, int(np.sqrt(self.d))))
        m_frac = np.log(inner) / np.log(self.alpha)
        lo = int(np.floor(m_frac))
        hi = lo + 1
        best, best_cost = 1, np.inf
        for m in (lo, hi):
            m = int(np.clip(m, 1, self.d))
            c = self.online_cost(m, k)
            if c < best_cost:
                best, best_cost = m, c
        return best


def fit_cost_model(
    data: np.ndarray,
    family: BregmanFamily,
    num_samples: int = 50,
    m_probe: tuple[int, int] = (2, 8),
    seed: int = 0,
) -> CostModel:
    """Fit A, alpha, beta from sampled point pairs (paper §5.1).

    * A, alpha: fit UB = A*alpha^M through the mean UB at two probe values
      of M over sampled (point, query) pairs.
    * beta: mean fraction of points whose exact distance falls inside a
      sample's UB, divided by that UB (lambda = beta * UB).
    """
    data = np.asarray(data)
    n, d = data.shape
    rng = np.random.default_rng(seed)
    num_samples = min(num_samples, n // 2) or 1
    xi = rng.choice(n, size=num_samples, replace=False)
    yi = rng.choice(n, size=num_samples, replace=False)

    m1, m2 = m_probe
    m1 = int(np.clip(m1, 1, d))
    m2 = int(np.clip(m2, m1 + 1, d)) if d > m1 else m1 + 1

    def mean_ub(m: int) -> float:
        part = make_partition(d, m)
        p = p_transform(data[xi], part, family)
        q = q_transform(data[yi], part, family)
        comp = bounds.ub_components(
            {k_: np.asarray(v) for k_, v in p.items()},
            {k_: np.asarray(v) for k_, v in q.items() if v.ndim == 2},
        )
        return float(np.mean(np.sum(np.asarray(comp), axis=-1)))

    ub1, ub2 = mean_ub(m1), mean_ub(m2)
    ub1 = max(ub1, 1e-9)
    ub2 = max(min(ub2, ub1 * (1 - 1e-6)), 1e-9)  # enforce decay for the fit
    alpha = float((ub2 / ub1) ** (1.0 / (m2 - m1)))
    alpha = float(np.clip(alpha, 1e-4, 1.0 - 1e-4))
    a = float(ub1 / (alpha ** m1))

    # beta: pruning fraction per unit bound, measured on a data subsample.
    sub = data[rng.choice(n, size=min(n, 2048), replace=False)]
    lam = []
    for i in range(min(8, num_samples)):
        y = data[yi[i]]
        ub = a * alpha ** m1  # representative bound magnitude
        dist = np.asarray(family.distance(sub, y[None, :]))
        lam.append(np.mean(dist <= ub) / max(ub, 1e-9))
    beta = float(np.clip(np.mean(lam), 1e-8, 1e3))
    return CostModel(a=a, alpha=alpha, beta=beta, n=n, d=d)


# ---------------------------------------------------------------------------
# Merge-vs-rebuild decision for the mutable index (core/segments.py)
# ---------------------------------------------------------------------------

# Queries a compaction is amortized over before its cost "counts" — the
# serving-side knob: streams that compact rarely can afford a rebuild,
# chatty streams should merge.
COMPACT_AMORTIZE_QUERIES = 2048


def decide_compaction(
    model: CostModel,
    m: int,
    *,
    stale_fraction: float,
    amortize_queries: int = COMPACT_AMORTIZE_QUERIES,
    k: int = 1,
) -> str:
    """``"merge"`` or ``"rebuild"`` for a segmented forest (Theorem-4 model).

    A merge keeps the sealed segment's partition, centroids and gamma
    buckets; appended points were assigned against stale centroids and
    tombstones leave corner stats conservatively wide, so the merged
    index's expected candidate set — the ``beta * A * alpha^M * n`` term of
    the online cost — is inflated by roughly the stale fraction (appended +
    deleted over live).  A rebuild restores the fitted candidate estimate
    but pays :meth:`CostModel.build_cost` once, amortized over
    ``amortize_queries``.  Pick whichever per-query cost is lower.
    """
    base = model.online_cost(m, k)
    cost_merge = base + stale_fraction * model.candidates(m) * (
        model.d + np.log(max(k, 2)))
    cost_rebuild = base + model.build_cost(m) / max(amortize_queries, 1)
    return "rebuild" if cost_rebuild < cost_merge else "merge"


# ---------------------------------------------------------------------------
# PCCP — Pearson Correlation Coefficient-based Partition (paper §5.2)
# ---------------------------------------------------------------------------

def correlation_matrix(data: np.ndarray) -> np.ndarray:
    """|Pearson correlation| between all dimension pairs (d, d)."""
    x = np.asarray(data, dtype=np.float64)
    x = x - x.mean(axis=0, keepdims=True)
    std = np.sqrt((x * x).mean(axis=0))
    std = np.where(std < 1e-12, 1.0, std)
    corr = (x.T @ x) / (x.shape[0] * std[:, None] * std[None, :])
    np.fill_diagonal(corr, 0.0)
    return np.abs(corr)


def pccp_order(corr: np.ndarray, m: int, seed: int = 0) -> np.ndarray:
    """PCCP dim order: greedy correlation groups, then deal across partitions.

    Assignment: build ``G = ceil(d/M)`` groups of (up to) ``M`` dims each by
    greedily growing each group with the dim most correlated to *any* dim
    already in the group (paper's "assignment" step; first dim random).

    Partitioning: partition ``j`` takes the j-th member of every group, so
    highly-correlated dims land in *different* slots of the deal and each
    partition samples every correlation cluster — partitions become similar,
    their candidate sets overlap, the union shrinks (paper's motivation).

    Returns a dim order array to feed :func:`make_partition` — subspace ``i``
    is ``order[i*w:(i+1)*w]``.
    """
    d = corr.shape[0]
    rng = np.random.default_rng(seed)
    w = -(-d // m)                     # dims per partition = number of groups
    unassigned = set(range(d))
    groups: list[list[int]] = []
    while unassigned:
        first = int(rng.choice(sorted(unassigned)))
        group = [first]
        unassigned.discard(first)
        while len(group) < m and unassigned:
            cand = np.fromiter(unassigned, dtype=np.int64)
            sub = corr[np.ix_(group, cand)]       # (|group|, |cand|)
            best = cand[int(np.argmax(sub.max(axis=0)))]
            group.append(int(best))
            unassigned.discard(int(best))
        groups.append(group)
    assert len(groups) <= w + 1
    # Deal: partition j = {group[g][j] for all groups g that have a j-th dim}.
    partitions: list[list[int]] = [[] for _ in range(m)]
    for g in groups:
        for j, dim in enumerate(g):
            partitions[j % m].append(dim)
    # Flatten into a dealt order, padding-aware: make_partition slices w at a
    # time, so emit partitions in sequence, each padded later by the mask.
    order: list[int] = []
    for pdim in partitions:
        order.extend(pdim)
    return np.asarray(order, dtype=np.int32)


def build_pccp_partition(
    data: np.ndarray, m: int, seed: int = 0, corr: np.ndarray | None = None
) -> Partition:
    """Full PCCP pipeline: correlations -> order -> Partition layout.

    Note: the PCCP deal can make partition sizes uneven by +/-1 when
    ``d % M != 0``; we re-balance by splitting the flat dealt order into
    equal ``w``-sized chunks (semantically identical: chunks still mix
    correlation groups).
    """
    if corr is None:
        corr = correlation_matrix(data)
    order = pccp_order(corr, m, seed)
    return make_partition(corr.shape[0], m, order=order)
