"""Bregman distance families.

A Bregman distance is ``D_f(x, y) = f(x) - f(y) - <grad f(y), x - y>`` for a
strictly convex generator ``f``.  Every family used by the paper (and by this
framework) is *separable*: ``f(x) = sum_j phi(x_j)`` for a scalar convex
``phi``.  Separability is exactly the property the paper needs for
dimensionality partitioning ("cumulative after partitioning", §3.1) — the
distance over the full space is the sum of the distances over disjoint
subspaces.  KL divergence over the simplex is excluded for this reason
(its normalization couples dimensions).

Each family exposes the scalar generator ``phi``, its derivative
``phi_prime`` and the inverse of the derivative ``phi_prime_inv``
(= gradient of the convex conjugate, needed by the Cayton-style geodesic
bound in ``core/baselines.py``), a domain sampler and a domain projection.

All callables are pure jnp and safe under ``jit``/``vmap``/``grad``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BregmanFamily:
    """A separable Bregman generator ``f(x) = sum_j phi(x_j)``."""

    name: str
    phi: Callable[[Array], Array]            # elementwise generator
    phi_prime: Callable[[Array], Array]      # elementwise derivative
    phi_prime_inv: Callable[[Array], Array]  # inverse of phi_prime (dual grad)
    domain_low: float                        # open lower bound of the domain
    domain_high: float
    sample_shift: float = 0.0                # used by sample() to stay interior

    # -- generator-level ops -------------------------------------------------
    def f(self, x: Array) -> Array:
        """``f(x)``: sum of the elementwise generator over the trailing axis."""
        return jnp.sum(self.phi(x), axis=-1)

    def grad_f(self, x: Array) -> Array:
        return self.phi_prime(x)

    def distance(self, x: Array, y: Array) -> Array:
        """``D_f(x, y)`` over the trailing axis (broadcasts on leading axes)."""
        term = self.phi(x) - self.phi(y) - self.phi_prime(y) * (x - y)
        return jnp.sum(term, axis=-1)

    def distance_masked(self, x: Array, y: Array, mask: Array) -> Array:
        """``D_f`` restricted to dims where ``mask`` is 1 (padded subspaces)."""
        term = self.phi(x) - self.phi(y) - self.phi_prime(y) * (x - y)
        return jnp.sum(term * mask, axis=-1)

    def pairwise_distance(self, xs: Array, y: Array) -> Array:
        """``D_f(xs[i], y)`` for a stack of points ``xs`` of shape (n, d)."""
        return self.distance(xs, y[None, :])

    # -- domain helpers ------------------------------------------------------
    def project(self, x: Array) -> Array:
        """Clip into the (numerically safe interior of the) domain."""
        lo = self.domain_low + 1e-6 if jnp.isfinite(self.domain_low) else None
        hi = self.domain_high - 1e-6 if jnp.isfinite(self.domain_high) else None
        return jnp.clip(x, lo, hi)

    def sample(self, key: Array, shape, scale: float = 1.0) -> Array:
        """Draw valid data for this family (used by tests/benchmarks)."""
        raw = jax.random.normal(key, shape) * scale
        if self.name in ("itakura_saito", "burg", "shannon"):
            # strictly positive data
            return jnp.abs(raw) + 0.05 + self.sample_shift
        if self.name == "exponential":
            # keep exp(x) in a sane range
            return jnp.clip(raw, -4.0, 4.0)
        return raw + self.sample_shift


def validate_rows(family, rows, *, mode: str = "raise",
                  what: str = "row"):
    """Per-row domain gate: finite entries inside the family's OPEN domain.

    A NaN/inf coordinate, or a non-positive entry under a positive-domain
    generator (Itakura-Saito, Burg, Shannon), makes every downstream
    quantity (UB totals, Theorem-3 bounds, refine distances) garbage
    without any error — ``top_k`` over NaNs silently returns arbitrary
    rows.  This is THE cheap admission gate shared by query validation
    (``core.search.validate_queries``) and index-row quarantine
    (``core.segments``): one elementwise compare + row reduction, O(q*d).

    ``rows`` is (d,) or (q, d); returns a host-side (q,) bool ``ok`` mask
    (scalar-shaped input returns shape (1,)).  ``mode="raise"`` raises a
    ``ValueError`` naming the FIRST offending row; ``mode="mask"`` returns
    the mask so callers (the retrieval service's degraded path) can shed
    only the poisoned rows.  ``what`` names the rows in the error message.
    """
    fam = get_family(family) if isinstance(family, str) else family
    if mode not in ("raise", "mask"):
        raise ValueError(f"mode must be 'raise' or 'mask', got {mode!r}")
    arr = np.asarray(rows)
    if arr.ndim == 1:
        arr = arr[None, :]
    if arr.ndim != 2:
        raise ValueError(f"expected (d,) or (q, d) {what}s, got {arr.shape}")
    ok = np.isfinite(arr).all(axis=1)
    lo, hi = fam.domain_low, fam.domain_high
    with np.errstate(invalid="ignore"):
        if np.isfinite(lo):
            ok &= (arr > lo).all(axis=1)
        if np.isfinite(hi):
            ok &= (arr < hi).all(axis=1)
    if mode == "raise" and not ok.all():
        bad = int(np.argmax(~ok))
        lo_s = f"{lo:g}" if np.isfinite(lo) else "-inf"
        hi_s = f"{hi:g}" if np.isfinite(hi) else "inf"
        raise ValueError(
            f"{what} {bad} is invalid for Bregman family {fam.name!r}: "
            f"entries must be finite and inside the open domain "
            f"({lo_s}, {hi_s}); got {what} values "
            f"min={np.nanmin(arr[bad]):g} max={np.nanmax(arr[bad]):g} "
            f"finite={bool(np.isfinite(arr[bad]).all())}")
    return ok


def _squared_euclidean() -> BregmanFamily:
    return BregmanFamily(
        name="squared_euclidean",
        phi=lambda x: 0.5 * x * x,
        phi_prime=lambda x: x,
        phi_prime_inv=lambda t: t,
        domain_low=-jnp.inf,
        domain_high=jnp.inf,
    )


def _itakura_saito() -> BregmanFamily:
    # f(x) = -sum log x_i  ->  D_f(x,y) = sum(x/y - log(x/y) - 1)
    return BregmanFamily(
        name="itakura_saito",
        phi=lambda x: -jnp.log(x),
        phi_prime=lambda x: -1.0 / x,
        phi_prime_inv=lambda t: -1.0 / t,
        domain_low=0.0,
        domain_high=jnp.inf,
    )


def _exponential() -> BregmanFamily:
    # f(x) = sum exp(x_i)  ->  D_f(x,y) = sum(e^x - (x - y + 1) e^y)
    return BregmanFamily(
        name="exponential",
        phi=jnp.exp,
        phi_prime=jnp.exp,
        phi_prime_inv=jnp.log,
        domain_low=-jnp.inf,
        domain_high=jnp.inf,
    )


def _burg() -> BregmanFamily:
    # Burg entropy f(x) = -sum log x_i + x_i  (strictly convex on x>0)
    return BregmanFamily(
        name="burg",
        phi=lambda x: x - jnp.log(x),
        phi_prime=lambda x: 1.0 - 1.0 / x,
        phi_prime_inv=lambda t: 1.0 / (1.0 - t),
        domain_low=0.0,
        domain_high=jnp.inf,
    )


def _shannon() -> BregmanFamily:
    # Shannon entropy f(x) = sum x log x  (generalized I-divergence)
    return BregmanFamily(
        name="shannon",
        phi=lambda x: x * jnp.log(x),
        phi_prime=lambda x: jnp.log(x) + 1.0,
        phi_prime_inv=lambda t: jnp.exp(t - 1.0),
        domain_low=0.0,
        domain_high=jnp.inf,
    )


def mahalanobis(q_diag) -> BregmanFamily:
    """Squared Mahalanobis distance with a diagonal PSD matrix ``Q``.

    ``f(x) = 0.5 x^T Q x`` with diagonal ``Q`` stays separable; a full ``Q``
    would couple dimensions and break the partition bound (DESIGN.md §6).
    """
    q = jnp.asarray(q_diag)
    return BregmanFamily(
        name="mahalanobis",
        phi=lambda x: 0.5 * q * x * x,
        phi_prime=lambda x: q * x,
        phi_prime_inv=lambda t: t / q,
        domain_low=-jnp.inf,
        domain_high=jnp.inf,
    )


_REGISTRY = {
    "squared_euclidean": _squared_euclidean,
    "itakura_saito": _itakura_saito,
    "exponential": _exponential,
    "burg": _burg,
    "shannon": _shannon,
}

# Paper dataset-measure shorthand.
ALIASES = {"ed": "exponential", "isd": "itakura_saito", "se": "squared_euclidean"}


def get_family(name: str) -> BregmanFamily:
    key = ALIASES.get(name.lower(), name.lower())
    if key not in _REGISTRY:
        raise KeyError(f"unknown Bregman family {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[key]()


def family_names():
    return sorted(_REGISTRY)
