"""Exact and approximate kNN search (paper Alg. 6 + §8) on a BallForest.

TPU execution model: everything after the query transform is one jit'd
program with static shapes.  The dynamic-size candidate set of the paper is
realized as a static ``budget``-sized selection with an exactness flag
(DESIGN.md §6, item 5); :func:`knn_search` is the jit core and
:func:`knn` is the host wrapper that doubles the budget on overflow, so
results are ALWAYS exact for the exact mode.

Pipeline per query (Alg. 6):
  1. Q-transform (O(d)).
  2. UB filter over all points — matmul form (kernels/bregman_ub).
  3. tau = k-th smallest UB; per-subspace bounds qb (Alg. 4).
  4. Ball pruning per subspace (tuple-space LB, DESIGN §3.3); candidate mask
     = union over subspaces (Theorem 3).
  5. Refine selected candidates with exact D_f (kernels/bregman_dist),
     global top-k.

Batched pipeline (:func:`knn_search_batch`): a (q, d) query block runs the
same five phases end-to-end as ONE jitted program instead of a vmap of the
single-query core, with three structural differences that make it the
serving fast path:

  * **Filter** — the q per-query UB passes collapse onto a single
    (n, M) x (M, q) ``bregman_ub_matrix`` call (the MXU matmul form), and
    the per-column k smallest UBs are extracted by a *streaming* tiled
    k-selection: a ``lax.scan`` over ``block_rows``-sized row blocks merges
    each block's (bn, q) UB tile into a running (q, k) best set, so the
    (n, q) f32 UB matrix never materializes.
  * **Prune + compact** — a second scan over the SAME row blocks
    (:func:`_stream_prune_compact`) runs the whole post-filter pipeline
    in one streaming pass.  Each block is first tested at BLOCK
    granularity against the index's precomputed corner envelopes
    (``env_alpha_min``/``env_sqrt_gamma_max``, core/index.py — the
    tightest alpha_min / loosest sqrt_gamma_max over each
    ENV_BLOCK_ROWS-row group): an envelope dominates every row it covers,
    so a block no query admits is SKIPPED outright (``lax.cond``) without
    touching its per-point tile.  Surviving blocks run the fused
    Theorem-3 per-point admit kernel (kernels/ops.bregman_prune_block —
    corner recompute, compare, and mask emit in one VMEM-resident pass),
    take a per-block per-query prefix count, and scatter admitted rows
    straight into their static (q, budget) candidate slots via the
    running member count carried across blocks.  The historical (n, q)
    bool mask, (q, n) int32 cumsum, and per-query binary searches are
    gone: peak intermediate memory is O(block_rows * q + q * budget),
    independent of n (guarded by the hlo-analysis regression test in
    tests/test_stream_memory.py).  Slot order is index order, not UB
    order; when the union overflows the budget the overflowing queries
    are flagged ``exact=False`` and the host wrapper retries, exactly
    like the single-query path.  (:func:`knn_search_batch_reference`
    keeps the materialized mask/cumsum implementation as the bit-parity
    oracle for tests and benchmarks.)

Refinement then runs ONE batched kernel call over all queries' candidate
rows (kernels/bregman_dist.bregman_refine_batch) with per-query grad/c_y
tiles.  The §8 approximate mode's CDF shrink is vectorized over the batch.
:func:`knn_batch` is the host wrapper: an iterative, capped
budget-doubling loop shared by the whole batch.

Every public entry point also accepts the mutable
:class:`~repro.core.segments.SegmentedForest`: it is snapshotted to its
one-BallForest view (``_as_forest``), whose tombstoned rows are
search-inert in the filter, prune, and refine phases by construction.

Storage tiers: all paths run unchanged on the int8 BallForest
(``build_index(quantize=True)``).  The filter streams int8 codes through
the quantized UB kernel and inflates the Alg.-4 bounds by the stat
rounding slack (:func:`_qb_slack`), the prune decodes directed-rounded
(conservative) corner codes, and the refine runs the fused
dequantize+refine kernel on the surviving candidate rows — exact results
over the decoded point set at ~4x lower filter traffic
(docs/quantization.md).
"""

from __future__ import annotations

import functools
import logging
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .bregman import get_family, validate_rows
from .calibrate import resolve_p_guarantee
from .index import BallForest, ENV_BLOCK_ROWS
from .transform import q_transform
from . import bounds
from . import quantize as qz

Array = jax.Array

NEG_BIG = -1e30
POS_BIG = 1e30

logger = logging.getLogger(__name__)

# Default row-block size for the streaming batched pipeline; one block is
# the unit of VMEM residency (the TPU analogue of the paper's disk page,
# sized so the (block, q) UB tile plus the (block, q) admit tile stay
# on-chip).  Tunable end to end via the ``block_rows`` argument — see
# :func:`resolve_block_rows` for the tradeoff.
DEFAULT_BLOCK_ROWS = 4096


def resolve_block_rows(block_rows: int | None, n: int, *,
                       q: int | None = None,
                       storage: str | None = None) -> int:
    """Validate the ``block_rows`` tuning knob against an index of n rows.

    ``None`` means "pick for me": consult the checked-in autotuner table
    (launch/autotune.py) for this backend/shape, falling back to
    :data:`DEFAULT_BLOCK_ROWS` when no tuned entry applies.  ``q`` and
    ``storage`` sharpen the table lookup and are optional — callers that
    know the query-batch width and the index storage tier should pass
    them.  The value bounds BOTH streaming scans' working sets (filter
    merge and prune+compact), so it trades peak memory/VMEM residency
    against scan overhead: smaller blocks -> lower peak intermediate
    bytes (O(block_rows * q)) and finer-grained envelope skipping, larger
    blocks -> fewer scan steps and better MXU utilization per step.
    Values beyond ``n`` are legal (the layout clamps to one block);
    non-positive or non-integer values are programming errors and raise.

    The empty-index guard fires on BOTH knob paths: an empty index is an
    error regardless of whether the caller tuned the knob.
    """
    if n < 1:
        raise ValueError(f"cannot search an empty index (n={n})")
    if block_rows is None:
        from repro.launch.autotune import lookup_block_rows
        tuned = lookup_block_rows(n, q, storage=storage)
        return tuned if tuned is not None else DEFAULT_BLOCK_ROWS
    if isinstance(block_rows, bool) or not isinstance(block_rows, int):
        raise ValueError(f"block_rows must be an int, got {block_rows!r}")
    if block_rows < 8:
        raise ValueError(
            f"block_rows={block_rows} is below the minimum tile of 8 rows")
    return block_rows


def resolve_env_block_rows(env_block_rows: int | None) -> int:
    """Validate the envelope-gate granularity knob.

    Envelope tables are STORED at :data:`~repro.core.index.ENV_BLOCK_ROWS`
    granularity; the gate can run at any coarser multiple by min/max-
    coarsening the tables on the fly (a coarser envelope is a strictly
    looser bound, so every admitted-row set is a superset and results are
    invariant — only the skip rate changes).  ``None`` means the storage
    granularity; the autotuner sweeps multiples.
    """
    if env_block_rows is None:
        return ENV_BLOCK_ROWS
    if (isinstance(env_block_rows, bool)
            or not isinstance(env_block_rows, int)):
        raise ValueError(
            f"env_block_rows must be an int, got {env_block_rows!r}")
    if env_block_rows < ENV_BLOCK_ROWS or env_block_rows % ENV_BLOCK_ROWS:
        raise ValueError(
            f"env_block_rows={env_block_rows} must be a positive multiple "
            f"of the storage granularity {ENV_BLOCK_ROWS}")
    return env_block_rows


class SearchResult(NamedTuple):
    ids: Array          # (k,) original point ids — (q, k) from the batch path
    dists: Array        # (k,) exact Bregman distances — (q, k) batched
    exact: Array        # () bool — candidate set fit in the budget; (q,) batched
    num_candidates: Array  # () int32 — Theorem-3 union size; (q,) batched


class BatchStats(NamedTuple):
    """Structured retry telemetry from :func:`knn_batch`.

    The budget-escalation path used to announce itself only through a log
    line; services and benchmarks alert on THESE counters instead of
    scraping logs (``escalations`` growing under load means the default
    budget is undersized; ``escalated_to_scan`` should be ~never).
    """

    escalations: int        # budget-growth retries taken (0 = first try fit)
    budget_final: int       # the budget the returned launch ran with
    escalated_to_scan: bool  # cap exhausted -> full linear-scan fallback
    stopped_early: bool      # a stop_retry deadline ended the ladder


def validate_queries(measure, q, *, mode: str = "raise"):
    """Admission gate: reject NaN / out-of-domain query rows up front.

    ``knn_search``/``knn_search_batch`` math silently returns garbage for a
    query outside the generator's open domain (a non-positive entry under
    Itakura-Saito/Burg/Shannon, any NaN/inf anywhere): the UB matmul and
    the refine kernel both produce NaNs that ``top_k`` resolves to
    arbitrary rows with ``exact=True``.  This host-side gate is one
    elementwise pass over the (q, d) block.  ``mode="raise"`` names the
    first offending row; ``mode="mask"`` returns a (q,) bool ``ok`` mask
    for callers that degrade per row instead of failing the whole block
    (serve/retrieval.py sheds exactly the flagged rows).  ``measure`` is a
    family name or :class:`~repro.core.bregman.BregmanFamily`.
    """
    return validate_rows(measure, q, mode=mode, what="query row")


def query_struct(y: Array, partition, family) -> dict:
    """Everything the pipeline needs about a query (or (q, d) block).

    Per-subspace triples (Alg. 3) plus the refine constants — the query
    representation of the single-query and batched paths.  The distributed
    path (dist/knn.py) builds the same dict from its pre-gathered subspace
    view via ``transform.q_transform_views`` + ``query_refine_constants``
    instead of calling this (the gather is hoisted to the host there).
    """
    q = q_transform(y, partition, family)
    q.update(bounds.query_refine_constants(y, family))
    return q


def _query_struct(index: BallForest, y: Array) -> dict:
    return query_struct(y, index.partition, index.family)


def _as_forest(index, k: int | None = None) -> BallForest:
    """Accept a BallForest or the mutable SegmentedForest (core/segments.py).

    A mutable index exposes ``view()`` — the cached one-BallForest snapshot
    over its sealed main + append segments — and ``live_n``; ``k`` is
    validated against the LIVE count when present, because tombstoned rows
    are physically in the snapshot but can never be returned (``index.n``
    alone would over-promise).
    """
    live_n = getattr(index, "live_n", None)
    if k is not None and live_n is not None and k > live_n:
        raise ValueError(f"k={k} exceeds live point count {live_n}")
    view = getattr(index, "view", None)
    return view() if callable(view) else index


def _tuple_rows(index: BallForest, idx: Array) -> dict:
    """Dequantized (alpha, sqrt_gamma) P-tuples at the given row indices.

    ``idx`` may be a scalar, (k,) or (q, k); fields come back with a
    trailing (M,) axis.  In the f32 tier this is a plain gather (bit-
    identical to reading the tables); in the int8 tier the gathered codes
    are decoded with their per-row affine — only the touched rows ever
    reach fp32.
    """
    a = jnp.take(index.alpha, idx, axis=0)
    g = jnp.take(index.sqrt_gamma, idx, axis=0)
    if index.storage == "int8":
        a = qz.dequantize_stats(a, jnp.take(index.alpha_scale, idx),
                                jnp.take(index.alpha_zp, idx))
        g = qz.dequantize_stats(g, jnp.take(index.sg_scale, idx),
                                jnp.take(index.sg_zp, idx))
    return {"alpha": a, "sqrt_gamma": g}


def _qb_slack(index: BallForest, idx: Array, sqrt_delta: Array):
    """Quantization slack for the Alg.-4 searching bounds (0 in f32).

    Admissibility (docs/quantization.md): among the k rows whose DECODED
    upper bounds are smallest, every row j satisfies
    ``UB_true(j) <= UB_hat(j) + eps_j`` with ``eps_j = sum_i (alpha_scale_j
    + sg_scale_j * sqrt_delta_i) / 2``, so the k-th smallest true distance
    is at most the k-th decoded UB plus ``max_j eps_j``.  The slack is
    distributed per subspace (componentwise max over the k rows) so the
    pigeonhole step of Theorem 3 still applies to the inflated ``qb``.

    ``idx`` is the filter's (…, k) top-k row indices; returns (…, M).
    """
    if index.storage != "int8":
        return jnp.zeros_like(sqrt_delta)
    a_s = jnp.max(jnp.take(index.alpha_scale, idx, axis=0), axis=-1)
    g_s = jnp.max(jnp.take(index.sg_scale, idx, axis=0), axis=-1)
    return qz.ub_slack(a_s, g_s, sqrt_delta)


def _corner_tables(index: BallForest) -> tuple[Array, Array]:
    """Full (n, M) fp32 corner tables (decoded in the int8 tier).

    The int8 corners were DIRECTED-rounded at build (alpha_min floored,
    sqrt_gamma_max ceiled), so the decoded values are conservative and the
    Theorem-3 admission below needs no slack term.
    """
    return qz.decoded_corner_tables(index)


def _corner_admit(amin_pt: Array, gmax_pt: Array, qconst: Array,
                  sqrt_delta: Array, qb: Array, sub_axis: int) -> Array:
    """THE Theorem-3 membership test, shared by every search path.

    Membership must be CLUSTER-granular: Theorem 3's pigeonhole argument
    bounds the per-subspace EXACT distance (D_i <= qb_i for some i), and
    the conservative cluster lower bound LB_c <= min_{x in c} D_i never
    prunes a cluster containing such a point.  (A per-point test on the
    Cauchy UPPER bound components is NOT valid — UB_i > qb_i for all i does
    not contradict D <= tau.)  The cluster corners are evaluated through
    the index's per-point view (``alpha_min_pt``/``sqrt_gamma_max_pt``,
    gathered once at build time from the gamma-bucketed corner stats —
    core/index.py), so the test is a pure broadcasted compare.  ``sub_axis``
    names the subspace axis of the broadcasted operands.
    """
    lb = amin_pt + qconst - gmax_pt * sqrt_delta
    return jnp.any(lb <= qb, axis=sub_axis)


def _candidate_mask(index: BallForest, q: dict, qb: Array) -> Array:
    """Theorem-3 union membership for one query. (n,) bool."""
    amin, gmax = _corner_tables(index)
    return _corner_admit(amin, gmax,
                         q["qconst"], q["sqrt_delta"], qb, sub_axis=-1)


def _refine(index: BallForest, q: dict, sel: Array, valid: Array, k: int):
    """Exact distances for one query's selected rows: the q=1 batch slice."""
    qs1 = {"grad": q["grad"][None], "c_y": q["c_y"][None]}
    ids, dists = _refine_batch(index, qs1, sel[None], valid[None], k)
    return ids[0], dists[0]


def _single_filter(index: BallForest, q: dict, k: int):
    """Filter phase for one query: (totals (n,), top-k idx (k,), qb (M,)).

    f32 storage runs the original ub_filter; the int8 tier streams the
    codes through the quantized UB kernel and inflates the Alg.-4 bounds
    by the stat rounding slack (`_qb_slack`) so the downstream prune stays
    admissible over the decoded point set.
    """
    from repro.kernels import ops as kernel_ops
    if index.storage == "int8":
        totals = kernel_ops.bregman_ub_matrix_quant(
            index.alpha, index.alpha_scale, index.alpha_zp,
            index.sqrt_gamma, index.sg_scale, index.sg_zp,
            q["qconst"][None], q["sqrt_delta"][None])[:, 0]
        _, idx = jax.lax.top_k(-totals, k)
        qb = (bounds.ub_components(_tuple_rows(index, idx[-1]), q)
              + _qb_slack(index, idx, q["sqrt_delta"]))
    else:
        totals, comp_kth_fn = kernel_ops.bregman_ub_filter(
            index.alpha, index.sqrt_gamma, q["qconst"], q["sqrt_delta"])
        _, idx = jax.lax.top_k(-totals, k)
        qb = comp_kth_fn(idx[-1])
    return totals, idx, qb


@functools.partial(jax.jit, static_argnames=("k", "budget"))
def _knn_search_jit(index: BallForest, y: Array, k: int,
                    budget: int) -> SearchResult:
    """Exact kNN for one query (jit core, static budget)."""
    q = _query_struct(index, y)

    # ---- filter: total UB for every point (MXU matmul form) ----
    totals, _idx, qb = _single_filter(index, q, k)     # (M,) Alg. 4 bounds

    # ---- ball pruning + union (Theorem 3) ----
    mask = _candidate_mask(index, q, qb)
    num_candidates = jnp.sum(mask.astype(jnp.int32))

    # ---- static-budget selection: all union members first, by UB ----
    priority = jnp.where(mask, POS_BIG - totals, NEG_BIG - totals)
    _, sel = jax.lax.top_k(priority, budget)
    valid = jnp.take(mask, sel)

    ids, dists = _refine(index, q, sel, valid, k)
    exact = num_candidates <= budget
    return SearchResult(ids=ids, dists=dists, exact=exact,
                        num_candidates=num_candidates)


def knn_search(index, y: Array, k: int, budget: int,
               validate: bool = True) -> SearchResult:
    """Exact kNN for one query (static budget; accepts a mutable index)."""
    if getattr(index, "is_tiered_store", False):
        res = index.search(jnp.asarray(y, jnp.float32)[None, :], k, budget,
                           validate=validate)
        return SearchResult(ids=res.ids[0], dists=res.dists[0],
                            exact=res.exact[0],
                            num_candidates=res.num_candidates[0])
    index = _as_forest(index, k)
    budget = resolve_budget(budget, index.n, k)
    if validate:
        validate_queries(index.family, y)
    return _knn_search_jit(index, y, k, budget)


@functools.partial(jax.jit, static_argnames=("k", "budget"))
def _knn_search_approx_jit(
    index: BallForest, y: Array, k: int, budget: int, p_guarantee: Array
) -> SearchResult:
    """Approximate kNN with probability guarantee p (paper §8, Prop. 1).

    The Cauchy slack mu of the k-th bound is shrunk to c*mu with
    ``c = Psi^-1(p*Psi(mu) + (1-p)*Psi(-kappa)) / mu`` where Psi is the
    empirical CDF of the cross term beta_xy (index.beta_samples); each
    subspace bound's sqrt term is scaled by c.  In the int8 tier the
    quantization slack inflates ``qb`` BEFORE the shrink (matching the
    batched and distributed paths), so the probabilistic guarantee holds
    w.r.t. the decoded point set.
    """
    q = _query_struct(index, y)

    totals, idx, qb = _single_filter(index, q, k)
    kth = idx[-1]

    # Full-space kappa and mu of the k-th bound (paper §8 notation).
    sqrt_term = _tuple_rows(index, kth)["sqrt_gamma"] * q["sqrt_delta"]  # (M,)
    kappa_i = qb - sqrt_term                           # per-subspace kappa
    kappa = jnp.sum(kappa_i)
    mu = jnp.sum(sqrt_term)

    c = _cdf_shrink(index.beta_samples, mu, kappa, p_guarantee)
    qb_approx = kappa_i + c * sqrt_term                # shrunk bounds

    mask = _candidate_mask(index, q, qb_approx)
    num_candidates = jnp.sum(mask.astype(jnp.int32))
    priority = jnp.where(mask, POS_BIG - totals, NEG_BIG - totals)
    _, sel = jax.lax.top_k(priority, budget)
    valid = jnp.take(mask, sel)
    ids, dists = _refine(index, q, sel, valid, k)
    return SearchResult(ids=ids, dists=dists, exact=num_candidates <= budget,
                        num_candidates=num_candidates)


def knn_search_approx(index, y: Array, k: int, budget: int,
                      p_guarantee: Array,
                      validate: bool = True) -> SearchResult:
    """§8 approximate kNN for one query (accepts a mutable index)."""
    if getattr(index, "is_tiered_store", False):
        res = index.search(jnp.asarray(y, jnp.float32)[None, :], k, budget,
                           p_guarantee=p_guarantee, validate=validate)
        return SearchResult(ids=res.ids[0], dists=res.dists[0],
                            exact=res.exact[0],
                            num_candidates=res.num_candidates[0])
    index = _as_forest(index, k)
    budget = resolve_budget(budget, index.n, k)
    validate_p_guarantee(p_guarantee)
    if validate:
        validate_queries(index.family, y)
    return _knn_search_approx_jit(index, y, k, budget, p_guarantee)


def _cdf_shrink(samples: Array, mu: Array, kappa: Array, p: Array) -> Array:
    """§8 Prop.-1 shrink factor c from the empirical beta_xy CDF.

    Vectorized: ``mu``/``kappa`` may be scalars (single query) or (q,)
    batches; returns the same shape.
    """
    s = samples.shape[0]

    def cdf(t):
        return jnp.searchsorted(samples, t, side="right").astype(jnp.float32) / s

    def inv_cdf(u):
        pos = jnp.clip(u * (s - 1), 0.0, s - 1.0)
        lo = jnp.floor(pos).astype(jnp.int32)
        hi = jnp.minimum(lo + 1, s - 1)
        w = pos - lo.astype(jnp.float32)
        return samples[lo] * (1 - w) + samples[hi] * w

    target = p * cdf(mu) + (1.0 - p) * cdf(-kappa)
    return jnp.clip(inv_cdf(target) / jnp.maximum(mu, 1e-12), 0.0, 1.0)


# ---------------------------------------------------------------------------
# Batched pipeline (the serving fast path)
# ---------------------------------------------------------------------------

def _block_layout(n: int, block_rows: int) -> tuple[int, int]:
    """(block, num_blocks) covering n rows; block <= block_rows."""
    bn = max(8, min(block_rows, n))
    nb = -(-n // bn)
    return bn, nb


def _pad_blocks(arr: Array, bn: int, nb: int, fill: float = 0.0) -> Array:
    """Pad (n, M) rows up to nb*bn with ``fill`` and reshape to (nb, bn, M)."""
    pad = nb * bn - arr.shape[0]
    return jnp.pad(arr, ((0, pad), (0, 0)),
                   constant_values=fill).reshape(nb, bn, arr.shape[1])


def _pad_cols(arr: Array, bn: int, nb: int, fill: float = 0.0) -> Array:
    """Pad a per-row (n,) column up to nb*bn and reshape to (nb, bn)."""
    pad = nb * bn - arr.shape[0]
    return jnp.pad(arr, (0, pad), constant_values=fill).reshape(nb, bn)


def _filter_blocks(index: BallForest, bn: int, nb: int) -> tuple:
    """The (nb, bn, ...) filter-table blocks (alpha / sqrt_gamma + decode).

    Shared by the filter scan and the fused filter+prune scan so both read
    identically padded blocks (zero-padded; padded rows are masked by the
    global-index guard in the consumers).
    """
    if index.storage == "int8":
        return (_pad_blocks(index.alpha, bn, nb),
                _pad_blocks(index.sqrt_gamma, bn, nb),
                _pad_cols(index.alpha_scale, bn, nb),
                _pad_cols(index.alpha_zp, bn, nb),
                _pad_cols(index.sg_scale, bn, nb),
                _pad_cols(index.sg_zp, bn, nb))
    return (_pad_blocks(index.alpha, bn, nb),
            _pad_blocks(index.sqrt_gamma, bn, nb))


def _batch_filter_topk(index: BallForest, qs: dict, k: int,
                       block_rows: int) -> tuple[Array, Array]:
    """Streaming per-column k-selection over the (n, q) UB matrix.

    One UB-matrix kernel call per row block inside a scan; the carry is
    the running (q, k) smallest totals + their global row indices, so peak
    memory is O(block_rows * q) regardless of n.  Ties resolve to the lower
    row index (carry rows precede the block in the merge concat), matching
    ``lax.top_k`` over the full column.  The int8 tier streams code blocks
    plus their per-row decode scalars through the quantized kernel — the
    full-width (n, M) reads are 1-byte, the 4x traffic win of the tier.
    """
    from repro.kernels import ops as kernel_ops
    n = index.alpha.shape[0]
    q = qs["qconst"].shape[0]
    bn, nb = _block_layout(n, block_rows)
    offs = jnp.arange(nb, dtype=jnp.int32) * bn
    xs = _filter_blocks(index, bn, nb) + (offs,)

    def step(carry, blk):
        best_v, best_i = carry                          # (q, k) each
        if index.storage == "int8":
            a, sg, a_s, a_z, g_s, g_z, off = blk
            vals = kernel_ops.bregman_ub_matrix_quant(
                a, a_s, a_z, sg, g_s, g_z,
                qs["qconst"], qs["sqrt_delta"])         # (bn, q)
        else:
            a, sg, off = blk
            vals = kernel_ops.bregman_ub_matrix(
                a, sg, qs["qconst"], qs["sqrt_delta"])  # (bn, q)
        gidx = off + jnp.arange(bn, dtype=jnp.int32)
        vals = jnp.where((gidx < n)[:, None], vals, POS_BIG)
        cand_v = jnp.concatenate([best_v, vals.T], axis=1)          # (q, k+bn)
        cand_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(gidx[None, :], (q, bn))], axis=1)
        neg, sel = jax.lax.top_k(-cand_v, k)
        return (-neg, jnp.take_along_axis(cand_i, sel, axis=1)), None

    init = (jnp.full((q, k), POS_BIG, jnp.float32),
            jnp.zeros((q, k), jnp.int32))
    (vals, idx), _ = jax.lax.scan(step, init, xs)
    return vals, idx                                    # ascending along k


def _candidate_mask_batch(index: BallForest, qs: dict, qb: Array,
                          block_rows: int) -> Array:
    """Batched Theorem-3 union membership -> (n, q) bool.

    :func:`_corner_admit` broadcast over the query batch, chunked over row
    blocks so the (block, M, q) intermediate bounds peak memory.
    """
    n = index.alpha_min_pt.shape[0]
    q = qb.shape[0]
    bn, nb = _block_layout(n, block_rows)
    qc = qs["qconst"].T[None, :, :]                     # (1, M, q)
    sd = qs["sqrt_delta"].T[None, :, :]                 # (1, M, q)
    qbT = qb.T[None, :, :]                              # (1, M, q)
    blocks = _corner_blocks(index, bn, nb)

    if index.storage == "int8":
        def block_mask(blk):
            am_q, gm_q, a_s, a_z, g_s, g_z = blk
            amin = qz.dequantize_stats(am_q, a_s, a_z)  # (bn, M)
            gmax = qz.dequantize_stats(gm_q, g_s, g_z)
            return _corner_admit(amin[:, :, None], gmax[:, :, None],
                                 qc, sd, qbT, sub_axis=1)   # (bn, q)
    else:
        def block_mask(blk):
            amin, gmax = blk                            # (bn, M)
            return _corner_admit(amin[:, :, None], gmax[:, :, None],
                                 qc, sd, qbT, sub_axis=1)   # (bn, q)

    mask = jax.lax.map(block_mask, blocks)              # (nb, bn, q)
    return mask.reshape(nb * bn, q)[:n]


def _corner_blocks(index: BallForest, bn: int, nb: int) -> tuple:
    """The (nb, bn, ...) corner-table blocks both prune implementations scan.

    THE one definition of the inert-row padding for the prune phase: the
    f32 tier pads ``alpha_min_pt`` with +BIG directly, the int8 tier
    streams the corner CODES (1 byte/entry) with the PAD_CORNER sentinel
    riding in the padded rows' zero-point (zero scale, so a padded row
    decodes to +BIG and fails every admission).  Shared by the streamed
    scan and the materialized reference so the two pipelines can never
    disagree on what a padded row decodes to.
    """
    if index.storage == "int8":
        return (_pad_blocks(index.alpha_min_pt, bn, nb),
                _pad_blocks(index.sqrt_gamma_max_pt, bn, nb),
                _pad_cols(index.amin_scale, bn, nb),
                _pad_cols(index.amin_zp, bn, nb, fill=POS_BIG),
                _pad_cols(index.gmax_scale, bn, nb),
                _pad_cols(index.gmax_zp, bn, nb))
    return (_pad_blocks(index.alpha_min_pt, bn, nb, fill=POS_BIG),
            _pad_blocks(index.sqrt_gamma_max_pt, bn, nb))


def _compact_candidates(mask: Array, budget: int) -> tuple[Array, Array, Array]:
    """Compact each query's union members into ``budget`` slots.

    Slot s holds the s-th member in index order, found by binary search on
    the running member count (``searchsorted(cumsum, s+1)``): O(n) cumsum +
    O(budget log n) searches per query, with no full-n top_k and no scatter
    (XLA CPU serializes scatters).  Returns (sel (q, budget) row indices,
    valid (q, budget) bool, num_candidates (q,)).  Members beyond the
    budget are dropped in index order; callers must check
    ``num_candidates <= budget`` for exactness.
    """
    maskT = mask.T                                      # (q, n)
    q, n = maskT.shape
    csum = jnp.cumsum(maskT.astype(jnp.int32), axis=1)  # (q, n) nondecreasing
    num_candidates = csum[:, -1]
    targets = jnp.arange(1, budget + 1, dtype=jnp.int32)
    sel = jax.vmap(lambda c: jnp.searchsorted(c, targets, side="left"))(csum)
    sel = jnp.minimum(sel, n - 1).astype(jnp.int32)     # clamp empty slots
    valid = targets[None, :] <= jnp.minimum(num_candidates, budget)[:, None]
    return sel, valid, num_candidates


def _fill_block_slots(sel: Array, count: Array, admit: Array, off: Array,
                      budget: int) -> tuple[Array, Array]:
    """Route one block's admitted rows into their budget slots.

    A block fills the contiguous slot range [count, count+tot); the row of
    within-block member rank r is found by binary search on the block's
    admit prefix-sum (the blockwise analogue of _compact_candidates'
    searchsorted).  Only min(bn, budget) ranks can occur per block, so the
    search is rank-limited and a budget-sized gather+select routes each
    slot to its rank — no scatter anywhere (XLA CPU serializes scatters)
    and no array longer than the block.  Factored out of the scan bodies
    so the fused and unfused paths share slot semantics by construction.
    """
    bn = admit.shape[0]
    csum = jnp.cumsum(admit, axis=0)                     # (bn, q)
    tot = csum[-1]                                       # (q,)
    t_ranks = min(bn, budget)
    ranks = jnp.arange(1, t_ranks + 1, dtype=jnp.int32)
    rows_for_rank = jax.vmap(
        lambda c: jnp.searchsorted(c, ranks, side="left"))(csum.T)
    rows_for_rank = jnp.minimum(rows_for_rank,
                                bn - 1).astype(jnp.int32)  # (q, T)
    r0 = (jnp.arange(budget, dtype=jnp.int32)[None, :]
          - count[:, None])                              # rank-1
    fill = (r0 >= 0) & (r0 < tot[:, None])
    rows_at_slot = jnp.take_along_axis(
        rows_for_rank, jnp.clip(r0, 0, t_ranks - 1), axis=1)
    sel = jnp.where(fill, off + rows_at_slot, sel)
    return sel, count + tot


def _env_tables(index: BallForest, n: int, m: int, eb: int, win: int,
                sharded: bool) -> tuple[Array, Array]:
    """Envelope tables at gate granularity ``eb``, padded with inert rows.

    The tables are STORED at ENV_BLOCK_ROWS granularity; a coarser gate
    (eb a multiple of it) min/max-coarsens them on the fly.  Coarser
    envelopes are strictly looser bounds, so the admitted-block set only
    grows and results stay bit-identical — the knob trades gate precision
    (skip rate) against gate cost, which is what the autotuner sweeps.
    """
    env_a, env_g = index.env_alpha_min, index.env_sqrt_gamma_max
    if env_a is None:
        if sharded:
            # The sharded path must carry GLOBAL envelope tables
            # (shard_index refreshes them); a local-n-sized always-admit
            # fallback indexed at a global offset would silently skip
            # every block on shards past the first.
            raise ValueError(
                "sharded streaming prune needs envelope tables; pass the "
                "forest through shard_index/refresh_envelopes first")
        # Hand-built index without envelopes: a full-length always-admit
        # table keeps the scan structure with skipping disabled.  It must
        # cover EVERY block's window (not just block 0), or later blocks
        # would slice into the inert padding and be wrongly skipped.
        ne = max(-(-n // eb), 1)
        env_a = jnp.full((ne, m), -POS_BIG, jnp.float32)
        env_g = jnp.zeros((ne, m), jnp.float32)
    elif eb != ENV_BLOCK_ROWS:
        f = eb // ENV_BLOCK_ROWS
        ne = env_a.shape[0]
        pad = -ne % f
        env_a = jnp.min(jnp.pad(env_a, ((0, pad), (0, 0)),
                                constant_values=POS_BIG)
                        .reshape(-1, f, m), axis=1)
        env_g = jnp.max(jnp.pad(env_g, ((0, pad), (0, 0)))
                        .reshape(-1, f, m), axis=1)
    env_a = jnp.pad(env_a, ((0, win), (0, 0)), constant_values=POS_BIG)
    env_g = jnp.pad(env_g, ((0, win), (0, 0)))
    return env_a, env_g


def _stream_prune_compact(index: BallForest, qs: dict, qb: Array,
                          budget: int, block_rows: int,
                          row_offset: Array | None = None,
                          fused: bool = True,
                          env_block_rows: int | None = None,
                          with_tau: bool = False):
    """Streaming prune + compact: one scan, no (n, q) intermediates.

    A second ``lax.scan`` over the filter's ``block_rows`` blocks replaces
    :func:`_candidate_mask_batch` + :func:`_compact_candidates` (kept as
    the bit-parity reference).  Per block:

    1. **Envelope gate** — the corner-envelope rows covering the block
       run the Theorem-3 test at block granularity.  An envelope
       dominates every row it covers, so a block NO query admits is
       skipped via ``lax.cond`` — its per-point corner tile is never
       read, its admit kernel never runs.  The FUSED path evaluates the
       whole envelope table in one vectorized pass before the scan (one
       (ne, M, q) op + a prefix-sum, so the per-block gate is two gathers
       instead of per-step dynamic slices); the unfused path keeps the
       original per-step ``dynamic_slice`` window as the comparator.
       Both compute identical gate bits.
    2. **Per-point admit** — surviving blocks call one kernel: the fused
       path runs ``bregman_filter_prune_block`` (UB tile + Theorem-3
       admit in one VMEM-resident pass over the row block — the UB
       values never round-trip through HBM, and feed the ``tau_admit``
       telemetry when ``with_tau``); the unfused path runs the original
       ``bregman_prune_block``.  Both emit the same (block, q) int32
       admit tile.
    3. **Streaming compaction** — :func:`_fill_block_slots` routes the
       block's members into the budget slots carried across blocks;
       slot order = index order, identical to the reference compaction.

    ``row_offset`` maps local rows to GLOBAL envelope rows for the
    sharded path (dist/knn.py keeps the envelope tables replicated and
    passes ``axis_index * local_n``); single-host callers leave it None.
    ``env_block_rows`` coarsens the gate granularity (see
    :func:`resolve_env_block_rows`); results are invariant, skip rates
    are not.  Returns ``(sel (q, budget), valid (q, budget),
    num_candidates (q,), env_admitted (q,), blocks_run (), tau (q,))``:
    ``env_admitted`` counts, per query, the (block, query) tiles the
    envelope gate admitted — ``nb * q - sum(env_admitted)`` tiles were
    rejected at envelope level — while ``blocks_run`` counts the blocks
    whose per-point kernel actually executed (a block runs, for ALL its
    query columns, whenever ANY query admits it).  ``tau`` is the
    per-query min UB over admitted rows (+BIG when nothing admitted or
    ``with_tau`` is off — the fused kernel's UB output is only consumed,
    and on the jnp ref path only computed, when the caller asks).
    """
    from repro.kernels import ops as kernel_ops
    n = index.alpha_min_pt.shape[0]
    q, m = qb.shape
    bn, nb = _block_layout(n, block_rows)
    eb = resolve_env_block_rows(env_block_rows)
    offs = jnp.arange(nb, dtype=jnp.int32) * bn
    # A block of bn rows spans at most win = ceil(bn / eb) + 1 envelope
    # rows at any alignment.  Pad with inert rows (never admit) so every
    # window is in range: block starts lie below the covered row count,
    # hence window starts below the unpadded table length.
    win = -(-bn // eb) + 1
    env_a, env_g = _env_tables(index, n, m, eb, win,
                               sharded=row_offset is not None)
    qcT, sdT, qbT = qs["qconst"].T, qs["sqrt_delta"].T, qb.T   # (M, q)
    goffs = offs if row_offset is None else row_offset + offs  # (nb,)

    if fused:
        # Hoisted envelope gate: per-row admit over the whole (padded)
        # table in one op, then each block's OR-over-span via a prefix-sum
        # difference — bitwise the same gate as the windowed slice (same
        # per-row admit bits, same span), without nb dynamic slices.
        lb_env = (env_a[:, :, None] + qcT[None]
                  - env_g[:, :, None] * sdT[None])         # (nep, M, q)
        row_admit = jnp.any(lb_env <= qbT[None], axis=1)   # (nep, q)
        ecs = jnp.concatenate(
            [jnp.zeros((1, q), jnp.int32),
             jnp.cumsum(row_admit.astype(jnp.int32), axis=0)], axis=0)
        e0s = goffs // eb                                  # (nb,)
        e_his = (goffs + bn - 1) // eb
        env_admit_all = (jnp.take(ecs, e_his + 1, axis=0)
                         - jnp.take(ecs, e0s, axis=0)) > 0  # (nb, q)
        xs = (_filter_blocks(index, bn, nb)
              + _corner_blocks(index, bn, nb) + (offs, env_admit_all))
    else:
        xs = _corner_blocks(index, bn, nb) + (offs,)

    def gate_windowed(goff):
        e0 = goff // eb
        wa = jax.lax.dynamic_slice(env_a, (e0, 0), (win, env_a.shape[1]))
        wg = jax.lax.dynamic_slice(env_g, (e0, 0), (win, env_g.shape[1]))
        # The static window is sized for the worst misalignment; rows past
        # the block's actual envelope span (e.g. the whole +1 row when the
        # block is eb-aligned) are masked inert so they cannot loosen the
        # gate.
        e_hi = (goff + bn - 1) // eb
        in_span = (e0 + jnp.arange(win)) <= e_hi                # (win,)
        wa = jnp.where(in_span[:, None], wa, POS_BIG)
        wg = jnp.where(in_span[:, None], wg, 0.0)
        lb = wa[:, :, None] + qcT[None] - wg[:, :, None] * sdT[None]
        return jnp.any(lb <= qbT[None], axis=(0, 1))            # (q,)

    def step(carry, blk):
        sel, count, admitted, blocks_run, tau = carry
        if fused:
            off, env_admit = blk[-2], blk[-1]
        else:
            off = blk[-1]
            env_admit = gate_windowed(
                off if row_offset is None else row_offset + off)

        def run(args):
            sel, count, tau = args
            if fused:
                if index.storage == "int8":
                    (a, sg, a_s, a_z, g_s, g_z,
                     am, gm, am_s, am_z, gm_s, gm_z, _, _) = blk
                    ub, admit = kernel_ops.bregman_filter_prune_block_quant(
                        a, a_s, a_z, sg, g_s, g_z,
                        am, am_s, am_z, gm, gm_s, gm_z,
                        qs["qconst"], qs["sqrt_delta"], qb)      # (bn, q) x2
                else:
                    a, sg, am, gm, _, _ = blk
                    ub, admit = kernel_ops.bregman_filter_prune_block(
                        a, sg, am, gm,
                        qs["qconst"], qs["sqrt_delta"], qb)
            else:
                ub = None
                if index.storage == "int8":
                    am, gm, a_s, a_z, g_s, g_z, _ = blk
                    admit = kernel_ops.bregman_prune_block_quant(
                        am, a_s, a_z, gm, g_s, g_z,
                        qs["qconst"], qs["sqrt_delta"], qb)      # (bn, q)
                else:
                    am, gm, _ = blk
                    admit = kernel_ops.bregman_prune_block(
                        am, gm, qs["qconst"], qs["sqrt_delta"], qb)
            gidx = off + jnp.arange(bn, dtype=jnp.int32)
            admit = admit * (gidx < n).astype(jnp.int32)[:, None]
            if with_tau and ub is not None:
                tau = jnp.minimum(
                    tau, jnp.min(jnp.where(admit > 0, ub, POS_BIG), axis=0))
            sel, count = _fill_block_slots(sel, count, admit, off, budget)
            return sel, count, tau

        any_admit = jnp.any(env_admit)
        sel, count, tau = jax.lax.cond(any_admit, run,
                                       lambda args: args, (sel, count, tau))
        return (sel, count, admitted + env_admit.astype(jnp.int32),
                blocks_run + any_admit.astype(jnp.int32), tau), None

    # Unfilled slots hold n-1, matching _compact_candidates' clamp, so the
    # two implementations agree bit-for-bit on every output.
    init = (jnp.full((q, budget), n - 1, jnp.int32),
            jnp.zeros((q,), jnp.int32), jnp.zeros((q,), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.full((q,), POS_BIG, jnp.float32))
    (sel, count, admitted, blocks_run, tau), _ = jax.lax.scan(step, init, xs)
    targets = jnp.arange(1, budget + 1, dtype=jnp.int32)
    valid = targets[None, :] <= jnp.minimum(count, budget)[:, None]
    return sel, valid, count, admitted, blocks_run, tau


def _refine_batch(index: BallForest, qs: dict, sel: Array, valid: Array,
                  k: int):
    """One batched kernel call refines all queries' candidate rows.

    The int8 tier gathers candidate CODES (1 byte/coord) plus two decode
    scalars per row and runs the fused dequantize+refine kernel, so the
    full fp32 point table never exists — exact distances over the decoded
    point set, 4x less refine gather traffic.
    """
    from repro.kernels import ops as kernel_ops
    if index.storage == "int8":
        codes = jnp.take(index.data, sel, axis=0)       # (q, budget, d) int8
        scale = jnp.take(index.data_scale, sel)         # (q, budget)
        zp = jnp.take(index.data_zp, sel)
        dist = kernel_ops.bregman_refine_batch_quant(
            codes, scale, zp, qs["grad"], qs["c_y"], index.family_name)
    else:
        rows = jnp.take(index.data, sel, axis=0)        # (q, budget, d)
        dist = kernel_ops.bregman_refine_batch(
            rows, qs["grad"], qs["c_y"], index.family_name)  # (q, budget)
    dist = jnp.where(valid, dist, POS_BIG)
    neg, pos = jax.lax.top_k(-dist, k)                  # (q, k)
    ids = jnp.take(index.point_ids,
                   jnp.take_along_axis(sel, pos, axis=1))
    return ids, -neg


def _knn_search_batch_core(index: BallForest, ys: Array, k: int, budget: int,
                           p_guarantee: Array | None, block_rows: int,
                           streaming: bool = True, with_stats: bool = False,
                           fused: bool = True,
                           env_block_rows: int | None = None):
    if k > index.n:
        # The streaming merge always has >= k columns, so without this guard
        # a too-large k would silently return sentinel rows as "exact".
        raise ValueError(f"k={k} exceeds index size n={index.n}")
    if budget < k:
        raise ValueError(f"budget={budget} must be >= k={k} (the refine "
                         "top-k needs at least k slots)")
    if ys.ndim != 2:
        raise ValueError(f"expected (q, d) queries, got {ys.shape}")
    qs = _query_struct(index, ys)                       # all fields (q, ...)

    # ---- phase 1+2: one fused filter matmul + streaming k-selection ----
    # The k-th row's tuple sets qb; the full top-k indices feed the int8
    # tier's bound slack (max rounding error over the rows that could have
    # determined the k-th UB).
    _, idx = _batch_filter_topk(index, qs, k, block_rows)
    kth = idx[:, -1]                                    # (q,)
    kth_tuple = _tuple_rows(index, kth)
    sqrt_term = kth_tuple["sqrt_gamma"] * qs["sqrt_delta"]       # (q, M)
    qb = (bounds.ub_components(kth_tuple, qs)           # (q, M) Alg. 4
          + _qb_slack(index, idx, qs["sqrt_delta"]))

    if p_guarantee is not None:                         # §8 shrink, batched
        kappa_i = qb - sqrt_term
        c = _cdf_shrink(index.beta_samples, jnp.sum(sqrt_term, -1),
                        jnp.sum(kappa_i, -1), p_guarantee)
        qb = kappa_i + c[:, None] * sqrt_term

    # ---- phase 3+4: streaming prune + compact (block-skip from envelopes),
    # then one batched refine ----
    if streaming:
        (sel, valid, num_candidates, env_admitted, blocks_run,
         tau) = _stream_prune_compact(index, qs, qb, budget, block_rows,
                                      fused=fused,
                                      env_block_rows=env_block_rows,
                                      with_tau=with_stats and fused)
    else:
        # Reference path: materialized (n, q) mask + (q, n) cumsum.
        mask = _candidate_mask_batch(index, qs, qb, block_rows)
        sel, valid, num_candidates = _compact_candidates(mask, budget)
        env_admitted = jnp.zeros((ys.shape[0],), jnp.int32)
        blocks_run = jnp.zeros((), jnp.int32)
        tau = jnp.full((ys.shape[0],), POS_BIG, jnp.float32)
    ids, dists = _refine_batch(index, qs, sel, valid, k)
    res = SearchResult(ids=ids, dists=dists,
                       exact=num_candidates <= budget,
                       num_candidates=num_candidates)
    return (res, env_admitted, blocks_run, tau) if with_stats else res


@functools.partial(jax.jit, static_argnames=("k", "budget", "block_rows",
                                             "env_block_rows"))
def _knn_search_batch_jit(index: BallForest, ys: Array, k: int, budget: int,
                          block_rows: int,
                          env_block_rows: int | None = None) -> SearchResult:
    return _knn_search_batch_core(index, ys, k, budget, None, block_rows,
                                  env_block_rows=env_block_rows)


@functools.partial(jax.jit, static_argnames=("k", "budget", "block_rows",
                                             "env_block_rows"))
def _knn_search_batch_unfused_jit(
    index: BallForest, ys: Array, k: int, budget: int, block_rows: int,
    env_block_rows: int | None = None,
) -> SearchResult:
    """The two-kernel streamed pipeline (separate UB + prune kernels,
    per-step envelope windows) — kept compiled as the fused path's A/B
    comparator for benchmarks and parity tests."""
    return _knn_search_batch_core(index, ys, k, budget, None, block_rows,
                                  fused=False, env_block_rows=env_block_rows)


def knn_search_batch(index, ys: Array, k: int, budget: int,
                     block_rows: int | None = None,
                     validate: bool = True,
                     env_block_rows: int | None = None) -> SearchResult:
    """Exact kNN for a (q, d) query block — one jitted program, (q, ...) fields."""
    if getattr(index, "is_tiered_store", False):
        # Out-of-core index (core/tiered.py): same pipeline, re-cut at the
        # host/device boundary — bit-identical results by contract.
        return index.search(ys, k, budget, block_rows=block_rows,
                            env_block_rows=env_block_rows,
                            validate=validate)
    index = _as_forest(index, k)
    budget = resolve_budget(budget, index.n, k)
    if validate:
        validate_queries(index.family, ys)
    br = resolve_block_rows(block_rows, index.n, q=ys.shape[0],
                            storage=index.storage)
    return _knn_search_batch_jit(index, ys, k, budget, br,
                                 resolve_env_block_rows(env_block_rows))


@functools.partial(jax.jit, static_argnames=("k", "budget", "block_rows"))
def _knn_search_batch_approx_jit(
    index: BallForest, ys: Array, k: int, budget: int, p_guarantee: Array,
    block_rows: int,
) -> SearchResult:
    return _knn_search_batch_core(index, ys, k, budget, p_guarantee,
                                  block_rows)


def knn_search_batch_approx(
    index, ys: Array, k: int, budget: int, p_guarantee: Array | None = None,
    block_rows: int | None = None, validate: bool = True,
    target_recall: float | None = None,
) -> SearchResult:
    """§8 approximate kNN for a (q, d) block; CDF shrink vectorized over q.

    Exactly one of ``p_guarantee`` (the raw §8 knob) and ``target_recall``
    must be given.  ``target_recall`` inverts the index's fitted recall
    calibration (core/calibrate.py) on the host to pick the shrink level —
    the measured-recall contract; on an uncalibrated index it falls back
    to ``p_guarantee = target_recall`` with a one-time warning.
    """
    if getattr(index, "is_tiered_store", False):
        if (p_guarantee is None) == (target_recall is None):
            raise ValueError(
                "pass exactly one of p_guarantee / target_recall")
        return index.search(ys, k, budget, p_guarantee=p_guarantee,
                            target_recall=target_recall,
                            block_rows=block_rows, validate=validate)
    index = _as_forest(index, k)
    budget = resolve_budget(budget, index.n, k)
    if (p_guarantee is None) == (target_recall is None):
        raise ValueError(
            "pass exactly one of p_guarantee / target_recall")
    if target_recall is not None:
        p_guarantee, _ = resolve_p_guarantee(index, target_recall)
    validate_p_guarantee(p_guarantee)
    if validate:
        validate_queries(index.family, ys)
    br = resolve_block_rows(block_rows, index.n, q=ys.shape[0],
                            storage=index.storage)
    return _knn_search_batch_approx_jit(index, ys, k, budget,
                                        jnp.float32(p_guarantee), br)


@functools.partial(jax.jit, static_argnames=("k", "budget", "block_rows"))
def _knn_search_batch_stats_jit(index: BallForest, ys: Array, k: int,
                                budget: int, block_rows: int):
    return _knn_search_batch_core(index, ys, k, budget, None, block_rows,
                                  with_stats=True)


def knn_search_batch_stats(index, ys: Array, k: int, budget: int,
                           block_rows: int | None = None,
                           ) -> tuple[SearchResult, dict]:
    """:func:`knn_search_batch` plus envelope block-skip telemetry.

    Returns ``(result, stats)`` with the streaming scan's shape
    (``num_blocks``, resolved ``block_rows``) and two distinct skip
    metrics — read them carefully when capacity planning:

    * ``block_skip_rate`` — fraction of (block, query) TILES the envelope
      gate rejected.  A rejected tile provably contributes no candidate,
      but its block's per-point kernel still runs (for all query columns)
      if ANY other query admits the block.
    * ``whole_block_skip_rate`` — fraction of BLOCKS whose per-point
      kernel never executed because every query rejected them; this is
      the fraction of per-point admit compute actually avoided.

    Same compiled pipeline as the plain entry point modulo the returned
    counters; meant for benchmarks and capacity planning, not the serving
    hot path.
    """
    if getattr(index, "is_tiered_store", False):
        raise TypeError(
            "knn_search_batch_stats runs the all-resident pipeline; a "
            "TieredPointStore reports its own telemetry via store.stats / "
            "store.cache_info(), or pass store.as_resident_forest()")
    index = _as_forest(index, k)
    budget = resolve_budget(budget, index.n, k)
    br = resolve_block_rows(block_rows, index.n, q=ys.shape[0],
                            storage=index.storage)
    res, env_admitted, blocks_run, tau = _knn_search_batch_stats_jit(
        index, ys, k, budget, br)
    bn, nb = _block_layout(index.n, br)
    tiles = nb * ys.shape[0]
    stats = {
        "block_rows": bn,
        "num_blocks": nb,
        "num_blocks_run": int(blocks_run),
        "env_admitted_tiles": int(jnp.sum(env_admitted)),
        "block_skip_rate": 1.0 - float(jnp.sum(env_admitted)) / tiles,
        "whole_block_skip_rate": 1.0 - int(blocks_run) / nb,
        # Tightest filter UB among admitted rows, per query — an upper
        # bound on the true kNN distance, a byproduct of the fused
        # kernel's VMEM-resident UB tile (no extra HBM traffic).
        "tau_admit": tau,
    }
    return res, stats


@functools.partial(jax.jit, static_argnames=("k", "budget", "block_rows"))
def _knn_search_batch_ref_jit(index: BallForest, ys: Array, k: int,
                              budget: int, block_rows: int) -> SearchResult:
    return _knn_search_batch_core(index, ys, k, budget, None, block_rows,
                                  streaming=False)


@functools.partial(jax.jit, static_argnames=("k", "budget", "block_rows"))
def _knn_search_batch_ref_approx_jit(
    index: BallForest, ys: Array, k: int, budget: int, p_guarantee: Array,
    block_rows: int,
) -> SearchResult:
    return _knn_search_batch_core(index, ys, k, budget, p_guarantee,
                                  block_rows, streaming=False)


def knn_search_batch_reference(index, ys: Array, k: int, budget: int,
                               p_guarantee: Array | None = None,
                               block_rows: int | None = None) -> SearchResult:
    """The materialized mask/cumsum pipeline — the bit-parity oracle.

    Identical math to :func:`knn_search_batch` but pruning via the full
    (n, q) Theorem-3 mask and compaction via the (q, n) cumsum binary
    search (the pre-streaming implementation).  O(n * q) peak memory, so
    tests and benchmarks only; the streamed path must match it
    bit-for-bit on every output field.
    """
    if getattr(index, "is_tiered_store", False):
        raise TypeError(
            "knn_search_batch_reference materializes the full (n, q) mask "
            "on device — meaningless for an out-of-core store; pass "
            "store.as_resident_forest() to oracle against the same points")
    index = _as_forest(index, k)
    budget = resolve_budget(budget, index.n, k)
    validate_p_guarantee(p_guarantee)
    br = resolve_block_rows(block_rows, index.n)
    if p_guarantee is None:
        return _knn_search_batch_ref_jit(index, ys, k, budget, br)
    return _knn_search_batch_ref_approx_jit(index, ys, k, budget,
                                            jnp.float32(p_guarantee), br)


# ---------------------------------------------------------------------------
# Host wrappers (escape hatch: double the budget until the union fits)
# ---------------------------------------------------------------------------

MAX_BUDGET_DOUBLINGS = 8


def resolve_budget(budget, n: int, k: int) -> int:
    """THE refine-budget resolver: every public entry point routes its
    ``budget`` knob through here before first use (brelint knob-contract,
    docs/static_analysis.md).

    ``None`` picks the cost model's candidate estimate; an explicit
    budget must be an integer >= k (fewer slots can never hold the k
    results — the same contract the jit core enforces) and is clamped to
    ``n``: a pinned budget can outlive a compaction that shrank the index
    (serve/knnlm.py), and ``top_k(priority, budget)`` needs budget <= n.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"resolve_budget: empty index (n={n})")
    if k > n:
        # Diagnose the real error before the budget math trips over it
        # (same message as the jit core's trace-time guard).
        raise ValueError(f"k={k} exceeds index size n={n}")
    if budget is None:
        return int(min(n, max(4 * k, 64, n // 16)))
    if isinstance(budget, bool) or budget != int(budget):
        raise TypeError(f"budget must be an int or None, got {budget!r}")
    budget = int(budget)
    if budget < k:
        raise ValueError(f"budget={budget} must be >= k={k} (the refine "
                         "top-k needs at least k slots)")
    return min(budget, n)


def validate_p_guarantee(p) -> None:
    """Range-gate a raw §8 shrink probability (``p_guarantee`` /
    ``approx_p``) before it enters a jitted program.

    Only host scalars are checked — traced/jax values pass through
    untouched (there is no host value to compare, and coercing one would
    be exactly the host-op-under-trace defect brelint exists to catch);
    the calibration sweep and the jit cores feed those paths.
    """
    if p is None:
        return
    if isinstance(p, bool) or not isinstance(
            p, (int, float, np.floating, np.integer)):
        return
    v = float(p)
    if not 0.0 <= v <= 1.0:    # False for NaN too
        raise ValueError(f"p_guarantee must be within [0, 1], got {v}")


def default_budget(index: BallForest, k: int) -> int:
    """Initial refine budget ~ the cost model's candidate estimate."""
    return resolve_budget(None, index.n, k)


def fitted_budget_for_n(n: int, k: int, needed: int) -> int:
    """Smallest power-of-two budget (>= k, capped at ``n``) covering
    ``needed`` candidates.  The ONE sizing rule for overflow handling:
    retries (single-host AND per-shard — dist/knn.py passes the shard
    size as ``n``) and serving-side pinned budgets all use it, so they
    land on the same static shapes and reuse each other's compiled
    programs.
    """
    need = max(int(needed), k, 1)
    return int(min(n, 1 << (need - 1).bit_length()))


def fitted_budget(index: BallForest, k: int, needed: int) -> int:
    """:func:`fitted_budget_for_n` against a whole index."""
    return fitted_budget_for_n(index.n, k, needed)


def knn(index: BallForest, y, k: int, budget: int | None = None,
        approx_p: float | None = None) -> SearchResult:
    """Host-level kNN: retries with doubled budget when the union overflows.

    Always exact when ``approx_p is None``; with ``approx_p`` the result has
    the paper's probability guarantee instead.
    """
    index = _as_forest(index, k)
    y = jnp.asarray(y, jnp.float32)
    validate_queries(index.family, y)
    validate_p_guarantee(approx_p)
    budget = resolve_budget(budget, index.n, k)
    while True:
        if approx_p is None:
            res = knn_search(index, y, k, budget, validate=False)
        else:
            res = knn_search_approx(index, y, k, budget,
                                    jnp.float32(approx_p), validate=False)
        if bool(res.exact) or budget >= index.n:
            return res
        budget = min(index.n, budget * 2)


def knn_batch(index: BallForest, ys, k: int, budget: int | None = None,
              approx_p: float | None = None, *,
              target_recall: float | None = None,
              max_doublings: int = MAX_BUDGET_DOUBLINGS,
              block_rows: int | None = None,
              stop_retry=None, return_stats: bool = False,
              validate: bool = True):
    """Batched kNN via the fused :func:`knn_search_batch` pipeline.

    One retry policy for the whole batch: if ANY query's Theorem-3 union
    overflows, the block re-runs with a budget sized to the largest
    observed union (``num_candidates`` is budget-independent, so one retry
    normally resolves the overflow), rounded up to a power of two so
    repeated budgets reuse compiled programs.  The loop is bounded by
    ``max_doublings``; if exhausted, a warning is logged and the block
    falls back to ONE fused brute-force scan (exact by construction, no
    per-query dataset gather), preserving the invariant that exact-mode
    results are exact and approx-mode results carry the §8 guarantee.

    ``block_rows`` tunes the streaming scans' block size (peak memory vs
    scan overhead — :func:`resolve_block_rows`); it is forwarded to every
    retry, so one setting governs the whole call.

    **Deadline-capped ladder**: ``stop_retry`` (no-arg callable -> bool) is
    consulted before every ADDITIONAL launch — each budget-growth retry
    and the final scan escalation.  Returning True ends the ladder
    immediately with the best result so far (rows whose union overflowed
    keep ``exact=False`` — a budget-capped PARTIAL result), instead of
    doubling forever past a deadline.  serve/retrieval.py passes
    ``lambda: clock() + est_launch > deadline`` here; the default ``None``
    preserves the always-exact contract.

    ``return_stats=True`` returns ``(SearchResult, BatchStats)`` — the
    structured escalation counters services and benchmarks alert on
    (the log line is advisory only).

    ``target_recall`` (mutually exclusive with ``approx_p``) selects the
    approximate mode at a CALIBRATED shrink: the index's fitted recall
    curve is inverted on the host (core/calibrate.py) and the resolved
    ``p_guarantee`` drives the usual §8 pipeline.
    """
    index = _as_forest(index, k)
    if target_recall is not None:
        if approx_p is not None:
            raise ValueError(
                "pass at most one of approx_p / target_recall")
        approx_p, _ = resolve_p_guarantee(index, target_recall)
    validate_p_guarantee(approx_p)
    ys = jnp.asarray(ys, jnp.float32)
    if ys.ndim != 2:
        raise ValueError(f"knn_batch wants (q, d) queries, got {ys.shape}")
    if validate:
        validate_queries(index.family, ys)
    budget = resolve_budget(budget, index.n, k)
    p = None if approx_p is None else jnp.float32(approx_p)

    def run(b):
        if p is None:
            return knn_search_batch(index, ys, k, b, block_rows,
                                    validate=False)
        return knn_search_batch_approx(index, ys, k, b, p, block_rows,
                                       validate=False)

    def done(res, escalations, scan=False, stopped=False):
        stats = BatchStats(escalations=escalations, budget_final=budget,
                           escalated_to_scan=scan, stopped_early=stopped)
        return (res, stats) if return_stats else res

    for attempt in range(max_doublings + 1):
        res = run(budget)
        if bool(jnp.all(res.exact)) or budget >= index.n:
            return done(res, attempt)
        if attempt == max_doublings:
            break
        if stop_retry is not None and stop_retry():
            # Deadline exhausted: hand back the budget-capped partial
            # result (overflowed rows keep exact=False) instead of
            # launching again.
            return done(res, attempt, stopped=True)
        # needed > budget on overflow, so the fitted budget strictly grows.
        budget = fitted_budget(index, k, int(jnp.max(res.num_candidates)))
    escalations = max_doublings
    if stop_retry is not None and stop_retry():
        return done(res, escalations, stopped=True)
    logger.warning(
        "knn_batch: budget cap exhausted after %d doublings (budget=%d, "
        "%d/%d queries overflowed); escalating to a full linear scan "
        "(n=%d)", max_doublings, budget,
        int(jnp.sum(~res.exact)), ys.shape[0], index.n)
    # Full scan instead of run(index.n): a budget=n refine would gather a
    # (q, n, d) copy of the dataset; the fused brute-force distance needs
    # no per-query row gather.  num_candidates (budget-independent) comes
    # from the last capped run.  A tiered store pays one full
    # materialization here — the escalation is already the worst case.
    scan_index = (index.as_resident_forest()
                  if getattr(index, "is_tiered_store", False) else index)
    ids, dists = _brute_force_live(scan_index, ys, k)
    res = SearchResult(ids=ids, dists=dists,
                       exact=jnp.ones(ys.shape[0], bool),
                       num_candidates=res.num_candidates)
    return done(res, escalations, scan=True)


@functools.partial(jax.jit, static_argnames=("k",))
def _brute_force_live(index: BallForest, ys: Array, k: int):
    """Linear scan over the LIVE rows of an index — the escalation oracle.

    Unlike :func:`brute_force_knn` over ``index.data``, this masks
    tombstoned/padded rows (``point_ids < 0``, whose data is the inert
    ones-fill at a finite distance) so a mutated index never surfaces a
    deleted id even on the budget-cap escape hatch.  ``rows_view`` decodes
    the int8 tier, so the scan is exact over the stored point set there
    too.
    """
    fam = index.family
    rows = index.rows_view()
    dist = jax.vmap(lambda y: fam.distance(rows, y[None, :]))(ys)
    dist = jnp.where((index.point_ids >= 0)[None, :], dist, POS_BIG)
    neg, idx = jax.lax.top_k(-dist, k)                  # (q, k)
    return jnp.take(index.point_ids, idx), -neg


def brute_force_knn(data, y, k: int, family) -> tuple[Array, Array]:
    """Linear-scan oracle (used by tests and as the paper's baseline floor).

    ``y`` may be a single (d,) query or a (q, d) batch; the batch form
    returns ((q, k) ids, (q, k) dists) so tests and benchmarks share one
    oracle with the batched pipeline.
    """
    fam = get_family(family) if isinstance(family, str) else family
    y = jnp.asarray(y)
    if y.ndim == 2:
        return jax.vmap(lambda yy: brute_force_knn(data, yy, k, fam))(y)
    dist = fam.distance(jnp.asarray(data), y[None, :])
    neg, idx = jax.lax.top_k(-dist, k)
    return idx, -neg
