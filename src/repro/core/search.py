"""Exact and approximate kNN search (paper Alg. 6 + §8) on a BallForest.

TPU execution model: everything after the query transform is one jit'd
program with static shapes.  The dynamic-size candidate set of the paper is
realized as a static ``budget``-sized selection with an exactness flag
(DESIGN.md §6, item 5); :func:`knn_search` is the jit core and
:func:`knn` is the host wrapper that doubles the budget on overflow, so
results are ALWAYS exact for the exact mode.

Pipeline per query (Alg. 6):
  1. Q-transform (O(d)).
  2. UB filter over all points — matmul form (kernels/bregman_ub).
  3. tau = k-th smallest UB; per-subspace bounds qb (Alg. 4).
  4. Ball pruning per subspace (tuple-space LB, DESIGN §3.3); candidate mask
     = union over subspaces (Theorem 3).
  5. Refine selected candidates with exact D_f (kernels/bregman_dist),
     global top-k.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bregman import get_family
from .index import BallForest
from .transform import q_transform
from . import bounds

Array = jax.Array

NEG_BIG = -1e30
POS_BIG = 1e30


class SearchResult(NamedTuple):
    ids: Array          # (k,) original point ids
    dists: Array        # (k,) exact Bregman distances
    exact: Array        # () bool — candidate set fit in the budget
    num_candidates: Array  # () int32 — Theorem-3 union size


def _query_struct(index: BallForest, y: Array) -> dict:
    fam = index.family
    q = q_transform(y, index.partition, fam)
    q.update(bounds.query_refine_constants(y, fam))
    return q


def _candidate_mask(index: BallForest, q: dict, qb: Array) -> Array:
    """Theorem-3 union membership via per-subspace cluster pruning. (n,) bool.

    Membership must be CLUSTER-granular: Theorem 3's pigeonhole argument
    bounds the per-subspace EXACT distance (D_i <= qb_i for some i), and
    the conservative cluster lower bound LB_c <= min_{x in c} D_i never
    prunes a cluster containing such a point.  (A per-point test on the
    Cauchy UPPER bound components is NOT valid — UB_i > qb_i for all i does
    not contradict D <= tau.)  Tightness comes from the index's
    gamma-bucketed corner stats (core/index.py): each ball contributes
    ``num_buckets`` (alpha_min, sqrt_gamma_max) corners instead of one.
    """
    # Bucketed-corner lower bounds: (M, C_eff)
    lb = (index.alpha_min + q["qconst"][:, None]
          - index.sqrt_gamma_max * q["sqrt_delta"][:, None])
    admitted = lb <= qb[:, None]                       # (M, C_eff) bool
    # Per-point admission per subspace, then union.
    per_sub = jax.vmap(lambda a, i: a[i], in_axes=(0, 1), out_axes=1)(
        admitted, index.assign
    )                                                  # (n, M)
    return jnp.any(per_sub, axis=-1)


def _refine(index: BallForest, q: dict, sel: Array, valid: Array, k: int):
    """Exact distances for the selected rows; invalid rows pushed to +inf."""
    from repro.kernels import ops as kernel_ops
    rows = jnp.take(index.data, sel, axis=0)           # (budget, d)
    dist = kernel_ops.bregman_refine(rows, q["grad"], q["c_y"], index.family_name)
    dist = jnp.where(valid, dist, POS_BIG)
    neg, pos = jax.lax.top_k(-dist, k)
    ids = jnp.take(index.point_ids, jnp.take(sel, pos))
    return ids, -neg


@functools.partial(jax.jit, static_argnames=("k", "budget"))
def knn_search(index: BallForest, y: Array, k: int, budget: int) -> SearchResult:
    """Exact kNN for one query (jit core, static budget)."""
    from repro.kernels import ops as kernel_ops
    q = _query_struct(index, y)

    # ---- filter: total UB for every point (MXU matmul form) ----
    totals, comp_kth_fn = kernel_ops.bregman_ub_filter(
        index.alpha, index.sqrt_gamma, q["qconst"], q["sqrt_delta"]
    )
    neg_vals, idx = jax.lax.top_k(-totals, k)
    kth = idx[-1]
    tau = -neg_vals[-1]
    qb = comp_kth_fn(kth)                              # (M,) Alg. 4 bounds

    # ---- ball pruning + union (Theorem 3) ----
    mask = _candidate_mask(index, q, qb)
    num_candidates = jnp.sum(mask.astype(jnp.int32))

    # ---- static-budget selection: all union members first, by UB ----
    priority = jnp.where(mask, POS_BIG - totals, NEG_BIG - totals)
    _, sel = jax.lax.top_k(priority, budget)
    valid = jnp.take(mask, sel)

    ids, dists = _refine(index, q, sel, valid, k)
    exact = num_candidates <= budget
    return SearchResult(ids=ids, dists=dists, exact=exact,
                        num_candidates=num_candidates)


@functools.partial(jax.jit, static_argnames=("k", "budget"))
def knn_search_approx(
    index: BallForest, y: Array, k: int, budget: int, p_guarantee: Array
) -> SearchResult:
    """Approximate kNN with probability guarantee p (paper §8, Prop. 1).

    The Cauchy slack mu of the k-th bound is shrunk to c*mu with
    ``c = Psi^-1(p*Psi(mu) + (1-p)*Psi(-kappa)) / mu`` where Psi is the
    empirical CDF of the cross term beta_xy (index.beta_samples); each
    subspace bound's sqrt term is scaled by c.
    """
    from repro.kernels import ops as kernel_ops
    q = _query_struct(index, y)

    totals, comp_kth_fn = kernel_ops.bregman_ub_filter(
        index.alpha, index.sqrt_gamma, q["qconst"], q["sqrt_delta"]
    )
    neg_vals, idx = jax.lax.top_k(-totals, k)
    kth = idx[-1]
    qb = comp_kth_fn(kth)

    # Full-space kappa and mu of the k-th bound (paper §8 notation).
    sqrt_term = jnp.take(index.sqrt_gamma, kth, axis=0) * q["sqrt_delta"]  # (M,)
    kappa_i = qb - sqrt_term                           # per-subspace kappa
    kappa = jnp.sum(kappa_i)
    mu = jnp.sum(sqrt_term)

    # Empirical CDF interpolation on the sorted beta sample.
    samples = index.beta_samples
    s = samples.shape[0]

    def cdf(t):
        return jnp.searchsorted(samples, t, side="right").astype(jnp.float32) / s

    def inv_cdf(u):
        pos = jnp.clip(u * (s - 1), 0.0, s - 1.0)
        lo = jnp.floor(pos).astype(jnp.int32)
        hi = jnp.minimum(lo + 1, s - 1)
        w = pos - lo.astype(jnp.float32)
        return samples[lo] * (1 - w) + samples[hi] * w

    target = p_guarantee * cdf(mu) + (1.0 - p_guarantee) * cdf(-kappa)
    c = jnp.clip(inv_cdf(target) / jnp.maximum(mu, 1e-12), 0.0, 1.0)

    qb_approx = kappa_i + c * sqrt_term                # shrunk bounds

    mask = _candidate_mask(index, q, qb_approx)
    num_candidates = jnp.sum(mask.astype(jnp.int32))
    priority = jnp.where(mask, POS_BIG - totals, NEG_BIG - totals)
    _, sel = jax.lax.top_k(priority, budget)
    valid = jnp.take(mask, sel)
    ids, dists = _refine(index, q, sel, valid, k)
    return SearchResult(ids=ids, dists=dists, exact=num_candidates <= budget,
                        num_candidates=num_candidates)


# ---------------------------------------------------------------------------
# Host wrappers (escape hatch: double the budget until the union fits)
# ---------------------------------------------------------------------------

def default_budget(index: BallForest, k: int) -> int:
    """Initial refine budget ~ the cost model's candidate estimate."""
    n = index.n
    return int(min(n, max(4 * k, 64, n // 16)))


def knn(index: BallForest, y, k: int, budget: int | None = None,
        approx_p: float | None = None) -> SearchResult:
    """Host-level kNN: retries with doubled budget when the union overflows.

    Always exact when ``approx_p is None``; with ``approx_p`` the result has
    the paper's probability guarantee instead.
    """
    y = jnp.asarray(y, jnp.float32)
    budget = budget or default_budget(index, k)
    while True:
        if approx_p is None:
            res = knn_search(index, y, k, budget)
        else:
            res = knn_search_approx(index, y, k, budget,
                                    jnp.float32(approx_p))
        if bool(res.exact) or budget >= index.n:
            return res
        budget = min(index.n, budget * 2)


def knn_batch(index: BallForest, ys, k: int, budget: int | None = None,
              approx_p: float | None = None):
    """vmapped batch search (single retry policy across the batch)."""
    ys = jnp.asarray(ys, jnp.float32)
    budget = budget or default_budget(index, k)
    if approx_p is None:
        fn = jax.vmap(lambda y: knn_search(index, y, k, budget))
    else:
        fn = jax.vmap(lambda y: knn_search_approx(index, y, k, budget,
                                                  jnp.float32(approx_p)))
    res = fn(ys)
    if approx_p is None and not bool(jnp.all(res.exact)) and budget < index.n:
        return knn_batch(index, ys, k, min(index.n, budget * 4), approx_p)
    return res


def brute_force_knn(data, y, k: int, family) -> tuple[Array, Array]:
    """Linear-scan oracle (used by tests and as the paper's baseline floor)."""
    fam = get_family(family) if isinstance(family, str) else family
    dist = fam.distance(jnp.asarray(data), jnp.asarray(y)[None, :])
    neg, idx = jax.lax.top_k(-dist, k)
    return idx, -neg
