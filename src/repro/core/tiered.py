"""Out-of-core tiered point store: envelope-gated host->device streaming.

The batched pipeline's peak COMPUTE memory has been O(block_rows * q)
since the streaming pass (PR 5) and the fused kernel pass (PR 7), but the
point tables themselves still lived wholly on device, capping ``n`` at
HBM.  This module splits a :class:`~repro.core.index.BallForest` into two
residency tiers:

* **Hot (always device-resident)** — everything the filter phase and the
  hoisted envelope gate stream: the (n, M) filter stats ``alpha`` /
  ``sqrt_gamma`` (int8 codes + per-row decode in the int8 tier), the
  per-block corner envelopes ``env_alpha_min`` / ``env_sqrt_gamma_max``,
  ``point_ids``, and the small replicated tables.  Hot bytes are
  O(n * M) — for d=128, m=8, int8 storage that is ~1/16 of the point
  table, which is what makes out-of-core n worthwhile at all.
* **Cold (host RAM)** — the (n, d) point rows and the (n, M) per-point
  corner tables (plus their decode columns in the int8 tier), held as
  pinned numpy blocks (:data:`~repro.core.index.cold_point_fields`).
  A cold block is fetched to device ONLY when the hoisted whole-table
  envelope gate (the same Theorem-3 test the resident path hoists in
  ``core.search._stream_prune_compact``) admits it for at least one
  query — the paper's partition-filter-refinement split is exactly the
  shape that tells us *before any transfer* which row blocks can matter.

The search is the resident pipeline re-cut at the host/device boundary:

1. **Stage A (one jit over hot tables)** — query transform, streaming
   filter top-k, Alg.-4 bounds ``qb`` (+ int8 slack, + optional §8
   shrink), then the hoisted envelope gate verbatim: a (nb, q) bool
   admission matrix.  The cold leaves ride in the hot forest as numpy
   arrays; ``jax.jit`` (default ``keep_unused=False``) prunes arguments
   the traced program never reads, so they are neither transferred nor
   compiled in (tests/test_stream_memory.py asserts the optimized HLO
   carries no n×d-sized cold allocation).
2. **Stage B (host loop, double-buffered)** — admitted blocks stream
   through the per-point Theorem-3 prune kernel in index order.  While
   block i runs, the next ``prefetch_depth`` admitted blocks' tiles are
   already in flight via ``transfer`` (``jax.device_put``) on a
   background executor.  Fetched bundles land in a device-side LRU block
   cache budgeted by the validated ``resident_bytes`` knob, so repeated
   queries against hot clusters pay zero transfer.  Per-block slot
   filling reuses ``core.search._fill_block_slots``, so slot semantics
   are shared with the resident scan by construction.
3. **Stage C (one jit)** — the blocks holding selected candidates (a
   subset of the admitted set, normally all cache hits) concatenate into
   one refine pool; the batched refine kernel, the validity mask, and the
   final top-k run exactly as ``core.search._refine_batch``.

**Bit parity.**  Stage A reuses the resident pipeline's own helpers, the
per-block admit kernel is the unfused ``bregman_prune_block`` whose admit
bits the kernel-parity tests pin to the fused kernel's, the per-block
tile padding reuses ``_corner_blocks``' inert fills, and Stage C masks
and ranks identically to ``_refine_batch`` — so results are bit-identical
to ``knn_search_batch`` / ``knn_search_batch_approx`` on the same point
set (tests/test_tiered.py sweeps all five families x {fp32, int8} x
{exact, approx}).

**Resident fast path.**  When the cold tables fit the ``resident_bytes``
budget (or the budget is ``None``), the store keeps the full device
forest and delegates to the resident pipeline — tiering degrades to a
no-op wrapper, never a slower copy of the same work.

See docs/tiered_storage.md for the tier contract and knob guidance.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeoutError

import jax
import jax.numpy as jnp
import numpy as np

from . import bounds
from . import search as _search
from .calibrate import resolve_p_guarantee
from .index import (
    BallForest,
    PAD_CORNER,
    cold_point_fields,
)
from .search import (
    POS_BIG,
    SearchResult,
    resolve_block_rows,
    resolve_budget,
    resolve_env_block_rows,
    validate_p_guarantee,
    validate_queries,
)

Array = jax.Array

# Double-buffer depth: while block i's kernel runs, this many admitted
# blocks beyond it are in flight on the fetch executor.  2 overlaps one
# transfer with one kernel plus one in reserve against fetch jitter;
# deeper pipelines only help when transfers are much slower than kernels
# (and cost proportionally more transient device memory).
DEFAULT_PREFETCH_DEPTH = 2
MAX_PREFETCH_DEPTH = 64


class FetchTimeout(RuntimeError):
    """A host->device block fetch exceeded the store's ``fetch_timeout_s``.

    Raised out of :meth:`TieredPointStore.search` so a wedged or
    pathologically slow copy surfaces as an ordinary launch failure —
    the serving layer's containment (retries, backoff, circuit breaker,
    degradation ladder) handles it like any other launch exception
    instead of blocking a microbatch forever (serve/retrieval.py).  The
    stalled fetch keeps running in the background; a retry that arrives
    after it lands is a cache hit.
    """


def resolve_resident_bytes(resident_bytes):
    """THE ``resident_bytes`` knob resolver (brelint knob-contract).

    ``None`` means "no budget": every table stays device-resident and the
    store is a passthrough to the resident pipeline.  An explicit budget
    must be a positive integer byte count — it bounds the device-side
    block cache, so zero/negative/bool/float values are config errors
    worth naming at construction, not at the first eviction.
    """
    if resident_bytes is None:
        return None
    if isinstance(resident_bytes, bool) or not isinstance(
            resident_bytes, (int, np.integer)):
        raise ValueError(
            f"resident_bytes must be an int byte count or None, "
            f"got {resident_bytes!r}")
    rb = int(resident_bytes)
    if rb < 1:
        raise ValueError(
            f"resident_bytes must be a positive byte count, got {rb}")
    return rb


def resolve_prefetch_depth(prefetch_depth):
    """THE ``prefetch_depth`` knob resolver (brelint knob-contract).

    ``None`` picks :data:`DEFAULT_PREFETCH_DEPTH`.  The depth is how many
    admitted blocks beyond the one in flight are prefetched; it must be
    an integer in [1, :data:`MAX_PREFETCH_DEPTH`] — 0 would serialize
    every transfer behind its kernel (the double-buffering the store
    exists to provide), and very deep pipelines just hold transient
    device copies with no overlap left to win.
    """
    if prefetch_depth is None:
        return DEFAULT_PREFETCH_DEPTH
    if isinstance(prefetch_depth, bool) or not isinstance(
            prefetch_depth, (int, np.integer)):
        raise ValueError(
            f"prefetch_depth must be an int or None, got {prefetch_depth!r}")
    depth = int(prefetch_depth)
    if not 1 <= depth <= MAX_PREFETCH_DEPTH:
        raise ValueError(
            f"prefetch_depth={depth} must be within "
            f"[1, {MAX_PREFETCH_DEPTH}]")
    return depth


# ---------------------------------------------------------------------------
# The three jitted stages
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "block_rows",
                                             "env_block_rows", "approx"))
def _stage_a_jit(hot: BallForest, ys: Array, k: int, block_rows: int,
                 env_block_rows: int | None, p_guarantee: Array,
                 approx: bool) -> dict:
    """Filter + bounds + hoisted envelope gate over the HOT tables only.

    ``hot`` carries the cold point-major fields as host numpy arrays;
    nothing traced here reads them, so jit's unused-argument pruning
    (``keep_unused=False``) keeps them off the device entirely — the
    compile-time guarantee tests/test_stream_memory.py walks the
    optimized HLO for.
    """
    qs = _search._query_struct(hot, ys)
    _, idx = _search._batch_filter_topk(hot, qs, k, block_rows)
    kth = idx[:, -1]                                    # (q,)
    kth_tuple = _search._tuple_rows(hot, kth)
    sqrt_term = kth_tuple["sqrt_gamma"] * qs["sqrt_delta"]       # (q, M)
    qb = (bounds.ub_components(kth_tuple, qs)           # (q, M) Alg. 4
          + _search._qb_slack(hot, idx, qs["sqrt_delta"]))
    if approx:                                          # §8 shrink, batched
        kappa_i = qb - sqrt_term
        c = _search._cdf_shrink(hot.beta_samples, jnp.sum(sqrt_term, -1),
                                jnp.sum(kappa_i, -1), p_guarantee)
        qb = kappa_i + c[:, None] * sqrt_term

    # Hoisted whole-table envelope gate — the same math as the fused
    # branch of _stream_prune_compact, bit-for-bit: per-envelope-row admit
    # in one op, per-block OR-over-span via a prefix-sum difference.
    n = hot.alpha.shape[0]
    q, m = qb.shape
    bn, nb = _search._block_layout(n, block_rows)
    eb = resolve_env_block_rows(env_block_rows)
    win = -(-bn // eb) + 1
    env_a, env_g = _search._env_tables(hot, n, m, eb, win, sharded=False)
    qcT, sdT, qbT = qs["qconst"].T, qs["sqrt_delta"].T, qb.T     # (M, q)
    goffs = jnp.arange(nb, dtype=jnp.int32) * bn
    lb_env = (env_a[:, :, None] + qcT[None]
              - env_g[:, :, None] * sdT[None])          # (nep, M, q)
    row_admit = jnp.any(lb_env <= qbT[None], axis=1)    # (nep, q)
    ecs = jnp.concatenate(
        [jnp.zeros((1, q), jnp.int32),
         jnp.cumsum(row_admit.astype(jnp.int32), axis=0)], axis=0)
    e0s = goffs // eb
    e_his = (goffs + bn - 1) // eb
    env_admit = (jnp.take(ecs, e_his + 1, axis=0)
                 - jnp.take(ecs, e0s, axis=0)) > 0      # (nb, q)
    return {"qb": qb, "env_admit": env_admit,
            "qconst": qs["qconst"], "sqrt_delta": qs["sqrt_delta"],
            "grad": qs["grad"], "c_y": qs["c_y"]}


def _prune_step(sel: Array, count: Array, tile: dict, qconst: Array,
                sqrt_delta: Array, qb: Array, off, budget: int, n: int,
                storage: str) -> tuple[Array, Array]:
    """One admitted block: Theorem-3 admit kernel + streaming slot fill.

    The block offset ``off`` is traced, so ONE compiled program serves
    every block of the store.  The admit bits match the fused resident
    kernel's exactly (kernel-parity tests pin fused == unfused), and
    ``_fill_block_slots`` is the resident scan's own compaction, so the
    carried (sel, count) stay bit-identical to ``_stream_prune_compact``
    over the same admitted blocks.
    """
    from repro.kernels import ops as kernel_ops
    if storage == "int8":
        admit = kernel_ops.bregman_prune_block_quant(
            tile["amin"], tile["amin_scale"], tile["amin_zp"],
            tile["gmax"], tile["gmax_scale"], tile["gmax_zp"],
            qconst, sqrt_delta, qb)                     # (bn, q)
    else:
        admit = kernel_ops.bregman_prune_block(
            tile["amin"], tile["gmax"], qconst, sqrt_delta, qb)
    bn = tile["amin"].shape[0]
    gidx = off + jnp.arange(bn, dtype=jnp.int32)
    admit = admit * (gidx < n).astype(jnp.int32)[:, None]
    return _search._fill_block_slots(sel, count, admit, off, budget)


_prune_step_jit = functools.partial(
    jax.jit, static_argnames=("budget", "n", "storage"))(_prune_step)


def _prune_pool(sel: Array, count: Array, tiles: dict, gidx: Array,
                qconst: Array, sqrt_delta: Array, qb: Array,
                budget: int, n: int, storage: str) -> tuple[Array, Array]:
    """All admitted blocks in ONE dispatch over the FLAT pooled rows.

    The steady-state fast path — used only when every admitted bundle is
    already cache-resident, so no fetch can stall the fused program.
    ``tiles`` holds the admitted blocks' corner tables concatenated
    row-wise (pow-2 padded with inert rows); ``gidx`` maps each pooled
    row to its global row id (pads carry ``n``, masking their admit
    bits).  Bit parity with the sequential per-block fills: the admit
    kernel is elementwise per row, the pool keeps ascending global
    order, and the slot routing is integer compaction in that same
    order — one rank search over the pool instead of one budget-sized
    routing per block, same (sel, count) to the bit.
    """
    from repro.kernels import ops as kernel_ops
    if storage == "int8":
        admit = kernel_ops.bregman_prune_block_quant(
            tiles["amin"], tiles["amin_scale"], tiles["amin_zp"],
            tiles["gmax"], tiles["gmax_scale"], tiles["gmax_zp"],
            qconst, sqrt_delta, qb)                     # (pn, q)
    else:
        admit = kernel_ops.bregman_prune_block(
            tiles["amin"], tiles["gmax"], qconst, sqrt_delta, qb)
    admit = admit * (gidx < n).astype(jnp.int32)[:, None]
    # _fill_block_slots with a gather-map: identical rank-compaction, but
    # local pool rows resolve to global ids through gidx instead of a
    # scalar block offset.
    pn = admit.shape[0]
    csum = jnp.cumsum(admit, axis=0)                     # (pn, q)
    tot = csum[-1]                                       # (q,)
    t_ranks = min(pn, budget)
    ranks = jnp.arange(1, t_ranks + 1, dtype=jnp.int32)
    rows_for_rank = jax.vmap(
        lambda c: jnp.searchsorted(c, ranks, side="left"))(csum.T)
    rows_for_rank = jnp.minimum(rows_for_rank,
                                pn - 1).astype(jnp.int32)  # (q, T)
    r0 = (jnp.arange(budget, dtype=jnp.int32)[None, :]
          - count[:, None])                              # rank-1
    fill = (r0 >= 0) & (r0 < tot[:, None])
    rows_at_slot = jnp.take_along_axis(
        rows_for_rank, jnp.clip(r0, 0, t_ranks - 1), axis=1)
    sel = jnp.where(fill, jnp.take(gidx, rows_at_slot), sel)
    return sel, count + tot


def _refine_tiles(tiles: dict, pos_of: Array, sel: Array, count: Array,
                  grad: Array, c_y: Array, point_ids: Array, k: int,
                  family_name: str, storage: str, bn: int, budget: int):
    """Batched refine over the fetched candidate blocks.

    ``tiles`` is the concatenation of the admitted blocks' data tiles;
    ``pos_of`` maps a global block id to its pool slot, so the global
    candidate rows remap in-jit (no host round-trip on ``sel``).  Every
    VALID candidate comes from an admitted block by construction —
    invalid slots map anywhere in range and are masked to +BIG exactly
    as the resident ``_refine_batch`` masks them, so they cannot affect
    the top-k.  ``sel`` stays GLOBAL: ids come from ``point_ids[sel]``
    with the original selection, so even never-filled slots resolve to
    the same id the resident path reports.
    """
    from repro.kernels import ops as kernel_ops
    targets = jnp.arange(1, budget + 1, dtype=jnp.int32)
    valid = targets[None, :] <= jnp.minimum(count, budget)[:, None]
    lsel = jnp.take(pos_of, sel // bn) * bn + sel % bn  # (q, budget)
    if storage == "int8":
        codes = jnp.take(tiles["data"], lsel, axis=0)   # (q, budget, d) int8
        scale = jnp.take(tiles["data_scale"], lsel)     # (q, budget)
        zp = jnp.take(tiles["data_zp"], lsel)
        dist = kernel_ops.bregman_refine_batch_quant(
            codes, scale, zp, grad, c_y, family_name)
    else:
        rows = jnp.take(tiles["data"], lsel, axis=0)    # (q, budget, d)
        dist = kernel_ops.bregman_refine_batch(
            rows, grad, c_y, family_name)               # (q, budget)
    dist = jnp.where(valid, dist, POS_BIG)
    neg, pos = jax.lax.top_k(-dist, k)                  # (q, k)
    ids = jnp.take(point_ids, jnp.take_along_axis(sel, pos, axis=1))
    return ids, -neg


_refine_tiles_jit = functools.partial(
    jax.jit, static_argnames=("k", "family_name", "storage", "bn",
                              "budget"))(_refine_tiles)


@functools.partial(jax.jit, static_argnames=("k", "family_name", "storage",
                                             "bn", "budget", "n"))
def _pool_search_jit(stacked: dict, gidx: Array, big: dict, pos_of: Array,
                     qconst: Array, sqrt_delta: Array, qb: Array,
                     grad: Array, c_y: Array, point_ids: Array, k: int,
                     family_name: str, storage: str, bn: int, budget: int,
                     n: int):
    """Steady-state Stages B+C in ONE dispatch: pooled prune then refine.

    Used only when every admitted bundle is cache-resident, so no fetch
    can stall the fused program.  Composes the exact `_prune_pool` and
    `_refine_tiles` bodies — one compiled program instead of two keeps
    the per-search dispatch overhead off the critical path.  The (sel,
    count) carry always enters this path at its init value, so it is
    materialized in-jit rather than transferred.
    """
    q = c_y.shape[0]
    sel = jnp.full((q, budget), n - 1, jnp.int32)
    count = jnp.zeros((q,), jnp.int32)
    sel, count = _prune_pool(sel, count, stacked, gidx, qconst,
                             sqrt_delta, qb, budget, n, storage)
    ids, dists = _refine_tiles(big, pos_of, sel, count, grad, c_y,
                               point_ids, k, family_name, storage, bn,
                               budget)
    return ids, dists, count


# Host-side per-field padding fills for the cold block tables, mirroring
# core.search._corner_blocks / index.INERT_FILL bit-for-bit: padded rows
# must fail every Theorem-3 admission (f32 corners +BIG/0; int8 corner
# codes 0 with the +BIG sentinel riding in a zero-scale zero-point) and
# decode to a domain-safe data row (never read — sel is always < n — but
# harmless even if a kernel touches it).
_PAD_FILLS_F32 = {"alpha_min_pt": POS_BIG, "sqrt_gamma_max_pt": 0.0,
                  "data": 1.0}
_PAD_FILLS_INT8 = {"alpha_min_pt": 0, "sqrt_gamma_max_pt": 0, "data": 0,
                   "amin_scale": 0.0, "amin_zp": PAD_CORNER,
                   "gmax_scale": 0.0, "gmax_zp": 0.0,
                   "data_scale": 0.0, "data_zp": 1.0}

# Cold-field -> tile-name maps: which bundle (prune vs refine) each cold
# table feeds, under the kernel-facing names the jitted stages use.
_PRUNE_TILE = {"alpha_min_pt": "amin", "sqrt_gamma_max_pt": "gmax",
               "amin_scale": "amin_scale", "amin_zp": "amin_zp",
               "gmax_scale": "gmax_scale", "gmax_zp": "gmax_zp"}
_REFINE_TILE = {"data": "data", "data_scale": "data_scale",
                "data_zp": "data_zp"}


class TieredPointStore:
    """Two-tier residency wrapper around a sealed BallForest snapshot.

    Build with :meth:`from_index` (accepts a BallForest or a mutable
    SegmentedForest — the snapshot is FROZEN at construction, the same
    policy as the sharded tenants in serve/retrieval.py: re-wrap after
    mutating).  Every ``core.search`` public entry point routes a store
    to :meth:`search` via the ``is_tiered_store`` marker, so callers use
    one API for both residency modes.

    Not thread-safe for CONCURRENT searches (the fetch executor is the
    only internal concurrency); the single-threaded service loop and the
    in-process hooks are the intended drivers.
    """

    is_tiered_store = True

    def __init__(self, snapshot: BallForest, *, resident_bytes=None,
                 prefetch_depth=None, block_rows=None,
                 pinned_row_range: tuple[int, int] | None = None,
                 transfer=None, fetch_timeout_s: float | None = None):
        self.resident_bytes = resolve_resident_bytes(resident_bytes)
        self.prefetch_depth = resolve_prefetch_depth(prefetch_depth)
        n = snapshot.n
        self.block_rows = resolve_block_rows(block_rows, n,
                                             storage=snapshot.storage)
        self.fetch_timeout_s = fetch_timeout_s
        self._transfer = jax.device_put if transfer is None else transfer
        self._lock = threading.Lock()
        ids_host = np.asarray(snapshot.point_ids)
        self._live_n = int((ids_host >= 0).sum())
        self.stats = self._zero_stats()

        cold = cold_point_fields(snapshot)
        host = {f: np.asarray(getattr(snapshot, f)) for f in cold}
        self.cold_bytes = int(sum(a.nbytes for a in host.values()))
        self._bn, self._nb = _search._block_layout(n, self.block_rows)

        if self.resident_bytes is None or \
                self.cold_bytes <= self.resident_bytes:
            # Resident fast path: everything fits the budget — keep the
            # full device forest and delegate.  No executor, no cache, no
            # host copy kept alive.
            self._resident: BallForest | None = snapshot
            self._hot = snapshot
            self._blocks = None
            self._pool = None
            self._cache: OrderedDict[int, dict] = OrderedDict()
            self._futures: dict = {}
            self._pinned: frozenset[int] = frozenset()
            self._cache_bytes = 0
            self._pool_cache = None
            self._inert_prune = None
            return

        self._resident = None
        # The hot forest: cold point-major leaves become the host arrays
        # themselves.  dataclasses.replace keeps statics and the host-only
        # calibration; jit prunes the (unused) numpy leaves per stage.
        self._hot = dataclasses.replace(snapshot, **host)
        fills = (_PAD_FILLS_INT8 if snapshot.storage == "int8"
                 else _PAD_FILLS_F32)
        bn, nb = self._bn, self._nb
        pad = nb * bn - n
        self._blocks = {}
        for f, arr in host.items():
            widths = ((0, pad),) + ((0, 0),) * (arr.ndim - 1)
            padded = np.pad(arr, widths, constant_values=fills[f])
            self._blocks[f] = np.ascontiguousarray(
                padded.reshape((nb, bn) + arr.shape[1:]))
        self._cache = OrderedDict()
        self._cache_bytes = 0
        self._futures = {}
        self._inert_refine: dict | None = None
        self._inert_prune: dict | None = None
        # Single-entry pooled-program cache for the steady-state fast
        # path: (admitted-set key, stacked prune tiles, offsets, pooled
        # refine tiles, block->slot map).  Holds ONE extra device copy of
        # the admitted set (bounded by resident_bytes, reported in
        # cache_info as pool_bytes).
        self._pool_cache: tuple | None = None
        self._pool = ThreadPoolExecutor(
            max_workers=self.prefetch_depth,
            thread_name_prefix="tiered-fetch")
        # Append-segment rows (pinned_row_range) stay device-resident:
        # their blocks are pre-fetched here and never evicted, so a
        # freshly inserted point costs no transfer on its first query.
        pinned: set[int] = set()
        if pinned_row_range is not None:
            lo, hi = pinned_row_range
            if hi > lo:
                pinned = set(range(lo // bn, -(-hi // bn)))
        self._pinned = frozenset(pinned)
        for bid in sorted(self._pinned):
            self._insert_cache(bid, self._fetch_block(bid))

    @staticmethod
    def _zero_stats() -> dict:
        return {"queries": 0, "searches": 0, "fetches": 0,
                "host_bytes_fetched": 0, "cache_hits": 0, "cache_misses": 0,
                "blocks_admitted": 0, "blocks_total": 0}

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_index(cls, index, *, resident_bytes=None, prefetch_depth=None,
                   block_rows=None, transfer=None,
                   fetch_timeout_s: float | None = None
                   ) -> "TieredPointStore":
        """Tier a BallForest or SegmentedForest snapshot.

        A mutable index is snapshotted through ``view()`` and its append
        segments' row range (``append_row_range``) is PINNED in the block
        cache — append segments stay resident, only the sealed main can
        tier (core/segments.py).  The snapshot is frozen: mutate-then-
        re-wrap, exactly like the sharded-tenant policy.
        """
        resident_bytes = resolve_resident_bytes(resident_bytes)
        prefetch_depth = resolve_prefetch_depth(prefetch_depth)
        snapshot = index
        pinned = None
        view = getattr(index, "view", None)
        if callable(view):
            snapshot = view()
            rng = getattr(index, "append_row_range", None)
            if callable(rng):
                pinned = rng()
        block_rows = resolve_block_rows(block_rows, snapshot.n,
                                        storage=snapshot.storage)
        return cls(snapshot, resident_bytes=resident_bytes,
                   prefetch_depth=prefetch_depth, block_rows=block_rows,
                   pinned_row_range=pinned, transfer=transfer,
                   fetch_timeout_s=fetch_timeout_s)

    # -- index-protocol surface --------------------------------------------

    @property
    def n(self) -> int:
        return self._hot.n

    @property
    def d(self) -> int:
        return self._hot.d

    @property
    def m(self) -> int:
        return self._hot.m

    @property
    def family(self):
        return self._hot.family

    @property
    def family_name(self) -> str:
        return self._hot.family_name

    @property
    def storage(self) -> str:
        return self._hot.storage

    @property
    def calibration(self):
        return self._hot.calibration

    @property
    def live_n(self) -> int:
        return self._live_n

    @property
    def is_resident(self) -> bool:
        """True when the resident fast path is active (no tiering)."""
        return self._resident is not None

    @property
    def num_blocks(self) -> int:
        return self._nb

    def as_resident_forest(self) -> BallForest:
        """Materialize the FULL device forest (one O(n) transfer).

        The escape hatch for paths that genuinely need every row on
        device at once — today only ``knn_batch``'s budget-cap linear
        scan.  Deliberately uncached: holding the result would defeat
        the residency budget, so callers own its lifetime.
        """
        if self._resident is not None:
            return self._resident
        return dataclasses.replace(self._hot, **{
            f: jnp.asarray(getattr(self._hot, f))
            for f in cold_point_fields(self._hot)})

    def reset_stats(self) -> None:
        self.stats = self._zero_stats()

    def cache_info(self) -> dict:
        """Block-cache occupancy snapshot (bench/telemetry surface)."""
        pool_bytes = 0
        if self._pool_cache is not None:
            _, stacked, _, big, _ = self._pool_cache
            pool_bytes = int(sum(x.nbytes for x in stacked.values())
                             + sum(x.nbytes for x in big.values()))
        return {"blocks_cached": len(self._cache),
                "bytes_cached": self._cache_bytes,
                "pool_bytes": pool_bytes,
                "pinned_blocks": len(self._pinned),
                "num_blocks": self._nb,
                "resident_bytes": self.resident_bytes,
                "cold_bytes": self.cold_bytes,
                "resident_fast_path": self.is_resident}

    def close(self) -> None:
        """Shut down the fetch executor (idempotent)."""
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    # -- cache + fetch machinery -------------------------------------------

    def _fetch_block(self, bid: int) -> dict:
        """Copy one cold block host->device; returns the bundle dict.

        Runs on the fetch executor.  One bundle carries BOTH the prune
        tile and the refine tile, so a block admitted by the gate is
        fetched once and serves both downstream stages.
        """
        tiles_np = {f: blocks[bid] for f, blocks in self._blocks.items()}
        host_nbytes = int(sum(a.nbytes for a in tiles_np.values()))
        dev = self._transfer(tiles_np)
        prune = {_PRUNE_TILE[f]: dev[f] for f in dev if f in _PRUNE_TILE}
        refine = {_REFINE_TILE[f]: dev[f] for f in dev if f in _REFINE_TILE}
        nbytes = int(sum(x.nbytes for x in dev.values()))
        return {"prune": prune, "refine": refine,
                "host_nbytes": host_nbytes, "nbytes": nbytes}

    def _insert_cache(self, bid: int, bundle: dict) -> None:
        self._cache[bid] = bundle
        self._cache.move_to_end(bid)
        self._cache_bytes += bundle["nbytes"]
        if self.resident_bytes is None:
            return
        # Evict LRU-first until under budget; the block just inserted and
        # the pinned (append-segment) blocks are never evicted, so the
        # cache may transiently exceed a budget smaller than one bundle.
        for victim in list(self._cache):
            if self._cache_bytes <= self.resident_bytes:
                break
            if victim == bid or victim in self._pinned:
                continue
            self._cache_bytes -= self._cache.pop(victim)["nbytes"]

    def _ensure_inflight(self, bid: int) -> None:
        with self._lock:
            if bid in self._cache or bid in self._futures:
                return
            self._futures[bid] = self._pool.submit(self._fetch_block, bid)

    def _block(self, bid: int) -> dict:
        """Resolve one block: cache hit, or wait on its (pre)fetch."""
        with self._lock:
            cached = self._cache.get(bid)
            if cached is not None:
                self.stats["cache_hits"] += 1
                self._cache.move_to_end(bid)
                return cached
            fut = self._futures.get(bid)
            if fut is None:
                fut = self._pool.submit(self._fetch_block, bid)
                self._futures[bid] = fut
        try:
            bundle = fut.result(timeout=self.fetch_timeout_s)
        except _FutureTimeoutError:
            raise FetchTimeout(
                f"host->device fetch of block {bid} exceeded "
                f"fetch_timeout_s={self.fetch_timeout_s}s; the transfer "
                f"keeps running — a retry may hit the cache") from None
        with self._lock:
            self._futures.pop(bid, None)
            if bid not in self._cache:
                self.stats["cache_misses"] += 1
                self.stats["fetches"] += 1
                self.stats["host_bytes_fetched"] += bundle["host_nbytes"]
                self._insert_cache(bid, bundle)
        return bundle

    def warm_cache(self) -> dict:
        """Pre-populate the block cache up to the residency budget.

        Fetches blocks in index order until the next bundle would exceed
        ``resident_bytes`` (pinned blocks are already cached).  Startup
        warming — the service's ``warm()`` API calls this after priming
        the compiled-program caches, so first-query latency pays neither
        compilation nor transfer.  Fetches here do NOT count toward the
        per-query stats.
        """
        if self._resident is not None:
            return {"blocks_cached": 0,
                    "bytes_cached": 0, "resident_fast_path": True}
        for bid in range(self._nb):
            if bid in self._cache:
                continue
            bundle = self._fetch_block(bid)
            if (self._cache_bytes + bundle["nbytes"] > self.resident_bytes
                    and bid not in self._pinned):
                break
            self._insert_cache(bid, bundle)
        return {"blocks_cached": len(self._cache),
                "bytes_cached": self._cache_bytes,
                "resident_fast_path": False}

    def _inert_refine_tile(self) -> dict:
        """One device-resident inert data tile for pow-2 pool padding."""
        if self._inert_refine is None:
            bn, d = self._bn, self.d
            if self.storage == "int8":
                self._inert_refine = {
                    "data": jnp.zeros((bn, d), jnp.int8),
                    "data_scale": jnp.zeros((bn,), jnp.float32),
                    "data_zp": jnp.ones((bn,), jnp.float32)}
            else:
                self._inert_refine = {
                    "data": jnp.ones((bn, d), jnp.float32)}
        return self._inert_refine

    def _inert_prune_tile(self) -> dict:
        """One inert corner tile for pow-2 prune-pool padding (its rows
        carry the same reject-everything sentinels as the tail pad)."""
        if self._inert_prune is None:
            fills = (_PAD_FILLS_INT8 if self.storage == "int8"
                     else _PAD_FILLS_F32)
            self._inert_prune = {
                _PRUNE_TILE[f]: jnp.full(blocks.shape[1:], fills[f],
                                         blocks.dtype)
                for f, blocks in self._blocks.items() if f in _PRUNE_TILE}
        return self._inert_prune

    def _pooled(self, key: tuple) -> tuple:
        """Stacked prune tiles + pooled refine tiles for one admitted set.

        Precondition: every block in ``key`` is cache-resident (the
        caller checked), so the ``_block`` calls below are hits.  The
        result is memoized single-entry — steady-state traffic repeats
        the same admitted set, so the stack/concat cost is paid once per
        working-set change, and both pools are padded to a power-of-two
        block count to keep the compiled-program cache O(log nb).
        """
        cached = self._pool_cache
        if cached is not None and cached[0] == key:
            # The pool reuse IS a cache hit for every block in the set —
            # count them so steady-state hit rate reads 1.0, not 0/0.
            self.stats["cache_hits"] += len(key)
            return cached[1:]
        bn = self._bn
        bundles = [self._block(b) for b in key]
        pool = 1 << (len(key) - 1).bit_length()
        pad = pool - len(key)
        prune_tiles = [b["prune"] for b in bundles] \
            + [self._inert_prune_tile()] * pad
        stacked = {nm: jnp.concatenate([t[nm] for t in prune_tiles],
                                       axis=0)
                   for nm in prune_tiles[0]}
        # pad rows ride with gidx = n: every admit bit masks to zero
        gidx_np = np.concatenate(
            [np.arange(b * bn, b * bn + bn, dtype=np.int32) for b in key]
            + [np.full(bn, self.n, np.int32)] * pad)
        offs = jnp.asarray(gidx_np)
        refine_tiles = [b["refine"] for b in bundles] \
            + [self._inert_refine_tile()] * pad
        big = {nm: jnp.concatenate([t[nm] for t in refine_tiles], axis=0)
               for nm in refine_tiles[0]}
        pos_np = np.zeros(self._nb, np.int32)
        pos_np[list(key)] = np.arange(len(key), dtype=np.int32)
        pos_of = jnp.asarray(pos_np)
        self._pool_cache = (key, stacked, offs, big, pos_of)
        return stacked, offs, big, pos_of

    # -- search -------------------------------------------------------------

    def search(self, ys, k: int, budget: int | None = None, *,
               p_guarantee=None, target_recall: float | None = None,
               block_rows: int | None = None,
               env_block_rows: int | None = None,
               validate: bool = True) -> SearchResult:
        """Batched kNN over the tiered store — bit-identical to the
        resident ``knn_search_batch`` (or ``..._approx`` when one of
        ``p_guarantee`` / ``target_recall`` is given) on the same points.

        ``block_rows`` was pinned at construction (the host blocks are
        physically cut at that granularity); passing a different explicit
        value is a programming error and raises.  ``env_block_rows``
        only coarsens the envelope gate — results are invariant, the
        admitted-block set is not.
        """
        if p_guarantee is not None and target_recall is not None:
            raise ValueError(
                "pass at most one of p_guarantee / target_recall")
        if target_recall is not None:
            p_guarantee, _ = resolve_p_guarantee(self, target_recall)
        validate_p_guarantee(p_guarantee)
        budget = resolve_budget(budget, self.n, k)
        if block_rows is not None:
            br = resolve_block_rows(block_rows, self.n, storage=self.storage)
            if br != self.block_rows:
                raise ValueError(
                    f"block_rows={br} conflicts with the store's pinned "
                    f"block size {self.block_rows} (host blocks are cut at "
                    f"construction; rebuild the store to change it)")
        eb = resolve_env_block_rows(env_block_rows)
        ys = jnp.asarray(ys, jnp.float32)
        if ys.ndim != 2:
            raise ValueError(f"expected (q, d) queries, got {ys.shape}")
        if validate:
            validate_queries(self.family, ys)

        if self._resident is not None:
            if p_guarantee is None:
                return _search.knn_search_batch(
                    self._resident, ys, k, budget, self.block_rows,
                    validate=False, env_block_rows=eb)
            return _search.knn_search_batch_approx(
                self._resident, ys, k, budget, jnp.float32(p_guarantee),
                self.block_rows, validate=False)
        return self._search_tiered(ys, k, budget, p_guarantee, eb)

    def _search_tiered(self, ys: Array, k: int, budget: int,
                       p_guarantee, env_block_rows: int) -> SearchResult:
        q = ys.shape[0]
        n, bn, nb = self.n, self._bn, self._nb
        approx = p_guarantee is not None
        p = jnp.float32(p_guarantee if approx else 0.0)

        # Stage A: hot-only jit — filter, bounds, envelope admission.
        a = _stage_a_jit(self._hot, ys, k, self.block_rows, env_block_rows,
                         p, approx)
        env_admit = np.asarray(a["env_admit"])          # (nb, q) bool
        # A block runs (for ALL query columns) iff ANY query admits it —
        # the resident scan's lax.cond gate, decided on the host so
        # rejected blocks are never fetched at all.
        admitted = np.nonzero(env_admit.any(axis=1))[0].tolist()
        self.stats["blocks_admitted"] += len(admitted)
        self.stats["blocks_total"] += nb
        self.stats["queries"] += int(q)
        self.stats["searches"] += 1

        if not admitted:
            sel = jnp.full((q, budget), n - 1, jnp.int32)
            count = jnp.zeros((q,), jnp.int32)
            # No query admitted anything: every slot is masked to +BIG, so
            # the resident top-k degenerates to the first k slots in order
            # (lax.top_k ties resolve to the lower index) — reproduce that
            # without fetching anything.
            ids = jnp.take(self._hot.point_ids, sel[:, :k])
            dists = jnp.full((q, k), POS_BIG, jnp.float32)
            return SearchResult(ids=ids, dists=dists, exact=count <= budget,
                                num_candidates=count)

        with self._lock:
            all_cached = all(b in self._cache for b in admitted)
        if all_cached:
            # Steady-state fast path: every admitted bundle is already on
            # device, so Stages B+C collapse to ONE fused program over
            # the memoized stacked pool — no per-block dispatch, no
            # fetch, one launch for prune + refine + top-k.
            stacked, offs, big, pos_of = self._pooled(tuple(admitted))
            ids, dists, count = _pool_search_jit(
                stacked, offs, big, pos_of, a["qconst"], a["sqrt_delta"],
                a["qb"], a["grad"], a["c_y"], self._hot.point_ids, k,
                self.family_name, self.storage, bn, budget, n)
            return SearchResult(ids=ids, dists=dists,
                                exact=count <= budget,
                                num_candidates=count)
        # Stage B: double-buffered host loop over the admitted blocks —
        # prefetch runs ``prefetch_depth`` bundles ahead while the
        # current block's prune kernel executes.
        sel = jnp.full((q, budget), n - 1, jnp.int32)
        count = jnp.zeros((q,), jnp.int32)
        depth = self.prefetch_depth
        for j, bid in enumerate(admitted):
            for ahead in admitted[j:j + 1 + depth]:
                self._ensure_inflight(ahead)
            bundle = self._block(bid)
            sel, count = _prune_step_jit(
                sel, count, bundle["prune"], a["qconst"],
                a["sqrt_delta"], a["qb"], bid * bn, budget, n,
                self.storage)
        # Stage C pool: every valid candidate lives in an admitted
        # block, so the refine pool is the admitted set itself — no
        # device->host sync on sel to discover it.  Blocks evicted
        # mid-loop (budget below the admitted working set) refetch.
        pool = 1 << (len(admitted) - 1).bit_length()
        for b in admitted:
            self._ensure_inflight(b)
        tiles = [self._block(b)["refine"] for b in admitted]
        tiles.extend([self._inert_refine_tile()]
                     * (pool - len(tiles)))
        big = {name: jnp.concatenate([t[name] for t in tiles], axis=0)
               for name in tiles[0]}
        pos_np = np.zeros(nb, np.int32)
        pos_np[admitted] = np.arange(len(admitted), dtype=np.int32)
        pos_of = jnp.asarray(pos_np)

        ids, dists = _refine_tiles_jit(
            big, pos_of, sel, count, a["grad"], a["c_y"],
            self._hot.point_ids, k, self.family_name, self.storage, bn,
            budget)
        return SearchResult(ids=ids, dists=dists, exact=count <= budget,
                            num_candidates=count)
