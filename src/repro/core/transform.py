"""Dimensionality partitioning layout + P/Q transforms (paper Alg. 2 & 3).

A :class:`Partition` is the static description of how the ``d`` original
dimensions are dealt into ``M`` subspaces of width ``w = ceil(d/M)``.
Padded slots (when ``M*w > d``) carry ``mask = 0`` and contribute nothing to
any transform — this keeps the Cauchy bound *tight* instead of the loose
"pad with a neutral element" alternative (DESIGN.md §6).

Transforms (Theorem 1 notation):

* data tuple  ``P(x) = (alpha_x, gamma_x)`` per subspace, where
  ``alpha_x = sum_j f(x_ij)`` and ``gamma_x = sum_j x_ij^2``;
* query triple ``Q(y) = (alpha_y, beta_yy, delta_y)`` per subspace, where
  ``alpha_y = -sum_j f(y_ij)``, ``beta_yy = sum_j y_ij f'(y_ij)`` and
  ``delta_y = sum_j f'(y_ij)^2``.

TPU adaptation: we additionally store ``sqrt(gamma_x)`` so that the filter's
Cauchy term ``sqrt(gamma_x * delta_y) = sqrt(gamma_x) * sqrt(delta_y)``
becomes a plain inner product over subspaces — the whole filter phase is one
(n, M) x (M, q) matmul on the MXU (see kernels/bregman_ub.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .bregman import BregmanFamily

Array = jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class Partition:
    """Static partition layout: which original dim sits in which subspace slot.

    Hash/eq are content-based so a Partition can ride in pytree aux data
    (static side of jit caches).
    """

    d: int
    num_subspaces: int                 # M
    width: int                         # w = ceil(d / M)
    idx: np.ndarray                    # (M, w) int32 indices into the original dims
    mask: np.ndarray                   # (M, w) float32, 0 for padded slots

    def __eq__(self, other):
        return (
            isinstance(other, Partition)
            and self.d == other.d
            and self.num_subspaces == other.num_subspaces
            and np.array_equal(self.idx, other.idx)
            and np.array_equal(self.mask, other.mask)
        )

    def __hash__(self):
        return hash((self.d, self.num_subspaces, self.width,
                     self.idx.tobytes(), self.mask.tobytes()))

    @property
    def m(self) -> int:
        return self.num_subspaces

    def gather(self, x: Array) -> Array:
        """(…, d) -> (…, M, w) subspace view (padded slots refer to dim 0)."""
        return jnp.take(x, jnp.asarray(self.idx), axis=-1)

    def subspace_mask(self) -> Array:
        return jnp.asarray(self.mask)

    def permutation(self) -> np.ndarray:
        """Flat order of the real dims, subspace-major (for layout decisions)."""
        flat_idx = self.idx.reshape(-1)
        flat_mask = self.mask.reshape(-1)
        return flat_idx[flat_mask > 0]


def make_partition(d: int, m: int, order: np.ndarray | None = None) -> Partition:
    """Build a partition of ``d`` dims into ``m`` subspaces.

    ``order`` is the dim order to deal from (contiguous baseline when None;
    the PCCP order from core/partition.py otherwise).  Dims are dealt
    contiguously in ``order``: subspace ``i`` takes ``order[i*w:(i+1)*w]``.
    """
    if m < 1 or m > d:
        raise ValueError(f"need 1 <= M <= d, got M={m}, d={d}")
    if order is None:
        order = np.arange(d)
    order = np.asarray(order, dtype=np.int32)
    if order.shape != (d,) or len(np.unique(order)) != d:
        raise ValueError("order must be a permutation of range(d)")
    w = -(-d // m)  # ceil
    idx = np.zeros((m, w), dtype=np.int32)
    mask = np.zeros((m, w), dtype=np.float32)
    for i in range(m):
        chunk = order[i * w:(i + 1) * w]
        idx[i, : len(chunk)] = chunk
        mask[i, : len(chunk)] = 1.0
    return Partition(d=d, num_subspaces=m, width=w, idx=idx, mask=mask)


# ---------------------------------------------------------------------------
# Transforms
# ---------------------------------------------------------------------------

def p_transform_views(xs: Array, mask: Array, family: BregmanFamily) -> dict:
    """Alg. 2 on a PRE-GATHERED (..., M, w) subspace view.

    The P-tuple depends on a point only through its subspace view, so
    callers that already hold the view — the streaming-insert path
    (core/segments.py) transforms new points with the SEALED partition's
    gathered view — share this math with :func:`p_transform`, mirroring
    the :func:`q_transform_views` split on the query side.
    """
    alpha = jnp.sum(family.phi(xs) * mask, axis=-1)
    gamma = jnp.sum(xs * xs * mask, axis=-1)
    return {"alpha": alpha, "gamma": gamma, "sqrt_gamma": jnp.sqrt(gamma)}


def p_transform(x: Array, part: Partition, family: BregmanFamily) -> dict:
    """Alg. 2 — transform data points into per-subspace tuples.

    Args:
      x: (..., d) data points.
    Returns dict with
      alpha: (..., M)   sum of f over the subspace dims
      gamma: (..., M)   sum of squares over the subspace dims
      sqrt_gamma: (..., M)  precomputed sqrt for the MXU filter form
    """
    return p_transform_views(part.gather(x), part.subspace_mask(), family)


def q_transform_views(ys: Array, mask: Array, family: BregmanFamily) -> dict:
    """Alg. 3 on a PRE-GATHERED (..., M, w) subspace view.

    The per-subspace triples depend on the query only through its subspace
    view, so distributed callers (dist/knn.py) gather once on the host and
    ship the view to every shard; this is the shared math.  Returns the
    per-subspace fields of :func:`q_transform` (everything except the
    original-order refinement constants).
    """
    g = family.phi_prime(ys)
    alpha = -jnp.sum(family.phi(ys) * mask, axis=-1)
    beta_yy = jnp.sum(ys * g * mask, axis=-1)
    delta = jnp.sum(g * g * mask, axis=-1)
    return {
        "alpha": alpha,
        "beta_yy": beta_yy,
        "delta": delta,
        "qconst": alpha + beta_yy,
        "sqrt_delta": jnp.sqrt(delta),
    }


def q_transform(y: Array, part: Partition, family: BregmanFamily) -> dict:
    """Alg. 3 — transform query points into per-subspace triples.

    Returns dict with
      alpha: (..., M)      -sum f(y)
      beta_yy: (..., M)    sum y * f'(y)
      delta: (..., M)      sum f'(y)^2
      qconst: (..., M)     alpha + beta_yy (the per-subspace additive constant)
      sqrt_delta: (..., M) sqrt for the MXU filter form
      grad: (..., d)       f'(y) in ORIGINAL dim order (for refinement)
      f_y: (...)           f(y) over all dims (for refinement constant)
    """
    q = q_transform_views(part.gather(y), part.subspace_mask(), family)
    q["grad"] = family.phi_prime(y)
    q["f_y"] = family.f(y)
    return q
