"""Recall calibration: turn the §8 approximate knob into a measured SLO.

The approximate search mode (paper §8, ``core/search.py``) shrinks the
Alg.-4 filter bounds by a factor derived from the empirical beta_xy CDF at
a guarantee level ``p_guarantee``.  Prop. 1 ties ``p`` to the probability
that any single pruned point was a true neighbor — NOT to recall@k, which
is what callers actually care about and what depends on the data
distribution, the family, k, and the index layout.  Following Abdullah et
al. (arXiv 1108.0835 — trade accuracy for time, but *measure* the trade),
this module makes the mapping empirical:

* :func:`fit_calibration` sweeps a ``p`` grid over a held-out query sample
  (jittered live rows — in-distribution by construction, valid for every
  family domain), measures recall@k against the exact oracle
  (``_brute_force_live``), and monotone-regularizes the curve (recall is
  non-decreasing in ``p`` in expectation; isotonic projection removes
  sampling noise).  One compiled program serves the whole sweep: ``p`` is
  a traced scalar of the approx pipeline, never a static.
* :class:`RecallCalibration` stores the fitted curve as plain host-side
  numpy.  It lives on ``BallForest.calibration`` — a host-only field
  EXCLUDED from the pytree flatten, so it survives every
  ``dataclasses.replace``-based index operation (pad / slice / concat /
  shard / tombstone / quantize) without fragmenting any jit cache, and is
  simply absent inside traced code (inversion happens on the host before
  a launch, never inside one).
* :func:`resolve_p_guarantee` inverts the curve conservatively: the
  SMALLEST grid ``p`` whose measured recall meets the target, reported
  together with that measured recall as the ``expected_recall`` estimate.
  Uncalibrated indexes fall back to the historical behavior (``p`` =
  target, no estimate) with a one-time warning, so nothing breaks for
  indexes built before calibration existed.

Lifecycle: fitted at ``build_index(calibrate=True)`` /
``build_datastore(calibrate=True)`` time; inserts and tombstones leave the
curve in place (stale-but-conservative, same philosophy as the block
envelope tables); ``SegmentedForest.compact`` refits it with the stored
fit parameters for both merge and rebuild.  See docs/accuracy.md.
"""

from __future__ import annotations

import dataclasses
import logging

import numpy as np

logger = logging.getLogger(__name__)

# Default guarantee grid: dense near the top where the recall curve is
# steepest (and where SLO targets live), sparse below.
DEFAULT_P_GRID = (0.0, 0.2, 0.4, 0.6, 0.7, 0.8, 0.9, 0.95, 0.99, 1.0)
DEFAULT_NUM_QUERIES = 64
DEFAULT_JITTER = 0.05

_warned_uncalibrated = False


@dataclasses.dataclass(frozen=True)
class RecallCalibration:
    """A fitted ``p_guarantee`` -> measured recall@k curve (host-side).

    ``p_grid`` is ascending and ends at 1.0 (the no-shrink point);
    ``recall_grid`` is the monotone-regularized measured recall@``k`` at
    each grid point.  ``num_queries`` / ``seed`` / ``jitter`` record the
    fit so compaction can refit with identical settings.
    """

    p_grid: np.ndarray          # (G,) ascending guarantee levels
    recall_grid: np.ndarray     # (G,) measured recall@k, non-decreasing
    k: int
    num_queries: int
    seed: int
    jitter: float = DEFAULT_JITTER

    def __post_init__(self):
        # Accept tuples/lists (hand-built curves in tests, literals in
        # docs) but store arrays so the lookups below stay uniform.
        object.__setattr__(self, "p_grid",
                           np.asarray(self.p_grid, np.float64))
        object.__setattr__(self, "recall_grid",
                           np.asarray(self.recall_grid, np.float64))

    def expected_recall(self, p: float) -> float:
        """Measured recall estimate at guarantee level ``p`` (interpolated)."""
        return float(np.interp(float(p), self.p_grid, self.recall_grid))

    def resolve(self, target_recall: float) -> tuple[float, float]:
        """Smallest grid ``p`` whose MEASURED recall meets the target.

        Returns ``(p_guarantee, expected_recall)``.  Conservative on both
        ends: an achievable target gets the cheapest grid point that met
        it during the fit (never an interpolated p between grid points,
        whose recall was not measured); a target above everything the fit
        achieved gets ``p = 1.0`` — the unshrunk §8 pipeline — and the
        honest (lower) measured estimate, so callers can see the SLO is
        not attainable rather than being promised it silently.
        """
        t = float(target_recall)
        if not 0.0 <= t <= 1.0:
            raise ValueError(f"target_recall must be in [0, 1], got {t}")
        idx = int(np.searchsorted(self.recall_grid, t, side="left"))
        if idx >= self.p_grid.shape[0]:
            return float(self.p_grid[-1]), float(self.recall_grid[-1])
        return float(self.p_grid[idx]), float(self.recall_grid[idx])


def _recall_at_k(ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean fraction of oracle ids recovered, set-wise per query row."""
    hits = 0
    for row, truth in zip(ids, true_ids, strict=True):
        hits += len(set(row.tolist()) & set(truth.tolist()))
    return hits / true_ids.size


def held_out_queries(index, num_queries: int, seed: int,
                     jitter: float = DEFAULT_JITTER) -> np.ndarray:
    """An in-distribution held-out query sample: jittered live rows.

    Multiplicative log-normal jitter keeps every positive-domain family
    (Itakura-Saito / Burg / Shannon) inside its open domain and perturbs
    each coordinate by ~``jitter`` relative — close enough to the data to
    have non-trivial neighbors, far enough to not be the stored row
    itself.
    """
    from .search import _as_forest
    forest = _as_forest(index)
    rows = np.asarray(forest.rows_view())
    live = np.flatnonzero(np.asarray(forest.point_ids) >= 0)
    if live.size == 0:
        raise ValueError("cannot sample held-out queries: no live rows")
    rng = np.random.default_rng(seed)
    pick = rng.choice(live, size=num_queries, replace=live.size < num_queries)
    qs = rows[pick] * np.exp(
        jitter * rng.standard_normal((num_queries, rows.shape[1])))
    return np.asarray(qs, np.float32)


def fit_calibration(index, *, k: int = 10,
                    num_queries: int = DEFAULT_NUM_QUERIES,
                    p_grid=None, seed: int = 0,
                    jitter: float = DEFAULT_JITTER) -> RecallCalibration:
    """Measure recall@``k`` over a ``p_guarantee`` grid for this index.

    Accepts a BallForest or a SegmentedForest (snapshotted).  The oracle
    is the live-row linear scan — tombstones masked, int8 rows decoded —
    so the measured recall is w.r.t. exactly the point set the approx
    pipeline searches.  ``p`` rides the grid as a traced scalar, so the
    whole sweep compiles once.
    """
    from .search import _as_forest, _brute_force_live, knn_batch
    forest = _as_forest(index, k)
    grid = np.asarray(DEFAULT_P_GRID if p_grid is None else p_grid,
                      np.float64)
    if grid.ndim != 1 or grid.size < 2 or np.any(np.diff(grid) <= 0):
        raise ValueError("p_grid must be a strictly ascending 1-D grid")
    if grid[-1] != 1.0:
        raise ValueError("p_grid must end at 1.0 (the no-shrink point)")
    live = int(np.sum(np.asarray(forest.point_ids) >= 0))
    num_queries = max(1, min(int(num_queries), max(live, 1)))
    qs = held_out_queries(forest, num_queries, seed, jitter)
    true_ids, _ = _brute_force_live(forest, qs, k)
    true_ids = np.asarray(true_ids)
    rec = np.empty(grid.shape[0], np.float64)
    for i, p in enumerate(grid):
        res = knn_batch(forest, qs, k, approx_p=float(p), validate=False)
        rec[i] = _recall_at_k(np.asarray(res.ids), true_ids)
    # Isotonic projection: recall is non-decreasing in p in expectation;
    # the running max removes finite-sample wiggles while never promising
    # more than some grid point actually measured.
    rec = np.maximum.accumulate(rec)
    return RecallCalibration(p_grid=grid, recall_grid=rec, k=k,
                             num_queries=num_queries, seed=seed,
                             jitter=float(jitter))


def validate_target_recall(target_recall) -> None:
    """Range-gate a raw ``target_recall`` knob (None = knob unused).

    The resolver pair (:func:`resolve_p_guarantee` / the calibration's
    ``resolve``) re-checks the range where it inverts the curve; this
    standalone gate is for entry points that accept the knob but hand it
    off later (serve/retrieval.py stores it per-request), so a malformed
    value fails at submission instead of deep inside the batch ladder.
    """
    if target_recall is None:
        return
    t = float(target_recall)
    if not 0.0 <= t <= 1.0:    # False for NaN too
        raise ValueError(f"target_recall must be in [0, 1], got {t}")


def resolve_p_guarantee(index, target_recall: float):
    """Invert an index's calibration curve: target recall -> (p, expected).

    Returns ``(p_guarantee, expected_recall)``.  ``expected_recall`` is
    the fit's measured recall at the chosen grid point, or ``None`` when
    the index carries no calibration — in which case the historical
    conflation (``p = target_recall``) is preserved, announced once per
    process, so pre-calibration indexes keep working unchanged.
    """
    cal = getattr(index, "calibration", None)
    if cal is None:
        global _warned_uncalibrated
        if not _warned_uncalibrated:
            _warned_uncalibrated = True
            logger.warning(
                "target_recall=%s requested on an uncalibrated index; "
                "falling back to p_guarantee=target_recall. Build with "
                "calibrate=True (build_index / build_datastore) for a "
                "measured recall contract.", target_recall)
        t = float(target_recall)
        if not 0.0 <= t <= 1.0:
            raise ValueError(f"target_recall must be in [0, 1], got {t}")
        return t, None
    return cal.resolve(target_recall)


def ensure_calibration(index, *, k: int = 10,
                       num_queries: int = DEFAULT_NUM_QUERIES,
                       p_grid=None, seed: int = 0,
                       jitter: float = DEFAULT_JITTER):
    """Attach a fitted curve to an index that lacks one; returns the index.

    BallForests come back as a ``dataclasses.replace`` copy; a mutable
    SegmentedForest is updated IN PLACE (its sealed main segment carries
    the curve — the duck-typed ``.main`` check avoids importing
    core.segments here) and its cached snapshot invalidated so the next
    ``view()`` carries the curve too.
    """
    if getattr(index, "calibration", None) is not None:
        return index
    cal = fit_calibration(index, k=k, num_queries=num_queries,
                          p_grid=p_grid, seed=seed, jitter=jitter)
    if hasattr(index, "main"):
        index.main = dataclasses.replace(index.main, calibration=cal)
        index._view = None
        return index
    return dataclasses.replace(index, calibration=cal)
