"""Bregman k-means (Banerjee et al. style) for the ball-forest index.

Assignment minimizes ``D_f(x, c)`` (data in the first slot); the optimal
center for that orientation is the arithmetic mean of the cluster, so Lloyd
iterations are exact.

TPU-friendly pairwise-distance form (no (n, C, w) intermediate):

    D_f(x, c) = sum_j f(x_j)  -  x . f'(c)  +  [c . f'(c) - f(c)]
              =   fx[n]      -   (X @ G^T)[n, C]  +  cconst[C]

i.e. one (n, w) x (w, C) matmul per iteration — the same fused form the
refinement kernel uses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


def pairwise_bregman(x: Array, centers: Array, mask: Array, family) -> Array:
    """D_f(x_i, c_j) for all pairs, masked dims excluded. (n, C)."""
    mask = mask[None, :]
    fx = jnp.sum(family.phi(x) * mask, axis=-1)                 # (n,)
    g = family.phi_prime(centers) * mask                        # (C, w)
    cconst = jnp.sum(centers * g - family.phi(centers) * mask, axis=-1)  # (C,)
    cross = x @ g.T                                             # (n, C) matmul
    return fx[:, None] - cross + cconst[None, :]


@functools.partial(jax.jit, static_argnames=("family", "num_clusters", "iters"))
def kmeans(
    points: Array,
    mask: Array,
    key: Array,
    *,
    family,
    num_clusters: int,
    iters: int = 12,
) -> tuple[Array, Array]:
    """Lloyd iterations; returns (centers (C, w), assignment (n,) int32).

    Empty clusters keep their previous center (standard fix; a reseed would
    break jit determinism).
    """
    n, w = points.shape
    c = num_clusters
    init_idx = jax.random.choice(key, n, shape=(c,), replace=False)
    centers0 = points[init_idx]

    def body(_, centers):
        dist = pairwise_bregman(points, centers, mask, family)   # (n, C)
        assign = jnp.argmin(dist, axis=-1)
        sums = jax.ops.segment_sum(points, assign, num_segments=c)
        cnts = jax.ops.segment_sum(jnp.ones((n,), points.dtype), assign, num_segments=c)
        means = sums / jnp.maximum(cnts, 1.0)[:, None]
        return jnp.where((cnts > 0)[:, None], means, centers)

    centers = jax.lax.fori_loop(0, iters, body, centers0)
    assign = jnp.argmin(pairwise_bregman(points, centers, mask, family), axis=-1)
    return centers, assign.astype(jnp.int32)


def cluster_stats(values: Array, assign: Array, num_clusters: int) -> dict:
    """Per-cluster min/max/count of a per-point scalar (for pruning bounds)."""
    big = jnp.asarray(jnp.finfo(values.dtype).max, values.dtype)
    vmin = jax.ops.segment_min(values, assign, num_segments=num_clusters)
    vmax = jax.ops.segment_max(values, assign, num_segments=num_clusters)
    cnt = jax.ops.segment_sum(jnp.ones_like(values), assign, num_segments=num_clusters)
    empty = cnt == 0
    # Empty clusters must never be admitted by the pruning test: make their
    # interval impossible (min=+inf, max=0 => LB=+inf).
    vmin = jnp.where(empty, big, vmin)
    vmax = jnp.where(empty, jnp.zeros_like(vmax), vmax)
    return {"min": vmin, "max": vmax, "count": cnt}
