"""Mutable segmented BallForest: streaming insert/delete without rebuild.

The paper's partition-filter-refinement index (§5-§7) is built once
offline; serving workloads (streaming ingestion into the kNN-LM datastore,
per-user corpora) need to add and retire points without the full
O(n * d * M) rebuild.  The classic LSM answer, adapted to the BB-forest:

* **Sealed main segment** — a :class:`~repro.core.index.BallForest` built
  by ``build_index`` exactly as today.  Its partition, Bregman-k-means
  centroids, gamma-bucket edges and beta samples are FROZEN: they define
  the coordinate system every later mutation reuses.
* **Append segments** — each :meth:`SegmentedForest.insert` call seals its
  points into a small BallForest that shares the main segment's statics
  and replicated tables.  New points do NOT re-run PCCP or the Theorem-4
  cost model: they are P-transformed with the sealed partition
  (``transform.p_transform_views``), assigned to the nearest EXISTING
  centroid per subspace, gamma-bucketed with the sealed quantile edges,
  and given *singleton* per-point corners (``alpha_min_pt = alpha``,
  ``sqrt_gamma_max_pt = sqrt_gamma``).  A singleton corner is the point's
  own Cauchy lower bound, so the Theorem-3 admission test stays exact for
  appended points (it is in fact tighter than a shared cluster corner).
* **Tombstones** — :meth:`SegmentedForest.delete` overwrites a point's row
  with the search-inert fill (``index.tombstone_rows``): filter stats
  beyond any finite top-k, corner stats that fail every admission, id -1.
  The filter, Theorem-3 prune, and refine phases of all three search
  paths (``knn_search``, ``knn_search_batch``, ``dist.distributed_knn``)
  skip deleted rows without knowing deletions exist.
* **Compaction** — :meth:`SegmentedForest.compact` re-seals everything
  into one main segment, either by a cheap **merge** (drop dead rows,
  re-sort the shared layout, recompute corner tables with
  ``clustering.cluster_stats`` — no k-means) or a full **rebuild**
  (``build_index`` over the live points, original ids preserved).  The
  choice is driven by the fitted Theorem-4 :class:`CostModel`
  (``partition.decide_compaction``); inserts auto-compact when the stale
  fraction crosses :attr:`SegmentedForest.compact_threshold`.

Searches never look at this class's bookkeeping: :meth:`view` snapshots
the segments into ONE plain BallForest (``index.concat_points``) and every
entry point in ``core/search.py`` / ``dist/knn.py`` accepts either type.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .bregman import BregmanFamily, validate_rows
from .clustering import cluster_stats, pairwise_bregman
from .index import (
    BallForest,
    build_index,
    concat_points,
    point_fields,
    refresh_envelopes,
    tombstone_rows,
)
from .partition import CostModel, decide_compaction, fit_cost_model
from .transform import p_transform_views
from . import quantize as qz

Array = jax.Array

# Stale fraction (appended + deleted over live) above which insert/delete
# auto-compact.  0.5 ~ "append segments cost as much as the main scan".
DEFAULT_COMPACT_THRESHOLD = 0.5


def _append_segment(main: BallForest, points: Array,
                    first_id: int) -> BallForest:
    """Seal ``points`` into a searchable append segment of ``main``'s index.

    Reuses the sealed partition / transforms / centroids / bucket edges;
    recomputes only the per-point P-tuples, the nearest-centroid
    assignment, and the (singleton) per-point corner stats.

    In the int8 storage tier the new points are snapped to int8 FIRST
    (fresh per-row affines — the sealed part of the quantizer is the
    scheme, the scales travel with each row) and the transforms run over
    the decoded rows, so the appended segment obeys the same contract as
    the main one: its stats describe exactly the rows refine will decode.
    """
    part, fam = main.partition, main.family
    pts = jnp.asarray(points, jnp.float32)
    if pts.ndim != 2 or pts.shape[1] != main.d:
        raise ValueError(f"expected (a, {main.d}) points, got {pts.shape}")
    if main.storage == "int8":
        codes, d_scale, d_zp = qz.quantize_rows(pts)
        pts = qz.dequantize_rows(codes, d_scale, d_zp, fam)
    sub = part.gather(pts)                          # (a, M, w)
    mask = part.subspace_mask()
    p = p_transform_views(sub, mask, fam)
    alpha, sqrt_gamma = p["alpha"], p["sqrt_gamma"]

    # Nearest existing centroid per subspace, then the sealed gamma-bucket
    # edges, reproduce build_index's effective segment id for new points.
    num_centers = main.centers.shape[1]
    nb = main.num_clusters // num_centers
    assign_eff = []
    for i in range(part.num_subspaces):
        dist = pairwise_bregman(sub[:, i, :], main.centers[i], mask[i], fam)
        ball = jnp.argmin(dist, axis=-1).astype(jnp.int32)
        bucket = jnp.searchsorted(
            main.gamma_edges[i], sqrt_gamma[:, i]).astype(jnp.int32)
        assign_eff.append(ball * nb + bucket)
    assign_eff = jnp.stack(assign_eff, axis=1)      # (a, M)

    ids = jnp.arange(first_id, first_id + pts.shape[0], dtype=jnp.int32)
    # Singleton corners: the point's own lower-bound tuple.  Conservative
    # (lb = LB_i(x, y) <= D_i(x, y)) and tighter than any shared corner, so
    # appended points need no update to the sealed cluster tables.
    if main.storage == "int8":
        # Exact per-point stats are in hand, so the (singleton) corner
        # codes round directionally from the TRUE values — same
        # conservatism as build, one shared encode rule.
        seg = dataclasses.replace(
            main, data=codes, data_scale=d_scale, data_zp=d_zp,
            point_ids=ids, assign=assign_eff,
            **qz.encode_stat_tables(alpha, sqrt_gamma, alpha, sqrt_gamma))
    else:
        seg = dataclasses.replace(
            main, data=pts, point_ids=ids, alpha=alpha,
            sqrt_gamma=sqrt_gamma, assign=assign_eff, alpha_min_pt=alpha,
            sqrt_gamma_max_pt=sqrt_gamma)
    # The segment's block envelopes come from ITS (decoded singleton)
    # corners, not the main segment's — the snapshot concat recomputes the
    # global table, but a self-consistent per-segment table keeps every
    # BallForest independently searchable.
    return refresh_envelopes(seg)


@dataclasses.dataclass
class SegmentedForest:
    """A mutable BrePartition index: sealed main + append segments.

    Host-side bookkeeping (live masks, id lookup) lives in numpy; all
    searchable state lives in the segments' device arrays, so
    :meth:`view` is a concat — no host->device transfer per query.
    """

    main: BallForest
    segments: list[BallForest]
    live: list[np.ndarray]          # bool mask per block (0 = main)
    ids_host: list[np.ndarray]      # point_ids per block (-1 = dead/pad)
    next_id: int
    cost_model: CostModel | None = None
    compact_threshold: float = DEFAULT_COMPACT_THRESHOLD
    _view: BallForest | None = dataclasses.field(
        default=None, init=False, repr=False)

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_forest(cls, forest: BallForest, *,
                    cost_model: CostModel | None = None,
                    compact_threshold: float = DEFAULT_COMPACT_THRESHOLD,
                    ) -> "SegmentedForest":
        ids = np.asarray(forest.point_ids)
        return cls(main=forest, segments=[], live=[ids >= 0],
                   ids_host=[ids.copy()],
                   next_id=int(ids.max(initial=-1)) + 1,
                   cost_model=cost_model,
                   compact_threshold=compact_threshold)

    # -- snapshot & stats ---------------------------------------------------

    def view(self) -> BallForest:
        """One searchable BallForest over every segment (cached)."""
        if self._view is None:
            self._view = concat_points([self.main] + self.segments)
        return self._view

    @property
    def family(self) -> BregmanFamily:
        return self.main.family

    @property
    def family_name(self) -> str:
        return self.main.family_name

    @property
    def partition(self):
        return self.main.partition

    @property
    def num_clusters(self) -> int:
        return self.main.num_clusters

    @property
    def storage(self) -> str:
        return self.main.storage

    @property
    def calibration(self):
        """The recall-calibration curve (core/calibrate.py), if fitted.

        Lives on the sealed main segment; inserts and tombstones leave it
        in place (stale-but-measured, like the block envelopes staying
        conservatively loose), :meth:`compact` refits it.
        """
        return self.main.calibration

    @property
    def n(self) -> int:
        """Physical rows (tombstones included) — the searched array length."""
        return self.main.n + sum(s.n for s in self.segments)

    @property
    def d(self) -> int:
        return self.main.d

    @property
    def m(self) -> int:
        return self.main.m

    @property
    def live_n(self) -> int:
        return int(sum(int(mask.sum()) for mask in self.live))

    @property
    def appended_live(self) -> int:
        return int(sum(int(mask.sum()) for mask in self.live[1:]))

    @property
    def deleted_n(self) -> int:
        return self.n - self.live_n

    @property
    def append_fraction(self) -> float:
        return self.appended_live / max(self.live_n, 1)

    @property
    def stale_fraction(self) -> float:
        """Appended + deleted over live — the compaction pressure metric."""
        return (self.appended_live + self.deleted_n) / max(self.live_n, 1)

    def live_ids(self) -> np.ndarray:
        """Original ids of the live points, in layout order."""
        return np.concatenate(
            [ids[mask] for ids, mask in zip(self.ids_host, self.live, strict=True)])

    def append_row_range(self) -> tuple[int, int]:
        """[start, stop) rows of ``view()`` held by the append segments.

        ``view()`` concatenates main first, then segments in order, so
        the append rows are exactly the tail.  The tiered store pins
        this range device-resident (core/tiered.py): append segments are
        the hot, recently-written working set, and compaction folds them
        into the sealed main — the only tier that goes cold.
        """
        return self.main.n, self.n

    # -- mutations ----------------------------------------------------------

    def insert(self, points, *, auto_compact: bool = True,
               validate: bool = False) -> np.ndarray:
        """Append ``points`` as a new searchable segment; returns their ids.

        O(a * d * C) — one nearest-centroid pass against the sealed
        centroids — versus O(n * d * C * iters) for a rebuild.  Note the
        snapshot's row count changes, so the next search compiles a new
        program; batch inserts (and the auto-compact threshold) keep that
        churn bounded.

        ``validate=True`` runs the domain gate
        (:func:`~repro.core.bregman.validate_rows`) and raises — naming
        the offending row — BEFORE anything is sealed, so a poisoned
        ingest batch can never contaminate the searchable tables.
        Ingestion paths that prefer quarantine-over-reject insert without
        validation and call :meth:`quarantine` afterwards (or let the
        serving layer do it — serve/retrieval.py).
        """
        if validate:
            validate_rows(self.family, points, what="insert row")
        seg = _append_segment(self.main, points, self.next_id)
        self.segments.append(seg)
        self.live.append(np.ones(seg.n, dtype=bool))
        self.ids_host.append(np.asarray(seg.point_ids).copy())
        self.next_id += seg.n
        self._view = None
        out = np.asarray(seg.point_ids)
        if auto_compact and self.stale_fraction > self.compact_threshold:
            self.compact()
        return out

    def delete(self, ids, *, auto_compact: bool = True) -> int:
        """Tombstone the given original ids; returns how many were live.

        Unknown or already-deleted ids are ignored.  Rows stay physically
        present (static shapes — no recompile) but become search-inert in
        every phase of every path; compaction reclaims them.
        """
        ids = np.unique(np.asarray(ids, np.int64))
        removed = 0
        blocks = [self.main] + self.segments
        for b, block in enumerate(blocks):
            dead = np.isin(self.ids_host[b], ids) & self.live[b]
            if not dead.any():
                continue
            removed += int(dead.sum())
            self.live[b] = self.live[b] & ~dead
            self.ids_host[b][dead] = -1
            patched = tombstone_rows(block, jnp.asarray(dead))
            if b == 0:
                self.main = patched
            else:
                self.segments[b - 1] = patched
        if removed:
            self._view = None
            if auto_compact and self.stale_fraction > self.compact_threshold:
                self.compact()
        return removed

    def find_invalid(self) -> np.ndarray:
        """Original ids of LIVE rows that violate the family domain.

        Scans what refine actually computes distances over —
        ``rows_view()``, i.e. the decoded rows in the int8 tier — for
        NaN/inf or open-domain violations (``bregman.validate_rows``).  A
        poisoned row makes every query that admits it return NaN
        distances, so this is the index-side admission check the serving
        layer runs before trusting a tenant's index (and after any
        unvalidated bulk load).
        """
        blocks = [self.main] + self.segments
        bad: list[np.ndarray] = []
        for b, mask in zip(blocks, self.live, strict=True):
            if not mask.any():
                continue
            rows = np.asarray(b.rows_view())
            ok = validate_rows(self.family, rows, mode="mask")
            bad_rows = mask & ~ok
            if bad_rows.any():
                bad.append(np.asarray(b.point_ids)[bad_rows])
        if not bad:
            return np.empty((0,), np.int32)
        return np.concatenate(bad).astype(np.int32)

    def quarantine(self) -> np.ndarray:
        """Tombstone every domain-violating live row; returns their ids.

        The poisoned-index containment path: rows found by
        :meth:`find_invalid` become search-inert tombstones (exactly like
        :meth:`delete`, but auto-compaction is suppressed so the caller
        controls when the reclaim pause happens).  Searches over the
        remaining live set are exact again; the returned ids let the
        owner audit or re-ingest corrected rows.
        """
        bad = self.find_invalid()
        if bad.size:
            self.delete(bad, auto_compact=False)
        return bad

    # -- compaction ---------------------------------------------------------

    def fitted_cost_model(self) -> CostModel:
        """The Theorem-4 model for merge-vs-rebuild (fit lazily, cached)."""
        if self.cost_model is None:
            self.cost_model = fit_cost_model(self._live_rows(), self.family)
        return self.cost_model

    def decide(self) -> str:
        """``"merge"`` or ``"rebuild"`` per the CostModel rule."""
        return decide_compaction(self.fitted_cost_model(), self.m,
                                 stale_fraction=self.stale_fraction)

    def compact(self, mode: str | None = None, *, seed: int = 0) -> str:
        """Re-seal every segment (and reclaim tombstones) into the main.

        ``mode`` forces ``"merge"`` or ``"rebuild"``; ``None`` asks
        :meth:`decide`.  Either way original ids are preserved, so stored
        side tables (e.g. the kNN-LM token values) stay valid.

        A fitted recall calibration is REFIT over the compacted index with
        its stored fit parameters (both modes: a merge changes the layout
        and drops rows, a rebuild re-clusters — either moves the measured
        curve), so ``target_recall`` contracts stay anchored to what the
        live index actually serves.
        """
        prev_cal = self.main.calibration
        if self.live_n == 0:
            # Nothing to model or re-cluster: an empty merge just drops the
            # dead rows (a rebuild would hand build_index a 0-row array).
            mode = "merge"
        elif mode is None:
            mode = self.decide()
        if mode not in ("merge", "rebuild"):
            raise ValueError(f"unknown compaction mode {mode!r}")
        if mode == "rebuild":
            self.main = self._rebuild(seed)
        else:
            self.main = self._merge()
        self.segments = []
        if prev_cal is not None:
            from . import calibrate as _calibrate
            cal = None
            if self.main.n and int(np.sum(
                    np.asarray(self.main.point_ids) >= 0)) >= prev_cal.k:
                cal = _calibrate.fit_calibration(
                    self.main, k=prev_cal.k,
                    num_queries=prev_cal.num_queries,
                    p_grid=prev_cal.p_grid, seed=prev_cal.seed,
                    jitter=prev_cal.jitter)
            # Too few live rows to measure recall@k: drop the curve rather
            # than serve a stale one over a different point set.
            self.main = dataclasses.replace(self.main, calibration=cal)
        ids = np.asarray(self.main.point_ids)
        self.live = [ids >= 0]
        self.ids_host = [ids.copy()]
        self._view = None
        # The model was fit on a previous cycle's live set; n/alpha/beta
        # drift with every grow/evict, so refit per compaction cycle.
        self.cost_model = None
        return mode

    def _live_arrays(self, fields=None) -> tuple[np.ndarray, ...]:
        """Host copies of the live rows of the given point-major fields."""
        if fields is None:
            fields = point_fields(self.main)
        blocks = [self.main] + self.segments
        out = []
        for f in fields:
            out.append(np.concatenate([
                np.asarray(getattr(b, f))[mask]
                for b, mask in zip(blocks, self.live, strict=True)]))
        return tuple(out)

    def _live_rows(self) -> np.ndarray:
        """Live fp32 point rows (decoded in the int8 tier), layout order."""
        blocks = [self.main] + self.segments
        return np.concatenate([
            np.asarray(b.rows_view())[mask]
            for b, mask in zip(blocks, self.live, strict=True)])

    def _rebuild(self, seed: int) -> BallForest:
        """Full Alg.-5 rebuild over the live points, original ids kept.

        In the int8 tier the rebuild re-quantizes the decoded rows with
        fresh per-row affines, so stored points may drift by at most one
        quantization step per coordinate (docs/quantization.md); a merge
        preserves them bit-exactly.
        """
        (ids,) = self._live_arrays(("point_ids",))
        data = self._live_rows()
        num_centers = self.main.centers.shape[1]
        nb = max(self.main.num_clusters // num_centers, 1)
        forest = build_index(
            data, self.family_name, m=self.m,
            num_clusters=min(num_centers, data.shape[0]),
            gamma_buckets=nb, quantize=self.storage == "int8", seed=seed)
        # build_index ids index into `data`; route them through the
        # original-id map so external references survive the rebuild.
        return dataclasses.replace(
            forest,
            point_ids=jnp.asarray(ids)[forest.point_ids])

    def _merge(self) -> BallForest:
        """Cheap re-seal: keep the sealed centroids/buckets, drop dead rows,
        restore the shared layout, recompute the corner tables exactly.

        Int8 tier: data/stat codes and their per-row decode move as
        opaque rows (the stored points are bit-identical after the merge);
        only the corner tables are refit — from a CONSERVATIVE decode of
        the stat codes (nearest-rounded, so the true stat may be half a
        step to either side) and then directed-rounded again, keeping the
        Theorem-3 test admissible across compactions.
        """
        fields = point_fields(self.main)
        arrays = dict(zip(fields, self._live_arrays(fields), strict=True))
        order = np.argsort(arrays["assign"][:, 0], kind="stable")
        arrays = {f: jnp.asarray(a[order]) for f, a in arrays.items()}

        if self.main.storage == "int8":
            # Worst-case true stats behind the nearest-rounded codes: alpha
            # may be up to scale/2 below its decode, sqrt_gamma up to
            # scale/2 above, so min/max over these envelopes bound the
            # min/max of the true values.
            alpha = qz.dequantize_stats(
                arrays["alpha"], arrays["alpha_scale"], arrays["alpha_zp"])
            sqrt_gamma = qz.dequantize_stats(
                arrays["sqrt_gamma"], arrays["sg_scale"], arrays["sg_zp"])
            alpha_lo = alpha - qz.UB_SLACK * arrays["alpha_scale"][:, None]
            sg_hi = sqrt_gamma + qz.UB_SLACK * arrays["sg_scale"][:, None]
        else:
            alpha = alpha_lo = arrays["alpha"]
            sqrt_gamma = sg_hi = arrays["sqrt_gamma"]
        assign = arrays["assign"]

        c_eff, m = self.num_clusters, self.m
        stats_a = [cluster_stats(alpha_lo[:, i], assign[:, i], c_eff)
                   for i in range(m)]
        stats_g = [cluster_stats(sg_hi[:, i], assign[:, i], c_eff)
                   for i in range(m)]
        amin = jnp.stack([s["min"] for s in stats_a])
        gmax = jnp.stack([s["max"] for s in stats_g])
        counts = jnp.stack([s["count"] for s in stats_a])
        take_pt = jax.vmap(lambda a, s: a[s], in_axes=(0, 1), out_axes=1)
        amin_pt, gmax_pt = take_pt(amin, assign), take_pt(gmax, assign)
        if self.main.storage == "int8":
            corners = qz.encode_corner_tables(amin_pt, gmax_pt)
            merged = dataclasses.replace(
                self.main,
                **{f: arrays[f] for f in fields if f not in corners},
                alpha_min=amin, sqrt_gamma_max=gmax, counts=counts,
                **corners)
        else:
            merged = dataclasses.replace(
                self.main, data=arrays["data"],
                point_ids=arrays["point_ids"],
                alpha=alpha, sqrt_gamma=sqrt_gamma, assign=assign,
                alpha_min=amin, sqrt_gamma_max=gmax, counts=counts,
                alpha_min_pt=amin_pt, sqrt_gamma_max_pt=gmax_pt)
        # Dead rows are gone and the layout re-sorted, so the block
        # envelopes are refit exactly (tombstoning itself only ever leaves
        # them conservatively loose — index.tombstone_rows).
        return refresh_envelopes(merged)


def build_segmented_index(data, family, **build_kwargs) -> SegmentedForest:
    """``build_index`` wrapped as the mutable index (Alg. 5 + segments)."""
    threshold = build_kwargs.pop("compact_threshold",
                                 DEFAULT_COMPACT_THRESHOLD)
    forest = build_index(data, family, **build_kwargs)
    return SegmentedForest.from_forest(forest, compact_threshold=threshold)
